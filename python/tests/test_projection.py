"""KL-projection invariants (L2): the masked log-domain Sinkhorn
projection used by both the AOT model and (in its Rust twin) the
coordinator's native solver.  hypothesis sweeps shapes and mask patterns.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

F32 = np.float32


def _marginals(s, active):
    la = np.full(s, ref.NEG, F32)
    la[:active] = -np.log(active)
    return jnp.asarray(la)


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([8, 32, 64, 200]),
    r=st.sampled_from([2, 4, 8]),
    frac_active=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_projection_feasibility(s, r, frac_active, seed):
    """Projected kernel satisfies both marginal families."""
    rng = np.random.default_rng(seed)
    active = max(2, int(s * frac_active))
    loga = _marginals(s, active)
    logg = jnp.full((r,), -np.log(float(r)), F32)
    logK = jnp.asarray(rng.normal(size=(s, r)).astype(F32))
    logQ = model.sinkhorn_project(logK + float(loga[0]), loga, logg, 40)
    Q = np.asarray(jnp.exp(jnp.where(logQ < ref.NEG / 4, ref.NEG, logQ)))
    # columns match g
    np.testing.assert_allclose(Q.sum(0), 1.0 / r, atol=3e-3)
    # active rows match a; padded rows empty
    np.testing.assert_allclose(Q[:active].sum(1), 1.0 / active, atol=3e-3)
    assert Q[active:].max(initial=0.0) < 1e-12


@settings(max_examples=12, deadline=None)
@given(
    s=st.sampled_from([16, 64]),
    r=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_projection_matches_ref_oracle(s, r, seed):
    rng = np.random.default_rng(seed)
    loga = jnp.full((s,), -np.log(s), F32)
    logg = jnp.full((r,), -np.log(float(r)), F32)
    logK = jnp.asarray(rng.normal(size=(s, r)).astype(F32))
    got = model.sinkhorn_project(logK, loga, logg, 10)
    want = ref.sinkhorn_project_ref(logK, loga, logg, 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_projection_idempotent_on_feasible_input():
    """Projecting an already-feasible kernel changes (almost) nothing."""
    s, r = 32, 2
    loga = jnp.full((s,), -np.log(s), F32)
    logg = jnp.full((r,), -np.log(float(r)), F32)
    # feasible: product coupling a g^T
    logK = loga[:, None] + logg[None, :]
    out = model.sinkhorn_project(logK, loga, logg, 15)
    np.testing.assert_allclose(np.asarray(out), np.asarray(logK), atol=1e-5)


def test_projection_preserves_row_argmax_order():
    """The projection adds rank-one potentials: within a row the ordering
    of entries is preserved (f_i shifts whole rows; h shifts columns
    uniformly across rows) up to the column shift h."""
    s, r = 24, 3
    rng = np.random.default_rng(0)
    loga = jnp.full((s,), -np.log(s), F32)
    logg = jnp.full((r,), -np.log(float(r)), F32)
    logK = jnp.asarray(rng.normal(size=(s, r)).astype(F32))
    out = np.asarray(model.sinkhorn_project(logK, loga, logg, 25))
    # out = logK + f 1^T + 1 h^T  =>  out - logK has rank ≤ 2 structure:
    # column-differences constant across rows
    diff = out - np.asarray(logK)
    col_gap = diff[:, 1:] - diff[:, :-1]
    assert np.allclose(col_gap, col_gap[0:1, :], atol=1e-4)
