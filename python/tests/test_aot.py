"""AOT pipeline tests: lowering produces loadable, well-formed HLO text."""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot


def test_lower_bucket_produces_hlo_text():
    text = aot.lower_bucket(64, 2, 4)
    assert "ENTRY" in text
    assert "HloModule" in text
    # 6 parameters: U, V, loga, logb, noise_q, noise_r
    for i in range(6):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    # shapes are baked in
    assert "f32[64,4]" in text
    assert "f32[64,2]" in text


def test_lowered_text_roundtrips_through_reexecution():
    """Compile the lowered StableHLO back with jax and compare numerics."""
    import jax
    import jax.numpy as jnp
    from compile import model
    from compile.kernels import ref

    s, r, k = 32, 2, 4
    hyper = aot.HYPER._replace(rank=r)
    fn = jax.jit(model.make_lrot(s, k, hyper))

    rng = np.random.default_rng(0)
    X = rng.normal(size=(s, 2)).astype(np.float32)
    Y = rng.normal(size=(s, 2)).astype(np.float32)
    U, V = ref.sqeuclid_factors_ref(jnp.asarray(X), jnp.asarray(Y))
    loga = jnp.full((s,), -np.log(s), jnp.float32)
    nq = jnp.asarray(rng.normal(size=(s, r)).astype(np.float32))
    nr = jnp.asarray(rng.normal(size=(s, r)).astype(np.float32))
    Q, R = fn(U, V, loga, loga, nq, nr)
    assert np.isfinite(np.asarray(Q)).all()
    # Text lowering of the same function must succeed and mention outputs
    text = aot.lower_bucket(s, r, k)
    assert f"f32[{s},{r}]" in text


def test_grid_definitions_sane():
    for name, grid in aot.GRIDS.items():
        for s in grid["sizes"]:
            assert s & (s - 1) == 0, f"{name}: bucket size {s} not a power of 2"
        for r in grid["ranks"]:
            assert r >= 2
        for k in grid["ks"]:
            assert k >= 3  # d+2 with d>=1


def test_manifest_written(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--grid", "small"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        check=True, env=env,
    )
    manifest = out / "manifest.tsv"
    assert manifest.exists()
    lines = manifest.read_text().strip().splitlines()
    assert len(lines) >= 4
    for line in lines:
        cols = line.split("\t")
        assert len(cols) == 8
        s, r, k = int(cols[0]), int(cols[1]), int(cols[2])
        assert (out / cols[7]).exists()
        assert r * 2 <= s and k >= 3
