"""L2 correctness: the LROT mirror-descent model (compile/model.py).

Checks the invariants HiRef's recursion relies on:
  * factor feasibility (column sums == g, active-row sums == a),
  * padding exactness (phantom rows receive no mass),
  * the Proposition 3.1 behaviour: on a dataset and its shuffled copy,
    the optimal factors co-cluster Monge pairs,
  * model == python-loop oracle (ref.lrot_ref).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

F32 = np.float32


def _problem(rng, s, d, noise=0.01):
    """Dataset + shuffled copy; Monge map of W2 cost is the shuffle."""
    X = rng.normal(size=(s, d)).astype(F32)
    perm = rng.permutation(s)
    Y = X[perm] + noise * rng.normal(size=(s, d)).astype(F32)
    return X, Y, perm


def _run(X, Y, rank, rng, loga=None, logb=None, hyper=None):
    s, d = X.shape
    hyper = hyper or model.LrotHyper(rank=rank)
    U, V = ref.sqeuclid_factors_ref(jnp.asarray(X), jnp.asarray(Y))
    if loga is None:
        loga = jnp.full((s,), -np.log(s), F32)
    if logb is None:
        logb = jnp.full((s,), -np.log(s), F32)
    nq = jnp.asarray(rng.normal(size=(s, rank)).astype(F32))
    nr = jnp.asarray(rng.normal(size=(s, rank)).astype(F32))
    fn = jax.jit(model.make_lrot(s, d + 2, hyper))
    Q, R = fn(U, V, loga, logb, nq, nr)
    return np.asarray(Q), np.asarray(R)


def test_factor_feasibility():
    rng = np.random.default_rng(0)
    X, Y, _ = _problem(rng, 128, 2)
    Q, R = _run(X, Y, 4, rng)
    # column sums match uniform g = 1/r
    np.testing.assert_allclose(Q.sum(0), 0.25, atol=2e-3)
    np.testing.assert_allclose(R.sum(0), 0.25, atol=2e-3)
    # total mass 1
    np.testing.assert_allclose(Q.sum(), 1.0, atol=1e-3)
    assert (Q >= 0).all() and (R >= 0).all()


def test_monge_co_clustering_rank2():
    """Proposition 3.1: q*(x) == r*(T(x)) for most points (approx solver)."""
    rng = np.random.default_rng(1)
    X, Y, perm = _problem(rng, 256, 2)
    Q, R = _run(X, Y, 2, rng)
    qa, ra = Q.argmax(1), R.argmax(1)
    # y_j = T(x_{perm[j]}) so agreement is qa[perm] == ra
    agree = float((qa[perm] == ra).mean())
    assert agree > 0.9, f"Monge co-cluster agreement too low: {agree}"


def test_monge_co_clustering_rank8():
    rng = np.random.default_rng(2)
    X, Y, perm = _problem(rng, 256, 4)
    Q, R = _run(X, Y, 8, rng)
    agree = float((Q.argmax(1)[perm] == R.argmax(1)).mean())
    assert agree > 0.75, f"rank-8 agreement too low: {agree}"


def test_split_is_balanced():
    rng = np.random.default_rng(3)
    X, Y, _ = _problem(rng, 256, 2)
    Q, R = _run(X, Y, 2, rng)
    for M in (Q, R):
        counts = np.bincount(M.argmax(1), minlength=2)
        assert abs(int(counts[0]) - 128) <= 26, counts


def test_padding_rows_receive_no_mass():
    """Phantom rows (log-mass NEG) must stay at ~zero in Q."""
    rng = np.random.default_rng(4)
    s, active = 64, 40
    X, Y, _ = _problem(rng, s, 2)
    loga = np.full(s, ref.NEG, F32)
    loga[:active] = -np.log(active)
    logb = loga.copy()
    Q, R = _run(X, Y, 2, rng, jnp.asarray(loga), jnp.asarray(logb))
    assert Q[active:].max() < 1e-12
    assert R[active:].max() < 1e-12
    np.testing.assert_allclose(Q[:active].sum(), 1.0, atol=1e-3)


def test_padded_solution_matches_unpadded_assignment():
    """Solving 48 active points inside a 64-bucket must give the same hard
    assignment as solving the 48 points exactly (same noise)."""
    rng = np.random.default_rng(5)
    active, s = 48, 64
    X, Y, _ = _problem(rng, active, 2)
    Xp = np.zeros((s, 2), F32); Xp[:active] = X
    Yp = np.zeros((s, 2), F32); Yp[:active] = Y
    noise_q = rng.normal(size=(s, 2)).astype(F32)
    noise_r = rng.normal(size=(s, 2)).astype(F32)

    hyper = model.LrotHyper(rank=2)
    # exact-size run
    U, V = ref.sqeuclid_factors_ref(jnp.asarray(X), jnp.asarray(Y))
    la = jnp.full((active,), -np.log(active), F32)
    Q0, R0 = jax.jit(model.make_lrot(active, 4, hyper))(
        U, V, la, la, jnp.asarray(noise_q[:active]), jnp.asarray(noise_r[:active]))
    # padded run
    Up, Vp = ref.sqeuclid_factors_ref(jnp.asarray(Xp), jnp.asarray(Yp))
    lap = np.full(s, ref.NEG, F32); lap[:active] = -np.log(active)
    Q1, R1 = jax.jit(model.make_lrot(s, 4, hyper))(
        Up, Vp, jnp.asarray(lap), jnp.asarray(lap),
        jnp.asarray(noise_q), jnp.asarray(noise_r))

    qa0 = np.asarray(Q0).argmax(1)
    qa1 = np.asarray(Q1)[:active].argmax(1)
    # identical up to a possible global label swap
    same = (qa0 == qa1).mean()
    assert same > 0.95 or same < 0.05, f"padded != unpadded: agree={same}"


def test_model_matches_python_oracle():
    rng = np.random.default_rng(6)
    s, d, r = 64, 2, 2
    X, Y, _ = _problem(rng, s, d)
    U, V = ref.sqeuclid_factors_ref(jnp.asarray(X), jnp.asarray(Y))
    loga = jnp.full((s,), -np.log(s), F32)
    nq = jnp.asarray(rng.normal(size=(s, r)).astype(F32))
    nr = jnp.asarray(rng.normal(size=(s, r)).astype(F32))
    hyper = model.LrotHyper(rank=r, outer=5, inner=6)
    Q, R = jax.jit(model.make_lrot(s, d + 2, hyper))(U, V, loga, loga, nq, nr)
    Q2, R2 = ref.lrot_ref(U, V, loga, loga, nq, nr, r, 5, 6, hyper.gamma)
    np.testing.assert_allclose(np.asarray(Q), np.asarray(Q2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(R), np.asarray(R2), atol=2e-5)


def test_lower_cost_than_independent_clustering():
    """The coupled objective must beat assigning clusters at random."""
    rng = np.random.default_rng(7)
    X, Y, perm = _problem(rng, 128, 2, noise=0.05)
    Q, R = _run(X, Y, 2, rng)
    C = ((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1)
    P = Q @ np.diag([2.0, 2.0]) @ R.T
    cost = float((C * P).sum())
    # random-label baseline: expected cost of the trivial coupling a b^T
    cost_trivial = float(C.mean())
    assert cost < cost_trivial, (cost, cost_trivial)
