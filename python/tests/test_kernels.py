"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

hypothesis sweeps shapes (including non-power-of-two sample counts, which
exercise the fallback tiling) and dtypes, asserting allclose against ref.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lrot_kernels as K
from compile.kernels import ref

F32 = np.float32


def _rand(rng, *shape, dtype=F32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# lowrank_grad
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([8, 16, 64, 96, 256, 1000]),
    k=st.sampled_from([1, 4, 7, 64]),
    r=st.sampled_from([2, 3, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowrank_grad_matches_ref(s, k, r, seed):
    rng = np.random.default_rng(seed)
    U = _rand(rng, s, k)
    V = _rand(rng, s, k)
    R = jnp.abs(_rand(rng, s, r)) / s
    got = K.lowrank_grad(U, V, R, float(r))
    want = ref.lowrank_grad_ref(U, V, R, float(r))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_lowrank_grad_equals_dense_product():
    """The fused kernel must equal the dense (U V^T) R product it avoids."""
    rng = np.random.default_rng(7)
    U, V = _rand(rng, 32, 4), _rand(rng, 32, 4)
    R = jnp.abs(_rand(rng, 32, 2))
    C = np.asarray(U) @ np.asarray(V).T
    want = C @ np.asarray(R) * 2.0
    got = np.asarray(K.lowrank_grad(U, V, R, 2.0))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_lowrank_grad_bf16_runs():
    rng = np.random.default_rng(3)
    U = _rand(rng, 64, 4).astype(jnp.bfloat16)
    V = _rand(rng, 64, 4).astype(jnp.bfloat16)
    R = jnp.abs(_rand(rng, 64, 2)).astype(jnp.bfloat16)
    got = K.lowrank_grad(U, V, R, 2.0)
    want = ref.lowrank_grad_ref(U.astype(F32), V.astype(F32),
                                R.astype(F32), 2.0)
    np.testing.assert_allclose(np.asarray(got, dtype=F32), np.asarray(want),
                               rtol=0.1, atol=0.1)


# ---------------------------------------------------------------------------
# masked_row_logsumexp
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([4, 16, 64, 100, 256]),
    r=st.sampled_from([2, 5, 16]),
    frac_masked=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_lse_matches_ref(s, r, frac_masked, seed):
    rng = np.random.default_rng(seed)
    M = _rand(rng, s, r) * 10.0
    mask = jnp.asarray((rng.random(s) >= frac_masked).astype(F32))
    got = K.masked_row_logsumexp(M, mask)
    want = ref.masked_row_logsumexp_ref(M, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_masked_lse_masked_rows_get_neg():
    M = jnp.ones((8, 4))
    mask = jnp.asarray([1, 0, 1, 0, 1, 0, 1, 0], dtype=F32)
    out = np.asarray(K.masked_row_logsumexp(M, mask))
    assert np.all(out[1::2] == ref.NEG)
    np.testing.assert_allclose(out[::2], 1.0 + np.log(4.0), rtol=1e-6)


def test_masked_lse_is_finite_on_all_masked():
    """All-masked input must not produce NaN (padding safety)."""
    M = jnp.full((16, 3), ref.NEG)
    mask = jnp.zeros((16,), F32)
    out = np.asarray(K.masked_row_logsumexp(M, mask))
    assert np.all(np.isfinite(out))


def test_masked_lse_large_values_stable():
    M = jnp.asarray([[800.0, 800.0], [-800.0, -800.0]], dtype=F32)
    mask = jnp.ones((2,), F32)
    out = np.asarray(K.masked_row_logsumexp(M, mask))
    np.testing.assert_allclose(out, [800.0 + np.log(2.0),
                                     -800.0 + np.log(2.0)], rtol=1e-6)


# ---------------------------------------------------------------------------
# sqeuclid factorisation oracle (consumed by both layers)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([2, 9, 33, 128]),
    d=st.sampled_from([1, 2, 3, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sqeuclid_factorisation_exact(n, d, seed):
    rng = np.random.default_rng(seed)
    X = _rand(rng, n, d)
    Y = _rand(rng, n, d)
    U, V = ref.sqeuclid_factors_ref(X, Y)
    assert U.shape == (n, d + 2) and V.shape == (n, d + 2)
    C_lr = np.asarray(U) @ np.asarray(V).T
    Xn, Yn = np.asarray(X), np.asarray(Y)
    C = ((Xn[:, None, :] - Yn[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(C_lr, C, rtol=1e-3, atol=1e-4)
