"""AOT pipeline: lower the LROT model to HLO text per shape bucket.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts land in artifacts/ as

    lrot_s{S}_r{R}_k{K}.hlo.txt     one per (sample, rank, cost-factor) bucket
    manifest.tsv                    "s<TAB>r<TAB>k<TAB>outer<TAB>inner<TAB>gamma<TAB>tau<TAB>path"

The Rust runtime reads manifest.tsv, compiles each bucket once on the PJRT
CPU client, and serves every HiRef sub-problem from the smallest bucket that
fits (padding is exact — see model.py).  Python runs only here, never on the
request path.

Usage: python -m compile.aot --out-dir ../artifacts [--grid small|default|large]
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import LrotHyper, example_args, make_lrot

# Bucket grids: (sample sizes) × (ranks) × (cost-factor widths).
# k = d + 2 for the exact squared-Euclidean factorisation: k=4 covers the
# 2-D synthetic suites, k=64 covers 60-dim PCA transcriptomics and Indyk
# factorisations of high-dim embeddings (features are zero-padded, which is
# exact for factorised costs).
GRIDS = {
    "small": dict(sizes=(256, 1024), ranks=(2, 8), ks=(4,)),
    "default": dict(sizes=(256, 1024, 4096, 16384),
                    ranks=(2, 8, 16), ks=(4, 64)),
    "large": dict(sizes=(256, 1024, 4096, 16384, 65536),
                  ranks=(2, 8, 16, 32), ks=(4, 64)),
}

HYPER = LrotHyper(rank=0)  # rank filled per bucket; rest are the defaults


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_bucket(s: int, r: int, k: int) -> str:
    hyper = HYPER._replace(rank=r)
    fn = make_lrot(s, k, hyper)
    lowered = jax.jit(fn).lower(*example_args(s, k, r))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid", default="default", choices=sorted(GRIDS))
    args = ap.parse_args()

    grid = GRIDS[args.grid]
    os.makedirs(args.out_dir, exist_ok=True)
    rows = []
    n_buckets = (len(grid["sizes"]) * len(grid["ranks"]) * len(grid["ks"]))
    done = 0
    for s in grid["sizes"]:
        for r in grid["ranks"]:
            if r * 2 > s:
                continue
            for k in grid["ks"]:
                name = f"lrot_s{s}_r{r}_k{k}.hlo.txt"
                path = os.path.join(args.out_dir, name)
                text = lower_bucket(s, r, k)
                with open(path, "w") as f:
                    f.write(text)
                rows.append((s, r, k, HYPER.outer, HYPER.inner,
                             HYPER.gamma, HYPER.tau, name))
                done += 1
                print(f"[{done}/{n_buckets}] wrote {name} "
                      f"({len(text)//1024} KiB)", file=sys.stderr)

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        for row in rows:
            f.write("\t".join(str(x) for x in row) + "\n")
    print(f"wrote {manifest} with {len(rows)} buckets", file=sys.stderr)


if __name__ == "__main__":
    main()
