"""Layer-2 JAX model: the low-rank OT (LROT) solver HiRef calls per co-cluster.

Solves the paper's Eq. 7 —

    min_{Q ∈ Π(a,g), R ∈ Π(b,g)}  <C, Q diag(1/g) R^T>,   g = 1_r / r

— by FRLC-style mirror descent (Halmos et al. 2024) with the inner marginal
pinned uniform (the paper sends the inner step-size τ_in → ∞, which is
exactly a hard uniform constraint).  The cost matrix is never materialised:
the model consumes low-rank cost factors U, V with C = U V^T, so one
gradient costs O(s·k·r) (Layer-1 Pallas kernel `lowrank_grad`).

Marginals arrive in log space; padded (phantom) points carry log-mass NEG,
so a sub-problem of any size ≤ s runs exactly on a fixed (s, r, k) bucket —
this is what makes static-shape AOT artifacts usable from the Rust
coordinator.

This module is build-time only: `aot.py` lowers `make_lrot` per bucket to
HLO text; Python never runs on the Rust request path.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import lrot_kernels as K
from .kernels.ref import NEG


class LrotHyper(NamedTuple):
    """Static hyper-parameters baked into each lowered artifact."""
    rank: int
    outer: int = 30      # mirror-descent steps (L in the paper's complexity)
    inner: int = 12      # Sinkhorn sweeps per KL projection (B)
    gamma: float = 8.0   # base mirror step, rescaled by ||grad||_inf
    tau: float = 0.01    # init symmetry-breaking noise scale


def sinkhorn_project(logK, loga, logg, inner: int):
    """KL-project exp(logK) onto Π(a, g), log domain, masked rows.

    Matches ref.sinkhorn_project_ref but runs the row reduction through the
    Pallas kernel and the sweep through lax.fori_loop so it lowers compactly.
    """
    row_mask = (loga > NEG / 2).astype(logK.dtype)

    def body(_, carry):
        f, h = carry
        lse_r = K.masked_row_logsumexp(logK + h[None, :], row_mask)
        f = jnp.where(row_mask > 0.5, loga - lse_r, NEG)
        Mc = logK + f[:, None]
        mx = jnp.maximum(jnp.max(Mc, axis=0), NEG)
        lse_c = mx + jnp.log(jnp.sum(jnp.exp(Mc - mx[None, :]), axis=0))
        h = logg - lse_c
        return f, h

    f0 = jnp.zeros(logK.shape[0], logK.dtype)
    h0 = jnp.zeros(logK.shape[1], logK.dtype)
    f, h = jax.lax.fori_loop(0, inner, body, (f0, h0))
    return logK + f[:, None] + h[None, :]


def lrot(U, V, loga, logb, noise_q, noise_r, hyper: LrotHyper):
    """Run mirror descent; return hard-assignable factors (Q, R), each (s, r).

    U, V:   (s, k) cost factors (C = U V^T restricted to this co-cluster).
    loga/b: (s,) log marginals, NEG on padded rows.
    noise:  (s, r) symmetry-breaking perturbations (PRNG lives in Rust so
            artifacts stay deterministic functions of their inputs).
    """
    r = hyper.rank
    logg = jnp.full((r,), -jnp.log(float(r)), U.dtype)
    inv_g = float(r)

    logQ = sinkhorn_project(
        loga[:, None] + logg[None, :] + hyper.tau * noise_q,
        loga, logg, hyper.inner)
    logR = sinkhorn_project(
        logb[:, None] + logg[None, :] + hyper.tau * noise_r,
        logb, logg, hyper.inner)

    def body(_, carry):
        logQ, logR = carry
        Q = jnp.exp(logQ)
        R = jnp.exp(logR)
        gq = K.lowrank_grad(U, V, R, inv_g)    # (s, r) = U (V^T R) / g
        gr = K.lowrank_grad(V, U, Q, inv_g)    # (s, r) = V (U^T Q) / g
        scale = jnp.maximum(jnp.max(jnp.abs(gq)), jnp.max(jnp.abs(gr)))
        step = hyper.gamma / jnp.maximum(scale, 1e-12)
        logQ = sinkhorn_project(logQ - step * gq, loga, logg, hyper.inner)
        logR = sinkhorn_project(logR - step * gr, logb, logg, hyper.inner)
        return logQ, logR

    logQ, logR = jax.lax.fori_loop(0, hyper.outer, body, (logQ, logR))
    return jnp.exp(logQ), jnp.exp(logR)


def make_lrot(s: int, k: int, hyper: LrotHyper):
    """Return a jittable fn of (U, V, loga, logb, noise_q, noise_r) for a
    fixed (s, r, k) bucket, returning the tuple (Q, R)."""

    def fn(U, V, loga, logb, noise_q, noise_r):
        return lrot(U, V, loga, logb, noise_q, noise_r, hyper)

    return fn


def example_args(s: int, k: int, rank: int, dtype=jnp.float32):
    """ShapeDtypeStructs matching make_lrot's signature, for jit.lower."""
    f = functools.partial(jax.ShapeDtypeStruct, dtype=dtype)
    return (f((s, k)), f((s, k)), f((s,)), f((s,)), f((s, rank)), f((s, rank)))
