"""Layer-1 Pallas kernels for the low-rank OT mirror-descent hot spot.

Both kernels are the compute inner loop of every LROT call HiRef makes
(one per co-cluster per scale).  They are tiled over the sample axis so
each tile's working set fits VMEM: for a bucket (s, k, r) a tile holds
`block_s·k` factor rows plus the small (k, r) intermediate — the BlockSpec
expresses the HBM↔VMEM schedule that a GPU implementation would express
with thread blocks, and the `U_tile @ W` contraction is MXU-shaped
(bf16/f32 matmul over a (block_s, k) × (k, r) tile).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels lower to plain HLO (see DESIGN.md
§Hardware-adaptation).  Numerics are pinned to kernels/ref.py by pytest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG

_INTERPRET = True


def _pick_block(s: int, target: int = 256) -> int:
    """Largest power-of-two tile ≤ target that divides s (s itself if none)."""
    b = target
    while b > 1:
        if s % b == 0:
            return b
        b //= 2
    return s


# ---------------------------------------------------------------------------
# Kernel 1: fused low-rank gradient  (U @ (V^T @ R)) * inv_g
# ---------------------------------------------------------------------------

def _inner_matmul_kernel(v_ref, r_ref, w_ref):
    """W = V^T @ R for one column-tile of V/R, accumulated over the grid."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        w_ref[...] = jnp.zeros_like(w_ref)

    w_ref[...] += v_ref[...].T @ r_ref[...]


def _outer_matmul_kernel(u_ref, w_ref, o_ref, *, inv_g: float):
    """out_tile = (U_tile @ W) * inv_g."""
    o_ref[...] = (u_ref[...] @ w_ref[...]) * inv_g


def lowrank_grad(U: jnp.ndarray, V: jnp.ndarray, R: jnp.ndarray,
                 inv_g: float) -> jnp.ndarray:
    """Pallas version of ref.lowrank_grad_ref: (U @ (V^T @ R)) * inv_g.

    U, V: (s, k) cost factors; R: (s, r) coupling factor.  Returns (s, r).
    Stage 1 reduces V^T R over row tiles (k×r stays resident in VMEM);
    stage 2 streams row tiles of U against the resident W.
    """
    s, k = U.shape
    r = R.shape[1]
    bs = _pick_block(s)
    grid = (s // bs,)

    W = pl.pallas_call(
        _inner_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, k), lambda i: (i, 0)),
            pl.BlockSpec((bs, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, r), U.dtype),
        interpret=_INTERPRET,
    )(V, R)

    return pl.pallas_call(
        functools.partial(_outer_matmul_kernel, inv_g=float(inv_g)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, k), lambda i: (i, 0)),
            pl.BlockSpec((k, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, r), U.dtype),
        interpret=_INTERPRET,
    )(U, W)


# ---------------------------------------------------------------------------
# Kernel 2: masked row logsumexp (the Sinkhorn f-update reduction)
# ---------------------------------------------------------------------------

def _masked_lse_kernel(m_ref, mask_ref, o_ref):
    m = m_ref[...]
    mx = jnp.maximum(jnp.max(m, axis=-1, keepdims=True), NEG)
    lse = mx[:, 0] + jnp.log(jnp.sum(jnp.exp(m - mx), axis=-1))
    o_ref[...] = jnp.where(mask_ref[...] > 0.5, lse, NEG)


def masked_row_logsumexp(M: jnp.ndarray, row_mask: jnp.ndarray) -> jnp.ndarray:
    """Pallas version of ref.masked_row_logsumexp_ref.

    M: (s, r); row_mask: (s,) 1.0 = active, 0.0 = padded.  Returns (s,).
    """
    s, r = M.shape
    bs = _pick_block(s)
    return pl.pallas_call(
        _masked_lse_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((bs, r), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((s,), M.dtype),
        interpret=_INTERPRET,
    )(M, row_mask)
