"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness spec).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here.  pytest/hypothesis sweeps shapes and dtypes asserting
`assert_allclose(kernel(...), ref(...))` — this file is the single source
of numerical truth for Layer 1.
"""
from __future__ import annotations

import jax.numpy as jnp

# Large negative used to mask log-weights of padded (zero-mass) points.
# Chosen so exp(NEG) == 0 in f32 but NEG - NEG arithmetic stays finite.
NEG = -1.0e9


def lowrank_grad_ref(U: jnp.ndarray, V: jnp.ndarray, R: jnp.ndarray,
                     inv_g: float) -> jnp.ndarray:
    """Gradient of <C, Q diag(1/g) R^T> w.r.t. Q, with C = U @ V^T.

    Computes (U @ (V^T @ R)) * inv_g without materialising the s×s cost
    matrix — the core linear-space trick of low-rank OT.

    U: (s, k) left cost factor, V: (s, k) right cost factor, R: (s, r).
    Returns (s, r).
    """
    W = V.T @ R                      # (k, r) — small
    return (U @ W) * inv_g           # (s, r)


def masked_row_logsumexp_ref(M: jnp.ndarray, row_mask: jnp.ndarray) -> jnp.ndarray:
    """Row-wise logsumexp of M (s, r); rows with row_mask==0 return NEG.

    Stable: subtracts the row max.  Padded rows must not produce NaN/Inf
    that could leak into neighbouring rows under vectorised ops.
    """
    mx = jnp.max(M, axis=-1, keepdims=True)
    mx = jnp.maximum(mx, NEG)  # guard all-NEG rows
    lse = mx[:, 0] + jnp.log(jnp.sum(jnp.exp(M - mx), axis=-1))
    return jnp.where(row_mask > 0.5, lse, NEG)


def sinkhorn_project_ref(logK: jnp.ndarray, loga: jnp.ndarray,
                         logg: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Project exp(logK) onto Π(a, g) in log domain (KL projection).

    logK: (s, r) log kernel; loga: (s,) log row marginal (NEG = padded);
    logg: (r,) log inner marginal.  Returns logQ with row sums == a and
    column sums == g (up to `iters` Sinkhorn sweeps).
    """
    row_mask = (loga > NEG / 2).astype(logK.dtype)
    f = jnp.zeros(logK.shape[0], logK.dtype)
    h = jnp.zeros(logK.shape[1], logK.dtype)
    for _ in range(iters):
        # f-update: match row marginals a
        lse_r = masked_row_logsumexp_ref(logK + h[None, :], row_mask)
        f = jnp.where(row_mask > 0.5, loga - lse_r, NEG)
        # h-update: match column marginals g (columns always active)
        Mc = logK + f[:, None]
        mx = jnp.maximum(jnp.max(Mc, axis=0), NEG)
        lse_c = mx + jnp.log(jnp.sum(jnp.exp(Mc - mx[None, :]), axis=0))
        h = logg - lse_c
    return logK + f[:, None] + h[None, :]


def lrot_ref(U, V, loga, logb, noise_q, noise_r, rank: int,
             outer: int, inner: int, gamma: float):
    """Reference low-rank OT: mirror descent on (Q, R), uniform inner g.

    Solves  min <C, Q diag(1/g) R^T>  s.t. Q ∈ Π(a,g), R ∈ Π(b,g),
    g = 1/r uniform (paper Eq. 7), with C = U V^T.  Python-loop version of
    the lowered model — used as the oracle for model tests.
    Returns (Q, R) as (s, r) nonnegative arrays.
    """
    logg = jnp.full((rank,), -jnp.log(float(rank)), U.dtype)
    inv_g = float(rank)
    tau = 0.01
    logQ = sinkhorn_project_ref(
        loga[:, None] + logg[None, :] + tau * noise_q, loga, logg, inner)
    logR = sinkhorn_project_ref(
        logb[:, None] + logg[None, :] + tau * noise_r, logb, logg, inner)
    for _ in range(outer):
        Q = jnp.exp(logQ)
        R = jnp.exp(logR)
        gq = lowrank_grad_ref(U, V, R, inv_g)
        gr = lowrank_grad_ref(V, U, Q, inv_g)
        scale = jnp.maximum(jnp.max(jnp.abs(gq)), jnp.max(jnp.abs(gr)))
        step = gamma / jnp.maximum(scale, 1e-12)
        logQ = sinkhorn_project_ref(logQ - step * gq, loga, logg, inner)
        logR = sinkhorn_project_ref(logR - step * gr, logb, logg, inner)
    return jnp.exp(logQ), jnp.exp(logR)


def sqeuclid_factors_ref(X: jnp.ndarray, Y: jnp.ndarray):
    """Exact rank-(d+2) factorisation of the squared-Euclidean cost matrix.

    C_ij = |x_i|^2 - 2 x_i·y_j + |y_j|^2  =  (U V^T)_ij with
    U = [|x|^2, 1, -2x],  V = [1, |y|^2, y].  Returns (U, V), each (n, d+2).
    """
    nx = jnp.sum(X * X, axis=1, keepdims=True)
    ny = jnp.sum(Y * Y, axis=1, keepdims=True)
    U = jnp.concatenate([nx, jnp.ones_like(nx), -2.0 * X], axis=1)
    V = jnp.concatenate([jnp.ones_like(ny), ny, Y], axis=1)
    return U, V
