//! Simulated high-dimensional image-embedding clouds (ImageNet stand-in).
//!
//! §4.4 aligns 1.281M ResNet50 embeddings (2048-dim) split 50:50.  We
//! generate a clustered shell distribution that preserves what the
//! experiment actually measures — scalability and the cost ordering of
//! HiRef vs mini-batch vs low-rank OT on a high-dimensional, strongly
//! clustered distribution: `classes` anisotropic Gaussian clusters whose
//! centres sit on a sphere (ResNet features are approximately norm-
//! concentrated), sampled i.i.d. and split at random into X and Y.

use crate::linalg::Mat;
use crate::prng::Rng;

/// Paper's full ImageNet size after the divisibility trim (§D.4).
pub const IMAGENET_FULL: usize = 1_281_000;

/// Generate `(X, Y)` by sampling `2n` embeddings from a clustered shell
/// distribution in `d` dims with `classes` clusters and splitting 50:50
/// at random (mirrors the paper's torch.randperm split).
pub fn imagenet_like(n: usize, d: usize, classes: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed ^ 0x1A6E7);
    // class centres: random directions scaled to a common shell radius
    let mut centers = Mat::zeros(classes, d);
    rng.fill_normal(&mut centers.data);
    let radius = 8.0f32;
    for c in 0..classes {
        let row = centers.row_mut(c);
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
        for v in row.iter_mut() {
            *v *= radius / norm;
        }
    }
    let total = 2 * n;
    let mut all = Mat::zeros(total, d);
    let spread = 0.8f32;
    for i in 0..total {
        let c = rng.next_below(classes);
        let crow = centers.row(c);
        let row = all.row_mut(i);
        for (o, &m) in row.iter_mut().zip(crow) {
            *o = m + spread * rng.normal_f32();
        }
    }
    // 50:50 random split
    let perm = rng.permutation(total);
    let xi: Vec<u32> = perm[..n].to_vec();
    let yi: Vec<u32> = perm[n..].to_vec();
    (all.gather_rows(&xi), all.gather_rows(&yi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_split() {
        let (x, y) = imagenet_like(500, 32, 10, 0);
        assert_eq!((x.rows, x.cols), (500, 32));
        assert_eq!((y.rows, y.cols), (500, 32));
    }

    #[test]
    fn shell_concentration() {
        let (x, _) = imagenet_like(400, 64, 20, 1);
        let mut norms: Vec<f32> = (0..x.rows)
            .map(|i| x.row(i).iter().map(|v| v * v).sum::<f32>().sqrt())
            .collect();
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = norms[norms.len() / 2];
        assert!((med - 8.0).abs() < 8.0 * 0.75, "median norm {med}");
    }

    #[test]
    fn splits_share_distribution() {
        // mean of X ≈ mean of Y (same underlying cloud)
        let (x, y) = imagenet_like(2000, 16, 8, 2);
        for c in 0..16 {
            let mx: f64 = (0..x.rows).map(|i| x.at(i, c) as f64).sum::<f64>() / x.rows as f64;
            let my: f64 = (0..y.rows).map(|i| y.at(i, c) as f64).sum::<f64>() / y.rows as f64;
            assert!((mx - my).abs() < 0.6, "dim {c}: {mx} vs {my}");
        }
    }

    #[test]
    fn deterministic() {
        let (x1, _) = imagenet_like(100, 8, 4, 3);
        let (x2, _) = imagenet_like(100, 8, 4, 3);
        assert_eq!(x1.data, x2.data);
    }
}
