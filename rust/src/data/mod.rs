//! Dataset generators for every experiment in the paper.
//!
//! * [`synthetic`] — the three 2-D benchmark suites (Makkuva et al. 2020;
//!   Buzun et al. 2024) used in §4.1 / Tables S2–S4 / Figs 2–3, S4–S5.
//! * [`transcriptomics`] — simulated spatial-transcriptomics slices
//!   standing in for the MOSTA embryo atlas (§4.2, Table 1/S6) and the
//!   MERFISH brain-receptor slices (§4.3, Table S7); see DESIGN.md §3 for
//!   the substitution argument.
//! * [`embeddings`] — simulated high-dimensional image-embedding clouds
//!   standing in for ResNet50 ImageNet embeddings (§4.4, Table 2/S8).
//! * [`stream`] — chunked [`stream::DatasetSource`] ingestion (in-memory,
//!   generator-backed, binary-file) for beyond-RAM datasets: the solver
//!   consumes tiles of `chunk_rows` points, never the whole cloud.

#![forbid(unsafe_code)]

pub mod embeddings;
pub mod stream;
pub mod synthetic;
pub mod transcriptomics;

pub use stream::{BinFileSource, DatasetSource, GeneratorSource, InMemorySource};
