//! Streaming dataset ingestion: bounded-memory access to point clouds
//! that need not fit comfortably in RAM.
//!
//! PR 2 made the refinement core linear-space by construction, which left
//! dataset materialisation and cost factorisation as the real peak-memory
//! ceiling: both point clouds (`O(n·d)` each) were built up front even
//! though the solver itself only ever needs (a) the `O(n·r)` cost factors
//! and (b) small gathered tiles for base-case blocks.  This module closes
//! that gap:
//!
//! * [`DatasetSource`] — a chunked source of row-major `f32` points.
//!   Implementations promise deterministic content (`fill_rows` at the
//!   same offset always yields the same rows), which keeps every solve
//!   bit-reproducible regardless of chunk size.
//! * [`InMemorySource`] — zero-copy adapter over a [`Mat`]/[`MatView`]
//!   (its [`DatasetSource::view_rows`] hands out borrowed windows, so the
//!   chunked code paths add no copies for memory-resident data).
//! * [`GeneratorSource`] — points produced on demand by a per-row
//!   function (`row index → point`), the natural encoding of the paper's
//!   synthetic benchmark suites at `n = 2^20` and beyond: the full cloud
//!   never exists in memory.
//! * [`BinFileSource`] — little-endian `f32` rows read from a binary file
//!   on demand (mmap-style windowed access through seek + read; the
//!   vendored universe has no memmap crate).
//!
//! [`for_each_chunk`] drives any source in `chunk_rows`-sized tiles whose
//! scratch comes from the shared [`ScratchArena`], so chunked consumers
//! (the factor builders in [`crate::costs`], the base case of
//! [`crate::coordinator::hiref`]) hold **one tile plus their `O(n·r)`
//! output** — peak ingestion memory is `O(chunk_rows·d)` by construction.
//! [`for_each_chunk_parallel`] is its multi-worker twin (one live tile
//! *per worker*) for sweeps whose per-tile work is independent.
//!
//! All row access is **fallible**: [`DatasetSource::fill_rows`] /
//! [`DatasetSource::fetch_row`] return `io::Result`, and the chunk
//! drivers propagate the first failure instead of panicking mid-solve —
//! the coordinator surfaces it as a typed
//! [`crate::api::SolveError::Backend`].

use std::fs::File;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::fsio::PositionedFile;
use crate::linalg::{Mat, MatView};
use crate::pool::{self, ScratchArena};

/// A chunked, deterministic source of `rows() × dim()` row-major points.
///
/// `Sync` is a supertrait because sources are shared across the HiRef
/// worker pool (base-case blocks gather their rows concurrently, and the
/// parallel factor builders sweep tiles from several workers).
pub trait DatasetSource: Sync {
    /// Number of points.
    fn rows(&self) -> usize;

    /// Ambient dimension of each point.
    fn dim(&self) -> usize;

    /// Write rows `start .. start + out.len()/dim()` into `out`
    /// (row-major; `out.len()` must be a multiple of `dim()` and the range
    /// must be in bounds).  Must be deterministic in `start`.
    ///
    /// Sources whose backing storage can fail mid-read (e.g.
    /// [`BinFileSource`] on a truncated or vanished file) return the
    /// `io::Error` instead of panicking; solve paths thread it through as
    /// [`crate::api::SolveError::Backend`].  In-memory and generated
    /// sources are infallible and always return `Ok(())`.
    fn fill_rows(&self, start: usize, out: &mut [f32]) -> io::Result<()>;

    /// Zero-copy borrowed window for memory-resident sources; `None` means
    /// the caller must go through [`DatasetSource::fill_rows`] scratch.
    fn view_rows(&self, _start: usize, _end: usize) -> Option<MatView<'_>> {
        None
    }

    /// Fetch a single row (used for scattered access: factorisation
    /// anchors, base-case gathers, streamed cost evaluation).
    fn fetch_row(&self, i: usize, out: &mut [f32]) -> io::Result<()> {
        self.fill_rows(i, out)
    }
}

/// Drive `src` in `chunk_rows`-sized tiles, calling `f(start, tile)` for
/// each.  Tiles for non-resident sources are checked out of `arena` (one
/// tile live at a time — the bounded-memory contract); memory-resident
/// sources stream borrowed views with no copy at all.  Stops at the first
/// read failure and returns it.
pub fn for_each_chunk(
    src: &dyn DatasetSource,
    chunk_rows: usize,
    arena: &ScratchArena,
    mut f: impl FnMut(usize, MatView<'_>),
) -> io::Result<()> {
    let n = src.rows();
    let d = src.dim();
    if n == 0 {
        return Ok(());
    }
    let chunk = chunk_rows.max(1).min(n);
    // lazy checkout: a source that serves borrowed views (in-memory data)
    // never pays for a tile at all
    let mut tile: Option<crate::pool::ScratchF32<'_>> = None;
    let mut start = 0usize;
    while start < n {
        let end = (start + chunk).min(n);
        match src.view_rows(start, end) {
            Some(v) => f(start, v),
            None => {
                let t = tile.get_or_insert_with(|| arena.take_f32(chunk * d));
                let len = (end - start) * d;
                src.fill_rows(start, &mut t[..len])?;
                f(start, MatView::from_slice(end - start, d, &t[..len]));
            }
        }
        start = end;
    }
    Ok(())
}

/// Multi-worker twin of [`for_each_chunk`]: tiles are claimed by up to
/// `threads` workers (each with its own arena tile, so peak ingestion
/// memory is `O(threads · chunk_rows · d)`), and `f` runs once per tile,
/// concurrently.  `f` must therefore only touch disjoint per-tile state —
/// e.g. disjoint output row windows through a
/// [`crate::pool::SharedSlice`].  Tile boundaries depend only on
/// `chunk_rows`, never on `threads`, so any writes keyed by row index are
/// bit-identical for every thread count.  Returns the first read failure,
/// after all workers have stopped.
pub fn for_each_chunk_parallel(
    src: &dyn DatasetSource,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
    f: impl Fn(usize, MatView<'_>) + Sync,
) -> io::Result<()> {
    let n = src.rows();
    let d = src.dim();
    if n == 0 {
        return Ok(());
    }
    let chunk = chunk_rows.max(1).min(n);
    let n_tiles = n.div_ceil(chunk);
    let results = pool::parallel_map(n_tiles, threads, |t| -> io::Result<()> {
        let start = t * chunk;
        let end = (start + chunk).min(n);
        match src.view_rows(start, end) {
            Some(v) => f(start, v),
            None => {
                let len = (end - start) * d;
                let mut tile = arena.take_f32(len);
                src.fill_rows(start, &mut tile[..len])?;
                f(start, MatView::from_slice(end - start, d, &tile[..len]));
            }
        }
        Ok(())
    });
    results.into_iter().collect()
}

/// Gather scattered rows `ids` of `src` into a row-major `out` buffer
/// (`out.len() == ids.len() * dim`).  The base-case path of the streaming
/// solve: a block's points are fetched once into arena scratch.  Stops at
/// the first read failure and returns it.
pub fn gather_rows_into(src: &dyn DatasetSource, ids: &[u32], out: &mut [f32]) -> io::Result<()> {
    let d = src.dim();
    assert_eq!(out.len(), ids.len() * d, "gather buffer shape mismatch");
    for (row, &i) in out.chunks_mut(d).zip(ids) {
        src.fetch_row(i as usize, row)?;
    }
    Ok(())
}

/// Streaming FNV-1a (64-bit) content hash of a dataset: the shape
/// (`rows`, `dim`) followed by every row's `f32`s as little-endian bytes,
/// consumed in `chunk_rows`-sized tiles — no full materialisation, so it
/// works on beyond-RAM [`BinFileSource`]s at `O(chunk_rows · dim)` memory.
///
/// The hash identifies dataset *content*, independent of where it lives:
/// an [`InMemorySource`] and the `.bin` file produced from it by
/// [`convert_to_bin`] hash identically, for any chunk size.  `hiref
/// convert` prints it, and the `serve` subsystem uses it as the warm
/// session cache key (see [`crate::serve`]).
pub fn content_hash(
    src: &dyn DatasetSource,
    chunk_rows: usize,
    arena: &ScratchArena,
) -> io::Result<u64> {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    #[inline]
    fn mix(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
        h
    }
    // shape prefix: the same bytes viewed as 4×2 and 2×4 must not collide
    let mut h = FNV_OFFSET;
    h = mix(h, &(src.rows() as u64).to_le_bytes());
    h = mix(h, &(src.dim() as u64).to_le_bytes());
    for_each_chunk(src, chunk_rows, arena, |_, tile| {
        for &v in tile.data {
            h = mix(h, &v.to_le_bytes());
        }
    })?;
    Ok(h)
}

/// [`content_hash`] rendered as the fixed-width hex id the serve protocol
/// and `hiref convert` print (16 lowercase hex digits).
pub fn content_hash_hex(
    src: &dyn DatasetSource,
    chunk_rows: usize,
    arena: &ScratchArena,
) -> io::Result<String> {
    Ok(format!("{:016x}", content_hash(src, chunk_rows, arena)?))
}

// ---------------------------------------------------------------------------
// InMemorySource
// ---------------------------------------------------------------------------

/// Zero-copy [`DatasetSource`] over a borrowed matrix.  `view_rows`
/// returns borrowed windows, so chunked consumers add no copies.
#[derive(Clone, Copy)]
pub struct InMemorySource<'a> {
    view: MatView<'a>,
}

impl<'a> InMemorySource<'a> {
    pub fn new(m: &'a Mat) -> InMemorySource<'a> {
        InMemorySource { view: m.view() }
    }

    pub fn from_view(view: MatView<'a>) -> InMemorySource<'a> {
        InMemorySource { view }
    }
}

impl DatasetSource for InMemorySource<'_> {
    fn rows(&self) -> usize {
        self.view.rows
    }

    fn dim(&self) -> usize {
        self.view.cols
    }

    fn fill_rows(&self, start: usize, out: &mut [f32]) -> io::Result<()> {
        let d = self.view.cols;
        let k = out.len() / d;
        out.copy_from_slice(&self.view.data[start * d..(start + k) * d]);
        Ok(())
    }

    fn view_rows(&self, start: usize, end: usize) -> Option<MatView<'_>> {
        Some(self.view.rows_range(start, end))
    }
}

// ---------------------------------------------------------------------------
// GeneratorSource
// ---------------------------------------------------------------------------

/// Points produced on demand by a per-row function — `f(i, out)` writes
/// point `i`.  The function must be deterministic in `i` (seed per-row
/// generators from a hash of `(seed, i)`, not from a shared sequential
/// stream); the full cloud never exists in memory.
pub struct GeneratorSource {
    rows: usize,
    dim: usize,
    f: Box<dyn Fn(usize, &mut [f32]) + Send + Sync>,
}

impl GeneratorSource {
    pub fn new(
        rows: usize,
        dim: usize,
        f: impl Fn(usize, &mut [f32]) + Send + Sync + 'static,
    ) -> GeneratorSource {
        GeneratorSource { rows, dim, f: Box::new(f) }
    }
}

impl DatasetSource for GeneratorSource {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn fill_rows(&self, start: usize, out: &mut [f32]) -> io::Result<()> {
        for (o, row) in out.chunks_mut(self.dim).enumerate() {
            (self.f)(start + o, row);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// BinFileSource
// ---------------------------------------------------------------------------

/// On-disk element type of a [`BinFileSource`] (both little-endian;
/// `f64` values are narrowed to `f32` on read).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BinElem {
    F32,
    F64,
}

impl BinElem {
    fn size(self) -> usize {
        match self {
            BinElem::F32 => 4,
            BinElem::F64 => 8,
        }
    }
}

/// Little-endian float rows read from a binary file on demand — the
/// mmap-style path for datasets on disk.  [`BinFileSource::open`] reads
/// the raw headerless `.bin` format (f32 rows);
/// [`BinFileSource::open_npy`] reads NumPy `.npy` files (v1/v2 headers,
/// C-order `<f4`/`<f8`, f64 narrowed to f32).  On unix, reads are
/// positioned (`pread`): no shared cursor and no lock, so concurrent
/// base-case gathers from the worker pool never serialise on this
/// source.
pub struct BinFileSource {
    path: PathBuf,
    rows: usize,
    dim: usize,
    /// Byte offset of the first data element (0 for raw `.bin`, the
    /// header length for `.npy`).
    offset: u64,
    elem: BinElem,
    file: PositionedFile,
}

impl BinFileSource {
    /// Open `path` as `dim`-dimensional rows; the row count is inferred
    /// from the file length, which must be a multiple of `4 * dim` bytes.
    pub fn open(path: impl AsRef<Path>, dim: usize) -> io::Result<BinFileSource> {
        let path = path.as_ref().to_path_buf();
        let file = File::open(&path)?;
        let bytes = file.metadata()?.len() as usize;
        let row_bytes = 4 * dim;
        if dim == 0 || bytes % row_bytes != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: {bytes} bytes is not a whole number of {dim}-dim f32 rows",
                    path.display()
                ),
            ));
        }
        Ok(BinFileSource {
            path,
            rows: bytes / row_bytes,
            dim,
            offset: 0,
            elem: BinElem::F32,
            file: PositionedFile::new(file),
        })
    }

    /// Open a NumPy `.npy` file: v1/v2 headers, C-order (`fortran_order:
    /// False`), dtype `<f4` or `<f8` (f64 is narrowed to f32 on read),
    /// shape `(n,)` or `(n, d)`.  Shape and dtype come from the header;
    /// the payload length is validated against them.
    pub fn open_npy(path: impl AsRef<Path>) -> io::Result<BinFileSource> {
        let path = path.as_ref().to_path_buf();
        let file = File::open(&path)?;
        let header = parse_npy_header(&path, &file)?;
        let total = file.metadata()?.len();
        // checked: a corrupt header declaring an absurd shape must be
        // rejected, not wrap the expected length around
        let payload = header
            .rows
            .checked_mul(header.dim)
            .and_then(|e| e.checked_mul(header.elem.size()))
            .ok_or_else(|| {
                npy_err(&path, format!("npy shape ({}, {}) overflows", header.rows, header.dim))
            })?;
        let expect = header.offset + payload as u64;
        if total != expect {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: payload is {} bytes but the npy header promises {} ({}×{} {:?})",
                    path.display(),
                    total - header.offset.min(total),
                    expect - header.offset,
                    header.rows,
                    header.dim,
                    header.elem
                ),
            ));
        }
        Ok(BinFileSource {
            path,
            rows: header.rows,
            dim: header.dim,
            offset: header.offset,
            elem: header.elem,
            file: PositionedFile::new(file),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read `bytes.len()` bytes at absolute `offset` (lock-free `pread`
    /// on unix, mutexed seek + read elsewhere — see [`PositionedFile`]).
    fn read_at(&self, offset: u64, bytes: &mut [u8]) -> io::Result<()> {
        self.file.read_at(offset, bytes)
    }
}

impl DatasetSource for BinFileSource {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn fill_rows(&self, start: usize, out: &mut [f32]) -> io::Result<()> {
        // Byte staging goes through a per-thread reusable buffer: after
        // warm-up, neither single-row fetches (base-case gathers,
        // streamed cost evaluation — called per row) nor tile-sized
        // sweep reads allocate — the capacity is retained across calls,
        // matching the arena discipline of the f32 destination.
        thread_local! {
            static STAGING: std::cell::RefCell<Vec<u8>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        let esize = self.elem.size();
        STAGING.with(|cell| {
            let mut bytes = cell.borrow_mut();
            bytes.clear();
            bytes.resize(out.len() * esize, 0);
            self.read_at(self.offset + (start * self.dim * esize) as u64, &mut bytes)?;
            match self.elem {
                BinElem::F32 => {
                    for (v, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                        *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                    }
                }
                BinElem::F64 => {
                    for (v, b) in out.iter_mut().zip(bytes.chunks_exact(8)) {
                        let d = f64::from_le_bytes([
                            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                        ]);
                        *v = d as f32;
                    }
                }
            }
            Ok(())
        })
    }
}

// ---------------------------------------------------------------------------
// npy header parsing
// ---------------------------------------------------------------------------

struct NpyHeader {
    rows: usize,
    dim: usize,
    elem: BinElem,
    offset: u64,
}

fn npy_err(path: &Path, msg: impl std::fmt::Display) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("{}: {msg}", path.display()))
}

/// Parse a NumPy v1/v2 `.npy` header: magic `\x93NUMPY`, version, header
/// length (u16 LE for v1, u32 LE for v2), then the ASCII dict
/// `{'descr': '<f4', 'fortran_order': False, 'shape': (n, d), }`.
fn parse_npy_header(path: &Path, file: &File) -> io::Result<NpyHeader> {
    use std::io::Read;
    let mut f = file;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).map_err(|_| npy_err(path, "file too short for an npy magic"))?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(npy_err(path, "not an npy file (bad magic)"));
    }
    let major = magic[6];
    let (hlen, data_from) = match major {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            (u16::from_le_bytes(b) as usize, 10usize)
        }
        2 | 3 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            (u32::from_le_bytes(b) as usize, 12usize)
        }
        v => return Err(npy_err(path, format!("unsupported npy major version {v}"))),
    };
    let mut hdr = vec![0u8; hlen];
    f.read_exact(&mut hdr).map_err(|_| npy_err(path, "truncated npy header"))?;
    // header dicts are ASCII (latin-1 by spec; keys/values we read are
    // plain ASCII in practice)
    let hdr = String::from_utf8_lossy(&hdr);

    let descr = npy_field(&hdr, "descr").ok_or_else(|| npy_err(path, "npy header has no 'descr'"))?;
    let elem = match descr.trim_matches(|c| c == '\'' || c == '"') {
        "<f4" => BinElem::F32,
        "<f8" => BinElem::F64,
        other => {
            return Err(npy_err(
                path,
                format!("unsupported npy dtype {other:?} (supported: <f4, <f8)"),
            ))
        }
    };
    let fortran =
        npy_field(&hdr, "fortran_order").ok_or_else(|| npy_err(path, "npy header has no 'fortran_order'"))?;
    if fortran.trim() != "False" {
        return Err(npy_err(path, "fortran_order npy files are not supported (need C order)"));
    }
    let shape =
        npy_field(&hdr, "shape").ok_or_else(|| npy_err(path, "npy header has no 'shape'"))?;
    let dims = parse_npy_shape(shape).ok_or_else(|| npy_err(path, format!("bad npy shape {shape:?}")))?;
    let (rows, dim) = match dims.as_slice() {
        [n] => (*n, 1usize),
        [n, d] => (*n, *d),
        other => {
            return Err(npy_err(
                path,
                format!("npy shape has {} axes (need 1 or 2 for point rows)", other.len()),
            ))
        }
    };
    if dim == 0 || rows == 0 {
        return Err(npy_err(path, "npy shape has a zero axis"));
    }
    Ok(NpyHeader { rows, dim, elem, offset: (data_from + hlen) as u64 })
}

/// Value substring of `'key': value` inside an npy header dict — up to
/// the comma that closes the entry (tuple commas are kept by matching
/// parens).
fn npy_field<'a>(hdr: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("'{key}':");
    let at = hdr.find(&pat)? + pat.len();
    let rest = &hdr[at..];
    let mut depth = 0i32;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth -= 1,
            ',' | '}' if depth <= 0 => return Some(rest[..i].trim()),
            _ => {}
        }
    }
    Some(rest.trim_end_matches('}').trim())
}

/// Parse `(n,)` / `(n, d)` into its axes.
fn parse_npy_shape(s: &str) -> Option<Vec<usize>> {
    let inner = s.trim().strip_prefix('(')?.strip_suffix(')')?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue; // the trailing comma of a 1-tuple
        }
        out.push(part.parse().ok()?);
    }
    Some(out)
}

/// Stream `src` into the raw little-endian f32 `.bin` format
/// [`BinFileSource::open`] reads, one `chunk_rows`-sized tile at a time —
/// the workhorse of `hiref convert`.  Returns the number of rows written.
/// Both read and write failures stop the conversion immediately (a doomed
/// run must not keep streaming a beyond-RAM source).
pub fn convert_to_bin(
    src: &dyn DatasetSource,
    out_path: impl AsRef<Path>,
    chunk_rows: usize,
    arena: &ScratchArena,
) -> io::Result<usize> {
    let mut w = io::BufWriter::new(File::create(out_path.as_ref())?);
    let n = src.rows();
    let d = src.dim();
    let mut written = 0usize;
    if n > 0 {
        let chunk = chunk_rows.max(1).min(n);
        // one staged write per tile, not one per element — at beyond-RAM
        // scales the per-call overhead of element-wise writes dominates
        let mut staging: Vec<u8> = Vec::new();
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let mut tile;
            let view = match src.view_rows(start, end) {
                Some(v) => v,
                None => {
                    tile = arena.take_f32((end - start) * d);
                    src.fill_rows(start, &mut tile)?;
                    MatView::from_slice(end - start, d, &tile)
                }
            };
            staging.clear();
            staging.reserve(view.data.len() * 4);
            for &v in view.data {
                staging.extend_from_slice(&v.to_le_bytes());
            }
            w.write_all(&staging)?;
            written += view.rows;
            start = end;
        }
    }
    w.into_inner()?.sync_all().ok();
    // row sanity check: a short generator or a lying header would
    // otherwise silently truncate the dataset
    if written != src.rows() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("wrote {written} rows but the source reports {}", src.rows()),
        ));
    }
    Ok(written)
}

/// Write a matrix (or any view) as little-endian `f32` rows — the format
/// [`BinFileSource`] reads.
pub fn write_bin<'a>(path: impl AsRef<Path>, m: impl Into<MatView<'a>>) -> io::Result<()> {
    let m = m.into();
    let mut f = io::BufWriter::new(File::create(path)?);
    for &v in m.data {
        f.write_all(&v.to_le_bytes())?;
    }
    f.into_inner()?.sync_all().ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_mat(seed: u64, n: usize, d: usize) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    /// Collect a source's content through the chunked driver.
    fn drain(src: &dyn DatasetSource, chunk_rows: usize) -> Vec<f32> {
        let arena = ScratchArena::new(1);
        let mut out = vec![0.0f32; src.rows() * src.dim()];
        for_each_chunk(src, chunk_rows, &arena, |start, tile| {
            let d = tile.cols;
            out[start * d..start * d + tile.data.len()].copy_from_slice(tile.data);
        })
        .unwrap();
        out
    }

    /// A source that errors once reads reach row `fail_at` — the
    /// mid-solve I/O failure the fallible contract exists for.
    struct FailingSource {
        rows: usize,
        dim: usize,
        fail_at: usize,
    }

    impl DatasetSource for FailingSource {
        fn rows(&self) -> usize {
            self.rows
        }

        fn dim(&self) -> usize {
            self.dim
        }

        fn fill_rows(&self, start: usize, out: &mut [f32]) -> io::Result<()> {
            if start + out.len() / self.dim > self.fail_at {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "device vanished"));
            }
            out.fill(start as f32);
            Ok(())
        }
    }

    #[test]
    fn in_memory_source_round_trips_at_any_chunk_size() {
        let m = rand_mat(0, 37, 3);
        let src = InMemorySource::new(&m);
        assert_eq!((src.rows(), src.dim()), (37, 3));
        for chunk in [1usize, 2, 7, 36, 37, 1000] {
            assert_eq!(drain(&src, chunk), m.data, "chunk {chunk}");
        }
        // zero-copy window
        let v = src.view_rows(5, 9).unwrap();
        assert_eq!(v.data, &m.data[15..27]);
        // scattered fetch
        let mut row = [0.0f32; 3];
        src.fetch_row(11, &mut row).unwrap();
        assert_eq!(&row, m.row(11));
    }

    #[test]
    fn generator_source_is_deterministic_and_chunk_invariant() {
        let gen = |i: usize, out: &mut [f32]| {
            let mut rng = Rng::new(42 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            rng.fill_normal(out);
        };
        let src = GeneratorSource::new(50, 4, gen);
        let a = drain(&src, 50);
        let b = drain(&src, 7);
        let c = drain(&src, 1);
        assert_eq!(a, b);
        assert_eq!(a, c);
        // per-row random access agrees with bulk fill
        let mut row = [0.0f32; 4];
        src.fetch_row(23, &mut row).unwrap();
        assert_eq!(&row, &a[23 * 4..24 * 4]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn bin_file_source_round_trips() {
        let m = rand_mat(7, 29, 5);
        let path = std::env::temp_dir()
            .join(format!("hiref_stream_test_{}.bin", std::process::id()));
        write_bin(&path, &m).unwrap();
        let src = BinFileSource::open(&path, 5).unwrap();
        assert_eq!((src.rows(), src.dim()), (29, 5));
        for chunk in [1usize, 4, 29, 64] {
            assert_eq!(drain(&src, chunk), m.data, "chunk {chunk}");
        }
        let mut row = [0.0f32; 5];
        src.fetch_row(17, &mut row).unwrap();
        assert_eq!(&row, m.row(17));
        // a file truncated AFTER open surfaces a typed read error, not a
        // panic (the fallible mid-solve contract); the surviving prefix
        // still reads fine
        std::fs::write(&path, &m.data[..5].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<_>>())
            .unwrap(); // one row survives
        let mut tile = vec![0.0f32; 2 * 5];
        assert!(src.fill_rows(3, &mut tile).is_err());
        assert!(src.fill_rows(0, &mut row).is_ok());
        // truncated file (not a whole number of rows) is rejected at open
        std::fs::write(&path, [0u8; 7]).unwrap();
        assert!(BinFileSource::open(&path, 5).is_err());
        let _ = std::fs::remove_file(&path);
    }

    /// Write a minimal `.npy` file by hand (v1 or v2 header).
    fn write_npy(path: &Path, descr: &str, fortran: bool, shape: &str, payload: &[u8], v2: bool) {
        let dict = format!(
            "{{'descr': '{descr}', 'fortran_order': {}, 'shape': {shape}, }}",
            if fortran { "True" } else { "False" }
        );
        // pad the header so data starts 64-byte aligned, as numpy does
        let pre = if v2 { 12 } else { 10 };
        let pad = (64 - (pre + dict.len() + 1) % 64) % 64;
        let header = format!("{dict}{}\n", " ".repeat(pad));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"\x93NUMPY");
        if v2 {
            bytes.extend_from_slice(&[2, 0]);
            bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        } else {
            bytes.extend_from_slice(&[1, 0]);
            bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        }
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(payload);
        std::fs::write(path, bytes).unwrap();
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hiref_npy_{}_{name}", std::process::id()))
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn npy_f32_v1_round_trips() {
        let m = rand_mat(21, 13, 3);
        let payload: Vec<u8> = m.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let path = tmp("f32v1.npy");
        write_npy(&path, "<f4", false, "(13, 3)", &payload, false);
        let src = BinFileSource::open_npy(&path).unwrap();
        assert_eq!((src.rows(), src.dim()), (13, 3));
        for chunk in [1usize, 5, 13] {
            assert_eq!(drain(&src, chunk), m.data, "chunk {chunk}");
        }
        let mut row = [0.0f32; 3];
        src.fetch_row(7, &mut row).unwrap();
        assert_eq!(&row, m.row(7));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn npy_f64_v2_narrows_to_f32() {
        let m = rand_mat(22, 9, 2);
        let payload: Vec<u8> =
            m.data.iter().flat_map(|&v| (v as f64).to_le_bytes()).collect();
        let path = tmp("f64v2.npy");
        write_npy(&path, "<f8", false, "(9, 2)", &payload, true);
        let src = BinFileSource::open_npy(&path).unwrap();
        assert_eq!((src.rows(), src.dim()), (9, 2));
        // f32 → f64 → f32 is exact, so the round trip is bitwise
        assert_eq!(drain(&src, 4), m.data);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn npy_one_dimensional_shape_reads_as_dim_1() {
        let vals = [1.5f32, -2.0, 3.25];
        let payload: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let path = tmp("1d.npy");
        write_npy(&path, "<f4", false, "(3,)", &payload, false);
        let src = BinFileSource::open_npy(&path).unwrap();
        assert_eq!((src.rows(), src.dim()), (3, 1));
        assert_eq!(drain(&src, 2), vals);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn npy_rejects_fortran_wrong_dtype_and_bad_lengths() {
        let payload = [0u8; 24];
        let path = tmp("bad.npy");
        write_npy(&path, "<f4", true, "(2, 3)", &payload, false);
        assert!(BinFileSource::open_npy(&path).is_err(), "fortran order must be rejected");
        write_npy(&path, "<i4", false, "(2, 3)", &payload, false);
        assert!(BinFileSource::open_npy(&path).is_err(), "non-float dtype must be rejected");
        // header promises more data than the payload holds
        write_npy(&path, "<f4", false, "(2, 4)", &payload, false);
        assert!(BinFileSource::open_npy(&path).is_err(), "short payload must be rejected");
        // not an npy file at all
        std::fs::write(&path, b"PK\x03\x04 definitely a zip").unwrap();
        assert!(BinFileSource::open_npy(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn convert_to_bin_round_trips_npy() {
        let m = rand_mat(23, 17, 4);
        let payload: Vec<u8> = m.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let npy = tmp("conv.npy");
        let bin = tmp("conv.bin");
        write_npy(&npy, "<f4", false, "(17, 4)", &payload, false);
        let src = BinFileSource::open_npy(&npy).unwrap();
        let arena = ScratchArena::new(1);
        let written = convert_to_bin(&src, &bin, 5, &arena).unwrap();
        assert_eq!(written, 17);
        let out = BinFileSource::open(&bin, 4).unwrap();
        assert_eq!((out.rows(), out.dim()), (17, 4));
        assert_eq!(drain(&out, 17), m.data);
        let _ = std::fs::remove_file(&npy);
        let _ = std::fs::remove_file(&bin);
    }

    #[test]
    fn gather_rows_into_matches_gather_rows() {
        let m = rand_mat(3, 20, 2);
        let src = InMemorySource::new(&m);
        let ids = [19u32, 0, 7, 7, 3];
        let mut got = vec![0.0f32; ids.len() * 2];
        gather_rows_into(&src, &ids, &mut got).unwrap();
        assert_eq!(got, m.gather_rows(&ids).data);
    }

    #[test]
    fn chunk_driver_handles_empty_source() {
        let m = Mat::zeros(0, 3);
        let src = InMemorySource::new(&m);
        let arena = ScratchArena::new(1);
        let mut calls = 0;
        for_each_chunk(&src, 8, &arena, |_, _| calls += 1).unwrap();
        assert_eq!(calls, 0);
    }

    #[test]
    fn chunk_drivers_propagate_read_errors() {
        let src = FailingSource { rows: 40, dim: 2, fail_at: 20 };
        let arena = ScratchArena::new(2);
        // serial driver: tiles before the failure are delivered, then the
        // error surfaces instead of a panic
        let mut seen = 0usize;
        let err = for_each_chunk(&src, 8, &arena, |_, tile| seen += tile.rows).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        assert_eq!(seen, 16, "tiles before the failure still stream");
        // parallel driver: every worker stops, first error returned
        let err =
            for_each_chunk_parallel(&src, 8, &arena, 4, |_, _| {}).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // gather: scattered fetch past the failure point errors too
        let mut out = vec![0.0f32; 4];
        assert!(gather_rows_into(&src, &[1, 39], &mut out).is_err());
        assert!(gather_rows_into(&src, &[1, 2], &mut out).is_ok());
    }

    #[test]
    fn parallel_chunk_driver_matches_serial_for_any_thread_count() {
        use std::sync::Mutex;
        let m = rand_mat(11, 53, 3);
        let src = InMemorySource::new(&m);
        let arena = ScratchArena::new(4);
        for threads in [1usize, 2, 8] {
            let out = Mutex::new(vec![0.0f32; 53 * 3]);
            for_each_chunk_parallel(&src, 7, &arena, threads, |start, tile| {
                let d = tile.cols;
                out.lock().unwrap()[start * d..start * d + tile.data.len()]
                    .copy_from_slice(tile.data);
            })
            .unwrap();
            assert_eq!(out.into_inner().unwrap(), m.data, "threads {threads}");
        }
        // a generator (fill_rows) source takes the per-worker tile path
        let gen = GeneratorSource::new(29, 2, |i, out| out.fill(i as f32));
        let want = drain(&gen, 5);
        let got = Mutex::new(vec![0.0f32; 29 * 2]);
        for_each_chunk_parallel(&gen, 5, &arena, 3, |start, tile| {
            got.lock().unwrap()[start * 2..start * 2 + tile.data.len()]
                .copy_from_slice(tile.data);
        })
        .unwrap();
        assert_eq!(got.into_inner().unwrap(), want);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn content_hash_is_chunk_invariant_and_location_independent() {
        let arena = ScratchArena::new(1);
        let m = rand_mat(3, 41, 5);
        let src = InMemorySource::new(&m);
        let h = content_hash(&src, 41, &arena).unwrap();
        for chunk in [1usize, 2, 7, 40, 41, 1000] {
            assert_eq!(content_hash(&src, chunk, &arena).unwrap(), h, "chunk {chunk}");
        }
        // the converted .bin file hashes identically to the in-memory data
        let path =
            std::env::temp_dir().join(format!("hiref_hash_{}.bin", std::process::id()));
        write_bin(&path, &m).unwrap();
        let file = BinFileSource::open(&path, 5).unwrap();
        assert_eq!(content_hash(&file, 7, &arena).unwrap(), h);
        assert_eq!(content_hash_hex(&file, 7, &arena).unwrap(), format!("{h:016x}"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn content_hash_separates_content_and_shape() {
        let arena = ScratchArena::new(1);
        let a = rand_mat(1, 32, 4);
        let mut b = a.clone();
        b.data[17] += 1.0; // one-element perturbation
        let ha = content_hash(&InMemorySource::new(&a), 8, &arena).unwrap();
        let hb = content_hash(&InMemorySource::new(&b), 8, &arena).unwrap();
        assert_ne!(ha, hb);
        // same bytes, different shape: the (rows, dim) prefix must split them
        let wide = Mat::from_vec(16, 8, a.data.clone());
        let hw = content_hash(&InMemorySource::new(&wide), 8, &arena).unwrap();
        assert_ne!(ha, hw);
    }

    #[test]
    fn content_hash_surfaces_read_errors() {
        let arena = ScratchArena::new(1);
        let src = FailingSource { rows: 64, dim: 2, fail_at: 16 };
        assert!(content_hash(&src, 8, &arena).is_err());
    }
}
