//! 2-D synthetic benchmark datasets from the paper's §4.1.
//!
//! Each generator returns a pair `(X, Y)` of equal-sized point clouds,
//! reproducing the constructions described in Appendix D.1:
//!
//! * **Checkerboard** (Makkuva et al. 2020) — source on 5 diagonal cells,
//!   target on the 4 anti-diagonal cells.
//! * **MAF Moons & Rings** (Buzun et al. 2024) — crescent via a quadratic
//!   warp of a Gaussian vs four noisy concentric rings.
//! * **Half-moon & S-curve** (Buzun et al. 2024) — scikit-learn style
//!   `make_moons` / `make_s_curve` projections with a rotation + scale +
//!   translation applied.

use crate::linalg::Mat;
use crate::prng::Rng;

/// Checkerboard dataset (Makkuva et al. 2020): returns `(X, Y)`, each
/// `n×2`.  Source cells on the diagonal pattern, target on the off cells.
pub fn checkerboard(n: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed ^ 0xC4EC);
    let src_centers: [(f64, f64); 5] =
        [(0.0, 0.0), (1.0, 1.0), (1.0, -1.0), (-1.0, 1.0), (-1.0, -1.0)];
    let tgt_centers: [(f64, f64); 4] = [(0.0, 1.0), (0.0, -1.0), (1.0, 0.0), (-1.0, 0.0)];
    let mut x = Mat::zeros(n, 2);
    let mut y = Mat::zeros(n, 2);
    for i in 0..n {
        let (cx, cy) = src_centers[rng.next_below(5)];
        x.row_mut(i)[0] = (cx + rng.uniform(-0.5, 0.5)) as f32;
        x.row_mut(i)[1] = (cy + rng.uniform(-0.5, 0.5)) as f32;
        let (cx, cy) = tgt_centers[rng.next_below(4)];
        y.row_mut(i)[0] = (cx + rng.uniform(-0.5, 0.5)) as f32;
        y.row_mut(i)[1] = (cy + rng.uniform(-0.5, 0.5)) as f32;
    }
    (x, y)
}

/// MAF Moons (crescent) & Rings (Buzun et al. 2024): `(X, Y)`, each `n×2`.
pub fn maf_moons_rings(n: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed ^ 0x3A_F00);
    let mut x = Mat::zeros(n, 2);
    let mut y = Mat::zeros(n, 2);
    for i in 0..n {
        // crescent: y1 = 0.5*(x1 + x2^2) - 5, y2 = x2 over N(0, I)
        let g1 = rng.normal();
        let g2 = rng.normal();
        x.row_mut(i)[0] = (0.5 * (g1 + g2 * g2) - 5.0) as f32;
        x.row_mut(i)[1] = g2 as f32;
        // rings: radius in {0.25, 0.55, 0.9, 1.2} * 3, angle uniform
        const RADII: [f64; 4] = [0.25, 0.55, 0.9, 1.2];
        let r = RADII[rng.next_below(4)];
        let th = rng.uniform(0.0, std::f64::consts::TAU);
        let sigma = 0.08;
        y.row_mut(i)[0] = (3.0 * r * th.cos() + sigma * rng.normal()) as f32;
        y.row_mut(i)[1] = (3.0 * r * th.sin() + sigma * rng.normal()) as f32;
    }
    (x, y)
}

/// Half-moon & S-curve (Buzun et al. 2024): `(X, Y)`, each `n×2`.
/// The S-curve is the classic 3-D `make_s_curve` projected to (x, z); both
/// clouds then get a rotation, scaling and translation as in the paper.
pub fn half_moon_s_curve(n: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed ^ 0x5C0_2E);
    let mut x = Mat::zeros(n, 2);
    let mut y = Mat::zeros(n, 2);
    let noise = 0.05;
    for i in 0..n {
        // two interleaved half moons (make_moons)
        let upper = rng.next_below(2) == 0;
        let t = rng.uniform(0.0, std::f64::consts::PI);
        let (mx, my) = if upper {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        x.row_mut(i)[0] = (mx + noise * rng.normal()) as f32;
        x.row_mut(i)[1] = (my + noise * rng.normal()) as f32;
        // S-curve: t in [-3π/2, 3π/2); (sin t, sign(t)(cos t − 1))
        let t = rng.uniform(-1.5 * std::f64::consts::PI, 1.5 * std::f64::consts::PI);
        let sx = t.sin();
        let sz = t.signum() * (t.cos() - 1.0);
        y.row_mut(i)[0] = (sx + noise * rng.normal()) as f32;
        y.row_mut(i)[1] = (sz + noise * rng.normal()) as f32;
    }
    // rotation + scaling + translation applied to the target (paper D.1)
    let theta = 0.5f64;
    let (c, s) = (theta.cos() as f32, theta.sin() as f32);
    let lambda = 1.5f32;
    let (tx, ty) = (1.0f32, -0.5f32);
    for i in 0..n {
        let r = y.row_mut(i);
        let (a, b) = (r[0] * lambda, r[1] * lambda);
        r[0] = c * a - s * b + tx;
        r[1] = s * a + c * b + ty;
    }
    (x, y)
}

// ---------------------------------------------------------------------------
// Streaming (per-row) generators
// ---------------------------------------------------------------------------

/// Per-row RNG for the streaming generators: seeded from a hash of
/// `(seed, tag, i)`, so any row can be produced independently — the
/// property [`crate::data::stream::GeneratorSource`] needs for chunked,
/// random-access generation (the in-memory generators above share one
/// sequential stream and therefore cannot be windowed).
fn row_rng(seed: u64, tag: u64, i: usize) -> Rng {
    let mut state = (seed ^ tag).wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    Rng::new(crate::prng::splitmix64(&mut state))
}

/// Streaming twin of the half-moon side of [`half_moon_s_curve`]: write
/// point `i` of the source cloud (same distribution, independently seeded
/// per row).
pub fn half_moon_row(seed: u64, i: usize, out: &mut [f32]) {
    let mut rng = row_rng(seed, 0x5C0_2E ^ 0xA1A1, i);
    let noise = 0.05;
    let upper = rng.next_below(2) == 0;
    let t = rng.uniform(0.0, std::f64::consts::PI);
    let (mx, my) = if upper {
        (t.cos(), t.sin())
    } else {
        (1.0 - t.cos(), 0.5 - t.sin())
    };
    out[0] = (mx + noise * rng.normal()) as f32;
    out[1] = (my + noise * rng.normal()) as f32;
}

/// Streaming twin of the S-curve side of [`half_moon_s_curve`], including
/// the paper's rotation + scaling + translation (Appendix D.1).
pub fn s_curve_row(seed: u64, i: usize, out: &mut [f32]) {
    let mut rng = row_rng(seed, 0x5C0_2E ^ 0xB2B2, i);
    let noise = 0.05;
    let t = rng.uniform(-1.5 * std::f64::consts::PI, 1.5 * std::f64::consts::PI);
    let sx = t.sin();
    let sz = t.signum() * (t.cos() - 1.0);
    let a = (sx + noise * rng.normal()) as f32;
    let b = (sz + noise * rng.normal()) as f32;
    let theta = 0.5f64;
    let (c, s) = (theta.cos() as f32, theta.sin() as f32);
    let lambda = 1.5f32;
    let (tx, ty) = (1.0f32, -0.5f32);
    let (a, b) = (a * lambda, b * lambda);
    out[0] = c * a - s * b + tx;
    out[1] = s * a + c * b + ty;
}

/// The Half-Moon & S-Curve benchmark as a pair of streaming
/// [`crate::data::stream::GeneratorSource`]s: points are generated on
/// demand per row, so the clouds never exist in memory — the ingestion
/// path for `n = 2^20` and beyond (`examples/million_points.rs`).
pub fn half_moon_s_curve_sources(
    n: usize,
    seed: u64,
) -> (
    crate::data::stream::GeneratorSource,
    crate::data::stream::GeneratorSource,
) {
    use crate::data::stream::GeneratorSource;
    (
        GeneratorSource::new(n, 2, move |i, out| half_moon_row(seed, i, out)),
        GeneratorSource::new(n, 2, move |i, out| s_curve_row(seed, i, out)),
    )
}

/// Dataset selector used by the CLI and the benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Synthetic {
    Checkerboard,
    MafMoonsRings,
    HalfMoonSCurve,
}

impl Synthetic {
    pub const ALL: [Synthetic; 3] =
        [Synthetic::Checkerboard, Synthetic::MafMoonsRings, Synthetic::HalfMoonSCurve];

    pub fn generate(&self, n: usize, seed: u64) -> (Mat, Mat) {
        match self {
            Synthetic::Checkerboard => checkerboard(n, seed),
            Synthetic::MafMoonsRings => maf_moons_rings(n, seed),
            Synthetic::HalfMoonSCurve => half_moon_s_curve(n, seed),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Synthetic::Checkerboard => "Checkerboard",
            Synthetic::MafMoonsRings => "MAF Moons & Rings",
            Synthetic::HalfMoonSCurve => "Half Moon & S-Curve",
        }
    }

    pub fn parse(s: &str) -> Option<Synthetic> {
        match s.to_ascii_lowercase().as_str() {
            "checkerboard" | "checker" => Some(Synthetic::Checkerboard),
            "moons-rings" | "maf" => Some(Synthetic::MafMoonsRings),
            "halfmoon-scurve" | "halfmoon" => Some(Synthetic::HalfMoonSCurve),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        for ds in Synthetic::ALL {
            let (x1, y1) = ds.generate(256, 7);
            let (x2, y2) = ds.generate(256, 7);
            assert_eq!((x1.rows, x1.cols), (256, 2));
            assert_eq!((y1.rows, y1.cols), (256, 2));
            assert_eq!(x1.data, x2.data);
            assert_eq!(y1.data, y2.data);
            let (x3, _) = ds.generate(256, 8);
            assert_ne!(x1.data, x3.data);
        }
    }

    #[test]
    fn checkerboard_supports() {
        let (x, y) = checkerboard(2000, 0);
        for i in 0..x.rows {
            // every source point within 1.5 of origin in sup norm
            assert!(x.row(i)[0].abs() <= 1.5 + 1e-5);
            assert!(x.row(i)[1].abs() <= 1.5 + 1e-5);
            // target cells exclude the center cell: max coordinate ≥ 0.5
            let r = y.row(i);
            assert!(r[0].abs().max(r[1].abs()) >= 0.5 - 1e-5);
        }
    }

    #[test]
    fn rings_have_bounded_radius() {
        let (_, y) = maf_moons_rings(2000, 1);
        for i in 0..y.rows {
            let r = (y.row(i)[0].powi(2) + y.row(i)[1].powi(2)).sqrt();
            assert!(r < 3.0 * 1.2 + 1.0, "radius {r}");
            assert!(r > 3.0 * 0.25 - 1.0, "radius {r}");
        }
    }

    #[test]
    fn streaming_generators_match_in_memory_distribution_envelope() {
        use crate::data::stream::DatasetSource;
        let (xs, ys) = half_moon_s_curve_sources(500, 3);
        assert_eq!((xs.rows(), xs.dim(), ys.rows(), ys.dim()), (500, 2, 500, 2));
        let mut xbuf = vec![0.0f32; 500 * 2];
        let mut ybuf = vec![0.0f32; 500 * 2];
        xs.fill_rows(0, &mut xbuf).unwrap();
        ys.fill_rows(0, &mut ybuf).unwrap();
        assert!(xbuf.iter().chain(&ybuf).all(|v| v.is_finite()));
        // half-moon source stays in its known bounding box
        for row in xbuf.chunks(2) {
            assert!(row[0].abs() < 2.5 && row[1].abs() < 2.5, "{row:?}");
        }
        // transformed s-curve has the scaled spread of the in-memory twin
        let span = ybuf.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(span > 2.0, "span {span}");
        // per-row random access agrees with bulk fill (chunk invariance)
        let mut row = [0.0f32; 2];
        xs.fetch_row(123, &mut row).unwrap();
        assert_eq!(&row, &xbuf[246..248]);
        // deterministic across re-creation
        let (xs2, _) = half_moon_s_curve_sources(500, 3);
        let mut xbuf2 = vec![0.0f32; 500 * 2];
        xs2.fill_rows(0, &mut xbuf2).unwrap();
        assert_eq!(xbuf, xbuf2);
    }

    #[test]
    fn halfmoon_is_finite_and_spread() {
        let (x, y) = half_moon_s_curve(1000, 2);
        assert!(x.data.iter().all(|v| v.is_finite()));
        assert!(y.data.iter().all(|v| v.is_finite()));
        // target was scaled by 1.5 => larger spread than raw s-curve
        let span = y.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(span > 2.0);
    }
}
