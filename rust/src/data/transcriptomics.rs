//! Simulated spatial-transcriptomics substrates.
//!
//! The paper's §4.2 uses the MOSTA Stereo-seq mouse-embryo atlas (8 stages,
//! 5.9k→122k cells, 60-dim PCA of expression) and §4.3 uses two MERFISH
//! brain slices (~84k spots, 5 spatially-varying genes).  Both datasets are
//! proprietary-download resources; per the substitution rule we generate
//! synthetic equivalents that exercise identical code paths:
//!
//! * a *stage sequence* of growing anisotropic Gaussian-mixture "tissues"
//!   whose component centres drift smoothly between consecutive stages —
//!   consecutive-pair alignment in 60-dim feature space, growing `n`;
//! * a *slice pair*: the same mixture "anatomy" sampled twice with jitter
//!   and an affine misregistration, plus smooth synthetic spatial gene
//!   fields used for the expression-transfer benchmark (cosine similarity
//!   after 200µm-style binning, exactly as in Clifton et al. 2023).

use crate::linalg::Mat;
use crate::prng::Rng;

/// Number of mixture components in the simulated tissue.
const TISSUE_COMPONENTS: usize = 12;

/// Paper stage sizes (E9.5 … E16.5).  `scale_down` divides them for
/// CI-class runs (the benches use 10 by default, 1 under HIREF_FULL=1).
pub const MOSTA_SIZES: [usize; 8] =
    [5913, 18408, 30124, 51365, 77369, 102519, 113350, 121767];

/// Stage labels as in the paper's tables.
pub const MOSTA_LABELS: [&str; 8] =
    ["E9.5", "E10.5", "E11.5", "E12.5", "E13.5", "E14.5", "E15.5", "E16.5"];

/// A simulated tissue "anatomy": mixture component centres in feature
/// space + spatial plane, with per-component anisotropy.
struct Anatomy {
    centers_feat: Mat,  // components × d_feat
    centers_sp: Mat,    // components × 2
    scales: Vec<f32>,
}

impl Anatomy {
    fn new(rng: &mut Rng, d_feat: usize) -> Anatomy {
        let mut centers_feat = Mat::zeros(TISSUE_COMPONENTS, d_feat);
        rng.fill_normal(&mut centers_feat.data);
        for v in centers_feat.data.iter_mut() {
            *v *= 3.0;
        }
        let mut centers_sp = Mat::zeros(TISSUE_COMPONENTS, 2);
        for i in 0..TISSUE_COMPONENTS {
            let th = std::f64::consts::TAU * i as f64 / TISSUE_COMPONENTS as f64;
            let rad = 4.0 + 2.0 * rng.next_f64();
            centers_sp.row_mut(i)[0] = (rad * th.cos()) as f32;
            centers_sp.row_mut(i)[1] = (rad * th.sin()) as f32;
        }
        let scales = (0..TISSUE_COMPONENTS).map(|_| 0.5 + rng.next_f32()).collect();
        Anatomy { centers_feat, centers_sp, scales }
    }

    /// Drift component centres smoothly (consecutive embryo stages share
    /// anatomy up to growth + drift — this is what makes a low-cost map
    /// between consecutive stages exist, as in the real atlas).
    fn drift(&mut self, rng: &mut Rng, amount: f32) {
        for v in self.centers_feat.data.iter_mut() {
            *v += amount * rng.normal_f32();
        }
        for v in self.centers_sp.data.iter_mut() {
            *v += 0.3 * amount * rng.normal_f32();
        }
    }

    /// Sample a slice of `n` cells: returns (features n×d_feat, spatial n×2).
    fn sample(&self, rng: &mut Rng, n: usize) -> (Mat, Mat) {
        let d = self.centers_feat.cols;
        let mut feat = Mat::zeros(n, d);
        let mut sp = Mat::zeros(n, 2);
        for i in 0..n {
            let c = rng.next_below(TISSUE_COMPONENTS);
            let s = self.scales[c];
            let fc = self.centers_feat.row(c);
            let frow = feat.row_mut(i);
            for (o, &m) in frow.iter_mut().zip(fc) {
                *o = m + s * rng.normal_f32();
            }
            let sc = self.centers_sp.row(c);
            let srow = sp.row_mut(i);
            srow[0] = sc[0] + 0.8 * s * rng.normal_f32();
            srow[1] = sc[1] + 0.8 * s * rng.normal_f32();
        }
        (feat, sp)
    }
}

/// One simulated developmental stage.
pub struct Stage {
    pub label: &'static str,
    /// `n × 60` PCA-like expression features.
    pub features: Mat,
    /// `n × 2` spatial coordinates.
    pub spatial: Mat,
}

/// Generate the 8-stage simulated MOSTA sequence.  `scale_down ≥ 1`
/// divides the paper's per-stage sizes.  Deterministic in `seed`.
pub fn mosta_stages(scale_down: usize, d_feat: usize, seed: u64) -> Vec<Stage> {
    let mut rng = Rng::new(seed ^ 0x0517A);
    let mut anatomy = Anatomy::new(&mut rng, d_feat);
    let mut out = Vec::with_capacity(8);
    for (idx, (&size, &label)) in MOSTA_SIZES.iter().zip(&MOSTA_LABELS).enumerate() {
        if idx > 0 {
            anatomy.drift(&mut rng, 0.4);
        }
        let n = (size / scale_down.max(1)).max(64);
        let (features, spatial) = anatomy.sample(&mut rng, n);
        out.push(Stage { label, features, spatial });
    }
    out
}

/// A simulated MERFISH-style slice: spatial coordinates plus raw counts
/// for `GENES` synthetic spatially-patterned genes.
pub struct Slice {
    /// `n × 2` registered spatial coordinates.
    pub spatial: Mat,
    /// `n × GENES` nonnegative expression counts.
    pub genes: Mat,
}

/// The five "spatially-patterned genes" of Table S7.
pub const GENE_LABELS: [&str; 5] = ["Slc17a7", "Grm4", "Olig1", "Gad1", "Peg10"];

/// Smooth synthetic spatial expression field g(s) for gene `gi` — mixtures
/// of bumps anchored on the anatomy, distinct per gene.
fn gene_field(gi: usize, s: &[f32], anatomy_sp: &Mat) -> f32 {
    let mut v = 0.0f64;
    let k = anatomy_sp.rows;
    for c in 0..k {
        // per-gene sparse loading over components
        if (c + gi) % 3 != 0 {
            continue;
        }
        let d2 = crate::linalg::sq_dist(s, anatomy_sp.row(c));
        let width = 2.0 + 0.7 * ((gi * 13 + c * 7) % 5) as f64;
        v += (8.0 + (gi as f64) * 2.0) * (-d2 / width).exp();
    }
    v as f32
}

/// Generate a pair of MERFISH-like slices (source, target): same anatomy
/// sampled twice with jitter, plus a small affine misregistration applied
/// to the source (the evaluation registers it away with a rotation, as the
/// paper does — we emit already-registered coordinates plus the residual
/// jitter so the alignment is non-trivial).
pub fn merfish_pair(n: usize, seed: u64) -> (Slice, Slice) {
    let mut rng = Rng::new(seed ^ 0xEF15);
    let anatomy = Anatomy::new(&mut rng, 8);
    let make = |rng: &mut Rng, jitter: f32| {
        let (_, mut sp) = anatomy.sample(rng, n);
        for v in sp.data.iter_mut() {
            *v += jitter * rng.normal_f32();
        }
        let mut genes = Mat::zeros(n, GENE_LABELS.len());
        for i in 0..n {
            let srow = [sp.at(i, 0), sp.at(i, 1)];
            for gi in 0..GENE_LABELS.len() {
                let lam = gene_field(gi, &srow, &anatomy.centers_sp) as f64;
                // Poisson-ish counts: Gaussian approx, clipped at 0
                let cnt = lam + lam.sqrt() * rng.normal();
                *genes.at_mut(i, gi) = cnt.max(0.0) as f32;
            }
        }
        Slice { spatial: sp, genes }
    };
    let source = make(&mut rng, 0.15);
    let target = make(&mut rng, 0.15);
    (source, target)
}

/// Spatially bin a per-spot scalar onto a `bins × bins` grid over the
/// slice's bounding box and average within bins (Clifton et al. 2023 use
/// 200µm windows ≈ 75×75 over a 15mm slice; the paper uses 5625 bins).
/// Returns the flat binned vector (NaN-free; empty bins are 0).
pub fn bin_average(spatial: &Mat, values: &[f32], bins: usize) -> Vec<f32> {
    assert_eq!(spatial.rows, values.len());
    let (mut xmin, mut xmax) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..spatial.rows {
        xmin = xmin.min(spatial.at(i, 0));
        xmax = xmax.max(spatial.at(i, 0));
        ymin = ymin.min(spatial.at(i, 1));
        ymax = ymax.max(spatial.at(i, 1));
    }
    let eps = 1e-6;
    let mut sums = vec![0.0f64; bins * bins];
    let mut counts = vec![0u32; bins * bins];
    for i in 0..spatial.rows {
        let bx = (((spatial.at(i, 0) - xmin) / (xmax - xmin + eps)) * bins as f32) as usize;
        let by = (((spatial.at(i, 1) - ymin) / (ymax - ymin + eps)) * bins as f32) as usize;
        let b = bx.min(bins - 1) * bins + by.min(bins - 1);
        sums[b] += values[i] as f64;
        counts[b] += 1;
    }
    sums.iter()
        .zip(&counts)
        .map(|(&s, &c)| if c > 0 { (s / c as f64) as f32 } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_grow_and_are_deterministic() {
        let stages = mosta_stages(50, 16, 3);
        assert_eq!(stages.len(), 8);
        for w in stages.windows(2) {
            assert!(w[1].features.rows >= w[0].features.rows);
        }
        let stages2 = mosta_stages(50, 16, 3);
        assert_eq!(stages[0].features.data, stages2[0].features.data);
        assert_eq!(stages[3].features.cols, 16);
        assert_eq!(stages[3].spatial.cols, 2);
    }

    #[test]
    fn consecutive_stages_are_closer_than_random() {
        // anatomy drift is small: mean NN-distance between consecutive
        // stages should be far below distance to an unrelated anatomy
        let stages = mosta_stages(100, 8, 1);
        let other = mosta_stages(100, 8, 999);
        let d_consec = mean_nn(&stages[0].features, &stages[1].features);
        let d_other = mean_nn(&stages[0].features, &other[1].features);
        assert!(d_consec < d_other, "{d_consec} vs {d_other}");
    }

    fn mean_nn(a: &Mat, b: &Mat) -> f64 {
        let mut tot = 0.0;
        for i in 0..a.rows.min(50) {
            let mut best = f64::INFINITY;
            for j in 0..b.rows {
                best = best.min(crate::linalg::sq_dist(a.row(i), b.row(j)));
            }
            tot += best.sqrt();
        }
        tot / a.rows.min(50) as f64
    }

    #[test]
    fn merfish_pair_has_correlated_genes() {
        let (s, t) = merfish_pair(800, 5);
        assert_eq!(s.genes.cols, 5);
        assert!(s.genes.data.iter().all(|&v| v >= 0.0));
        // same anatomy => binned gene-0 fields correlate across slices
        let vs = bin_average(&s.spatial, &(0..800).map(|i| s.genes.at(i, 0)).collect::<Vec<_>>(), 10);
        let vt = bin_average(&t.spatial, &(0..800).map(|i| t.genes.at(i, 0)).collect::<Vec<_>>(), 10);
        let cos = crate::metrics::cosine(&vs, &vt);
        assert!(cos > 0.7, "cross-slice field cosine {cos}");
    }

    #[test]
    fn bin_average_constant_field() {
        let mut sp = Mat::zeros(100, 2);
        let mut rng = Rng::new(0);
        rng.fill_normal(&mut sp.data);
        let vals = vec![2.5f32; 100];
        let binned = bin_average(&sp, &vals, 4);
        assert_eq!(binned.len(), 16);
        for v in binned {
            assert!(v == 0.0 || (v - 2.5).abs() < 1e-6);
        }
    }
}
