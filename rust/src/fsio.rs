//! Positioned file I/O shared by every file-backed data path
//! ([`crate::data::stream::BinFileSource`], [`crate::pool::SpillStore`]).
//!
//! On unix, reads and writes are positioned (`pread`/`pwrite`): no shared
//! cursor and no lock, so concurrent accesses from the worker pool never
//! serialise on the file.  Elsewhere a mutexed seek + read/write pair
//! provides the same interface.  One implementation, two consumers — the
//! platform-conditional code cannot drift between them.

#![forbid(unsafe_code)]

use std::fs::File;
use std::io;
#[cfg(not(unix))]
use std::sync::Mutex;

/// A file handle supporting concurrent offset-addressed reads and writes.
pub(crate) struct PositionedFile {
    #[cfg(unix)]
    file: File,
    #[cfg(not(unix))]
    file: Mutex<File>,
}

impl PositionedFile {
    pub(crate) fn new(file: File) -> PositionedFile {
        PositionedFile {
            #[cfg(unix)]
            file,
            #[cfg(not(unix))]
            file: Mutex::new(file),
        }
    }

    /// Read exactly `bytes.len()` bytes at absolute `offset`.
    #[cfg(unix)]
    pub(crate) fn read_at(&self, offset: u64, bytes: &mut [u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.read_exact_at(bytes, offset)
    }

    /// Write all of `bytes` at absolute `offset`.
    #[cfg(unix)]
    pub(crate) fn write_at(&self, offset: u64, bytes: &[u8]) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.write_all_at(bytes, offset)
    }

    #[cfg(not(unix))]
    pub(crate) fn read_at(&self, offset: u64, bytes: &mut [u8]) -> io::Result<()> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(bytes)
    }

    #[cfg(not(unix))]
    pub(crate) fn write_at(&self, offset: u64, bytes: &[u8]) -> io::Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs::OpenOptions;

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: needs real file I/O")]
    fn positioned_round_trip() {
        let path = std::env::temp_dir().join(format!("hiref_fsio_{}.bin", std::process::id()));
        let file =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(&path).unwrap();
        let pf = PositionedFile::new(file);
        pf.write_at(4, &[1, 2, 3, 4]).unwrap();
        pf.write_at(0, &[9, 9]).unwrap();
        let mut out = [0u8; 4];
        pf.read_at(4, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3, 4]);
        let mut two = [0u8; 2];
        pf.read_at(0, &mut two).unwrap();
        assert_eq!(two, [9, 9]);
        // reads past EOF error instead of panicking
        assert!(pf.read_at(6, &mut out).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
