//! The Hierarchical Refinement engine — paper Algorithm 1/2 — built on a
//! **zero-copy, contiguous-range data layout**.
//!
//! Starting from the trivial co-clustering `Γ_0 = {(X, Y)}`, each scale
//! splits every co-cluster `(X_q, Y_q)` with a rank-`r_{t+1}` LROT solve
//! whose factors co-cluster Monge pairs (Prop. 3.1); balanced assignment
//! ([`super::assign`]) turns the factors into `r_{t+1}` equal-sized child
//! pairs.  Blocks that reach the base size are sealed with an *exact*
//! assignment solver.  The output is a bijection — `n` nonzeros, never an
//! `n×n` matrix (paper §3.4).
//!
//! # Range-based layout (in-place recursive re-indexing)
//!
//! The engine owns one global **permutation array per side**
//! (`position → original point id`) and one working copy of the cost
//! factors per side, gathered exactly once at the start.  After each
//! level's balanced assignment, the worker **physically reorders** the
//! factor rows and permutation entries *within its block's range* so that
//! every child co-cluster becomes a contiguous `start..end` window.  A
//! [`Block`] therefore carries only two `Range<u32>`s and a level — no
//! per-block index vectors, no per-block factor-row copies:
//!
//! * LROT consumes `MatView` slices of the working factor buffers;
//! * balanced assignment reads the LROT factors in place;
//! * the base case writes the dense block cost into a scratch-arena
//!   buffer straight from the original points (`dense_cost_indexed_into`)
//!   and solves it as a `MatView`;
//! * `record_scales` snapshots are O(1) range pairs, materialised to
//!   index sets only once at the end of the run.
//!
//! Ranges at one scale exactly partition the parent range, so concurrent
//! workers always own pairwise-disjoint windows of the shared buffers
//! ([`RangeShared`]) — the same `(start, end)` idiom as hierarchical
//! community-detection codes, and exactly the layout the batched backend
//! exploits (same-size blocks at a level are one strided batch).
//!
//! # Level-synchronous batched execution (the default)
//!
//! Up to 2^ℓ blocks of *identical shape* exist at scale ℓ, and each block
//! is already a contiguous window of the shared factor buffers — so the
//! engine schedules **levels, not blocks**.  Per scale it:
//!
//! 1. partitions the level's blocks into base-case blocks and refinement
//!    blocks, and groups the refinement blocks by size (splits are
//!    ±1-balanced, so a level has at most two distinct sizes — the ragged
//!    remainder forms its own batch);
//! 2. runs **one batched LROT solve per group**
//!    ([`lrot::solve_factored_batch`], or [`PjrtEngine::lrot_batch`] when
//!    the backend fits): every block is a lane of one strided
//!    [`crate::linalg::BatchView`] over the factor working copies, the
//!    mirror-descent loop is shared across lanes, and per-lane
//!    convergence masks retire early-converged blocks;
//! 3. runs one batched balanced-assign / re-index pass (`parallel_map`
//!    over lanes; sibling ranges are disjoint, so the [`RangeShared`]
//!    writeback stays sound) to produce the next level's blocks; and
//! 4. seals the level's base-case blocks with one batched exact pass
//!    (`parallel_map` over their Hungarian/auction tiles).
//!
//! Per-block seeds stay anchored on each range's first original id, so the
//! batched path is **bit-identical** to the per-block path — which remains
//! selectable for A/B comparison via `HiRefConfig::batching = false`
//! (`HiRefBuilder::batching`), executing the classic condvar-parked
//! [`WorkQueue`] recursion.  Both paths share the split/seed/base-case
//! helpers and the 1-lane-equals-N-lane LROT core, so they cannot drift.
//!
//! # Spillable factor storage
//!
//! Factor ownership lives behind the [`FactorStore`] protocol: the
//! default [`ResidentStore`] is today's zero-cost behaviour (checkouts
//! are pointers into one shared buffer), while [`SpillStore`]
//! ([`HiRefConfig::spill`]) keeps the rows in a scratch file with a
//! bounded LRU shard cache.  The engine checks factor windows out **per
//! level batch**: `run_levels` pins exactly one batch group's lane
//! windows at a time (sub-capped by the spill budget — lane solves are
//! independent, so sub-batching preserves bit-identity), the
//! counting-sort re-index rewrites each lane in place, and the dirty
//! release writes the shards back.  A level batch is thus the unit of
//! storage — the natural shard unit for multi-node sharding later.
//! Spilled and resident runs are **bit-identical by construction**: same
//! rows, same views, same seeds.
//!
//! # Memory model
//!
//! Three bounded tiers: `O(chunk_rows·d)` streaming ingestion tiles (see
//! below) + factor working copies that are either fully resident
//! (`O(n·d)`) or spilled (`O(spill_budget)` cache + one in-flight level
//! batch's lane windows) + `O(n)` permutations and output + transient
//! scratch served by a [`ScratchArena`].  Scratch tracks **one in-flight
//! level, not one block**: at scale ℓ the batched LROT state (logits,
//! gradients, potentials) for all 2^ℓ lanes together is `O(n·r)` — the
//! same linear bound the per-block path reached at its peak, because
//! sibling blocks shrink geometrically while their count doubles.  The
//! base-case levels hold `O(threads · base_size²)` dense tiles.  Peak
//! bytes and freelist hit-rate are reported in [`RunStats`], along with
//! the batch shape counters (`batches`, `lanes_max`, `batched_frac`) and
//! the spill counters (`spill_bytes_written`, `spill_reads`,
//! `resident_factor_bytes`).  Nothing anywhere scales quadratically with
//! `n` — the paper's linear-space claim, enforced by construction.
//!
//! LROT batches are served either by the PJRT runtime (AOT artifacts from
//! the JAX/Pallas layers) or by the native Rust solver — dispatch is at
//! **batch granularity** (`BackendKind::Auto` falls back to native for
//! any batch whose shape has no artifact bucket).
//!
//! # Streaming ingestion
//!
//! Nothing above needs the raw point clouds except cost factorisation and
//! the ≤ `base_size` rows of each leaf block, so [`HiRef::align_source`]
//! runs the identical recursion against chunked
//! [`DatasetSource`]s: factors come from the chunked builders
//! ([`costs::factors_for_source_into`], one `chunk_rows×d` tile at a
//! time, written straight into the factor stores) and base blocks gather
//! their rows into arena scratch on demand.  Peak memory is then bounded
//! by construction — factors (spillable) + permutations + tiles —
//! regardless of where (or whether) the points are stored.
//! [`HiRef::align_prefactored`] additionally accepts caller-built
//! factors, so one factorisation can serve many solves.

use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::SolveError;
use crate::coordinator::annealing;
use crate::coordinator::assign;
use crate::coordinator::warmstart;
use crate::costs::{self, CostKind};
use crate::data::stream::{self, DatasetSource};
use crate::linalg::{BatchItem, BatchView, Mat, MatView};
use crate::metrics;
use crate::pool::{
    self, Checkout, FactorStore, Precision, RangeShared, ResidentStore, ScratchArena, SpillStore,
    WorkQueue,
};
use crate::runtime::PjrtEngine;
use crate::solvers::exact;
use crate::solvers::lrot::{self, LrotConfig};

/// Which LROT backend serves refinement sub-problems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust mirror descent ([`crate::solvers::lrot`]).
    Native,
    /// AOT artifacts through PJRT; error if an artifact is missing.
    Pjrt,
    /// PJRT when a bucket fits, native otherwise (default).
    Auto,
}

/// Spillable factor storage ([`HiRefConfig::spill`]): when set, the
/// per-side factor working copies live in a [`SpillStore`] — file-backed
/// shards under `dir` with at most `budget_bytes` of unpinned shard cache
/// resident — instead of a fully resident buffer, so only the `O(n)`
/// permutations (plus one in-flight level batch's lane windows) must stay
/// in memory.  Output is bit-identical to the resident path.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Directory for the per-solve scratch files (created if absent,
    /// files removed when the solve finishes).
    pub dir: PathBuf,
    /// Cap on resident *unpinned* shard-cache bytes across both sides
    /// (half per side); 0 disables caching so every checkout re-reads its
    /// shards from disk.
    pub budget_bytes: usize,
}

/// Default spill cache budget when only a directory was configured.
pub const DEFAULT_SPILL_BUDGET: usize = 256 << 20;

/// Configuration for [`HiRef`].
#[derive(Clone, Debug)]
pub struct HiRefConfig {
    /// Ground cost (paper uses both `‖·‖₂` and `‖·‖₂²`).
    pub cost: CostKind,
    /// Maximal intermediate rank C of the annealing schedule.
    pub max_rank: usize,
    /// Maximal base-case block (paper's "maximal base rank Q"): blocks of
    /// at most this size are finished by the exact solver.
    pub base_size: usize,
    /// Optional cap on the hierarchy depth κ.
    pub max_depth: Option<usize>,
    /// Blocks up to this size use Hungarian; larger base blocks use the
    /// ε-scaling auction (near-exact, much faster).
    pub hungarian_cutoff: usize,
    /// LROT hyper-parameters (rank is overridden per scale).
    pub lrot: LrotConfig,
    /// Factor width for non-factorisable costs (Indyk et al. 2019).
    pub indyk_width: usize,
    pub seed: u64,
    pub threads: usize,
    pub backend: BackendKind,
    /// Where the AOT artifacts live (manifest.tsv + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Record the co-clustering Γ_t at every scale (Fig. S3 diagnostics).
    /// With the range layout this costs O(1) per block during the run;
    /// index sets are materialised once at the end.
    pub record_scales: bool,
    /// Tile size (rows) for the streaming ingestion path
    /// ([`HiRef::align_source`]): chunked cost factorisation never holds
    /// more than one `chunk_rows×d` tile of points.
    pub chunk_rows: usize,
    /// Level-synchronous batched execution (the default): every same-shape
    /// group of blocks at a scale is solved as one strided LROT batch.
    /// `false` selects the per-block work-queue path — bit-identical
    /// output, kept for A/B comparison.
    pub batching: bool,
    /// Spillable factor storage: `None` (default) keeps the factor
    /// working copies fully resident ([`ResidentStore`]); `Some` moves
    /// them behind a file-backed [`SpillStore`] (see [`SpillConfig`]).
    pub spill: Option<SpillConfig>,
    /// Stored element format of the factor working copies
    /// ([`Precision::F32`] default — bit-identical to prior releases).
    /// bf16/f16 halve resident/spill factor bytes; the solve path stays
    /// f32 (checkouts decode, dirty releases re-encode RNE), so the
    /// bijection cost moves only by the factor-quantisation error.
    pub factor_precision: Precision,
    /// Cluster-warmstart the top `warmstart_levels` scales of the batched
    /// hierarchy (docs/warmstart.md): those scales are co-clustered
    /// directly from the factor rows by [`warmstart::cluster_block`]
    /// (no LROT), and the first scale below them runs LROT warm-started
    /// from a clustering of its lanes.  `0` (the default) is the exact
    /// path, **bit-identical** to prior releases and kept for A/B; deeper
    /// scales always run the exact solver, and the base case stays exact
    /// either way, so only coarse co-membership is approximated (the
    /// bijection cost stays within the documented 5% relative tolerance).
    /// Ignored by the per-block A/B path (`batching = false`), which is
    /// always exact.
    pub warmstart_levels: usize,
}

impl Default for HiRefConfig {
    fn default() -> Self {
        HiRefConfig {
            cost: CostKind::SqEuclidean,
            max_rank: 16,
            base_size: 256,
            max_depth: None,
            hungarian_cutoff: 128,
            lrot: LrotConfig::default(),
            indyk_width: 32,
            seed: 0,
            threads: pool::default_threads(),
            backend: BackendKind::Auto,
            artifacts_dir: PathBuf::from("artifacts"),
            record_scales: false,
            chunk_rows: 1 << 16,
            batching: true,
            spill: None,
            factor_precision: Precision::F32,
            warmstart_levels: 0,
        }
    }
}

/// Per-scale breakdown of a batched run ([`RunStats::level_stats`]): one
/// entry per scale the level scheduler walked, in depth order — the
/// measurable record of what the cluster-warmstart engine did (empty on
/// the per-block A/B path).
#[derive(Clone, Debug, Default)]
pub struct LevelStat {
    /// Scale index (0 = root).
    pub level: usize,
    /// Blocks entering this scale (refinement + base-case).
    pub blocks: usize,
    /// Refinement lanes dispatched at this scale (0 once every block has
    /// reached the base case).
    pub lanes: usize,
    /// Native mirror-descent iterations summed over the scale's lanes —
    /// 0 at clustered scales (no LROT ran) and for lanes served by PJRT
    /// or a host hook (those backends do not report iteration counts).
    pub lrot_iters: usize,
    /// Wall-clock spent on the scale (base seal + solves + re-index).
    pub elapsed: Duration,
    /// Scale was served by the warmstart engine: co-clustered outright,
    /// or LROT warm-started from a clustering of its lanes.
    pub warmstarted: bool,
}

/// Counters from a run.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub lrot_calls: usize,
    pub pjrt_calls: usize,
    pub native_calls: usize,
    pub base_calls: usize,
    /// High-water mark of simultaneously checked-out scratch capacity —
    /// the transient term of the memory model: `O(n·(d + r))` while the
    /// top-of-hierarchy LROT solves run, `O(threads · base_size²)` once
    /// the recursion reaches the leaves.
    pub peak_scratch_bytes: usize,
    /// Scratch checkouts served from a freelist without allocating.
    pub arena_hits: usize,
    /// Scratch checkouts that allocated a fresh buffer.
    pub arena_misses: usize,
    /// Bytes held by the cost-factor working copies (`2·n·k·w`, where
    /// `w` is the stored element width of `factor_precision`) — the
    /// persistent term of the memory model; together with
    /// `peak_scratch_bytes` this is the whole solve-path footprint of a
    /// streaming run (`O(n·r)` factors + `O(chunk_rows·d)`-bounded tiles).
    pub factor_bytes: usize,
    /// Batched LROT dispatches issued by the level scheduler (one per
    /// same-shape group per scale); 0 on the per-block path.
    pub batches: usize,
    /// Largest lane count of any single batch (the widest level group).
    pub lanes_max: usize,
    /// Fraction of LROT block solves that shared a batch with at least
    /// one sibling lane (0.0 on the per-block path; singleton batches —
    /// e.g. the root — do not count as shared).
    pub batched_frac: f64,
    /// Bytes written to the factor spill files (initial factor build +
    /// dirty shard write-backs after each level's re-index); 0 on
    /// resident runs.
    pub spill_bytes_written: usize,
    /// Factor shard reads served from the spill files (checkouts the
    /// resident shard cache could not serve); 0 on resident runs.
    pub spill_reads: usize,
    /// Peak resident factor bytes, both sides: the whole working copies
    /// (== `factor_bytes`) on resident runs; cache + in-flight checkout
    /// windows — bounded by `spill_budget + one level batch's lane
    /// windows` — on spill runs.
    pub resident_factor_bytes: usize,
    /// The kernel implementation every linalg primitive dispatched to —
    /// `"scalar"`, `"avx2"` or `"neon"` (see [`crate::linalg::kernels`]).
    pub kernel_path: &'static str,
    /// Stored element format of the factor working copies — `"f32"`,
    /// `"bf16"` or `"f16"` ([`HiRefConfig::factor_precision`]).
    pub factor_precision: &'static str,
    /// Lane-crew worker threads spawned by this run: `min(threads,
    /// lanes)` **per batch** — the persistent-pool acceptance property
    /// (the historical loop spawned every iteration).  0 on the per-block
    /// path and on single-threaded runs.
    pub iter_spawns: usize,
    /// Lane clusterings performed by the warmstart engine
    /// ([`HiRefConfig::warmstart_levels`]): blocks co-clustered instead
    /// of LROT-solved at the clustered scales, plus the boundary scale's
    /// warm-init clusterings.  0 on exact runs.
    pub cluster_calls: usize,
    /// Native mirror-descent iterations summed over every in-process
    /// LROT solve (PJRT/hook-served lanes do not report iteration
    /// counts) — the warmstart A/B's "fewer iterations" claim, end to
    /// end.  Per-scale breakdown in [`RunStats::level_stats`].
    pub lrot_iters: usize,
    /// Per-scale breakdown of the batched run (empty on the per-block
    /// A/B path).
    pub level_stats: Vec<LevelStat>,
    pub elapsed: Duration,
}

impl RunStats {
    /// Fraction of scratch checkouts that reused a pooled buffer.
    pub fn arena_hit_rate(&self) -> f64 {
        let total = self.arena_hits + self.arena_misses;
        if total == 0 {
            1.0
        } else {
            self.arena_hits as f64 / total as f64
        }
    }
}

/// Result of [`HiRef::align`]: a bijection plus diagnostics.
pub struct Alignment {
    /// `perm[i] = j` pairs `x_i ↔ y_j`; exactly the paper's output
    /// `{(x_i, T(x_i))}` — n nonzeros.
    pub perm: Vec<u32>,
    /// The rank-annealing schedule used.
    pub schedule: Vec<usize>,
    pub stats: RunStats,
    /// Final hierarchy order of the X side: `x_order[p]` is the original
    /// point id at contiguous position `p` (points of one leaf block are
    /// adjacent; shallower blocks are nested unions of leaf runs).
    pub x_order: Vec<u32>,
    /// Same for the Y side.
    pub y_order: Vec<u32>,
    /// Γ_t per scale when `record_scales` was set: the co-cluster index
    /// pairs entering each scale.
    pub scales: Option<Vec<Vec<(Vec<u32>, Vec<u32>)>>>,
}

impl Alignment {
    /// Primal transport cost ⟨C, P⟩ of the bijection (linear space/time).
    pub fn cost(&self, x: &Mat, y: &Mat, kind: CostKind) -> f64 {
        metrics::bijection_cost(x, y, &self.perm, kind)
    }

    /// Verify the output is a bijection.
    pub fn is_bijection(&self) -> bool {
        let n = self.perm.len();
        let mut seen = vec![false; n];
        self.perm.iter().all(|&j| {
            let j = j as usize;
            j < n && !std::mem::replace(&mut seen[j], true)
        })
    }
}

/// Hooks a long-lived host (e.g. the `serve` scheduler) installs on a
/// solver via [`HiRef::with_hooks`] to observe and steer a run from
/// outside the engine:
///
/// * [`SolveHooks::cancelled`] is polled at every scheduling edge — level
///   step, per-block refine, batch start, base-case seal — and **never
///   while a factor checkout is pinned**, so returning `true` aborts the
///   run promptly with [`SolveError::Cancelled`] without leaking pinned
///   checkouts or arena scratch (every guard is released before the next
///   poll by construction).
/// * [`SolveHooks::lrot_batch`] may take over dispatch of one same-shape
///   LROT batch.  The serve scheduler uses this to merge batches from
///   different in-flight requests into one strided
///   [`lrot::solve_factored_batch`] call; lane solves are independent of
///   `threads` and of which other lanes share the batch (asserted in the
///   LROT tests), so any such regrouping is bit-identical to a solo run.
pub trait SolveHooks: Send + Sync {
    /// Should the run abort now?  Polled between batches/blocks only;
    /// must be cheap (an atomic or clock read).
    fn cancelled(&self) -> bool {
        false
    }

    /// Intercept one LROT batch (all lanes share `active` rows and
    /// `cfg`; lane `l` is `u.items[l]`/`v.items[l]` with seed
    /// `seeds[l]`).  Return `Some(outputs)` — one `(Q, R)` per lane, in
    /// lane order — to substitute for the in-process solve, or `None` to
    /// let the engine dispatch locally (PJRT or native).
    fn lrot_batch(
        &self,
        _u: BatchView<'_>,
        _v: BatchView<'_>,
        _active: usize,
        _cfg: &LrotConfig,
        _seeds: &[u64],
    ) -> Option<Vec<(Mat, Mat)>> {
        None
    }
}

/// The Hierarchical Refinement solver.
pub struct HiRef {
    cfg: HiRefConfig,
    engine: Option<Arc<PjrtEngine>>,
    hooks: Option<Arc<dyn SolveHooks>>,
}

/// One co-cluster: contiguous position ranges into the per-side working
/// buffers (`x_order`/`y_order` and the factor rows).  No index vectors —
/// children re-index their parent's range in place and inherit windows.
struct Block {
    x: Range<u32>,
    y: Range<u32>,
    level: usize,
}

/// How the base case reaches original point rows: borrowed matrices (the
/// classic path) or chunked [`DatasetSource`]s (the streaming path, which
/// gathers each leaf block's ≤ `base_size` rows into arena scratch).
#[derive(Clone, Copy)]
enum Points<'a> {
    Mats(&'a Mat, &'a Mat),
    Sources(&'a dyn DatasetSource, &'a dyn DatasetSource),
}

/// Shared per-run solve state: the re-indexable working buffers plus
/// output and diagnostics sinks.  Workers only touch the window their
/// current block owns, which is what makes the `RangeShared` accesses
/// sound (children partition the parent's range; sibling ranges are
/// disjoint; a range is processed by exactly one worker).
struct SolveState<'a> {
    /// Factor width (columns of the working factor buffers).
    k: usize,
    /// Working factor rows, X side (row p belongs to original point
    /// `x_order[p]`), checked out per block / per level batch and
    /// re-ordered in place at every split.  Resident or spilled behind
    /// the [`FactorStore`] protocol — same rows either way.
    fu: &'a dyn FactorStore,
    fv: &'a dyn FactorStore,
    /// position → original id maps, re-ordered in tandem with fu/fv
    /// (always resident — the `O(n)` term of the memory model).
    x_order: RangeShared<u32>,
    y_order: RangeShared<u32>,
    arena: &'a ScratchArena,
    perm: Mutex<Vec<u32>>,
    scales: Option<Vec<Mutex<Vec<(Range<u32>, Range<u32>)>>>>,
    stats: StatsAtomics,
    /// Per-scale breakdown, pushed by the level scheduler in depth order
    /// (stays empty on the per-block path).
    level_stats: Mutex<Vec<LevelStat>>,
    /// First solver-internal failure (e.g. a mid-solve dataset I/O error
    /// on the streaming path).  Workers record it and bail out of their
    /// block; the run surfaces it as the solve result.
    error: Mutex<Option<SolveError>>,
}

impl SolveState<'_> {
    /// Record the first failure; later ones are dropped (the first is the
    /// actionable one and the run is already doomed).
    fn set_error(&self, e: SolveError) {
        let mut guard = self.error.lock().unwrap();
        if guard.is_none() {
            *guard = Some(e);
        }
    }

    /// Has any worker recorded a failure?  Checked before scheduling more
    /// work so a doomed run (e.g. a vanished dataset with slow failing
    /// reads) surfaces its error in one block's time, not after
    /// re-attempting every remaining block.
    fn has_error(&self) -> bool {
        self.error.lock().unwrap().is_some()
    }
}

impl HiRef {
    /// Build a solver; loads the PJRT artifact registry when the backend
    /// allows it (Auto silently degrades to native if artifacts are
    /// absent, Pjrt errors at align time).
    pub fn new(cfg: HiRefConfig) -> HiRef {
        let engine = match cfg.backend {
            BackendKind::Native => None,
            BackendKind::Pjrt | BackendKind::Auto => {
                PjrtEngine::load(&cfg.artifacts_dir).ok().map(Arc::new)
            }
        };
        HiRef { cfg, engine, hooks: None }
    }

    /// Install host [`SolveHooks`] (cancellation polling + LROT batch
    /// interception) on this instance.
    pub fn with_hooks(mut self, hooks: Arc<dyn SolveHooks>) -> HiRef {
        self.hooks = Some(hooks);
        self
    }

    /// Borrow the loaded PJRT engine, if any.
    pub fn engine(&self) -> Option<&Arc<PjrtEngine>> {
        self.engine.as_ref()
    }

    /// Poll the host hooks; on cancellation record the typed error (the
    /// run then drains without doing further work, exactly like an I/O
    /// failure) and report `true`.
    fn poll_cancel(&self, st: &SolveState<'_>) -> bool {
        match &self.hooks {
            Some(h) if h.cancelled() => {
                st.set_error(SolveError::Cancelled);
                true
            }
            _ => false,
        }
    }

    /// Shared structural validation for every alignment entry point.
    fn validate_sizes(&self, n: usize, m: usize, dx: usize, dy: usize) -> Result<(), SolveError> {
        if n == 0 || m == 0 {
            return Err(SolveError::EmptyInput);
        }
        if n != m {
            return Err(SolveError::ShapeMismatch { n, m });
        }
        if dx != dy {
            return Err(SolveError::DimMismatch { dx, dy });
        }
        if self.cfg.backend == BackendKind::Pjrt && self.engine.is_none() {
            return Err(SolveError::Backend(format!(
                "backend = Pjrt but artifacts not loadable from {} (run `make artifacts`)",
                self.cfg.artifacts_dir.display()
            )));
        }
        Ok(())
    }

    /// Wrap prebuilt factor matrices in the configured [`FactorStore`]s:
    /// zero-cost resident buffers by default, or spill files (the
    /// matrices are written out and dropped) when `cfg.spill` is set.
    fn stores_from_mats(
        &self,
        fu: Mat,
        fv: Mat,
    ) -> Result<(Box<dyn FactorStore>, Box<dyn FactorStore>), SolveError> {
        let prec = self.cfg.factor_precision;
        match &self.cfg.spill {
            None => Ok((
                Box::new(ResidentStore::from_mat_with(fu, prec)),
                Box::new(ResidentStore::from_mat_with(fv, prec)),
            )),
            Some(sc) => {
                let su = SpillStore::create_with(&sc.dir, fu.rows, fu.cols, sc.budget_bytes / 2, prec)?;
                let sv = SpillStore::create_with(&sc.dir, fv.rows, fv.cols, sc.budget_bytes / 2, prec)?;
                // SAFETY: no checkouts exist yet; single-threaded writes.
                unsafe {
                    su.write_rows(0, &fu.data)?;
                    sv.write_rows(0, &fv.data)?;
                }
                Ok((Box::new(su), Box::new(sv)))
            }
        }
    }

    /// Empty stores of the given shapes for the chunked factor builders
    /// to fill tile by tile (the streaming path's no-full-matrix route).
    fn empty_stores(
        &self,
        n: usize,
        m: usize,
        k: usize,
    ) -> Result<(Box<dyn FactorStore>, Box<dyn FactorStore>), SolveError> {
        let prec = self.cfg.factor_precision;
        match &self.cfg.spill {
            None => Ok((
                Box::new(ResidentStore::zeroed_with(n, k, prec)),
                Box::new(ResidentStore::zeroed_with(m, k, prec)),
            )),
            Some(sc) => Ok((
                Box::new(SpillStore::create_with(&sc.dir, n, k, sc.budget_bytes / 2, prec)?),
                Box::new(SpillStore::create_with(&sc.dir, m, k, sc.budget_bytes / 2, prec)?),
            )),
        }
    }

    /// Compute a bijective alignment between equal-sized `x` and `y`.
    pub fn align(&self, x: &Mat, y: &Mat) -> Result<Alignment, SolveError> {
        self.validate_sizes(x.rows, y.rows, x.cols, y.cols)?;
        let t0 = Instant::now();
        // Global cost factors, gathered exactly once (both factorisations
        // are row-separable, so row slices of these are exact sub-block
        // factors).  They become the recursion's working buffers and are
        // re-ordered in place from here on.
        let (fu, fv) =
            costs::factors_for(x, y, self.cfg.cost, self.cfg.indyk_width, self.cfg.seed);
        let stores = self.stores_from_mats(fu, fv)?;
        let arena = ScratchArena::new(self.cfg.threads);
        self.align_inner(stores, Points::Mats(x, y), arena, t0)
    }

    /// [`HiRef::align`] with caller-supplied cost factors `C ≈ fu · fvᵀ`
    /// (e.g. shared across several solves, or loaded from disk).  The
    /// factors are consumed as the recursion's working buffers; shapes are
    /// validated against the point clouds.
    pub fn align_prefactored(
        &self,
        fu: Mat,
        fv: Mat,
        x: &Mat,
        y: &Mat,
    ) -> Result<Alignment, SolveError> {
        self.validate_sizes(x.rows, y.rows, x.cols, y.cols)?;
        if fu.rows != x.rows || fv.rows != y.rows || fu.cols != fv.cols {
            return Err(SolveError::InvalidConfig(format!(
                "prefactored shapes {}x{} / {}x{} do not match an {}-point problem",
                fu.rows, fu.cols, fv.rows, fv.cols, x.rows
            )));
        }
        let t0 = Instant::now();
        let stores = self.stores_from_mats(fu, fv)?;
        let arena = ScratchArena::new(self.cfg.threads);
        self.align_inner(stores, Points::Mats(x, y), arena, t0)
    }

    /// [`HiRef::align_prefactored`] against chunked [`DatasetSource`]s —
    /// the warm-session serving path: factors were built once (and cached
    /// by the host), the points stay wherever they live, and base-case
    /// blocks gather their ≤ `base_size` rows on demand.  Performs zero
    /// factorisation work; bit-identical to [`HiRef::align`] /
    /// [`HiRef::align_source`] on equal data.
    pub fn align_prefactored_source(
        &self,
        fu: Mat,
        fv: Mat,
        x: &dyn DatasetSource,
        y: &dyn DatasetSource,
    ) -> Result<Alignment, SolveError> {
        self.validate_sizes(x.rows(), y.rows(), x.dim(), y.dim())?;
        if fu.rows != x.rows() || fv.rows != y.rows() || fu.cols != fv.cols {
            return Err(SolveError::InvalidConfig(format!(
                "prefactored shapes {}x{} / {}x{} do not match an {}-point problem",
                fu.rows, fu.cols, fv.rows, fv.cols,
                x.rows()
            )));
        }
        let t0 = Instant::now();
        let stores = self.stores_from_mats(fu, fv)?;
        let arena = ScratchArena::new(self.cfg.threads);
        self.align_inner(stores, Points::Sources(x, y), arena, t0)
    }

    /// Streaming alignment: both point clouds arrive as chunked
    /// [`DatasetSource`]s.  Cost factors are built by the chunked
    /// builders ([`costs::factors_for_source`]) in `cfg.chunk_rows`-sized
    /// tiles, and base-case blocks gather their ≤ `base_size` rows into
    /// arena scratch on demand — at no point does either full point cloud
    /// exist in memory.  Peak footprint: `O(n·r)` factors + permutations
    /// + `O(chunk_rows·d)` ingestion tiles + in-flight-block scratch (all
    /// reported in [`RunStats`]).  For equal data, the result is
    /// identical to [`HiRef::align`] regardless of chunk size.
    pub fn align_source(
        &self,
        x: &dyn DatasetSource,
        y: &dyn DatasetSource,
    ) -> Result<Alignment, SolveError> {
        self.validate_sizes(x.rows(), y.rows(), x.dim(), y.dim())?;
        let t0 = Instant::now();
        let arena = ScratchArena::new(self.cfg.threads);
        // The chunked builders write factor tiles straight into the
        // stores — with spill configured, the full factor matrices never
        // exist in memory at any point of the run.  Factorisation I/O
        // failures surface as SolveError::Backend via From<io::Error>.
        let k = costs::factor_width(self.cfg.cost, x.dim(), x.rows(), y.rows(), self.cfg.indyk_width);
        let stores = self.empty_stores(x.rows(), y.rows(), k)?;
        costs::factors_for_source_into(
            x,
            y,
            self.cfg.cost,
            self.cfg.indyk_width,
            self.cfg.seed,
            self.cfg.chunk_rows,
            &arena,
            self.cfg.threads,
            &*stores.0,
            &*stores.1,
        )?;
        self.align_inner(stores, Points::Sources(x, y), arena, t0)
    }

    /// The recursion shared by every entry point: consumes the factor
    /// stores, fans the co-cluster hierarchy out over the worker pool,
    /// and seals base blocks against `points`.
    fn align_inner(
        &self,
        stores: (Box<dyn FactorStore>, Box<dyn FactorStore>),
        points: Points<'_>,
        arena: ScratchArena,
        t0: Instant,
    ) -> Result<Alignment, SolveError> {
        let (fu, fv) = stores;
        let n = fu.rows();
        let k = fu.cols();
        debug_assert_eq!(k, fv.cols());
        let factor_bytes = (fu.rows() + fv.rows()) * k * fu.precision().bytes();
        let spawns0 = pool::crew_spawns();

        let schedule = annealing::optimal_rank_schedule(
            n,
            self.cfg.base_size,
            self.cfg.max_rank,
            self.cfg.max_depth,
        );

        let st = SolveState {
            k,
            fu: &*fu,
            fv: &*fv,
            x_order: RangeShared::new((0..n as u32).collect()),
            y_order: RangeShared::new((0..n as u32).collect()),
            arena: &arena,
            perm: Mutex::new(vec![u32::MAX; n]),
            scales: if self.cfg.record_scales {
                Some((0..=schedule.len()).map(|_| Mutex::new(Vec::new())).collect())
            } else {
                None
            },
            stats: StatsAtomics::default(),
            level_stats: Mutex::new(Vec::new()),
            error: Mutex::new(None),
        };

        let root = Block { x: 0..n as u32, y: 0..n as u32, level: 0 };
        if self.cfg.batching {
            // level-synchronous batched execution (the default)
            self.run_levels(&schedule, points, root, &st);
        } else {
            // per-block A/B path: the classic work-queue recursion
            let queue = WorkQueue::new(vec![root]);
            queue.run(self.cfg.threads, |block, queue| {
                self.record_scale(&block, &st);
                let len = (block.x.end - block.x.start) as usize;
                if len <= self.cfg.base_size || block.level >= schedule.len() {
                    self.solve_base(points, &block, &st);
                } else {
                    self.refine(&schedule, block, queue, &st);
                }
            });
        }

        if let Some(e) = st.error.into_inner().unwrap() {
            return Err(e);
        }
        let perm = st.perm.into_inner().unwrap();
        let unassigned = perm.iter().filter(|&&j| j == u32::MAX).count();
        if unassigned > 0 {
            return Err(SolveError::IncompleteAssignment { n, unassigned });
        }
        let x_order = st.x_order.into_inner();
        let y_order = st.y_order.into_inner();
        // Materialise recorded scales from the final orders: deeper splits
        // only permute *within* a recorded range, so the id set of every
        // snapshot is intact (content identical to eager recording).
        let scales = st.scales.map(|sc| {
            sc.into_iter()
                .map(|m| {
                    m.into_inner()
                        .unwrap()
                        .into_iter()
                        .map(|(rx, ry)| {
                            (
                                x_order[rx.start as usize..rx.end as usize].to_vec(),
                                y_order[ry.start as usize..ry.end as usize].to_vec(),
                            )
                        })
                        .collect()
                })
                .collect()
        });
        let mut stats = st.stats.snapshot(t0.elapsed(), &arena);
        stats.level_stats = st.level_stats.into_inner().unwrap();
        stats.factor_bytes = factor_bytes;
        stats.factor_precision = fu.precision().as_str();
        // lane-crew worker threads spawned by this run: O(threads) per
        // batch, not O(iterations · threads).  The underlying counter is
        // process-global, so the delta is exact only when no other solve
        // runs concurrently (true for the CLI and the benches; concurrent
        // serve solves see the sum of their batches).
        stats.iter_spawns = pool::crew_spawns() - spawns0;
        let (su, sv) = (fu.stats(), fv.stats());
        stats.spill_bytes_written = su.spill_bytes_written + sv.spill_bytes_written;
        stats.spill_reads = su.spill_reads + sv.spill_reads;
        stats.resident_factor_bytes = su.resident_peak + sv.resident_peak;
        Ok(Alignment { perm, schedule, stats, x_order, y_order, scales })
    }

    /// O(1) co-clustering snapshot for Fig. S3 diagnostics: just the
    /// range pair, no index clones (materialised at the end of the run).
    fn record_scale(&self, block: &Block, st: &SolveState<'_>) {
        if let Some(sc) = &st.scales {
            if block.level < sc.len() {
                sc[block.level].lock().unwrap().push((block.x.clone(), block.y.clone()));
            }
        }
    }

    /// Per-block deterministic seed, anchored on the first original id in
    /// the block — invariant under the physical layout **and** under the
    /// execution strategy, which is what makes the batched and per-block
    /// paths bit-identical.
    fn block_seed(&self, block: &Block, st: &SolveState<'_>) -> u64 {
        let xs = block.x.start as usize;
        // SAFETY: this block exclusively owns positions [xs, xe) — sibling
        // ranges are disjoint and the parent finished re-indexing before
        // this block was scheduled.
        let anchor = unsafe { st.x_order.slice(xs, xs + 1)[0] };
        self.cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((block.level as u64) << 32)
            .wrapping_add(anchor as u64)
    }

    /// Balanced assignment + in-place re-indexing of one block's windows
    /// so each child co-cluster is contiguous; returns the child blocks
    /// (Algorithm 1, lines 8–17 — with `Assign`'s split realised as a
    /// stable counting reorder instead of index-set materialisation).
    /// The factor rows are rewritten inside the checked-out lane windows
    /// (`cox`/`coy` lane `lane`) — the store persists them at release —
    /// while the permutation windows mutate in place (always resident).
    /// Shared by the per-block and level-batched paths.
    #[allow(clippy::too_many_arguments)]
    fn split_block(
        &self,
        block: &Block,
        cox: &Checkout<'_>,
        coy: &Checkout<'_>,
        lane: usize,
        q: &Mat,
        rmat: &Mat,
        st: &SolveState<'_>,
    ) -> Vec<Block> {
        let len = (block.x.end - block.x.start) as usize;
        let labels_x = assign::balanced_assign(q, len);
        let labels_y = assign::balanced_assign(rmat, len);
        self.split_block_with_labels(block, cox, coy, lane, &labels_x, &labels_y, q.cols, st)
    }

    /// The label-driven half of [`HiRef::split_block`]: reorder the
    /// block's windows by pre-computed balanced co-cluster labels (from
    /// an LROT factor pair's balanced assignment, or straight from the
    /// warmstart engine — both honour [`assign::capacities`]`(len, rank)`
    /// exactly, which the counting reorder requires) and emit the child
    /// blocks.
    #[allow(clippy::too_many_arguments)]
    fn split_block_with_labels(
        &self,
        block: &Block,
        cox: &Checkout<'_>,
        coy: &Checkout<'_>,
        lane: usize,
        labels_x: &[u32],
        labels_y: &[u32],
        rank: usize,
        st: &SolveState<'_>,
    ) -> Vec<Block> {
        let (xs, xe) = (block.x.start as usize, block.x.end as usize);
        let (ys, ye) = (block.y.start as usize, block.y.end as usize);
        let len = xe - xs;
        let caps = assign::capacities(len, rank);

        // SAFETY: this block exclusively owns its lane and its order
        // window — sibling lanes/ranges are disjoint, and the batch's
        // LROT read phase has ended before any split runs.
        unsafe {
            reorder_window(cox.lane_mut(lane), st.x_order.slice_mut(xs, xe), st.k, labels_x, &caps, st.arena);
            reorder_window(coy.lane_mut(lane), st.y_order.slice_mut(ys, ye), st.k, labels_y, &caps, st.arena);
        }

        let mut children = Vec::with_capacity(caps.len());
        let mut off = 0usize;
        for &cap in &caps {
            if cap > 0 {
                children.push(Block {
                    x: (xs + off) as u32..(xs + off + cap) as u32,
                    y: (ys + off) as u32..(ys + off + cap) as u32,
                    level: block.level + 1,
                });
            }
            off += cap;
        }
        debug_assert_eq!(off, len, "children must partition the parent range");
        children
    }

    /// One refinement step of the per-block path: check the co-cluster's
    /// factor-row windows out of the stores, LROT on them, then
    /// [`HiRef::split_block`], then release (dirty — the split re-indexed
    /// the rows) and enqueue the children.
    fn refine(
        &self,
        schedule: &[usize],
        block: Block,
        queue: &WorkQueue<Block>,
        st: &SolveState<'_>,
    ) {
        if st.has_error() || self.poll_cancel(st) {
            return; // doomed run: drain the queue without doing work
        }
        let (xs, xe) = (block.x.start as usize, block.x.end as usize);
        let (ys, ye) = (block.y.start as usize, block.y.end as usize);
        let len = xe - xs;
        debug_assert_eq!(len, ye - ys, "unbalanced co-cluster");
        let k = st.k;
        // Rank at this scale: schedule entry, clamped so a block is never
        // split into more parts than it has points.
        let rank = schedule[block.level].min(len).max(2);
        let seed = self.block_seed(&block, st);

        let cox = match st.fu.checkout(std::slice::from_ref(&block.x), st.arena) {
            Ok(c) => c,
            Err(e) => return st.set_error(e.into()),
        };
        let coy = match st.fv.checkout(std::slice::from_ref(&block.y), st.arena) {
            Ok(c) => c,
            Err(e) => {
                let _ = st.fu.release(cox, false);
                return st.set_error(e.into());
            }
        };
        st.stats.lrot.fetch_add(1, Ordering::Relaxed);
        let (q, rmat) = {
            // SAFETY: shared reads of our own lanes, dropped before the
            // exclusive re-indexing borrows inside split_block.
            let u = MatView::from_slice(len, k, unsafe { cox.lane(0) });
            let v = MatView::from_slice(len, k, unsafe { coy.lane(0) });
            self.solve_lrot(u, v, len, rank, seed, st)
        };
        let children = self.split_block(&block, &cox, &coy, 0, &q, &rmat, st);
        // write back only if some child will read these rows again — a
        // block whose children are all base cases never has its factor
        // rows checked out again, so its write-back would be wasted I/O
        // (release both sides even if the first write-back fails)
        let dirty = self.any_child_refines(&children, schedule);
        let ru = st.fu.release(cox, dirty);
        let rv = st.fv.release(coy, dirty);
        if let Err(e) = ru.and(rv) {
            return st.set_error(e.into());
        }
        // This block's borrows of the order windows are over; retire them
        // in the race detector before the children — sub-ranges of this
        // block's window — are published to other workers, which would
        // otherwise see a stale cross-thread claim as a conflict.
        pool::guard::retire_thread();
        for child in children {
            queue.push(child);
        }
    }

    /// Will any of these freshly split children be refined (and therefore
    /// check its factor rows out again)?  Mirrors the base/refine
    /// partition predicate of [`HiRef::run_levels`]: base-case children
    /// are sealed from points and orders alone, so a block whose children
    /// are all base cases needs no factor write-back.
    fn any_child_refines(&self, children: &[Block], schedule: &[usize]) -> bool {
        children.iter().any(|c| {
            (c.x.end - c.x.start) as usize > self.cfg.base_size && c.level < schedule.len()
        })
    }

    /// The level-synchronous scheduler (the default execution strategy):
    /// walk the hierarchy one scale at a time, sealing the scale's
    /// base-case blocks with one batched exact pass and solving each
    /// same-shape group of refinement blocks as one strided LROT batch.
    fn run_levels(&self, schedule: &[usize], points: Points<'_>, root: Block, st: &SolveState<'_>) {
        let threads = self.cfg.threads;
        let warm_levels = self.cfg.warmstart_levels.min(schedule.len());
        let mut current = vec![root];
        while !current.is_empty() {
            // fail fast: a recorded error (or a host cancellation — no
            // checkout is pinned here) dooms the run, so stop scheduling
            // levels instead of grinding through them
            if st.has_error() || self.poll_cancel(st) {
                return;
            }
            for b in &current {
                self.record_scale(b, st);
            }
            let level = current[0].level;
            debug_assert!(current.iter().all(|b| b.level == level));
            let t_level = Instant::now();
            let blocks_in = current.len();
            let iters0 = st.stats.lrot_iters.load(Ordering::Relaxed);
            // The warmstart plan for this scale (docs/warmstart.md):
            // scales above the boundary are co-clustered directly — no
            // LROT at all — and the boundary scale itself runs LROT
            // warm-started from a clustering of its lanes.  Every scale
            // below is the unchanged exact path.
            let clustered = level < warm_levels;
            let warm_init = warm_levels > 0 && level == warm_levels;
            let (refine, base): (Vec<Block>, Vec<Block>) = current.into_iter().partition(|b| {
                let len = (b.x.end - b.x.start) as usize;
                len > self.cfg.base_size && b.level < schedule.len()
            });
            // one batched exact pass over the level's base tiles
            if !base.is_empty() {
                pool::parallel_map(base.len(), threads, |i| self.solve_base(points, &base[i], st));
            }
            // group refinement blocks by size: ±1-balanced splits leave at
            // most two distinct sizes per level, so the ragged remainder
            // forms its own (possibly 1-lane) batch.  BTreeMap keeps the
            // group order deterministic.
            let mut groups: std::collections::BTreeMap<usize, Vec<Block>> =
                std::collections::BTreeMap::new();
            for b in refine {
                let len = (b.x.end - b.x.start) as usize;
                groups.entry(len).or_default().push(b);
            }
            let mut next = Vec::new();
            let mut lanes_total = 0usize;
            for (len, blocks) in groups {
                let rank = schedule[level].min(len).max(2);
                // With spill configured, cap the lanes pinned at once so
                // the in-flight checkout window tracks the budget (lane
                // solves are independent, so sub-batching preserves
                // bit-identity; the resident path keeps whole groups).
                let cap = self.batch_lane_cap(len, st.k);
                let mut i = 0usize;
                while i < blocks.len() {
                    let j = blocks.len().min(i.saturating_add(cap));
                    lanes_total += j - i;
                    next.extend(if clustered {
                        self.cluster_batch(&blocks[i..j], len, rank, schedule, st)
                    } else {
                        self.refine_batch(&blocks[i..j], len, rank, schedule, warm_init, st)
                    });
                    i = j;
                }
            }
            st.level_stats.lock().unwrap().push(LevelStat {
                level,
                blocks: blocks_in,
                lanes: lanes_total,
                lrot_iters: st.stats.lrot_iters.load(Ordering::Relaxed) - iters0,
                elapsed: t_level.elapsed(),
                warmstarted: lanes_total > 0 && (clustered || warm_init),
            });
            current = next;
        }
    }

    /// How many same-shape lanes one batch may pin: unbounded on the
    /// resident path (zero-copy checkouts), budget-derived on the spill
    /// path — but always at least one lane, because a lane's rows must be
    /// resident to solve it (the root pins one full-side lane).
    fn batch_lane_cap(&self, len: usize, k: usize) -> usize {
        match &self.cfg.spill {
            None => usize::MAX,
            Some(sc) => {
                // lanes are pinned at the stored element width, so a
                // bf16/f16 run fits twice the lanes per batch under the
                // same budget
                let lane_bytes = (len * k * self.cfg.factor_precision.bytes()).max(1);
                ((sc.budget_bytes / 2) / lane_bytes).max(1)
            }
        }
    }

    /// Refine one same-shape group of blocks as a single strided LROT
    /// batch over the group's checked-out lane windows, then run the
    /// batched balanced-assign / re-index pass that produces the next
    /// level's blocks, then release the windows (dirty) so the store
    /// persists the re-indexed rows.
    /// With `warm_init` set (the first scale below the clustered ones —
    /// see [`HiRef::run_levels`]), every lane is first co-clustered by
    /// the warmstart engine and LROT starts mirror descent from that
    /// co-clustering instead of uniform factors.
    #[allow(clippy::too_many_arguments)]
    fn refine_batch(
        &self,
        blocks: &[Block],
        len: usize,
        rank: usize,
        schedule: &[usize],
        warm_init: bool,
        st: &SolveState<'_>,
    ) -> Vec<Block> {
        if st.has_error() || self.poll_cancel(st) {
            return Vec::new(); // doomed run: stop scheduling batches
        }
        let lanes = blocks.len();
        let k = st.k;
        // pin exactly this batch's lane windows — the "one in-flight
        // level batch" unit of the spill memory model
        let x_ranges: Vec<Range<u32>> = blocks.iter().map(|b| b.x.clone()).collect();
        let y_ranges: Vec<Range<u32>> = blocks.iter().map(|b| b.y.clone()).collect();
        let cox = match st.fu.checkout(&x_ranges, st.arena) {
            Ok(c) => c,
            Err(e) => {
                st.set_error(e.into());
                return Vec::new();
            }
        };
        let coy = match st.fv.checkout(&y_ranges, st.arena) {
            Ok(c) => c,
            Err(e) => {
                let _ = st.fu.release(cox, false);
                st.set_error(e.into());
                return Vec::new();
            }
        };
        st.stats.lrot.fetch_add(lanes, Ordering::Relaxed);
        st.stats.batches.fetch_add(1, Ordering::Relaxed);
        st.stats.lanes_max.fetch_max(lanes, Ordering::Relaxed);
        if lanes >= 2 {
            st.stats.batched_lanes.fetch_add(lanes, Ordering::Relaxed);
        }
        let seeds: Vec<u64> = blocks.iter().map(|b| self.block_seed(b, st)).collect();
        // Warm-started descent at the boundary scale: cluster every lane
        // first (shared lane reads; the claims are retired at the
        // parallel_map epoch boundary, before the LROT read stage claims
        // the spans) and hand the labels to the native solver as initial
        // co-clusterings.
        let warm: Option<Vec<warmstart::CoClusters>> = if warm_init {
            st.stats.clustered.fetch_add(lanes, Ordering::Relaxed);
            Some(pool::parallel_map(lanes, self.cfg.threads, |l| {
                self.cluster_lane(&cox, &coy, l, len, rank, st)
            }))
        } else {
            None
        };
        let outs: Vec<(Mat, Mat)> = {
            // SAFETY: the LROT stage only *reads* the checked-out spans
            // (sliced into disjoint lane windows); nothing writes them
            // until the re-index pass below, by which point these borrows
            // have ended.
            let fu = unsafe { cox.data() };
            let fv = unsafe { coy.data() };
            let u_items: Vec<BatchItem> = (0..lanes)
                .map(|l| {
                    let r0 = cox.lane_row(l);
                    BatchItem::new(r0..r0 + len, k)
                })
                .collect();
            let v_items: Vec<BatchItem> = (0..lanes)
                .map(|l| {
                    let r0 = coy.lane_row(l);
                    BatchItem::new(r0..r0 + len, k)
                })
                .collect();
            let u = BatchView::new(fu, &u_items);
            let v = BatchView::new(fv, &v_items);
            self.solve_lrot_batch(u, v, len, rank, &seeds, warm.as_deref(), st)
        };
        // one batched balanced-assign + re-index pass over the lanes;
        // sibling lane windows are disjoint, so the concurrent in-place
        // reorders stay within the checkout's disjointness contract.
        let children: Vec<Block> = pool::parallel_map(lanes, self.cfg.threads, |l| {
            self.split_block(&blocks[l], &cox, &coy, l, &outs[l].0, &outs[l].1, st)
        })
        .into_iter()
        .flatten()
        .collect();
        // write back only if some child will read these rows again (see
        // any_child_refines); release both sides even if the first
        // write-back fails
        let dirty = self.any_child_refines(&children, schedule);
        let ru = st.fu.release(cox, dirty);
        let rv = st.fv.release(coy, dirty);
        if let Err(e) = ru.and(rv) {
            st.set_error(e.into());
            return Vec::new();
        }
        children
    }

    /// Cluster one checked-out lane into `rank` balanced co-clusters —
    /// the warmstart engine's unit of work.  Initial centroids are `rank`
    /// evenly spaced factor rows of the lane, read through the checkout
    /// ([`Checkout::sample_lane_rows`]), so the clustering is
    /// deterministic (no RNG) and identical on resident, spilled and
    /// narrow-precision stores.
    fn cluster_lane(
        &self,
        cox: &Checkout<'_>,
        coy: &Checkout<'_>,
        lane: usize,
        len: usize,
        rank: usize,
        st: &SolveState<'_>,
    ) -> warmstart::CoClusters {
        let k = st.k;
        let mut cent = st.arena.take_f32(rank * k);
        // SAFETY: shared reads of this batch's lane windows — nothing
        // writes them until the re-index pass, and these borrows end
        // before any exclusive claim is taken (the parallel_map epoch
        // boundary retires the claims).
        let (ux, vy) = unsafe {
            cox.sample_lane_rows(lane, &mut cent);
            (cox.lane(lane), coy.lane(lane))
        };
        warmstart::cluster_block(ux, vy, len, k, rank, &cent, st.arena)
    }

    /// Co-cluster one same-shape group of blocks directly — the
    /// coarse-scale path of the warmstart engine: no LROT solve, just a
    /// clustering per lane followed by the same balanced re-index pass
    /// [`HiRef::refine_batch`] runs.  Children have identical geometry to
    /// the exact path (capacities depend only on `(len, rank)`), so every
    /// scale below still partitions `0..n` and the same-shape grouping is
    /// unchanged.
    fn cluster_batch(
        &self,
        blocks: &[Block],
        len: usize,
        rank: usize,
        schedule: &[usize],
        st: &SolveState<'_>,
    ) -> Vec<Block> {
        if st.has_error() || self.poll_cancel(st) {
            return Vec::new(); // doomed run: stop scheduling batches
        }
        let lanes = blocks.len();
        let x_ranges: Vec<Range<u32>> = blocks.iter().map(|b| b.x.clone()).collect();
        let y_ranges: Vec<Range<u32>> = blocks.iter().map(|b| b.y.clone()).collect();
        let cox = match st.fu.checkout(&x_ranges, st.arena) {
            Ok(c) => c,
            Err(e) => {
                st.set_error(e.into());
                return Vec::new();
            }
        };
        let coy = match st.fv.checkout(&y_ranges, st.arena) {
            Ok(c) => c,
            Err(e) => {
                let _ = st.fu.release(cox, false);
                st.set_error(e.into());
                return Vec::new();
            }
        };
        // clustered lanes count toward `cluster_calls`, not the LROT
        // batch counters (`lrot_calls`/`batches`/`batched_frac` keep
        // describing actual LROT dispatches)
        st.stats.clustered.fetch_add(lanes, Ordering::Relaxed);
        // one fused cluster + re-index pass per lane: the lane's shared
        // read claims end inside `cluster_lane`, and the same thread may
        // then take the exclusive re-index claim on its own lane (sibling
        // lanes are disjoint windows).
        let children: Vec<Block> = pool::parallel_map(lanes, self.cfg.threads, |l| {
            let cc = self.cluster_lane(&cox, &coy, l, len, rank, st);
            self.split_block_with_labels(
                &blocks[l],
                &cox,
                &coy,
                l,
                &cc.labels_x,
                &cc.labels_y,
                rank,
                st,
            )
        })
        .into_iter()
        .flatten()
        .collect();
        // write back only if some child will read these rows again (see
        // any_child_refines); release both sides even if the first
        // write-back fails
        let dirty = self.any_child_refines(&children, schedule);
        let ru = st.fu.release(cox, dirty);
        let rv = st.fv.release(coy, dirty);
        if let Err(e) = ru.and(rv) {
            st.set_error(e.into());
            return Vec::new();
        }
        children
    }

    /// Batch-granularity LROT dispatch: the whole batch goes to PJRT when
    /// the backend can serve its shape, else to the native batched solver.
    /// A warm-started batch (`warm` present) goes straight to the native
    /// solver: the warm seam is a native-solver feature (host hooks and
    /// the PJRT buckets take no initial co-clustering), and warmstart runs
    /// are approximate by contract — there is no cross-backend bit-parity
    /// to preserve.
    #[allow(clippy::too_many_arguments)]
    fn solve_lrot_batch(
        &self,
        u: BatchView<'_>,
        v: BatchView<'_>,
        active: usize,
        rank: usize,
        seeds: &[u64],
        warm: Option<&[warmstart::CoClusters]>,
        st: &SolveState<'_>,
    ) -> Vec<(Mat, Mat)> {
        let lanes = u.len();
        // a host hook (the serve microbatcher) may take the whole batch —
        // e.g. to merge it with same-shape batches of other in-flight
        // requests; lane independence keeps the outputs bit-identical
        if warm.is_none() {
            if let Some(hooks) = &self.hooks {
                let cfg = LrotConfig { rank, ..self.cfg.lrot.clone() };
                if let Some(outs) = hooks.lrot_batch(u, v, active, &cfg, seeds) {
                    assert_eq!(outs.len(), lanes, "hook returned a wrong-sized batch");
                    st.stats.native.fetch_add(lanes, Ordering::Relaxed);
                    return outs;
                }
            }
        }
        let actives: Vec<(usize, usize)> = vec![(active, active); lanes];
        if warm.is_none() && self.cfg.backend != BackendKind::Native {
            if let Some(engine) = &self.engine {
                match engine.lrot_batch(u, v, &actives, rank, seeds) {
                    Ok(Some(outs)) => {
                        st.stats.pjrt.fetch_add(lanes, Ordering::Relaxed);
                        return outs;
                    }
                    Ok(None) => {} // no bucket for this shape: native batch
                    Err(e) => {
                        // degrade gracefully; correctness is identical
                        eprintln!("[hiref] pjrt LROT batch failed ({e}); using native");
                    }
                }
            }
        }
        st.stats.native.fetch_add(lanes, Ordering::Relaxed);
        let cfg = LrotConfig { rank, ..self.cfg.lrot.clone() };
        let warm_lanes: Vec<Option<lrot::WarmLabels<'_>>> = warm
            .map(|cs| {
                cs.iter()
                    .map(|c| Some(lrot::WarmLabels { x: &c.labels_x[..], y: &c.labels_y[..] }))
                    .collect()
            })
            .unwrap_or_default();
        let outs = lrot::solve_factored_batch_warm(
            u,
            v,
            &actives,
            &cfg,
            seeds,
            &warm_lanes,
            st.arena,
            self.cfg.threads,
        );
        st.stats
            .lrot_iters
            .fetch_add(outs.iter().map(|o| o.iters).sum::<usize>(), Ordering::Relaxed);
        outs.into_iter().map(|o| (o.q, o.r)).collect()
    }

    /// LROT dispatch: PJRT bucket when available, else native.  Both paths
    /// consume the borrowed factor windows directly.
    fn solve_lrot(
        &self,
        u: MatView<'_>,
        v: MatView<'_>,
        active: usize,
        rank: usize,
        seed: u64,
        st: &SolveState<'_>,
    ) -> (Mat, Mat) {
        if self.cfg.backend != BackendKind::Native {
            if let Some(engine) = &self.engine {
                match engine.lrot(u, v, active, active, rank, seed) {
                    Ok(Some(qr)) => {
                        st.stats.pjrt.fetch_add(1, Ordering::Relaxed);
                        return qr;
                    }
                    Ok(None) => {} // no bucket: fall through to native
                    Err(e) => {
                        // degrade gracefully; correctness is identical
                        eprintln!("[hiref] pjrt LROT failed ({e}); using native");
                    }
                }
            }
        }
        st.stats.native.fetch_add(1, Ordering::Relaxed);
        let cfg = LrotConfig { rank, ..self.cfg.lrot.clone() };
        let out = lrot::solve_factored_in(u, v, active, active, &cfg, seed, st.arena);
        st.stats.lrot_iters.fetch_add(out.iters, Ordering::Relaxed);
        (out.q, out.r)
    }

    /// Base case: exact assignment inside the block (Hungarian below the
    /// cutoff, ε-scaling auction above), sealing `perm`.  The dense block
    /// cost is written into a scratch-arena buffer straight from the
    /// original points — no owned cost matrix.  On the streaming path the
    /// block's ≤ `base_size` point rows are first gathered from the
    /// sources into arena scratch (the only point rows a streaming solve
    /// ever materialises).
    fn solve_base(&self, points: Points<'_>, block: &Block, st: &SolveState<'_>) {
        if st.has_error() || self.poll_cancel(st) {
            return; // doomed run: don't re-attempt reads block by block
        }
        st.stats.base.fetch_add(1, Ordering::Relaxed);
        let (xs, xe) = (block.x.start as usize, block.x.end as usize);
        let (ys, ye) = (block.y.start as usize, block.y.end as usize);
        let len = xe - xs;
        debug_assert_eq!(len, ye - ys);
        // SAFETY: base blocks are leaves — this worker exclusively owns the
        // window and nothing re-indexes it afterwards.
        let xids = unsafe { st.x_order.slice(xs, xe) };
        let yids = unsafe { st.y_order.slice(ys, ye) };
        let local = if len == 1 {
            vec![0u32]
        } else {
            let mut cbuf = st.arena.take_f32(len * len);
            match points {
                Points::Mats(x, y) => {
                    costs::dense_cost_indexed_into(x, y, xids, yids, self.cfg.cost, &mut cbuf);
                }
                Points::Sources(x, y) => {
                    let d = x.dim();
                    let mut xtile = st.arena.take_f32(len * d);
                    let mut ytile = st.arena.take_f32(len * d);
                    // mid-solve I/O failures surface as a typed error on
                    // the run, not a worker panic
                    let gathered = stream::gather_rows_into(x, xids, &mut xtile)
                        .and_then(|()| stream::gather_rows_into(y, yids, &mut ytile));
                    if let Err(e) = gathered {
                        st.set_error(SolveError::Backend(format!(
                            "dataset read failed gathering a base block: {e}"
                        )));
                        return;
                    }
                    costs::dense_cost_into(
                        MatView::from_slice(len, d, &xtile),
                        MatView::from_slice(len, d, &ytile),
                        self.cfg.cost,
                        &mut cbuf,
                    );
                }
            }
            let c = MatView::from_slice(len, len, &cbuf);
            if len <= self.cfg.hungarian_cutoff {
                exact::hungarian(c)
            } else {
                exact::auction(c, 1.0)
            }
        };
        let mut guard = st.perm.lock().unwrap();
        for (i, &j) in local.iter().enumerate() {
            guard[xids[i] as usize] = yids[j as usize];
        }
    }
}

/// Stable counting-sort reorder of one side's window: factor rows (the
/// block's checked-out lane) and the position→id map move together so
/// that cluster `z`'s members become the contiguous sub-range
/// `offsets[z]..offsets[z]+caps[z]` (order within a cluster preserves the
/// parent's order — the same sequence `assign::split_by_labels` would
/// have produced, without materialising index sets).  Scratch comes from
/// the arena; the two `copy_from_slice` writebacks are the only data
/// movement per split.
fn reorder_window(
    dst_rows: &mut [f32],
    dst_order: &mut [u32],
    k: usize,
    labels: &[u32],
    caps: &[usize],
    arena: &ScratchArena,
) {
    let len = dst_order.len();
    debug_assert_eq!(labels.len(), len);
    debug_assert_eq!(dst_rows.len(), len * k);
    let mut cursor = assign::cluster_offsets(caps);
    let mut srows = arena.take_f32(len * k);
    let mut sorder = arena.take_u32(len);
    for (i, &z) in labels.iter().enumerate() {
        let d = cursor[z as usize];
        cursor[z as usize] += 1;
        srows[d * k..(d + 1) * k].copy_from_slice(&dst_rows[i * k..(i + 1) * k]);
        sorder[d] = dst_order[i];
    }
    dst_rows.copy_from_slice(&srows);
    dst_order.copy_from_slice(&sorder);
}

/// Internal atomics for [`RunStats`].
#[derive(Default)]
struct StatsAtomics {
    lrot: AtomicUsize,
    pjrt: AtomicUsize,
    native: AtomicUsize,
    base: AtomicUsize,
    batches: AtomicUsize,
    lanes_max: AtomicUsize,
    /// LROT block solves that shared a batch with ≥ 1 sibling lane.
    batched_lanes: AtomicUsize,
    /// Warmstart-engine lane clusterings (see `RunStats::cluster_calls`).
    clustered: AtomicUsize,
    /// Native mirror-descent iterations, summed over lanes.
    lrot_iters: AtomicUsize,
}

impl StatsAtomics {
    fn snapshot(&self, elapsed: Duration, arena: &ScratchArena) -> RunStats {
        let lrot_calls = self.lrot.load(Ordering::Relaxed);
        let batched_lanes = self.batched_lanes.load(Ordering::Relaxed);
        RunStats {
            lrot_calls,
            pjrt_calls: self.pjrt.load(Ordering::Relaxed),
            native_calls: self.native.load(Ordering::Relaxed),
            base_calls: self.base.load(Ordering::Relaxed),
            peak_scratch_bytes: arena.peak_bytes(),
            arena_hits: arena.hits(),
            arena_misses: arena.misses(),
            factor_bytes: 0, // filled in by align_inner, as are the
            spill_bytes_written: 0, // store counters below
            spill_reads: 0,
            resident_factor_bytes: 0,
            kernel_path: crate::linalg::kernels::active().as_str(),
            factor_precision: Precision::F32.as_str(), // filled in by align_inner
            iter_spawns: 0, // filled in by align_inner (crew-spawn delta)
            cluster_calls: self.clustered.load(Ordering::Relaxed),
            lrot_iters: self.lrot_iters.load(Ordering::Relaxed),
            level_stats: Vec::new(), // filled in by align_inner
            batches: self.batches.load(Ordering::Relaxed),
            lanes_max: self.lanes_max.load(Ordering::Relaxed),
            batched_frac: if lrot_calls == 0 {
                0.0
            } else {
                batched_lanes as f64 / lrot_calls as f64
            },
            elapsed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn native_cfg() -> HiRefConfig {
        HiRefConfig {
            backend: BackendKind::Native,
            base_size: 32,
            max_rank: 4,
            threads: 2,
            ..Default::default()
        }
    }

    fn shuffled_pair(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, d);
        rng.fill_normal(&mut x.data);
        let perm = rng.permutation(n);
        let mut y = x.gather_rows(&perm);
        for v in y.data.iter_mut() {
            *v += 0.001 * rng.normal_f32();
        }
        (x, y, perm)
    }

    /// Two independent clouds: O(1)-scale bijection costs, so relative
    /// cost comparisons (the precision harness) are well-conditioned.
    fn rand_pair(n: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, d);
        rng.fill_normal(&mut x.data);
        let mut y = Mat::zeros(n, d);
        rng.fill_normal(&mut y.data);
        (x, y)
    }

    #[test]
    fn output_is_bijection() {
        let (x, y, _) = shuffled_pair(300, 2, 0);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        assert_eq!(out.perm.len(), 300);
    }

    #[test]
    fn recovers_near_monge_map_on_shuffled_data() {
        // y is a shuffled copy of x (+tiny noise): the Monge map is the
        // shuffle and its cost ~0.  HiRef must find a near-zero-cost map.
        let (x, y, _) = shuffled_pair(256, 2, 1);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let cost = out.cost(&x, &y, CostKind::SqEuclidean);
        assert!(cost < 0.02, "cost {cost} too high for shuffled data");
    }

    #[test]
    fn matches_exact_solver_on_small_instance() {
        let (x, y, _) = shuffled_pair(64, 2, 2);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let c = costs::dense_cost(&x, &y, CostKind::SqEuclidean);
        let h = exact::hungarian(&c);
        let opt = metrics::bijection_cost(&x, &y, &h, CostKind::SqEuclidean);
        let got = out.cost(&x, &y, CostKind::SqEuclidean);
        assert!(got >= opt - 1e-9);
        assert!(got <= opt.max(1e-6) * 1.5 + 1e-4, "hiref {got} vs opt {opt}");
    }

    #[test]
    fn odd_sizes_work() {
        for n in [33usize, 97, 130] {
            let (x, y, _) = shuffled_pair(n, 2, n as u64);
            let cfg = HiRefConfig { base_size: 16, ..native_cfg() };
            let out = HiRef::new(cfg).align(&x, &y).unwrap();
            assert!(out.is_bijection(), "n={n}");
        }
    }

    #[test]
    fn batched_and_per_block_paths_bit_identical() {
        // the acceptance property: batching(true) — the default — must
        // produce exactly the permutation of the per-block work-queue
        // path, including the in-place re-index orders.
        for (n, base, max_rank) in [(300usize, 32usize, 4usize), (97, 16, 8), (40, 32, 4)] {
            let (x, y, _) = shuffled_pair(n, 2, n as u64);
            let cfg_b = HiRefConfig { base_size: base, max_rank, ..native_cfg() };
            let cfg_q = HiRefConfig { batching: false, ..cfg_b.clone() };
            let a = HiRef::new(cfg_b).align(&x, &y).unwrap();
            let b = HiRef::new(cfg_q).align(&x, &y).unwrap();
            assert_eq!(a.perm, b.perm, "n={n} base={base} C={max_rank}");
            assert_eq!(a.x_order, b.x_order, "n={n}");
            assert_eq!(a.y_order, b.y_order, "n={n}");
            // same solver work on both paths
            assert_eq!(a.stats.lrot_calls, b.stats.lrot_calls);
            assert_eq!(a.stats.base_calls, b.stats.base_calls);
        }
    }

    #[test]
    fn batch_stats_reported() {
        let (x, y, _) = shuffled_pair(256, 2, 13);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        // base 32, C 4 over 256 points: deeper levels have many same-shape
        // sibling blocks, so real multi-lane batches must occur
        assert!(out.stats.batches > 0, "no batches recorded");
        assert!(out.stats.lanes_max >= 2, "lanes_max {}", out.stats.lanes_max);
        assert!(out.stats.batched_frac > 0.0);
        assert!(out.stats.batched_frac <= 1.0);
        // the per-block path reports an unbatched run
        let cfg = HiRefConfig { batching: false, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert_eq!(out.stats.batches, 0);
        assert_eq!(out.stats.lanes_max, 0);
        assert_eq!(out.stats.batched_frac, 0.0);
    }

    #[test]
    fn single_block_problem_runs_as_one_lane_batch() {
        // n ≤ base_size: the level scheduler sees one base block and no
        // LROT batches at all; n slightly above: the root is a 1-lane batch
        let (x, y, _) = shuffled_pair(30, 2, 14);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        assert_eq!(out.stats.batches, 0);
        let (x, y, _) = shuffled_pair(40, 2, 15);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        assert!(out.stats.batches >= 1);
        assert_eq!(out.stats.batched_frac, 0.0, "root lane is a singleton batch");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y, _) = shuffled_pair(128, 2, 5);
        let a = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let b = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert_eq!(a.perm, b.perm);
        assert_eq!(a.x_order, b.x_order);
        assert_eq!(a.y_order, b.y_order);
    }

    #[test]
    fn align_source_identical_to_align_for_any_chunk_size() {
        use crate::data::stream::InMemorySource;
        let (x, y, _) = shuffled_pair(300, 2, 21);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        for chunk in [1usize, 17, 300, 1 << 16] {
            let cfg = HiRefConfig { chunk_rows: chunk, ..native_cfg() };
            let out = HiRef::new(cfg)
                .align_source(&InMemorySource::new(&x), &InMemorySource::new(&y))
                .unwrap();
            assert_eq!(out.perm, want.perm, "chunk {chunk}");
            assert_eq!(out.x_order, want.x_order, "chunk {chunk}");
            assert!(out.stats.factor_bytes > 0);
        }
    }

    #[test]
    fn align_source_euclidean_cost_matches_in_memory() {
        use crate::data::stream::InMemorySource;
        let (x, y, _) = shuffled_pair(200, 3, 22);
        let cfg = HiRefConfig { cost: CostKind::Euclidean, indyk_width: 8, ..native_cfg() };
        let want = HiRef::new(cfg.clone()).align(&x, &y).unwrap();
        let cfg = HiRefConfig { chunk_rows: 23, ..cfg };
        let out = HiRef::new(cfg)
            .align_source(&InMemorySource::new(&x), &InMemorySource::new(&y))
            .unwrap();
        // chunked Indyk factors are identical, so so is the bijection
        assert_eq!(out.perm, want.perm);
    }

    #[test]
    fn align_source_from_generator_is_bijective_and_deterministic() {
        use crate::data::stream::GeneratorSource;
        let gen = |side: u64| {
            GeneratorSource::new(257, 2, move |i, out| {
                let mut rng = crate::prng::Rng::new(
                    side ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                rng.fill_normal(out);
            })
        };
        let solver = HiRef::new(HiRefConfig { chunk_rows: 31, ..native_cfg() });
        let a = solver.align_source(&gen(1), &gen(2)).unwrap();
        let b = solver.align_source(&gen(1), &gen(2)).unwrap();
        assert!(a.is_bijection());
        assert_eq!(a.perm, b.perm);
    }

    #[test]
    fn align_source_surfaces_mid_solve_read_errors() {
        use crate::data::stream::DatasetSource;
        // bulk tile sweeps (factorisation) succeed; the scattered base-case
        // gather fails — the run must end in a typed Backend error, not a
        // worker panic and not an IncompleteAssignment.
        struct GatherFails;
        impl DatasetSource for GatherFails {
            fn rows(&self) -> usize {
                64
            }
            fn dim(&self) -> usize {
                2
            }
            fn fill_rows(&self, start: usize, out: &mut [f32]) -> std::io::Result<()> {
                for (o, row) in out.chunks_mut(2).enumerate() {
                    row[0] = ((start + o) % 13) as f32;
                    row[1] = ((start + o) % 7) as f32;
                }
                Ok(())
            }
            fn fetch_row(&self, _i: usize, _out: &mut [f32]) -> std::io::Result<()> {
                Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "disk vanished"))
            }
        }
        let err = HiRef::new(native_cfg()).align_source(&GatherFails, &GatherFails).unwrap_err();
        assert!(matches!(err, SolveError::Backend(_)), "{err:?}");
        // a source failing during factorisation sweeps errors too
        struct FillFails;
        impl DatasetSource for FillFails {
            fn rows(&self) -> usize {
                64
            }
            fn dim(&self) -> usize {
                2
            }
            fn fill_rows(&self, _start: usize, _out: &mut [f32]) -> std::io::Result<()> {
                Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "gone"))
            }
        }
        let err = HiRef::new(native_cfg()).align_source(&FillFails, &FillFails).unwrap_err();
        assert!(matches!(err, SolveError::Backend(_)), "{err:?}");
    }

    #[test]
    fn align_prefactored_matches_align() {
        let (x, y, _) = shuffled_pair(150, 2, 23);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let (fu, fv) = costs::factors_for(&x, &y, CostKind::SqEuclidean, 32, 0);
        let out = HiRef::new(native_cfg()).align_prefactored(fu, fv, &x, &y).unwrap();
        assert_eq!(out.perm, want.perm);
        // shape-mismatched factors are rejected
        let (fu, fv) = costs::factors_for(&x, &y, CostKind::SqEuclidean, 32, 0);
        let (bad, _, _) = shuffled_pair(151, 2, 24);
        assert!(HiRef::new(native_cfg()).align_prefactored(fu, fv, &bad, &bad).is_err());
    }

    #[test]
    fn align_prefactored_source_matches_align() {
        use crate::data::stream::InMemorySource;
        let (x, y, _) = shuffled_pair(150, 2, 29);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let (fu, fv) = costs::factors_for(&x, &y, CostKind::SqEuclidean, 32, 0);
        let out = HiRef::new(native_cfg())
            .align_prefactored_source(fu, fv, &InMemorySource::new(&x), &InMemorySource::new(&y))
            .unwrap();
        assert_eq!(out.perm, want.perm);
        assert_eq!(out.x_order, want.x_order);
        assert_eq!(out.y_order, want.y_order);
        // shape-mismatched factors are rejected
        let (fu, fv) = costs::factors_for(&x, &y, CostKind::SqEuclidean, 32, 0);
        let (bad, _, _) = shuffled_pair(151, 2, 24);
        assert!(matches!(
            HiRef::new(native_cfg()).align_prefactored_source(
                fu,
                fv,
                &InMemorySource::new(&bad),
                &InMemorySource::new(&bad)
            ),
            Err(SolveError::InvalidConfig(_))
        ));
    }

    #[test]
    fn hooks_cancellation_is_typed_and_prompt() {
        // a hook that cancels after a few polls: the run must abort with
        // the typed error, and a fresh solve on the same inputs must be
        // unaffected (no corrupted shared state anywhere)
        struct CancelAfter(AtomicUsize, usize);
        impl SolveHooks for CancelAfter {
            fn cancelled(&self) -> bool {
                self.0.fetch_add(1, Ordering::Relaxed) + 1 > self.1
            }
        }
        let (x, y, _) = shuffled_pair(300, 2, 31);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        for polls in [0usize, 2] {
            let solver =
                HiRef::new(native_cfg()).with_hooks(Arc::new(CancelAfter(AtomicUsize::new(0), polls)));
            match solver.align(&x, &y) {
                Err(SolveError::Cancelled) => {}
                other => panic!("expected Cancelled after {polls} polls, got {other:?}"),
            }
        }
        // a hook that never fires leaves the run bit-identical
        struct Never;
        impl SolveHooks for Never {}
        let out = HiRef::new(native_cfg()).with_hooks(Arc::new(Never)).align(&x, &y).unwrap();
        assert_eq!(out.perm, want.perm);
    }

    #[test]
    fn hooks_lrot_batch_takeover_is_bit_identical() {
        // an external hook that re-solves every batch itself — through a
        // different arena and thread count — must reproduce the engine's
        // output bit for bit (the serve microbatcher's correctness
        // property: lane solves are independent of execution context)
        struct Takeover {
            arena: ScratchArena,
            calls: AtomicUsize,
        }
        impl SolveHooks for Takeover {
            fn lrot_batch(
                &self,
                u: BatchView<'_>,
                v: BatchView<'_>,
                active: usize,
                cfg: &LrotConfig,
                seeds: &[u64],
            ) -> Option<Vec<(Mat, Mat)>> {
                self.calls.fetch_add(1, Ordering::Relaxed);
                let actives = vec![(active, active); u.len()];
                Some(
                    lrot::solve_factored_batch(u, v, &actives, cfg, seeds, &self.arena, 3)
                        .into_iter()
                        .map(|o| (o.q, o.r))
                        .collect(),
                )
            }
        }
        let (x, y, _) = shuffled_pair(260, 3, 37);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let hook = Arc::new(Takeover { arena: ScratchArena::new(3), calls: AtomicUsize::new(0) });
        let out = HiRef::new(native_cfg()).with_hooks(hook.clone()).align(&x, &y).unwrap();
        assert!(hook.calls.load(Ordering::Relaxed) > 0, "hook never dispatched a batch");
        assert_eq!(out.perm, want.perm);
        assert_eq!(out.x_order, want.x_order);
        assert_eq!(out.y_order, want.y_order);
    }

    #[test]
    fn mismatched_sizes_error() {
        let (x, _, _) = shuffled_pair(16, 2, 6);
        let (y, _, _) = shuffled_pair(17, 2, 7);
        assert!(HiRef::new(native_cfg()).align(&x, &y).is_err());
    }

    fn spill_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hiref_spill_test_{}_{tag}", std::process::id()))
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill dirs need real file I/O")]
    fn spill_run_bit_identical_to_resident() {
        let (x, y, _) = shuffled_pair(300, 2, 30);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let dir = spill_dir("identical");
        // budget 0 forces a disk read for every checkout; 4 KiB forces
        // eviction at every level; 16 MiB caches everything
        for budget in [0usize, 4096, 1 << 24] {
            let cfg = HiRefConfig {
                spill: Some(SpillConfig { dir: dir.clone(), budget_bytes: budget }),
                ..native_cfg()
            };
            let out = HiRef::new(cfg).align(&x, &y).unwrap();
            assert_eq!(out.perm, want.perm, "budget {budget}");
            assert_eq!(out.x_order, want.x_order, "budget {budget}");
            assert_eq!(out.y_order, want.y_order, "budget {budget}");
            assert!(out.stats.spill_bytes_written > 0, "nothing was spilled");
            if budget == 0 {
                assert!(out.stats.spill_reads > 0, "budget 0 must read from disk");
            }
            // the acceptance bound: cache budget + in-flight lane windows
            // (the root batch pins one full-side lane per side)
            assert!(
                out.stats.resident_factor_bytes <= budget + out.stats.factor_bytes,
                "resident {} > budget {budget} + factors {}",
                out.stats.resident_factor_bytes,
                out.stats.factor_bytes
            );
        }
        // the resident run reports zero spill traffic
        assert_eq!(want.stats.spill_bytes_written, 0);
        assert_eq!(want.stats.spill_reads, 0);
        assert_eq!(want.stats.resident_factor_bytes, want.stats.factor_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill dirs need real file I/O")]
    fn spill_per_block_path_bit_identical_too() {
        let (x, y, _) = shuffled_pair(200, 2, 31);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let dir = spill_dir("perblock");
        let cfg = HiRefConfig {
            batching: false,
            spill: Some(SpillConfig { dir: dir.clone(), budget_bytes: 2048 }),
            ..native_cfg()
        };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert_eq!(out.perm, want.perm);
        assert_eq!(out.x_order, want.x_order);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn explicit_f32_precision_is_bit_identical_to_default() {
        // the F32 default regression: `factor_precision: F32` must be the
        // same zero-copy code path as an untouched config, bit for bit
        let (x, y, _) = shuffled_pair(200, 2, 45);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert_eq!(want.stats.factor_precision, "f32");
        let cfg = HiRefConfig { factor_precision: Precision::F32, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert_eq!(out.perm, want.perm);
        assert_eq!(out.x_order, want.x_order);
        assert_eq!(out.y_order, want.y_order);
        assert_eq!(out.stats.factor_bytes, want.stats.factor_bytes);
        assert_eq!(out.stats.resident_factor_bytes, want.stats.resident_factor_bytes);
    }

    #[test]
    fn low_precision_cost_within_tolerance_of_f32_across_configs() {
        // the precision-accuracy harness: quantising the stored factors
        // perturbs the cost model, not the solver, so the low-precision
        // bijection must stay near-optimal — within 5% relative cost of
        // the f32 run across sizes, base blocks, ranks and thread counts.
        // Independent clouds keep the optimal cost O(1) so the relative
        // comparison is well-conditioned (a shuffled pair's near-zero
        // cost would make any ratio meaningless).
        for (n, base_size, max_rank, threads) in
            [(160usize, 32usize, 4usize, 1usize), (256, 32, 8, 2), (97, 16, 4, 2)]
        {
            let (x, y) = rand_pair(n, 3, 40 + n as u64);
            let cfg = HiRefConfig { base_size, max_rank, threads, ..native_cfg() };
            let f32_out = HiRef::new(cfg.clone()).align(&x, &y).unwrap();
            let c_f32 = f32_out.cost(&x, &y, CostKind::SqEuclidean);
            for prec in [Precision::Bf16, Precision::F16] {
                let cfg = HiRefConfig { factor_precision: prec, ..cfg.clone() };
                let out = HiRef::new(cfg).align(&x, &y).unwrap();
                assert!(out.is_bijection(), "{} n={n}", prec.as_str());
                assert_eq!(out.stats.factor_precision, prec.as_str());
                // two-byte elements: exactly half the persistent footprint
                assert_eq!(out.stats.factor_bytes * 2, f32_out.stats.factor_bytes);
                assert_eq!(
                    out.stats.resident_factor_bytes * 2,
                    f32_out.stats.resident_factor_bytes
                );
                let c = out.cost(&x, &y, CostKind::SqEuclidean);
                let rel = (c - c_f32).abs() / c_f32.max(1e-6);
                assert!(
                    rel < 0.05,
                    "{} n={n} base={base_size} rank={max_rank}: cost {c} vs f32 {c_f32} (rel {rel:.4})",
                    prec.as_str()
                );
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill dirs need real file I/O")]
    fn bf16_spill_bit_identical_to_bf16_resident_and_halves_spill_traffic() {
        // bit-identity across execution strategies holds *per precision*:
        // a bf16 spilled run replays the bf16 resident run exactly, at
        // every cache budget, while writing half the bytes of f32 spill
        let (x, y) = rand_pair(200, 2, 44);
        let bf16_cfg = HiRefConfig { factor_precision: Precision::Bf16, ..native_cfg() };
        let want = HiRef::new(bf16_cfg.clone()).align(&x, &y).unwrap();
        let c_want = want.cost(&x, &y, CostKind::SqEuclidean);
        let dir = spill_dir("bf16");
        let mut bf16_written = 0;
        for budget in [0usize, 4096, 1 << 24] {
            let cfg = HiRefConfig {
                spill: Some(SpillConfig { dir: dir.clone(), budget_bytes: budget }),
                ..bf16_cfg.clone()
            };
            let out = HiRef::new(cfg).align(&x, &y).unwrap();
            assert_eq!(out.perm, want.perm, "budget {budget}");
            assert_eq!(out.x_order, want.x_order, "budget {budget}");
            assert_eq!(out.y_order, want.y_order, "budget {budget}");
            assert!(out.stats.spill_bytes_written > 0, "nothing was spilled");
            assert!(
                out.stats.resident_factor_bytes <= budget + out.stats.factor_bytes,
                "resident {} > budget {budget} + factors {}",
                out.stats.resident_factor_bytes,
                out.stats.factor_bytes
            );
            assert!((out.cost(&x, &y, CostKind::SqEuclidean) - c_want).abs() < 1e-9);
            bf16_written = out.stats.spill_bytes_written;
        }
        // the hierarchy shape (levels, blocks, dirty releases) depends only
        // on sizes, so an f32 run at the same budget writes the same lane
        // rows — at twice the element width
        let f32_cfg = HiRefConfig {
            spill: Some(SpillConfig { dir: dir.clone(), budget_bytes: 1 << 24 }),
            ..native_cfg()
        };
        let f32_out = HiRef::new(f32_cfg).align(&x, &y).unwrap();
        assert_eq!(bf16_written * 2, f32_out.stats.spill_bytes_written);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill dirs need real file I/O")]
    fn spill_align_source_identical_and_streams_factors() {
        use crate::data::stream::InMemorySource;
        let (x, y, _) = shuffled_pair(257, 2, 32);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let dir = spill_dir("source");
        let cfg = HiRefConfig {
            chunk_rows: 19,
            spill: Some(SpillConfig { dir: dir.clone(), budget_bytes: 4096 }),
            ..native_cfg()
        };
        let out = HiRef::new(cfg)
            .align_source(&InMemorySource::new(&x), &InMemorySource::new(&y))
            .unwrap();
        assert_eq!(out.perm, want.perm);
        // the chunked builders wrote the factor tiles straight to disk
        assert!(out.stats.spill_bytes_written >= out.stats.factor_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill dirs need real file I/O")]
    fn spill_euclidean_cost_identical() {
        // the Indyk builder reads sampled U rows back through the store —
        // exercise that path end to end
        let (x, y, _) = shuffled_pair(150, 3, 33);
        let cfg = HiRefConfig { cost: CostKind::Euclidean, indyk_width: 8, ..native_cfg() };
        let want = HiRef::new(cfg.clone()).align(&x, &y).unwrap();
        let dir = spill_dir("euclid");
        let cfg = HiRefConfig {
            spill: Some(SpillConfig { dir: dir.clone(), budget_bytes: 0 }),
            ..cfg
        };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert_eq!(out.perm, want.perm);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill dirs need real file I/O")]
    fn spill_dir_under_a_file_errors_as_backend() {
        let dir = spill_dir("badroot");
        std::fs::create_dir_all(&dir).unwrap();
        let file_path = dir.join("not_a_dir");
        std::fs::write(&file_path, b"x").unwrap();
        let (x, y, _) = shuffled_pair(64, 2, 34);
        let cfg = HiRefConfig {
            spill: Some(SpillConfig { dir: file_path.join("sub"), budget_bytes: 0 }),
            ..native_cfg()
        };
        let err = HiRef::new(cfg).align(&x, &y).unwrap_err();
        assert!(matches!(err, SolveError::Backend(_)), "{err:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn final_orders_are_permutations() {
        let (x, y, _) = shuffled_pair(150, 2, 11);
        let cfg = HiRefConfig { base_size: 16, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        for order in [&out.x_order, &out.y_order] {
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..150u32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn arena_stats_reported() {
        let (x, y, _) = shuffled_pair(256, 2, 12);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert!(out.stats.peak_scratch_bytes > 0);
        assert!(out.stats.arena_hits + out.stats.arena_misses > 0);
        // many blocks reuse the same capacity classes: the freelists must
        // serve the bulk of checkouts after warm-up
        assert!(out.stats.arena_hit_rate() > 0.5, "{}", out.stats.arena_hit_rate());
        let rate = out.stats.arena_hit_rate();
        assert!((0.0..=1.0).contains(&rate));
    }

    #[test]
    fn scales_recorded_when_asked() {
        let (x, y, _) = shuffled_pair(128, 2, 8);
        let cfg = HiRefConfig { record_scales: true, base_size: 16, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        let scales = out.scales.as_ref().unwrap();
        assert!(!scales.is_empty());
        // scale 0 is the root co-cluster
        assert_eq!(scales[0].len(), 1);
        assert_eq!(scales[0][0].0.len(), 128);
        // each subsequent recorded scale partitions all points
        for lvl in scales.iter().take(out.schedule.len() + 1) {
            if lvl.is_empty() { continue; }
            let total: usize = lvl.iter().map(|(xs, _)| xs.len()).sum();
            assert_eq!(total, 128);
        }
    }

    #[test]
    fn euclidean_cost_path_works() {
        let (x, y, _) = shuffled_pair(150, 3, 9);
        let cfg = HiRefConfig { cost: CostKind::Euclidean, indyk_width: 8, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        let cost = out.cost(&x, &y, CostKind::Euclidean);
        // shuffled copy: near-zero optimal cost
        assert!(cost < 0.25, "euclidean cost {cost}");
    }

    #[test]
    fn refinement_monotone_improves_over_root(){
        // Prop 3.4 lower bound: finer scales do not increase cost.
        let (x, y, _) = shuffled_pair(256, 2, 10);
        let cfg = HiRefConfig { record_scales: true, base_size: 16, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        let scales = out.scales.as_ref().unwrap();
        let mut costs_per_scale = Vec::new();
        for lvl in scales {
            if lvl.is_empty() { continue; }
            let total: usize = lvl.iter().map(|(a, _)| a.len()).sum();
            if total != 256 { continue; }
            costs_per_scale.push(metrics::block_coupling_cost(
                &x, &y, lvl, CostKind::SqEuclidean));
        }
        assert!(costs_per_scale.len() >= 2);
        for w in costs_per_scale.windows(2) {
            assert!(w[1] <= w[0] * 1.05 + 1e-6, "scale cost increased: {w:?}");
        }
        // final bijection is at least as good as the last block coupling
        let final_cost = out.cost(&x, &y, CostKind::SqEuclidean);
        assert!(final_cost <= costs_per_scale.last().unwrap() + 1e-6);
    }

    #[test]
    fn explicit_warmstart_zero_is_bit_identical_to_default() {
        // the cold-path regression: `warmstart_levels: 0` must be the same
        // code path as an untouched config, bit for bit — no stray RNG
        // draws, no extra float work anywhere in the pipeline
        let (x, y, _) = shuffled_pair(300, 2, 46);
        let want = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let cfg = HiRefConfig { warmstart_levels: 0, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert_eq!(out.perm, want.perm);
        assert_eq!(out.x_order, want.x_order);
        assert_eq!(out.y_order, want.y_order);
        assert_eq!(out.stats.lrot_iters, want.stats.lrot_iters);
        // the cold run never clusters and never flags a level as warm
        assert_eq!(want.stats.cluster_calls, 0);
        assert!(want.stats.level_stats.iter().all(|ls| !ls.warmstarted));
    }

    #[test]
    fn warmstart_level_stats_record_clustered_scales() {
        let (x, y, _) = shuffled_pair(256, 2, 47);
        let cold = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let cfg = HiRefConfig { warmstart_levels: 1, ..native_cfg() };
        let warm = HiRef::new(cfg).align(&x, &y).unwrap();
        assert!(warm.is_bijection());
        // one LevelStat per batched level, for both runs, and the child
        // geometry is warmstart-invariant: identical blocks and lanes
        assert!(warm.stats.level_stats.len() >= 2, "need a boundary level below the clustered one");
        assert_eq!(cold.stats.level_stats.len(), warm.stats.level_stats.len());
        for (c, w) in cold.stats.level_stats.iter().zip(&warm.stats.level_stats) {
            assert_eq!(c.level, w.level);
            assert_eq!(c.blocks, w.blocks);
            assert_eq!(c.lanes, w.lanes);
            assert!(!c.warmstarted);
        }
        // the clustered scale ran no mirror descent at all; cold did
        let w0 = &warm.stats.level_stats[0];
        assert!(w0.warmstarted);
        assert_eq!(w0.lrot_iters, 0);
        assert!(cold.stats.level_stats[0].lrot_iters > 0);
        // the boundary level starts its descent from the lane clusterings
        let w1 = &warm.stats.level_stats[1];
        assert!(w1.warmstarted);
        assert!(w1.lrot_iters > 0);
        assert!(warm.stats.cluster_calls > 0);
        assert_eq!(cold.stats.cluster_calls, 0);
        // the per-level records account for every native descent iteration
        assert_eq!(
            warm.stats.lrot_iters,
            warm.stats.level_stats.iter().map(|l| l.lrot_iters).sum::<usize>()
        );
        assert_eq!(
            cold.stats.lrot_iters,
            cold.stats.level_stats.iter().map(|l| l.lrot_iters).sum::<usize>()
        );
    }

    #[test]
    fn warmstart_cost_within_tolerance_across_configs() {
        // the approximation contract (docs/warmstart.md): clustered coarse
        // scales keep the final bijection within 5% relative cost of the
        // exact path across sizes, base blocks, ranks, thread counts and
        // factor precisions.  Independent clouds keep the optimal cost
        // O(1) so the relative comparison is well-conditioned.
        for (n, base_size, max_rank, threads) in
            [(256usize, 32usize, 4usize, 2usize), (384, 32, 8, 1), (200, 16, 4, 4)]
        {
            let (x, y) = rand_pair(n, 3, 50 + n as u64);
            let base_cfg = HiRefConfig { base_size, max_rank, threads, ..native_cfg() };
            for prec in [Precision::F32, Precision::Bf16] {
                let cfg = HiRefConfig { factor_precision: prec, ..base_cfg.clone() };
                let exact = HiRef::new(cfg.clone()).align(&x, &y).unwrap();
                let c_exact = exact.cost(&x, &y, CostKind::SqEuclidean);
                for levels in [1usize, 2] {
                    let cfg = HiRefConfig { warmstart_levels: levels, ..cfg.clone() };
                    let out = HiRef::new(cfg).align(&x, &y).unwrap();
                    assert!(out.is_bijection(), "n={n} w={levels}");
                    assert!(out.stats.cluster_calls > 0, "n={n} w={levels}: nothing clustered");
                    let c = out.cost(&x, &y, CostKind::SqEuclidean);
                    let rel = (c - c_exact).abs() / c_exact.max(1e-6);
                    assert!(
                        rel < 0.05,
                        "{} n={n} base={base_size} C={max_rank} w={levels}: \
                         cost {c} vs exact {c_exact} (rel {rel:.4})",
                        prec.as_str()
                    );
                }
            }
        }
    }

    #[test]
    fn warmstart_deeper_than_schedule_clamps_and_stays_valid() {
        // asking for more clustered levels than the schedule has must not
        // panic or leave LROT batches expecting a warm boundary that never
        // comes — every refine level is clustered, the base case is exact
        let (x, y, _) = shuffled_pair(200, 2, 48);
        let cfg = HiRefConfig { warmstart_levels: 99, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        // every level that ran lanes ran them clustered (base-only tail
        // levels have no lanes and carry no flag)
        assert!(out.stats.level_stats.iter().all(|ls| ls.lanes == 0 || ls.warmstarted));
        assert!(out.stats.level_stats.iter().any(|ls| ls.warmstarted));
        assert_eq!(out.stats.lrot_iters, 0, "a fully clustered run solves no LROT");
    }
}
