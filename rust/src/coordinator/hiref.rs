//! The Hierarchical Refinement engine — paper Algorithm 1/2.
//!
//! Starting from the trivial co-clustering `Γ_0 = {(X, Y)}`, each scale
//! splits every co-cluster `(X_q, Y_q)` with a rank-`r_{t+1}` LROT solve
//! whose factors co-cluster Monge pairs (Prop. 3.1); balanced assignment
//! ([`super::assign`]) turns the factors into `r_{t+1}` equal-sized child
//! pairs.  Blocks that reach the base size are sealed with an *exact*
//! assignment solver.  The output is a bijection — `n` nonzeros, never an
//! `n×n` matrix: linear space, and `O(n log n)` time for bounded ranks
//! (paper §3.4).
//!
//! Co-clusters at the same scale are independent, so the engine fans them
//! out over a work-queue thread pool; LROT solves are served either by the
//! PJRT runtime (AOT artifacts from the JAX/Pallas layers) or by the
//! native Rust solver, per block, whichever fits (`BackendKind::Auto`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::SolveError;
use crate::coordinator::annealing;
use crate::coordinator::assign;
use crate::costs::{self, CostKind};
use crate::linalg::Mat;
use crate::metrics;
use crate::pool::{self, WorkQueue};
use crate::runtime::PjrtEngine;
use crate::solvers::exact;
use crate::solvers::lrot::{self, LrotConfig};

/// Which LROT backend serves refinement sub-problems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust mirror descent ([`crate::solvers::lrot`]).
    Native,
    /// AOT artifacts through PJRT; error if an artifact is missing.
    Pjrt,
    /// PJRT when a bucket fits, native otherwise (default).
    Auto,
}

/// Configuration for [`HiRef`].
#[derive(Clone, Debug)]
pub struct HiRefConfig {
    /// Ground cost (paper uses both `‖·‖₂` and `‖·‖₂²`).
    pub cost: CostKind,
    /// Maximal intermediate rank C of the annealing schedule.
    pub max_rank: usize,
    /// Maximal base-case block (paper's "maximal base rank Q"): blocks of
    /// at most this size are finished by the exact solver.
    pub base_size: usize,
    /// Optional cap on the hierarchy depth κ.
    pub max_depth: Option<usize>,
    /// Blocks up to this size use Hungarian; larger base blocks use the
    /// ε-scaling auction (near-exact, much faster).
    pub hungarian_cutoff: usize,
    /// LROT hyper-parameters (rank is overridden per scale).
    pub lrot: LrotConfig,
    /// Factor width for non-factorisable costs (Indyk et al. 2019).
    pub indyk_width: usize,
    pub seed: u64,
    pub threads: usize,
    pub backend: BackendKind,
    /// Where the AOT artifacts live (manifest.tsv + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Record the co-clustering Γ_t at every scale (Fig. S3 diagnostics;
    /// costs O(n) extra memory per scale).
    pub record_scales: bool,
}

impl Default for HiRefConfig {
    fn default() -> Self {
        HiRefConfig {
            cost: CostKind::SqEuclidean,
            max_rank: 16,
            base_size: 256,
            max_depth: None,
            hungarian_cutoff: 128,
            lrot: LrotConfig::default(),
            indyk_width: 32,
            seed: 0,
            threads: pool::default_threads(),
            backend: BackendKind::Auto,
            artifacts_dir: PathBuf::from("artifacts"),
            record_scales: false,
        }
    }
}

/// Counters from a run.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub lrot_calls: usize,
    pub pjrt_calls: usize,
    pub native_calls: usize,
    pub base_calls: usize,
    pub elapsed: Duration,
}

/// Result of [`HiRef::align`]: a bijection plus diagnostics.
pub struct Alignment {
    /// `perm[i] = j` pairs `x_i ↔ y_j`; exactly the paper's output
    /// `{(x_i, T(x_i))}` — n nonzeros.
    pub perm: Vec<u32>,
    /// The rank-annealing schedule used.
    pub schedule: Vec<usize>,
    pub stats: RunStats,
    /// Γ_t per scale when `record_scales` was set: the co-cluster index
    /// pairs entering each scale.
    pub scales: Option<Vec<Vec<(Vec<u32>, Vec<u32>)>>>,
}

impl Alignment {
    /// Primal transport cost ⟨C, P⟩ of the bijection (linear space/time).
    pub fn cost(&self, x: &Mat, y: &Mat, kind: CostKind) -> f64 {
        metrics::bijection_cost(x, y, &self.perm, kind)
    }

    /// Verify the output is a bijection.
    pub fn is_bijection(&self) -> bool {
        let n = self.perm.len();
        let mut seen = vec![false; n];
        self.perm.iter().all(|&j| {
            let j = j as usize;
            j < n && !std::mem::replace(&mut seen[j], true)
        })
    }
}

/// The Hierarchical Refinement solver.
pub struct HiRef {
    cfg: HiRefConfig,
    engine: Option<Arc<PjrtEngine>>,
}

struct Block {
    xs: Vec<u32>,
    ys: Vec<u32>,
    level: usize,
}

impl HiRef {
    /// Build a solver; loads the PJRT artifact registry when the backend
    /// allows it (Auto silently degrades to native if artifacts are
    /// absent, Pjrt errors at align time).
    pub fn new(cfg: HiRefConfig) -> HiRef {
        let engine = match cfg.backend {
            BackendKind::Native => None,
            BackendKind::Pjrt | BackendKind::Auto => {
                PjrtEngine::load(&cfg.artifacts_dir).ok().map(Arc::new)
            }
        };
        HiRef { cfg, engine }
    }

    /// Borrow the loaded PJRT engine, if any.
    pub fn engine(&self) -> Option<&Arc<PjrtEngine>> {
        self.engine.as_ref()
    }

    /// Compute a bijective alignment between equal-sized `x` and `y`.
    pub fn align(&self, x: &Mat, y: &Mat) -> Result<Alignment, SolveError> {
        let n = x.rows;
        if n == 0 || y.rows == 0 {
            return Err(SolveError::EmptyInput);
        }
        if n != y.rows {
            return Err(SolveError::ShapeMismatch { n, m: y.rows });
        }
        if x.cols != y.cols {
            return Err(SolveError::DimMismatch { dx: x.cols, dy: y.cols });
        }
        if self.cfg.backend == BackendKind::Pjrt && self.engine.is_none() {
            return Err(SolveError::Backend(format!(
                "backend = Pjrt but artifacts not loadable from {} (run `make artifacts`)",
                self.cfg.artifacts_dir.display()
            )));
        }
        let t0 = Instant::now();

        // Global cost factors; sub-blocks gather rows (both factorisations
        // are row-separable, so gathering is exact).
        let (fu, fv) =
            costs::factors_for(x, y, self.cfg.cost, self.cfg.indyk_width, self.cfg.seed);

        let schedule = annealing::optimal_rank_schedule(
            n,
            self.cfg.base_size,
            self.cfg.max_rank,
            self.cfg.max_depth,
        );

        let perm = Mutex::new(vec![u32::MAX; n]);
        let scales: Option<Vec<Mutex<Vec<(Vec<u32>, Vec<u32>)>>>> = if self.cfg.record_scales {
            Some((0..=schedule.len()).map(|_| Mutex::new(Vec::new())).collect())
        } else {
            None
        };
        let stats = StatsAtomics::default();

        let root = Block { xs: (0..n as u32).collect(), ys: (0..n as u32).collect(), level: 0 };
        let queue = WorkQueue::new(vec![root]);
        queue.run(self.cfg.threads, |block, queue| {
            if let Some(sc) = &scales {
                if block.level < sc.len() {
                    sc[block.level]
                        .lock()
                        .unwrap()
                        .push((block.xs.clone(), block.ys.clone()));
                }
            }
            if block.xs.len() <= self.cfg.base_size || block.level >= schedule.len() {
                self.solve_base(x, y, &block, &perm, &stats);
            } else {
                self.refine(&fu, &fv, &schedule, block, queue, &stats);
            }
        });

        let perm = perm.into_inner().unwrap();
        debug_assert!(perm.iter().all(|&j| j != u32::MAX), "unassigned points");
        Ok(Alignment {
            perm,
            schedule,
            stats: stats.snapshot(t0.elapsed()),
            scales: scales
                .map(|sc| sc.into_iter().map(|m| m.into_inner().unwrap()).collect()),
        })
    }

    /// One refinement step: LROT on the co-cluster, balanced assignment,
    /// enqueue the children (Algorithm 1, lines 8–17).
    fn refine(
        &self,
        fu: &Mat,
        fv: &Mat,
        schedule: &[usize],
        block: Block,
        queue: &WorkQueue<Block>,
        stats: &StatsAtomics,
    ) {
        let level = block.level;
        // Rank at this scale: schedule entry, clamped so a block is never
        // split into more parts than it has points.
        let rank = schedule[level].min(block.xs.len()).max(2);
        let active = block.xs.len();
        let u_blk = fu.gather_rows(&block.xs);
        let v_blk = fv.gather_rows(&block.ys);
        // per-block deterministic seed
        let seed = self
            .cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((level as u64) << 32)
            .wrapping_add(block.xs[0] as u64);

        stats.lrot.fetch_add(1, Ordering::Relaxed);
        let (q, rmat) = self.solve_lrot(&u_blk, &v_blk, active, rank, seed, stats);

        let labels_x = assign::balanced_assign(&q, active);
        let labels_y = assign::balanced_assign(&rmat, active);
        let children_x = assign::split_by_labels(&block.xs, &labels_x, rank);
        let children_y = assign::split_by_labels(&block.ys, &labels_y, rank);
        for (cx, cy) in children_x.into_iter().zip(children_y) {
            debug_assert_eq!(cx.len(), cy.len(), "unbalanced children");
            if !cx.is_empty() {
                queue.push(Block { xs: cx, ys: cy, level: level + 1 });
            }
        }
    }

    /// LROT dispatch: PJRT bucket when available, else native.
    fn solve_lrot(
        &self,
        u_blk: &Mat,
        v_blk: &Mat,
        active: usize,
        rank: usize,
        seed: u64,
        stats: &StatsAtomics,
    ) -> (Mat, Mat) {
        if self.cfg.backend != BackendKind::Native {
            if let Some(engine) = &self.engine {
                match engine.lrot(u_blk, v_blk, active, active, rank, seed) {
                    Ok(Some(qr)) => {
                        stats.pjrt.fetch_add(1, Ordering::Relaxed);
                        return qr;
                    }
                    Ok(None) => {} // no bucket: fall through to native
                    Err(e) => {
                        // degrade gracefully; correctness is identical
                        eprintln!("[hiref] pjrt LROT failed ({e}); using native");
                    }
                }
            }
        }
        stats.native.fetch_add(1, Ordering::Relaxed);
        let cfg = LrotConfig { rank, ..self.cfg.lrot.clone() };
        let out = lrot::solve_factored(u_blk, v_blk, active, active, &cfg, seed);
        (out.q, out.r)
    }

    /// Base case: exact assignment inside the block (Hungarian below the
    /// cutoff, ε-scaling auction above), sealing `perm`.
    fn solve_base(
        &self,
        x: &Mat,
        y: &Mat,
        block: &Block,
        perm: &Mutex<Vec<u32>>,
        stats: &StatsAtomics,
    ) {
        stats.base.fetch_add(1, Ordering::Relaxed);
        let xs = &block.xs;
        let ys = &block.ys;
        let local = if xs.len() == 1 {
            vec![0u32]
        } else {
            let xb = x.gather_rows(xs);
            let yb = y.gather_rows(ys);
            let c = costs::dense_cost(&xb, &yb, self.cfg.cost);
            if xs.len() <= self.cfg.hungarian_cutoff {
                exact::hungarian(&c)
            } else {
                exact::auction(&c, 1.0)
            }
        };
        let mut guard = perm.lock().unwrap();
        for (i, &j) in local.iter().enumerate() {
            guard[xs[i] as usize] = ys[j as usize];
        }
    }

}

/// Internal atomics for [`RunStats`].
#[derive(Default)]
struct StatsAtomics {
    lrot: AtomicUsize,
    pjrt: AtomicUsize,
    native: AtomicUsize,
    base: AtomicUsize,
}

impl StatsAtomics {
    fn snapshot(&self, elapsed: Duration) -> RunStats {
        RunStats {
            lrot_calls: self.lrot.load(Ordering::Relaxed),
            pjrt_calls: self.pjrt.load(Ordering::Relaxed),
            native_calls: self.native.load(Ordering::Relaxed),
            base_calls: self.base.load(Ordering::Relaxed),
            elapsed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn native_cfg() -> HiRefConfig {
        HiRefConfig {
            backend: BackendKind::Native,
            base_size: 32,
            max_rank: 4,
            threads: 2,
            ..Default::default()
        }
    }

    fn shuffled_pair(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, d);
        rng.fill_normal(&mut x.data);
        let perm = rng.permutation(n);
        let mut y = x.gather_rows(&perm);
        for v in y.data.iter_mut() {
            *v += 0.001 * rng.normal_f32();
        }
        (x, y, perm)
    }

    #[test]
    fn output_is_bijection() {
        let (x, y, _) = shuffled_pair(300, 2, 0);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        assert_eq!(out.perm.len(), 300);
    }

    #[test]
    fn recovers_near_monge_map_on_shuffled_data() {
        // y is a shuffled copy of x (+tiny noise): the Monge map is the
        // shuffle and its cost ~0.  HiRef must find a near-zero-cost map.
        let (x, y, _) = shuffled_pair(256, 2, 1);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let cost = out.cost(&x, &y, CostKind::SqEuclidean);
        assert!(cost < 0.02, "cost {cost} too high for shuffled data");
    }

    #[test]
    fn matches_exact_solver_on_small_instance() {
        let (x, y, _) = shuffled_pair(64, 2, 2);
        let out = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let c = costs::dense_cost(&x, &y, CostKind::SqEuclidean);
        let h = exact::hungarian(&c);
        let opt = metrics::bijection_cost(&x, &y, &h, CostKind::SqEuclidean);
        let got = out.cost(&x, &y, CostKind::SqEuclidean);
        assert!(got >= opt - 1e-9);
        assert!(got <= opt.max(1e-6) * 1.5 + 1e-4, "hiref {got} vs opt {opt}");
    }

    #[test]
    fn odd_sizes_work() {
        for n in [33usize, 97, 130] {
            let (x, y, _) = shuffled_pair(n, 2, n as u64);
            let cfg = HiRefConfig { base_size: 16, ..native_cfg() };
            let out = HiRef::new(cfg).align(&x, &y).unwrap();
            assert!(out.is_bijection(), "n={n}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y, _) = shuffled_pair(128, 2, 5);
        let a = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        let b = HiRef::new(native_cfg()).align(&x, &y).unwrap();
        assert_eq!(a.perm, b.perm);
    }

    #[test]
    fn mismatched_sizes_error() {
        let (x, _, _) = shuffled_pair(16, 2, 6);
        let (y, _, _) = shuffled_pair(17, 2, 7);
        assert!(HiRef::new(native_cfg()).align(&x, &y).is_err());
    }

    #[test]
    fn scales_recorded_when_asked() {
        let (x, y, _) = shuffled_pair(128, 2, 8);
        let cfg = HiRefConfig { record_scales: true, base_size: 16, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        let scales = out.scales.as_ref().unwrap();
        assert!(!scales.is_empty());
        // scale 0 is the root co-cluster
        assert_eq!(scales[0].len(), 1);
        assert_eq!(scales[0][0].0.len(), 128);
        // each subsequent recorded scale partitions all points
        for lvl in scales.iter().take(out.schedule.len() + 1) {
            if lvl.is_empty() { continue; }
            let total: usize = lvl.iter().map(|(xs, _)| xs.len()).sum();
            assert_eq!(total, 128);
        }
    }

    #[test]
    fn euclidean_cost_path_works() {
        let (x, y, _) = shuffled_pair(150, 3, 9);
        let cfg = HiRefConfig { cost: CostKind::Euclidean, indyk_width: 8, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        assert!(out.is_bijection());
        let cost = out.cost(&x, &y, CostKind::Euclidean);
        // shuffled copy: near-zero optimal cost
        assert!(cost < 0.25, "euclidean cost {cost}");
    }

    #[test]
    fn refinement_monotone_improves_over_root(){
        // Prop 3.4 lower bound: finer scales do not increase cost.
        let (x, y, _) = shuffled_pair(256, 2, 10);
        let cfg = HiRefConfig { record_scales: true, base_size: 16, ..native_cfg() };
        let out = HiRef::new(cfg).align(&x, &y).unwrap();
        let scales = out.scales.as_ref().unwrap();
        let mut costs_per_scale = Vec::new();
        for lvl in scales {
            if lvl.is_empty() { continue; }
            let total: usize = lvl.iter().map(|(a, _)| a.len()).sum();
            if total != 256 { continue; }
            costs_per_scale.push(metrics::block_coupling_cost(
                &x, &y, lvl, CostKind::SqEuclidean));
        }
        assert!(costs_per_scale.len() >= 2);
        for w in costs_per_scale.windows(2) {
            assert!(w[1] <= w[0] * 1.05 + 1e-6, "scale cost increased: {w:?}");
        }
        // final bijection is at least as good as the last block coupling
        let final_cost = out.cost(&x, &y, CostKind::SqEuclidean);
        assert!(final_cost <= costs_per_scale.last().unwrap() + 1e-6);
    }
}
