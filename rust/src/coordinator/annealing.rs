//! Rank-annealing schedule optimisation (paper §3.3, Eq. 14, Appendix E.1).
//!
//! Given `n` points, a base-case capacity `Q` (blocks of size ≤ Q are
//! finished by the exact solver) and a maximum intermediate rank `C`, find
//! the schedule `(r_1, …, r_κ)` minimising the total number of LROT calls
//! — proportional to the sum of partial products `Σ_j ρ_j`,
//! `ρ_j = Π_{i≤j} r_i` — subject to `ρ_κ ≥ ⌈n/Q⌉` and `r_i ≤ C`.
//!
//! The paper's dynamic program over stored factor tables runs in
//! `O(C·κ·n)`; ours memoises `f(depth, m) = min cost to cover m leaf
//! blocks`, identical complexity with `m = ⌈n/Q⌉` (HiRef splits blocks
//! into ±1-balanced parts, so exact divisibility of `n` is not required —
//! see `assign.rs`).

#![forbid(unsafe_code)]

use std::collections::HashMap;

/// Compute the optimal rank schedule.
///
/// * `n` — dataset size;
/// * `base` — maximal base-case block (paper's "maximal base rank Q");
/// * `max_rank` — maximal intermediate rank C;
/// * `max_depth` — optional cap on κ (None = unconstrained).
///
/// Returns the schedule `(r_1, …, r_κ)`, possibly empty when `n ≤ base`.
pub fn optimal_rank_schedule(
    n: usize,
    base: usize,
    max_rank: usize,
    max_depth: Option<usize>,
) -> Vec<usize> {
    assert!(base >= 1 && max_rank >= 2);
    let m = n.div_ceil(base.max(1));
    if m <= 1 {
        return Vec::new();
    }
    // minimal feasible depth: ceil(log_C m); allow a little slack for the
    // optimiser to trade depth against call count.
    let min_depth = {
        let mut d = 0usize;
        let mut cover = 1usize;
        while cover < m {
            cover = cover.saturating_mul(max_rank);
            d += 1;
        }
        d
    };
    let depth_cap = max_depth.unwrap_or(min_depth + 2).max(min_depth);

    let mut memo: HashMap<(usize, usize), (f64, usize)> = HashMap::new();
    let best = search(m, depth_cap, max_rank, &mut memo);
    if best.0.is_infinite() {
        // infeasible under the depth cap: fall back to repeated max_rank
        let mut sched = Vec::new();
        let mut cover = 1usize;
        while cover < m {
            sched.push(max_rank);
            cover = cover.saturating_mul(max_rank);
        }
        return sched;
    }
    // reconstruct
    let mut sched = Vec::new();
    let mut rem = m;
    let mut depth = depth_cap;
    while rem > 1 {
        let (_, r) = *memo.get(&(depth, rem)).expect("memo hole");
        sched.push(r);
        rem = rem.div_ceil(r);
        depth -= 1;
    }
    sched
}

/// `f(depth, m)`: minimal Σ_j ρ_j to split one block into ≥ m leaves
/// within `depth` levels.  Recursion: choosing first rank r costs
/// `r · (1 + f(depth−1, ⌈m/r⌉))` — the paper's recursive identity.
fn search(
    m: usize,
    depth: usize,
    max_rank: usize,
    memo: &mut HashMap<(usize, usize), (f64, usize)>,
) -> (f64, usize) {
    if m <= 1 {
        return (0.0, 0);
    }
    if depth == 0 {
        return (f64::INFINITY, 0);
    }
    if let Some(&v) = memo.get(&(depth, m)) {
        return v;
    }
    let mut best = (f64::INFINITY, 0usize);
    for r in 2..=max_rank.min(m.max(2)) {
        let sub = search(m.div_ceil(r), depth - 1, max_rank, memo);
        if sub.0.is_infinite() {
            continue;
        }
        let cost = r as f64 * (1.0 + sub.0);
        if cost < best.0 {
            best = (cost, r);
        }
    }
    memo.insert((depth, m), best);
    best
}

/// Effective ranks `ρ_t = Π_{s≤t} r_s` (paper Eq. S6) — also the number of
/// co-clusters at each scale.
pub fn effective_ranks(schedule: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(schedule.len());
    let mut p = 1usize;
    for &r in schedule {
        p = p.saturating_mul(r);
        out.push(p);
    }
    out
}

/// Σ_j ρ_j — the LROT call count proxy minimised by the DP.
pub fn schedule_cost(schedule: &[usize]) -> usize {
    effective_ranks(schedule).iter().sum()
}

/// Upper bound on the size of any single co-cluster *entering* scale
/// `level` (level 0 = the root block of n points).  Splits are ±1-balanced
/// (`assign::capacities`), so the ceil-division chain over the schedule
/// prefix bounds every block.  Used to size scratch-arena expectations and
/// report the base-case block size in perf profiles: the deepest level's
/// value is the largest block the exact solver ever sees.
pub fn level_block_size(n: usize, schedule: &[usize], level: usize) -> usize {
    let mut size = n;
    for &r in schedule.iter().take(level) {
        size = size.div_ceil(r);
    }
    size
}

#[cfg(test)]
mod tests {
    use super::*;

    fn covers(schedule: &[usize], n: usize, base: usize) -> bool {
        let rho: usize = schedule.iter().product();
        rho >= n.div_ceil(base)
    }

    #[test]
    fn trivial_when_n_fits_base() {
        assert!(optimal_rank_schedule(100, 128, 16, None).is_empty());
        assert!(optimal_rank_schedule(128, 128, 16, None).is_empty());
    }

    #[test]
    fn covers_and_respects_bounds() {
        for &(n, base, c) in &[
            (1 << 20, 1024, 16),
            (113_350, 1024, 128),
            (1_281_000 / 2, 2048, 64),
            (5913, 256, 16),
            (1000, 1, 8),
        ] {
            let s = optimal_rank_schedule(n, base, c, None);
            assert!(covers(&s, n, base), "schedule {s:?} fails n={n} base={base}");
            assert!(s.iter().all(|&r| r >= 2 && r <= c), "{s:?}");
        }
    }

    #[test]
    fn power_of_two_exact() {
        // n = 2^10, base 1, C = 2 → schedule must be ten 2s
        let s = optimal_rank_schedule(1024, 1, 2, None);
        assert_eq!(s, vec![2; 10]);
    }

    #[test]
    fn beats_naive_binary_when_allowed() {
        // with C = 16, covering 4096 leaves should use fewer LROT calls
        // than the pure binary schedule
        let s = optimal_rank_schedule(4096, 1, 16, None);
        let binary = vec![2usize; 12];
        assert!(covers(&s, 4096, 1));
        assert!(
            schedule_cost(&s) < schedule_cost(&binary),
            "{:?} cost {} vs binary {}",
            s,
            schedule_cost(&s),
            schedule_cost(&binary)
        );
    }

    #[test]
    fn matches_brute_force_small() {
        // exhaustive over schedules of depth ≤ 3 with ranks ≤ 6
        fn brute(m: usize, c: usize) -> usize {
            let mut best = usize::MAX;
            for r1 in 2..=c {
                if r1 >= m {
                    best = best.min(r1);
                    continue;
                }
                for r2 in 2..=c {
                    if r1 * r2 >= m {
                        best = best.min(r1 + r1 * r2);
                        continue;
                    }
                    for r3 in 2..=c {
                        if r1 * r2 * r3 >= m {
                            best = best.min(r1 + r1 * r2 + r1 * r2 * r3);
                        }
                    }
                }
            }
            best
        }
        for &m in &[5usize, 12, 30, 64, 100] {
            let s = optimal_rank_schedule(m, 1, 6, Some(3));
            let got = schedule_cost(&s);
            let want = brute(m, 6);
            assert!(got <= want, "m={m}: got {got} want {want} ({s:?})");
        }
    }

    #[test]
    fn depth_cap_respected() {
        let s = optimal_rank_schedule(1 << 16, 1, 16, Some(4));
        assert!(s.len() <= 4, "{s:?}");
        assert!(covers(&s, 1 << 16, 1));
    }

    #[test]
    fn effective_ranks_partial_products() {
        assert_eq!(effective_ranks(&[2, 8, 16]), vec![2, 16, 256]);
        assert_eq!(schedule_cost(&[2, 8, 16]), 274);
    }

    #[test]
    fn level_block_size_is_ceil_chain() {
        assert_eq!(level_block_size(1000, &[4, 4], 0), 1000);
        assert_eq!(level_block_size(1000, &[4, 4], 1), 250);
        assert_eq!(level_block_size(1000, &[4, 4], 2), 63);
        // deepest level is bounded by the base capacity the DP targeted
        let n = 113_350;
        let sched = optimal_rank_schedule(n, 1024, 16, None);
        assert!(level_block_size(n, &sched, sched.len()) <= 1024);
    }
}
