//! Cluster-warmstart engine: balanced co-clustering of one co-cluster
//! block **without an LROT solve** — the coarse-scale fast path of the
//! ROADMAP's "cluster-based initialization" workload (Transport
//! Clustering, arxiv 2603.03578: low-rank OT factors recovered via
//! clustering).
//!
//! The refinement hierarchy only ever consumes a *hard balanced
//! co-clustering* of each block (the LROT factors go through
//! [`assign::balanced_assign`] and are then discarded), so a scale can be
//! approximated by producing those labels directly:
//!
//! 1. **X side** — balanced k-means over the block's cost-factor rows
//!    `u_i`: a few deterministic Lloyd sweeps (initial centroids are
//!    evenly spaced rows, supplied by the caller so they come through the
//!    [`crate::pool::FactorStore`] checkout — resident, spilled and
//!    narrow-precision stores feed identical bytes), then one
//!    capacity-constrained greedy pass
//!    ([`assign::balanced_assign_scores`] on negated squared distances)
//!    that restores the exact ±1-balanced child sizes the in-place
//!    re-index requires.
//! 2. **Y side** — with `C = U Vᵀ`, the mean transport cost between
//!    x-cluster `z` and point `y_j` is `c̄_z · v_j` (`c̄_z` = mean factor
//!    row of the cluster), so each `y_j` greedily joins the x-cluster of
//!    lowest mean cost under the same capacities — the same objective the
//!    LROT factors' balanced assignment approximates, for `O(len·r·k)`
//!    instead of a mirror-descent solve.
//!
//! The child *geometry* is identical to the exact path (capacities depend
//! only on `(len, rank)`), so every level below a clustered scale still
//! partitions `0..n` and the base case still seals an exact bijection —
//! only the coarse co-membership is approximate (contract: docs/warmstart.md).
//! Everything here is deterministic — no RNG, no thread-count
//! sensitivity — in the style of graspologic's refinable
//! `leiden/hierarchical.rs` hierarchy: cluster-range bookkeeping stays
//! with the caller, this module only maps one block to labels.

#![forbid(unsafe_code)]

use crate::coordinator::assign;
use crate::linalg::MatView;
use crate::pool::ScratchArena;

/// Deterministic Lloyd sweeps before the balanced pass.  Diminishing
/// returns beyond a handful: the greedy capacity pass re-shuffles the
/// boundary points anyway, and the scales below refine the membership.
const KMEANS_SWEEPS: usize = 6;

/// Balanced co-cluster labels for one block: `labels_x[i]`/`labels_y[j]`
/// in `0..rank`, each honouring [`assign::capacities`]`(len, rank)`
/// exactly — drop-in for what [`assign::balanced_assign`] produces from
/// an LROT factor pair.
pub struct CoClusters {
    pub labels_x: Vec<u32>,
    pub labels_y: Vec<u32>,
}

/// Co-cluster one block into `rank` balanced parts from its cost-factor
/// rows alone: `ux`/`vy` are the block's `len×k` row-major factor
/// windows, `cent_seed` holds `rank` initial centroids (`rank×k`,
/// typically evenly spaced rows of `ux` — see
/// `Checkout::sample_lane_rows`).  Deterministic in its inputs.
pub fn cluster_block(
    ux: &[f32],
    vy: &[f32],
    len: usize,
    k: usize,
    rank: usize,
    cent_seed: &[f32],
    arena: &ScratchArena,
) -> CoClusters {
    debug_assert_eq!(ux.len(), len * k);
    debug_assert_eq!(vy.len(), len * k);
    debug_assert_eq!(cent_seed.len(), rank * k);
    debug_assert!(rank >= 1 && rank <= len, "rank {rank} out of range for {len} points");

    let mut cent = arena.take_f32(rank * k);
    cent.copy_from_slice(cent_seed);
    let mut labels = arena.take_u32(len);
    let mut counts = vec![0usize; rank];

    for _ in 0..KMEANS_SWEEPS {
        // unbalanced nearest-centroid assignment (lowest index on ties);
        // balance is restored by the capacity pass below
        for i in 0..len {
            let row = &ux[i * k..(i + 1) * k];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for z in 0..rank {
                let d = dist2(row, &cent[z * k..(z + 1) * k]);
                if d < best_d {
                    best_d = d;
                    best = z;
                }
            }
            labels[i] = best as u32;
        }
        mean_rows(ux, &labels, len, k, &mut cent, &mut counts);
        // re-seed any emptied cluster on the row farthest from its own
        // centroid (deterministic; duplicates-heavy blocks hit this)
        for z in 0..rank {
            if counts[z] > 0 {
                continue;
            }
            let mut far = 0usize;
            let mut far_d = f32::NEG_INFINITY;
            for i in 0..len {
                let zc = labels[i] as usize;
                if counts[zc] == 0 {
                    continue; // stale centroid: not a meaningful distance
                }
                let d = dist2(&ux[i * k..(i + 1) * k], &cent[zc * k..(zc + 1) * k]);
                if d > far_d {
                    far_d = d;
                    far = i;
                }
            }
            cent[z * k..(z + 1) * k].copy_from_slice(&ux[far * k..(far + 1) * k]);
            counts[z] = 1; // claimed: the next sweep re-assigns properly
        }
    }

    // balanced X labels: capacity-constrained greedy on −‖u_i − c_z‖²
    let mut scores = arena.take_f32(len * rank);
    for i in 0..len {
        let row = &ux[i * k..(i + 1) * k];
        for z in 0..rank {
            scores[i * rank + z] = -dist2(row, &cent[z * k..(z + 1) * k]);
        }
    }
    let labels_x = assign::balanced_assign_scores(MatView::from_slice(len, rank, &scores), len);

    // Y side scores against the centroids of the *balanced* clusters (the
    // memberships the children will actually have)
    mean_rows(ux, &labels_x, len, k, &mut cent, &mut counts);
    for j in 0..len {
        let row = &vy[j * k..(j + 1) * k];
        for z in 0..rank {
            scores[j * rank + z] = -dot(&cent[z * k..(z + 1) * k], row);
        }
    }
    let labels_y = assign::balanced_assign_scores(MatView::from_slice(len, rank, &scores), len);

    CoClusters { labels_x, labels_y }
}

/// Per-label mean rows of `data` into `cent` (counts as side output);
/// empty clusters keep a zero centroid and `counts[z] == 0`.
fn mean_rows(
    data: &[f32],
    labels: &[u32],
    len: usize,
    k: usize,
    cent: &mut [f32],
    counts: &mut [usize],
) {
    cent.fill(0.0);
    counts.fill(0);
    for i in 0..len {
        let z = labels[i] as usize;
        counts[z] += 1;
        for (c, &x) in cent[z * k..(z + 1) * k].iter_mut().zip(&data[i * k..(i + 1) * k]) {
            *c += x;
        }
    }
    for (z, &n) in counts.iter().enumerate() {
        if n > 0 {
            let inv = 1.0 / n as f32;
            for c in &mut cent[z * k..(z + 1) * k] {
                *c *= inv;
            }
        }
    }
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    /// Evenly spaced seed rows — the same sampling
    /// `Checkout::sample_lane_rows` performs.
    fn seed_rows(data: &[f32], len: usize, k: usize, rank: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rank * k];
        for t in 0..rank {
            let src = t * len / rank;
            out[t * k..(t + 1) * k].copy_from_slice(&data[src * k..(src + 1) * k]);
        }
        out
    }

    #[test]
    fn labels_honour_capacities_and_are_deterministic() {
        let (len, k, rank) = (101, 5, 4);
        let mut rng = Rng::new(7);
        let mut ux = vec![0.0f32; len * k];
        let mut vy = vec![0.0f32; len * k];
        for v in ux.iter_mut().chain(vy.iter_mut()) {
            *v = rng.normal_f32();
        }
        let cent = seed_rows(&ux, len, k, rank);
        let arena = ScratchArena::new(1);
        let a = cluster_block(&ux, &vy, len, k, rank, &cent, &arena);
        let b = cluster_block(&ux, &vy, len, k, rank, &cent, &arena);
        assert_eq!(a.labels_x, b.labels_x);
        assert_eq!(a.labels_y, b.labels_y);
        let caps = assign::capacities(len, rank);
        for labels in [&a.labels_x, &a.labels_y] {
            let mut counts = vec![0usize; rank];
            for &z in labels.iter() {
                counts[z as usize] += 1;
            }
            assert_eq!(counts, caps);
        }
    }

    #[test]
    fn duplicate_rows_still_partition() {
        // every row identical: k-means degenerates, the farthest-row
        // re-seed and the capacity pass must still hand back a partition
        let (len, k, rank) = (24, 3, 4);
        let ux = vec![0.5f32; len * k];
        let vy = vec![0.25f32; len * k];
        let cent = seed_rows(&ux, len, k, rank);
        let arena = ScratchArena::new(1);
        let cc = cluster_block(&ux, &vy, len, k, rank, &cent, &arena);
        let caps = assign::capacities(len, rank);
        for labels in [&cc.labels_x, &cc.labels_y] {
            let mut counts = vec![0usize; rank];
            for &z in labels.iter() {
                counts[z as usize] += 1;
            }
            assert_eq!(counts, caps);
        }
    }

    #[test]
    fn separated_blobs_co_cluster_below_mean_cost() {
        // two x-blobs along ±e0; y factor rows are built so that y points
        // matched to blob 0 have strongly negative cost against it (and
        // ~0 against the other).  The induced co-clustering must price
        // below the unclustered mean of C = U Vᵀ.
        let (len, k, rank) = (64, 4, 2);
        let mut rng = Rng::new(11);
        let mut ux = vec![0.0f32; len * k];
        let mut vy = vec![0.0f32; len * k];
        for i in 0..len {
            let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            ux[i * k] = sign * 4.0 + 0.05 * rng.normal_f32();
            vy[i * k] = -sign * 4.0 + 0.05 * rng.normal_f32();
            for c in 1..k {
                ux[i * k + c] = 0.05 * rng.normal_f32();
                vy[i * k + c] = 0.05 * rng.normal_f32();
            }
        }
        let cent = seed_rows(&ux, len, k, rank);
        let arena = ScratchArena::new(1);
        let cc = cluster_block(&ux, &vy, len, k, rank, &cent, &arena);
        let cost = |i: usize, j: usize| {
            dot(&ux[i * k..(i + 1) * k], &vy[j * k..(j + 1) * k]) as f64
        };
        let (mut within, mut wn) = (0.0f64, 0usize);
        let (mut total, mut tn) = (0.0f64, 0usize);
        for i in 0..len {
            for j in 0..len {
                let c = cost(i, j);
                total += c;
                tn += 1;
                if cc.labels_x[i] == cc.labels_y[j] {
                    within += c;
                    wn += 1;
                }
            }
        }
        let (within, total) = (within / wn as f64, total / tn as f64);
        assert!(
            within < total - 1.0,
            "co-clustered mean cost {within:.3} not below block mean {total:.3}"
        );
    }
}
