//! Balanced hard assignment — the `Assign` subroutine of Algorithm 1 with
//! the even-split guarantee of Lemma B.1 restored.
//!
//! An *optimal* LROT factor with uniform inner marginal is automatically a
//! balanced partition (Lemma B.1), but the approximate mirror-descent
//! solver returns soft factors whose plain row-argmax can be slightly
//! unbalanced.  The recursion requires exactly matched child sizes on the
//! X and Y sides, so we assign with **capacity constraints**: cluster `z`
//! receives exactly `cap_z` points, where `Σ cap_z = active` and the
//! capacities differ by at most one — identical on both sides, which is
//! what places the child blocks in bijective correspondence (Eq. S7).
//!
//! Points are processed in decreasing confidence margin (best minus
//! second-best factor weight), each taking its best cluster that still has
//! room — the standard greedy that is exact when the factor is already a
//! balanced partition.

#![forbid(unsafe_code)]

use crate::linalg::MatView;

/// Exact child capacities for splitting `active` points into `r` parts:
/// sizes differ by ≤ 1 and are deterministic (first `active % r` clusters
/// get the extra point).
pub fn capacities(active: usize, r: usize) -> Vec<usize> {
    let base = active / r;
    let rem = active % r;
    (0..r).map(|z| base + usize::from(z < rem)).collect()
}

/// Exclusive prefix sums of `caps`: `offsets[z]` is where cluster `z`'s
/// contiguous range starts after the in-place reorder (the range-based
/// layout of `coordinator::hiref` — child `z` occupies
/// `offsets[z]..offsets[z] + caps[z]` within its parent's range).
pub fn cluster_offsets(caps: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(caps.len());
    let mut acc = 0usize;
    for &c in caps {
        out.push(acc);
        acc += c;
    }
    out
}

/// Assign each of the first `active` rows of factor `m` (s×r) to one of
/// `r` clusters under [`capacities`].  Returns per-point labels.  Accepts
/// `&Mat` or a borrowed [`MatView`] (the factors are read, never copied).
pub fn balanced_assign<'a>(m: impl Into<MatView<'a>>, active: usize) -> Vec<u32> {
    balanced_assign_impl(m.into(), active, true)
}

/// [`balanced_assign`] for general *score* matrices (higher = better)
/// whose entries may be negative — the cluster-warmstart engine
/// (`coordinator::warmstart`) feeds negated distances/costs through here.
/// Identical greedy, but the confidence margin is `best − second` without
/// the non-negative clamp on `second`: the clamp is a no-op for the
/// strictly positive LROT factors `balanced_assign` sees (exp of logits),
/// while for all-negative scores it would collapse every margin to the
/// best score alone and mis-order the contested points.
pub fn balanced_assign_scores<'a>(m: impl Into<MatView<'a>>, active: usize) -> Vec<u32> {
    balanced_assign_impl(m.into(), active, false)
}

fn balanced_assign_impl(m: MatView<'_>, active: usize, clamp_margin: bool) -> Vec<u32> {
    let r = m.cols;
    let caps = capacities(active, r);
    let mut remaining = caps;
    // (margin, point) sorted by decreasing confidence.  For r = 1 there is
    // no second-best column: every point goes to the only cluster, so the
    // margin is defined as the point's sole weight (any constant would do
    // — the capacity is `active`) instead of leaning on `second.max(0.0)`
    // turning −∞ into 0.  Behaviour-identical to the general expression
    // (which already reduced to `row[0] − 0`); the branch exists to make
    // the degenerate case's definition explicit rather than emergent.
    let mut order: Vec<(f32, u32)> = (0..active)
        .map(|i| {
            let row = m.row(i);
            let margin = if r == 1 {
                row[0]
            } else {
                let (mut best, mut second) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
                for &v in row {
                    if v > best {
                        second = best;
                        best = v;
                    } else if v > second {
                        second = v;
                    }
                }
                best - if clamp_margin { second.max(0.0) } else { second }
            };
            (margin, i as u32)
        })
        .collect();
    // total_cmp: a NaN factor weight on a degenerate block (LROT over a
    // pathological window) must produce a deterministic order, not a
    // `partial_cmp().unwrap()` panic.  In IEEE total order +NaN sits
    // above +inf, so a NaN margin is processed first under this
    // descending sort — which spot it gets is policy-free (its weights
    // are garbage either way); what matters is that the order is
    // deterministic and capacities still partition.  Ties break by point
    // index so the split stays stable.
    order.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));

    let mut labels = vec![u32::MAX; active];
    for &(_, i) in &order {
        let row = m.row(i as usize);
        let mut best_z = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (z, &v) in row.iter().enumerate() {
            if remaining[z] > 0 && v > best_v {
                best_v = v;
                best_z = z;
            }
        }
        if best_z == usize::MAX {
            // every open cluster's weight compared false (NaN row): take
            // the first cluster with room — capacities still partition.
            best_z = remaining.iter().position(|&c| c > 0).expect("capacities exhausted early");
        }
        labels[i as usize] = best_z as u32;
        remaining[best_z] -= 1;
    }
    labels
}

/// Split an index set by labels into `r` child index sets (preserving the
/// original global indices).  Retained for callers that materialise index
/// sets (diagnostics, tests); the refinement engine itself reorders its
/// contiguous ranges in place instead (see `coordinator::hiref`).
pub fn split_by_labels(indices: &[u32], labels: &[u32], r: usize) -> Vec<Vec<u32>> {
    debug_assert_eq!(indices.len(), labels.len());
    let mut out: Vec<Vec<u32>> = (0..r).map(|_| Vec::new()).collect();
    for (&idx, &z) in indices.iter().zip(labels) {
        out[z as usize].push(idx);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::prng::Rng;

    #[test]
    fn offsets_are_exclusive_prefix_sums() {
        assert_eq!(cluster_offsets(&[3, 2, 4]), vec![0, 3, 5]);
        assert_eq!(cluster_offsets(&[]), Vec::<usize>::new());
        let caps = capacities(101, 4);
        let offs = cluster_offsets(&caps);
        assert_eq!(offs.last().unwrap() + caps.last().unwrap(), 101);
    }

    #[test]
    fn balanced_assign_on_view_matches_owned() {
        let mut rng = Rng::new(3);
        let mut m = Mat::zeros(40, 4);
        for v in m.data.iter_mut() {
            *v = rng.next_f32();
        }
        let owned = balanced_assign(&m, 40);
        let viewed = balanced_assign(m.row_range(0, 40), 40);
        assert_eq!(owned, viewed);
    }

    #[test]
    fn capacities_sum_and_balance() {
        for &(n, r) in &[(10usize, 3usize), (1024, 2), (7, 7), (100, 8), (5, 2)] {
            let c = capacities(n, r);
            assert_eq!(c.iter().sum::<usize>(), n);
            let mx = *c.iter().max().unwrap();
            let mn = *c.iter().min().unwrap();
            assert!(mx - mn <= 1, "{c:?}");
        }
    }

    #[test]
    fn respects_capacities_exactly() {
        let mut rng = Rng::new(0);
        let mut m = Mat::zeros(101, 4);
        for v in m.data.iter_mut() {
            *v = rng.next_f32();
        }
        let labels = balanced_assign(&m, 101);
        let mut counts = vec![0usize; 4];
        for &z in &labels {
            counts[z as usize] += 1;
        }
        assert_eq!(counts, capacities(101, 4));
    }

    #[test]
    fn exact_partition_factor_is_preserved() {
        // a factor that IS a balanced partition must round-trip exactly
        let n = 64;
        let mut m = Mat::zeros(n, 2);
        for i in 0..n {
            *m.at_mut(i, i % 2) = 1.0 / n as f32;
        }
        let labels = balanced_assign(&m, n);
        for (i, &z) in labels.iter().enumerate() {
            assert_eq!(z as usize, i % 2);
        }
    }

    #[test]
    fn confident_points_win_contested_slots() {
        // 3 points, 2 clusters with caps [2, 1]; point 0 strongly prefers
        // cluster 1, points 1-2 weakly prefer cluster 1 → point 0 gets it.
        let m = Mat::from_vec(3, 2, vec![
            0.01, 0.99, //
            0.45, 0.55, //
            0.48, 0.52,
        ]);
        let labels = balanced_assign(&m, 3);
        assert_eq!(labels[0], 1);
        assert_eq!(labels[1], 0);
        assert_eq!(labels[2], 0);
    }

    #[test]
    fn split_by_labels_round_trip() {
        let indices = vec![10u32, 20, 30, 40];
        let labels = vec![1u32, 0, 1, 0];
        let parts = split_by_labels(&indices, &labels, 2);
        assert_eq!(parts[0], vec![20, 40]);
        assert_eq!(parts[1], vec![10, 30]);
    }

    #[test]
    fn nan_weights_do_not_panic_and_capacities_still_hold() {
        // regression: partial_cmp().unwrap() panicked on NaN margins
        let mut m = Mat::zeros(12, 3);
        let mut rng = Rng::new(4);
        for v in m.data.iter_mut() {
            *v = rng.next_f32();
        }
        *m.at_mut(3, 0) = f32::NAN; // NaN margin for point 3
        for v in m.row_mut(7) {
            *v = f32::NAN; // fully degenerate row: argmax finds nothing
        }
        let labels = balanced_assign(&m, 12);
        let mut counts = vec![0usize; 3];
        for &z in &labels {
            assert!(z < 3, "unassigned label");
            counts[z as usize] += 1;
        }
        assert_eq!(counts, capacities(12, 3));
    }

    #[test]
    fn single_cluster_assigns_everything_to_it() {
        // r = 1: the margin is the sole weight; every point lands in
        // cluster 0 and the capacity is exactly `active`
        let mut m = Mat::zeros(9, 1);
        for (i, v) in m.data.iter_mut().enumerate() {
            *v = -(i as f32); // includes negative weights
        }
        let labels = balanced_assign(&m, 9);
        assert_eq!(labels, vec![0u32; 9]);
    }

    #[test]
    fn duplicate_rows_get_deterministic_stable_split() {
        // exact ties (duplicate points => duplicate factor rows) must
        // split deterministically by index, not arbitrarily
        let m = Mat::full(8, 2, 0.125);
        let a = balanced_assign(&m, 8);
        let b = balanced_assign(&m, 8);
        assert_eq!(a, b);
        let mut counts = [0usize; 2];
        for &z in &a {
            counts[z as usize] += 1;
        }
        assert_eq!(counts, [4, 4]);
    }

    #[test]
    fn scores_variant_lets_confident_points_win_on_negative_scores() {
        // negated distances (all-negative scores): point 0 is nearly
        // indifferent, point 1 strongly prefers cluster 1.  The unclamped
        // margin processes the confident point first, so it wins the
        // contested slot; the clamped factor variant would collapse both
        // margins to the best score and hand cluster 1 to point 0.
        let m = Mat::from_vec(2, 2, vec![
            -1.1, -1.0, //
            -9.0, -2.0,
        ]);
        assert_eq!(balanced_assign_scores(&m, 2), vec![0, 1]);
        assert_eq!(balanced_assign(&m, 2), vec![1, 0]);

        // and it honours capacities exactly, like the factor variant
        let mut rng = Rng::new(9);
        let mut m = Mat::zeros(33, 3);
        for v in m.data.iter_mut() {
            *v = -rng.next_f32();
        }
        let labels = balanced_assign_scores(&m, 33);
        let mut counts = vec![0usize; 3];
        for &z in &labels {
            counts[z as usize] += 1;
        }
        assert_eq!(counts, capacities(33, 3));
    }

    #[test]
    fn ignores_padded_rows() {
        let mut m = Mat::zeros(8, 2);
        for i in 0..8 {
            *m.at_mut(i, 0) = 1.0;
        }
        let labels = balanced_assign(&m, 4);
        assert_eq!(labels.len(), 4);
    }
}
