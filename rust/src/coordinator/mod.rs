//! Layer-3 coordinator: the paper's contribution.
//!
//! * [`annealing`] — the rank-annealing schedule DP (paper §3.3 / E.1).
//! * [`assign`] — balanced capacity-constrained hard assignment (the
//!   `Assign` subroutine of Algorithm 1 + Lemma B.1's even split).
//! * [`hiref`] — the Hierarchical Refinement engine (Algorithm 1/2):
//!   recursion over co-clusters, LROT backend dispatch (PJRT artifacts or
//!   native), base-case exact assignment, thread-pool fan-out.
//! * [`warmstart`] — balanced co-clustering straight from the cost-factor
//!   rows (no LROT): the coarse-scale fast path behind
//!   `HiRefConfig::warmstart_levels` (docs/warmstart.md).

pub mod annealing;
pub mod assign;
pub mod hiref;
pub mod warmstart;
