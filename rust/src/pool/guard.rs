//! Debug-only disjointness race detector for the unsafe concurrency core.
//!
//! Every unsafe shared-mutation surface in the crate — [`RangeShared`] /
//! [`SharedSlice`] windows, [`FactorStore`] row writes and checkout lanes,
//! the [`LaneCrew`] chunk partition — rests on one informal contract: *no
//! two concurrently live borrows overlap unless both are shared*.  This
//! module makes that contract machine-checked in debug builds (and in the
//! `guard`-feature CI leg): each underlying buffer owns a [`Registry`],
//! every window accessor records a claim tagged with its thread, call
//! site, the global epoch and the claiming thread's generation, and an
//! overlapping conflict panics **immediately, naming both claim sites**.
//!
//! [`RangeShared`]: crate::pool::RangeShared
//! [`SharedSlice`]: crate::pool::SharedSlice
//! [`FactorStore`]: crate::pool::store::FactorStore
//! [`LaneCrew`]: crate::pool::LaneCrew
//!
//! # Claim kinds
//!
//! * **Borrow claims** ([`Registry::claim_shared`] / [`Registry::claim_mut`])
//!   are fire-and-forget: the accessors that hand out `&[T]` / `&mut [T]`
//!   windows cannot know when the borrow ends, so liveness is inferred —
//!   a claim is live while the global epoch ([`advance_epoch`]) and its
//!   thread's generation ([`retire_thread`]) are unchanged.  The
//!   parallelism entry points ([`LaneCrew::run`][crate::pool::LaneCrew::run],
//!   [`parallel_map`][crate::pool::parallel_map]) advance the epoch at
//!   round boundaries, and the refinement scheduler retires its claims
//!   before publishing child blocks, so structurally-sequential reborrows
//!   never alias a *live* claim.  A same-thread overlapping borrow claim
//!   supersedes the old one (sequential reborrow).
//! * **Scoped claims** ([`Registry::scoped_shared`] / [`Registry::scoped_mut`])
//!   are RAII: registered for the duration of one store `write_rows` /
//!   `read_rows` / `fill_rows_with` call and removed on drop, so writes
//!   separated in time (a session archive now, a materialise later) can
//!   never false-positive against each other.
//! * **Pins** ([`Registry::pin`]) model checkout lane windows: created by
//!   `FactorStore::checkout`, released exactly once by `release`.  Pinned
//!   ranges must be pairwise disjoint and disjoint from every live pin;
//!   an exclusive claim overlapping a live pin panics (a builder writing
//!   rows out from under a checkout), double release panics, and checkout
//!   accessors call [`PinToken::assert_live`] so use-after-release panics.
//!
//! # Soundness of the liveness inference
//!
//! Epoch/generation staleness only ever **prunes** claims, so the
//! detector can miss a true race across concurrent solves (a stale claim
//! forgotten early) but can never report a false one.  Single-crew and
//! single-queue unit tests — the negative tests seeded in `pool`,
//! `pool::store` and this module — detect their violations
//! deterministically, because nothing advances the epoch between the two
//! conflicting claims.
//!
//! # Zero release overhead
//!
//! In release builds without the `guard` feature every type here is a
//! zero-sized no-op (see the `stub` twin at the bottom of this file):
//! `Registry::new` constructs a unit struct and the claim calls are empty
//! `#[inline(always)]` functions, so the layer compiles out entirely.
//! `benches/bench_kernels.rs` asserts `!guard::enabled()` so the perf
//! numbers can never silently include the checking.

#[cfg(any(debug_assertions, feature = "guard"))]
mod imp {
    use std::collections::HashMap;
    use std::ops::Range;
    use std::panic::Location;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
    use std::thread::{self, ThreadId};

    /// Whether the race detector is compiled in (true here; false in the
    /// release stub).  Benches assert the negation.
    pub fn enabled() -> bool {
        true
    }

    /// Global round counter: borrow claims from before the current round
    /// are stale (their borrows ended at the round boundary).
    static EPOCH: AtomicU64 = AtomicU64::new(0);

    /// Per-thread generation counters ([`retire_thread`] bumps the
    /// caller's), keyed by [`ThreadId`].
    fn gens() -> &'static Mutex<HashMap<ThreadId, u64>> {
        static GENS: OnceLock<Mutex<HashMap<ThreadId, u64>>> = OnceLock::new();
        GENS.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Poison-recovering lock: when a guard panic unwinds through a held
    /// lock, the *next* claimant must still receive the guard diagnostic,
    /// not a `PoisonError` (two-thread negative tests rely on this).
    fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Start a new round: every borrow claim registered before this call
    /// is considered dead.  Called by the parallelism entry points at
    /// round boundaries (before work is published and after it joins).
    pub fn advance_epoch() {
        EPOCH.fetch_add(1, Ordering::SeqCst);
    }

    /// Retire every borrow claim the calling thread has made so far
    /// (bumps its generation).  The refinement scheduler calls this after
    /// releasing a block's checkout and before publishing its children,
    /// whose claims sub-window the parent's.
    pub fn retire_thread() {
        *lock(gens()).entry(thread::current().id()).or_insert(0) += 1;
    }

    struct Claim {
        start: usize,
        end: usize,
        excl: bool,
        thread: ThreadId,
        epoch: u64,
        gen: u64,
        site: &'static Location<'static>,
        /// `Some(id)` for RAII-scoped claims — exempt from epoch/gen
        /// pruning and from same-thread supersession; removed on drop.
        scope: Option<u64>,
    }

    struct Pin {
        id: u64,
        ranges: Vec<(usize, usize)>,
        site: &'static Location<'static>,
    }

    #[derive(Default)]
    struct State {
        claims: Vec<Claim>,
        pins: Vec<Pin>,
        /// Released pin ids with their release site, kept for
        /// double-release / use-after-release diagnostics.
        released: Vec<(u64, &'static Location<'static>)>,
        next_id: u64,
    }

    struct Inner {
        label: &'static str,
        state: Mutex<State>,
    }

    fn kind(excl: bool) -> &'static str {
        if excl {
            "exclusive"
        } else {
            "shared"
        }
    }

    #[inline]
    fn overlaps(a: (usize, usize), b: (usize, usize)) -> bool {
        a.0 < b.1 && b.0 < a.1
    }

    /// Drop every fire-and-forget claim whose epoch or owning thread's
    /// generation has moved on (its borrow ended at a round boundary).
    fn prune(st: &mut State) {
        let epoch = EPOCH.load(Ordering::SeqCst);
        let gens = lock(gens());
        st.claims.retain(|c| {
            c.scope.is_some()
                || (c.epoch == epoch && c.gen == gens.get(&c.thread).copied().unwrap_or(0))
        });
    }

    /// Per-buffer borrow registry: one per [`RangeShared`] /
    /// [`SharedSlice`] / checkout span / store row space.  Cloning shares
    /// the underlying interval set.
    ///
    /// [`RangeShared`]: crate::pool::RangeShared
    /// [`SharedSlice`]: crate::pool::SharedSlice
    #[derive(Clone)]
    pub struct Registry {
        inner: Arc<Inner>,
    }

    impl Registry {
        pub fn new(label: &'static str) -> Registry {
            Registry { inner: Arc::new(Inner { label, state: Mutex::new(State::default()) }) }
        }

        /// Record a shared (read) borrow of `[start, end)`.
        #[track_caller]
        pub fn claim_shared(&self, start: usize, end: usize) {
            self.claim(start, end, false, false);
        }

        /// Record an exclusive (write) borrow of `[start, end)`.
        #[track_caller]
        pub fn claim_mut(&self, start: usize, end: usize) {
            self.claim(start, end, true, false);
        }

        /// Record a shared borrow for the lifetime of the returned token.
        #[track_caller]
        pub fn scoped_shared(&self, start: usize, end: usize) -> ScopedClaim {
            ScopedClaim { id: self.claim(start, end, false, true), inner: self.inner.clone() }
        }

        /// Record an exclusive borrow for the lifetime of the returned
        /// token.
        #[track_caller]
        pub fn scoped_mut(&self, start: usize, end: usize) -> ScopedClaim {
            ScopedClaim { id: self.claim(start, end, true, true), inner: self.inner.clone() }
        }

        #[track_caller]
        fn claim(&self, start: usize, end: usize, excl: bool, scoped: bool) -> u64 {
            let site = Location::caller();
            let me = thread::current().id();
            let label = self.inner.label;
            let mut st = lock(&self.inner.state);
            prune(&mut st);
            let (epoch, my_gen) = (
                EPOCH.load(Ordering::SeqCst),
                lock(gens()).get(&me).copied().unwrap_or(0),
            );
            // A same-thread overlapping borrow claim is a sequential
            // reborrow (the old `&`/`&mut` cannot still be in use when the
            // same thread derives a new one) — the new claim supersedes it.
            st.claims.retain(|c| {
                !(c.scope.is_none() && c.thread == me && overlaps((c.start, c.end), (start, end)))
            });
            if let Some(c) = st.claims.iter().find(|c| {
                overlaps((c.start, c.end), (start, end))
                    && (excl || c.excl)
                    && (c.thread != me || c.scope.is_some())
            }) {
                panic!(
                    "guard[{label}]: {} claim of [{start}, {end}) at {site} by {:?} \
                     conflicts with {} claim of [{}, {}) at {} by {:?}",
                    kind(excl),
                    me,
                    kind(c.excl),
                    c.start,
                    c.end,
                    c.site,
                    c.thread,
                );
            }
            if excl {
                for p in &st.pins {
                    if let Some(&(ps, pe)) =
                        p.ranges.iter().find(|&&r| overlaps(r, (start, end)))
                    {
                        panic!(
                            "guard[{label}]: exclusive claim of [{start}, {end}) at {site} \
                             by {me:?} overlaps pinned [{ps}, {pe}) (checked out at {})",
                            p.site,
                        );
                    }
                }
            }
            let id = st.next_id;
            st.next_id += 1;
            st.claims.push(Claim {
                start,
                end,
                excl,
                thread: me,
                epoch,
                gen: my_gen,
                site,
                scope: scoped.then_some(id),
            });
            id
        }

        /// Pin `ranges` (checkout lane windows).  Panics if the ranges
        /// overlap each other, overlap a live pin, or overlap a live
        /// exclusive claim.
        #[track_caller]
        pub fn pin(&self, ranges: &[Range<usize>]) -> PinToken {
            let site = Location::caller();
            let label = self.inner.label;
            let mut st = lock(&self.inner.state);
            prune(&mut st);
            for (i, a) in ranges.iter().enumerate() {
                for b in &ranges[i + 1..] {
                    if overlaps((a.start, a.end), (b.start, b.end)) {
                        panic!(
                            "guard[{label}]: checkout lanes overlap: [{}, {}) and [{}, {}) \
                             (checked out at {site})",
                            a.start, a.end, b.start, b.end,
                        );
                    }
                }
            }
            for r in ranges {
                for p in &st.pins {
                    if let Some(&(ps, pe)) =
                        p.ranges.iter().find(|&&pr| overlaps(pr, (r.start, r.end)))
                    {
                        panic!(
                            "guard[{label}]: checkout of [{}, {}) at {site} overlaps pinned \
                             [{ps}, {pe}) (checked out at {})",
                            r.start, r.end, p.site,
                        );
                    }
                }
                if let Some(c) = st
                    .claims
                    .iter()
                    .find(|c| c.excl && overlaps((c.start, c.end), (r.start, r.end)))
                {
                    panic!(
                        "guard[{label}]: checkout of [{}, {}) at {site} conflicts with \
                         exclusive claim of [{}, {}) at {} by {:?}",
                        r.start, r.end, c.start, c.end, c.site, c.thread,
                    );
                }
            }
            let id = st.next_id;
            st.next_id += 1;
            st.pins.push(Pin {
                id,
                ranges: ranges.iter().map(|r| (r.start, r.end)).collect(),
                site,
            });
            PinToken { id, inner: self.inner.clone() }
        }
    }

    /// RAII borrow claim returned by [`Registry::scoped_shared`] /
    /// [`Registry::scoped_mut`]; the claim ends when this drops.
    pub struct ScopedClaim {
        id: u64,
        inner: Arc<Inner>,
    }

    impl Drop for ScopedClaim {
        fn drop(&mut self) {
            let mut st = lock(&self.inner.state);
            st.claims.retain(|c| c.scope != Some(self.id));
        }
    }

    /// Handle to a live pin set ([`Registry::pin`]); released exactly once.
    pub struct PinToken {
        id: u64,
        inner: Arc<Inner>,
    }

    impl PinToken {
        /// Release the pin.  Panics on double release.
        #[track_caller]
        pub fn release(&self) {
            let site = Location::caller();
            let mut st = lock(&self.inner.state);
            match st.pins.iter().position(|p| p.id == self.id) {
                Some(i) => {
                    st.pins.swap_remove(i);
                    st.released.push((self.id, site));
                }
                None => {
                    let first = st
                        .released
                        .iter()
                        .find(|(id, _)| *id == self.id)
                        .map(|(_, s)| *s)
                        .expect("pin neither live nor released");
                    panic!(
                        "guard[{}]: double release of checkout pin at {site} \
                         (first released at {first})",
                        self.inner.label,
                    );
                }
            }
        }

        /// Panics if the pin has been released (checkout use-after-release).
        #[track_caller]
        pub fn assert_live(&self) {
            let site = Location::caller();
            let st = lock(&self.inner.state);
            if !st.pins.iter().any(|p| p.id == self.id) {
                let released = st
                    .released
                    .iter()
                    .find(|(id, _)| *id == self.id)
                    .map(|(_, s)| *s)
                    .expect("pin neither live nor released");
                panic!(
                    "guard[{}]: checkout access at {site} after release \
                     (released at {released})",
                    self.inner.label,
                );
            }
        }
    }
}

#[cfg(any(debug_assertions, feature = "guard"))]
pub use imp::*;

/// Zero-sized no-op twin: in release builds without the `guard` feature
/// the whole detector is this stub, and every call site compiles to
/// nothing (asserted by `benches/bench_kernels.rs` via [`enabled`]).
#[cfg(not(any(debug_assertions, feature = "guard")))]
mod stub {
    use std::ops::Range;

    /// False here: the detector is compiled out.
    #[inline(always)]
    pub fn enabled() -> bool {
        false
    }

    #[inline(always)]
    pub fn advance_epoch() {}

    #[inline(always)]
    pub fn retire_thread() {}

    /// No-op twin of the debug registry.
    #[derive(Clone, Default)]
    pub struct Registry;

    impl Registry {
        #[inline(always)]
        pub fn new(_label: &'static str) -> Registry {
            Registry
        }

        #[inline(always)]
        pub fn claim_shared(&self, _start: usize, _end: usize) {}

        #[inline(always)]
        pub fn claim_mut(&self, _start: usize, _end: usize) {}

        #[inline(always)]
        pub fn scoped_shared(&self, _start: usize, _end: usize) -> ScopedClaim {
            ScopedClaim
        }

        #[inline(always)]
        pub fn scoped_mut(&self, _start: usize, _end: usize) -> ScopedClaim {
            ScopedClaim
        }

        #[inline(always)]
        pub fn pin(&self, _ranges: &[Range<usize>]) -> PinToken {
            PinToken
        }
    }

    /// No-op twin of the RAII claim.
    pub struct ScopedClaim;

    /// No-op twin of the pin handle.
    pub struct PinToken;

    impl PinToken {
        #[inline(always)]
        pub fn release(&self) {}

        #[inline(always)]
        pub fn assert_live(&self) {}
    }
}

#[cfg(not(any(debug_assertions, feature = "guard")))]
pub use stub::*;

#[cfg(all(test, any(debug_assertions, feature = "guard")))]
mod tests {
    use super::*;
    use std::sync::Barrier;

    #[test]
    fn detector_is_enabled_in_debug_and_guard_builds() {
        assert!(enabled());
    }

    #[test]
    fn disjoint_and_shared_claims_coexist() {
        let r = Registry::new("test");
        r.claim_mut(0, 4);
        r.claim_mut(4, 8); // disjoint: fine
        r.claim_shared(8, 16);
        r.claim_shared(12, 20); // shared/shared overlap: fine
    }

    #[test]
    fn same_thread_overlap_is_a_sequential_reborrow() {
        let r = Registry::new("test");
        r.claim_mut(0, 8);
        r.claim_mut(2, 6); // supersedes — same thread cannot race itself
        r.claim_shared(0, 8);
    }

    /// A concurrent test elsewhere in the binary can bump the global
    /// epoch between a pair of seeded claims and prune the first (the
    /// documented miss-not-false-positive tradeoff), so the negative
    /// race tests retry until caught; a broken detector exhausts the
    /// retries and dies with a non-matching message instead.
    const SEED_ATTEMPTS: usize = 64;

    #[test]
    #[should_panic(expected = "conflicts with")]
    fn cross_thread_overlapping_mut_claims_panic() {
        for _ in 0..SEED_ATTEMPTS {
            let r = Registry::new("test");
            let barrier = Barrier::new(2);
            let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                std::thread::scope(|scope| {
                    scope.spawn(|| {
                        r.claim_mut(0, 6);
                        barrier.wait();
                    });
                    barrier.wait();
                    r.claim_mut(4, 8); // overlaps the other thread's live claim
                });
            }));
            if let Err(p) = got {
                std::panic::resume_unwind(p);
            }
        }
        panic!("guard never caught the cross-thread mut/mut overlap");
    }

    #[test]
    #[should_panic(expected = "conflicts with")]
    fn cross_thread_shared_vs_mut_panics() {
        for _ in 0..SEED_ATTEMPTS {
            let r = Registry::new("test");
            let barrier = Barrier::new(2);
            let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                std::thread::scope(|scope| {
                    scope.spawn(|| {
                        r.claim_shared(0, 6);
                        barrier.wait();
                    });
                    barrier.wait();
                    r.claim_mut(4, 8);
                });
            }));
            if let Err(p) = got {
                std::panic::resume_unwind(p);
            }
        }
        panic!("guard never caught the cross-thread shared/mut overlap");
    }

    #[test]
    fn epoch_advance_retires_borrow_claims() {
        let r = Registry::new("test");
        let barrier = Barrier::new(2);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                r.claim_mut(0, 6);
                barrier.wait();
            });
            barrier.wait();
            advance_epoch(); // round boundary: the other claim is stale
            r.claim_mut(4, 8);
        });
    }

    #[test]
    fn retire_thread_retires_only_that_threads_claims() {
        let r = Registry::new("test");
        let barrier = Barrier::new(2);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                r.claim_mut(0, 6);
                retire_thread(); // this thread's claims end here
                barrier.wait();
            });
            barrier.wait();
            r.claim_mut(4, 8);
        });
    }

    #[test]
    fn scoped_claims_end_at_drop_not_at_epoch() {
        let r = Registry::new("test");
        let held = r.scoped_mut(0, 8);
        advance_epoch(); // scoped claims survive round boundaries
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            std::thread::scope(|scope| {
                scope.spawn(|| r.claim_mut(4, 6)).join().unwrap();
            })
        }));
        assert!(res.is_err(), "scoped claim must still conflict after an epoch bump");
        drop(held);
        std::thread::scope(|scope| {
            scope.spawn(|| r.claim_mut(4, 6)).join().unwrap();
        });
    }

    #[test]
    #[should_panic(expected = "lanes overlap")]
    fn overlapping_pin_ranges_panic() {
        let r = Registry::new("test");
        let _ = r.pin(&[0..8, 4..12]);
    }

    #[test]
    #[should_panic(expected = "overlaps pinned")]
    fn pin_overlapping_live_pin_panics() {
        let r = Registry::new("test");
        let _a = r.pin(&[0..8]);
        let _b = r.pin(&[4..12]);
    }

    #[test]
    #[should_panic(expected = "overlaps pinned")]
    fn exclusive_claim_over_live_pin_panics() {
        let r = Registry::new("test");
        let _pin = r.pin(&[0..8]);
        r.claim_mut(2, 4);
    }

    #[test]
    fn shared_claim_over_live_pin_is_allowed() {
        let r = Registry::new("test");
        let _pin = r.pin(&[0..8]);
        r.claim_shared(2, 4);
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let r = Registry::new("test");
        let pin = r.pin(&[0..4]);
        pin.release();
        pin.release();
    }

    #[test]
    #[should_panic(expected = "after release")]
    fn use_after_release_panics() {
        let r = Registry::new("test");
        let pin = r.pin(&[0..4]);
        pin.release();
        pin.assert_live();
    }

    #[test]
    fn release_then_new_pin_over_same_rows_is_fine() {
        let r = Registry::new("test");
        let pin = r.pin(&[0..4]);
        pin.release();
        let pin2 = r.pin(&[0..4]);
        pin2.assert_live();
        pin2.release();
    }
}
