//! Spillable, shard-aware storage for the per-side cost-factor working
//! copies — the [`FactorStore`] abstraction behind which every consumer of
//! the `O(n·(d+2))` factor buffers now lives.
//!
//! The refinement core (see [`crate::coordinator::hiref`]) is linear-space
//! by construction, but until this module the *working copies of the cost
//! factors* were fully resident, so they — not the algorithm — set the
//! scaling ceiling.  `FactorStore` turns factor ownership into an access
//! protocol:
//!
//! * [`ResidentStore`] — today's behaviour, zero-cost: the factor rows
//!   live in one [`RangeShared`] buffer and a checkout is nothing but a
//!   pointer + per-lane offsets (no copy, no I/O).
//! * [`SpillStore`] — file-backed: the factor rows live in a
//!   process-private scratch file, and a checkout reads exactly the
//!   requested contiguous level ranges into one packed arena buffer.
//!   Released shards are written back (write-through), any cached shard
//!   overlapping the released rows is invalidated (so the cache is
//!   always coherent with the file), and a bounded LRU cache — capped by
//!   `budget_bytes` — keeps the freshly released shards resident so
//!   checkouts at the next scale skip the disk.
//!
//! The unit of checkout is a **batch of contiguous level ranges** — the
//! lane windows of one level-synchronous LROT batch — which makes a level
//! batch the unit of storage and therefore the natural shard unit for the
//! multi-node sharding the ROADMAP aims at.  The cache invariant is
//! `resident ≤ budget + pinned` at all times: cached (unpinned) shards
//! never exceed the budget, and pinned bytes are exactly the in-flight
//! checkout windows (one level batch at a time on the batched path).
//!
//! Spilled and resident runs are **bit-identical by construction**: a
//! checkout hands back exactly the same `f32` rows either way (the spill
//! file round-trips raw bits), and the solver consumes the same
//! [`crate::linalg::MatView`]/`BatchView` windows over them.
//!
//! Element precision is a store property ([`Precision`], default
//! [`Precision::F32`]): bf16/f16 stores hold rows in a 2-byte format and
//! narrow/widen through the dispatched convert kernels
//! ([`crate::linalg::kernels`]) — writes encode on the way in
//! (round-to-nearest-even), `checkout` decodes lane windows into f32
//! arena scratch, dirty `release` re-encodes — so the solver consumes
//! f32 either way and the F32 default keeps the zero-copy resident path
//! and raw-bits spill format unchanged.  Spilled == resident
//! bit-identity holds *per precision*: both stores decode the same
//! stored bits, and every byte counter (stats, cache budget) is in the
//! true stored width.

use std::fs::OpenOptions;
use std::io;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::fsio::PositionedFile;
use crate::linalg::{kernels, Mat};
use crate::pool::{guard, RangeShared, ScratchArena, ScratchF32};

/// Stored element format of a [`FactorStore`].  The solve path is always
/// f32 (decode on checkout, f32 accumulation, RNE re-encode on dirty
/// release); this only chooses what the rows look like at rest —
/// resident buffers, shard cache, and spill file all hold this format.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// IEEE binary32 — bit-identical to the pre-precision behaviour
    /// (zero-copy resident checkouts, raw-bits spill round-trip).
    #[default]
    F32,
    /// bfloat16: f32's full exponent range, 8-bit significand, 2
    /// bytes/element.  The robust low-precision default — narrowing can
    /// never overflow or flush to zero, only round.
    Bf16,
    /// IEEE binary16: 11-bit significand but a narrow exponent (±6.5e4,
    /// subnormals below 6.1e-5), 2 bytes/element.  More mantissa than
    /// bf16 for factors known to be well-scaled.
    F16,
}

impl Precision {
    /// Stored bytes per element.
    pub const fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 | Precision::F16 => 2,
        }
    }

    /// Canonical flag/display name (`f32`/`bf16`/`f16`).
    pub const fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::F16 => "f16",
        }
    }

    /// Parse a flag value as printed by [`Precision::as_str`].
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "bf16" => Some(Precision::Bf16),
            "f16" => Some(Precision::F16),
            _ => None,
        }
    }

    /// Narrow f32 values into this format's stored `u16` representation
    /// (round-to-nearest-even, via the dispatched convert kernels).
    pub(crate) fn encode(self, src: &[f32], dst: &mut [u16]) {
        match self {
            Precision::F32 => unreachable!("f32 stores hold raw f32 rows"),
            Precision::Bf16 => kernels::f32_to_bf16_slice(src, dst),
            Precision::F16 => kernels::f32_to_f16_slice(src, dst),
        }
    }

    /// Widen stored `u16` values back to f32 (exact — every bf16/f16
    /// value is representable in f32).
    pub(crate) fn decode(self, src: &[u16], dst: &mut [f32]) {
        match self {
            Precision::F32 => unreachable!("f32 stores hold raw f32 rows"),
            Precision::Bf16 => kernels::bf16_to_f32_slice(src, dst),
            Precision::F16 => kernels::f16_to_f32_slice(src, dst),
        }
    }
}

/// Storage counters of a [`FactorStore`], all in bytes unless noted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Bytes written to the spill file (initial population + dirty shard
    /// write-backs); 0 for a resident store.
    pub spill_bytes_written: usize,
    /// Shard reads served from the spill file (count, not bytes); 0 for a
    /// resident store and for checkouts served from the shard cache.
    pub spill_reads: usize,
    /// Checkout lanes served from the resident shard cache.
    pub cache_hits: usize,
    /// Factor bytes resident right now (cache + pinned checkouts; for a
    /// resident store this is the whole buffer).
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes`.
    pub resident_peak: usize,
    /// Bytes pinned by in-flight checkouts right now.
    pub pinned_bytes: usize,
    /// High-water mark of `pinned_bytes` — “one level batch's lane
    /// windows” in the memory model (`resident_peak ≤ budget +
    /// pinned_peak` for a [`SpillStore`]).
    pub pinned_peak: usize,
}

/// One lane of a [`Checkout`]: which store rows it covers and where it
/// starts inside the checked-out span.
struct Lane {
    start: u32,
    rows: u32,
    off_rows: usize,
}

/// A pinned set of factor-row windows: one shared row-major span of
/// `cols()` columns in which lane `i` occupies rows
/// `lane_row(i) .. lane_row(i) + len_i`.
///
/// For a [`ResidentStore`] the span aliases the store's own buffer
/// (zero-copy, lane offsets relative to the covering span); for a
/// [`SpillStore`] it is a packed arena buffer holding exactly the
/// requested rows.  Accessors are `unsafe` under the same caller-enforced
/// disjointness contract as [`RangeShared`]: no concurrently live borrow
/// may overlap an exclusive [`Checkout::lane_mut`] window, which the
/// refinement hierarchy guarantees structurally (sibling lanes are
/// disjoint; the LROT read phase ends before the re-index write phase).
pub struct Checkout<'a> {
    ptr: *mut f32,
    len: usize,
    k: usize,
    lanes: Vec<Lane>,
    /// Pinned bytes this checkout accounts for in its store.
    bytes: usize,
    /// Keeps the packed arena buffer alive for spill checkouts.
    _buf: Option<ScratchF32<'a>>,
    /// Debug-only borrow registry over this checkout's span (element
    /// units): `lane_mut` windows conflict with overlapping `data`/`lane`
    /// borrows across threads.
    span: guard::Registry,
    /// Debug-only pin in the owning store's registry; `release` releases
    /// it (double release panics) and every accessor asserts it is live
    /// (use-after-release panics).
    pin: guard::PinToken,
}

// SAFETY: same argument as `SharedSlice` — the raw span pointer is only
// dereferenced through the unsafe accessors, whose caller-enforced
// disjoint-range contract makes handing the checkout to workers sound
// (the f32 payload is Send).
unsafe impl Send for Checkout<'_> {}
// SAFETY: concurrent shared access from several threads is exactly the
// accessor contract (disjoint exclusive windows, freely shared reads),
// and `&f32` is thread-safe.
unsafe impl Sync for Checkout<'_> {}

impl Checkout<'_> {
    /// Number of lanes (requested ranges).
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Row offset of lane `i` within the checked-out span.
    #[inline]
    pub fn lane_row(&self, i: usize) -> usize {
        self.lanes[i].off_rows
    }

    /// Number of rows in lane `i`.
    #[inline]
    pub fn lane_rows(&self, i: usize) -> usize {
        self.lanes[i].rows as usize
    }

    /// The whole span as a shared slice (the backing buffer of a
    /// `BatchView` over the lanes).
    ///
    /// # Safety
    /// No concurrently live [`Checkout::lane_mut`] borrow may exist
    /// anywhere in the span.
    #[inline]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn data(&self) -> &[f32] {
        self.pin.assert_live();
        self.span.claim_shared(0, self.len);
        // SAFETY: ptr/len describe the live checkout span (pin asserted
        // above); aliasing is the caller's contract, checked in debug
        // builds by the span claim.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Lane `i` as a shared slice (`len_i · cols` elements, row-major).
    ///
    /// # Safety
    /// No concurrently live exclusive borrow may overlap lane `i`.
    #[inline]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn lane(&self, i: usize) -> &[f32] {
        let l = &self.lanes[i];
        self.pin.assert_live();
        self.span.claim_shared(l.off_rows * self.k, (l.off_rows + l.rows as usize) * self.k);
        // SAFETY: the lane window is inside the live checkout span (pin
        // asserted above); aliasing is the caller's contract, checked in
        // debug builds by the span claim.
        unsafe {
            std::slice::from_raw_parts(self.ptr.add(l.off_rows * self.k), l.rows as usize * self.k)
        }
    }

    /// Copy `dst.len() / cols` evenly spaced rows of lane `i` into `dst`
    /// (sample row `t` is lane row `t·len_i/take`) — deterministic
    /// centroid seeding for the cluster-warmstart engine, served through
    /// the checkout so it reads identical rows on resident, spilled and
    /// narrow-precision stores.
    ///
    /// # Safety
    /// Same contract as [`Checkout::lane`]: no concurrently live
    /// exclusive borrow may overlap lane `i`.
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn sample_lane_rows(&self, i: usize, dst: &mut [f32]) {
        let k = self.k;
        let take = dst.len() / k;
        debug_assert_eq!(dst.len(), take * k, "sample buffer must hold whole rows");
        // SAFETY: forwarded caller contract — a shared read of lane `i`.
        let rows = unsafe { self.lane(i) };
        let len = rows.len() / k;
        debug_assert!(take > 0 && take <= len, "cannot sample {take} of {len} rows");
        for t in 0..take {
            let src = t * len / take;
            dst[t * k..(t + 1) * k].copy_from_slice(&rows[src * k..(src + 1) * k]);
        }
    }

    /// Lane `i` as an exclusive slice (the in-place re-index target).
    ///
    /// # Safety
    /// No concurrently live borrow of any kind may overlap lane `i`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn lane_mut(&self, i: usize) -> &mut [f32] {
        let l = &self.lanes[i];
        self.pin.assert_live();
        self.span.claim_mut(l.off_rows * self.k, (l.off_rows + l.rows as usize) * self.k);
        // SAFETY: the lane window is inside the live checkout span (pin
        // asserted above); aliasing is the caller's contract, checked in
        // debug builds by the span claim.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.ptr.add(l.off_rows * self.k),
                l.rows as usize * self.k,
            )
        }
    }
}

/// Ownership abstraction for one side's factor working copy: `rows()`
/// row-major rows of `cols()` f32 columns, accessed through pinned
/// [`Checkout`]s of contiguous level ranges.  Rows may rest in a
/// narrower element format ([`FactorStore::precision`]); the f32 access
/// surface is unchanged — writes narrow (round-to-nearest-even), reads
/// widen.
///
/// Implementations must hand back bit-identical rows regardless of where
/// they live — the refinement engine relies on this for the spilled ==
/// resident equivalence (which holds per precision: same stored bits,
/// same decode).
pub trait FactorStore: Send + Sync {
    /// Number of factor rows (`n`).
    fn rows(&self) -> usize;

    /// Factor width (`d + 2` for squared Euclidean, `t` for Indyk).
    fn cols(&self) -> usize;

    /// Stored element format ([`Precision::F32`] unless the store was
    /// built with a `*_with` constructor).  All byte accounting — stats,
    /// cache budgets, spill-file size — is in this width.
    fn precision(&self) -> Precision;

    /// Write `data.len()/cols()` rows starting at `start_row` (initial
    /// population by the chunked factor builders — tiles go straight into
    /// the store, no full-matrix intermediate).
    ///
    /// # Safety
    /// Concurrent callers must write pairwise-disjoint row windows, and no
    /// checkout may be live over the written rows (same contract as
    /// [`crate::pool::SharedSlice`]).
    unsafe fn write_rows(&self, start_row: usize, data: &[f32]) -> io::Result<()>;

    /// Read `out.len()/cols()` rows starting at `start_row` (scattered
    /// access, e.g. the Indyk regression's sampled rows).
    ///
    /// # Safety
    /// No concurrently live overlapping [`FactorStore::write_rows`] or
    /// dirty checkout may exist over the read rows.
    unsafe fn read_rows(&self, start_row: usize, out: &mut [f32]) -> io::Result<()>;

    /// Populate `n_rows` rows starting at `start_row` by calling `fill`
    /// on a mutable window (`fill` must fully overwrite it — prior
    /// content is unspecified) — the tile-build primitive of the chunked
    /// factor builders.  The default stages in `arena` scratch and writes
    /// through ([`FactorStore::write_rows`]); a resident store overrides
    /// it to hand out its own row window, so the resident build path
    /// stays copy-free.
    ///
    /// # Safety
    /// Same contract as [`FactorStore::write_rows`]: concurrent callers
    /// must fill pairwise-disjoint row windows with no live checkout over
    /// them.
    unsafe fn fill_rows_with(
        &self,
        start_row: usize,
        n_rows: usize,
        arena: &ScratchArena,
        fill: &mut dyn FnMut(&mut [f32]),
    ) -> io::Result<()> {
        let mut buf = arena.take_f32(n_rows * self.cols());
        fill(&mut buf);
        // SAFETY: forwards this fn's own contract (disjoint concurrent
        // windows, no live checkout over them) to write_rows.
        unsafe { self.write_rows(start_row, &buf) }
    }

    /// Pin the factor rows of `ranges` (pairwise disjoint, each in
    /// bounds) as the lanes of one [`Checkout`].  Spill stores draw the
    /// packed buffer from `arena`.
    fn checkout<'a>(
        &'a self,
        ranges: &[Range<u32>],
        arena: &'a ScratchArena,
    ) -> io::Result<Checkout<'a>>;

    /// Unpin a checkout.  `dirty` means the lanes were rewritten in place
    /// (the counting-sort re-index) and must be persisted; a resident
    /// store mutated its own buffer, a spill store writes the shards back
    /// and re-admits them to the bounded cache.
    fn release(&self, co: Checkout<'_>, dirty: bool) -> io::Result<()>;

    /// Storage counters (see [`StoreStats`]).
    fn stats(&self) -> StoreStats;

    /// Materialise the full factor matrix (tests and compatibility
    /// wrappers only — the solve path never does this).
    fn into_mat(self: Box<Self>) -> io::Result<Mat>;
}

// ---------------------------------------------------------------------------
// ResidentStore
// ---------------------------------------------------------------------------

/// The in-memory [`FactorStore`]: factor rows live in one
/// [`RangeShared`] buffer.  At [`Precision::F32`] (the default) this is
/// exactly the pre-store behaviour — zero-cost: a checkout is a pointer
/// into the buffer, no copy, no I/O, `release` is a no-op.  At bf16/f16
/// the buffer holds encoded `u16` rows: writes narrow on the way in,
/// checkouts decode the lane windows packed into f32 arena scratch, and
/// a dirty release re-encodes in place (round-to-nearest-even).
pub struct ResidentStore {
    rows: usize,
    k: usize,
    prec: Precision,
    buf: ResidentBuf,
    pinned: AtomicUsize,
    pinned_peak: AtomicUsize,
}

/// Stored representation of a [`ResidentStore`]: raw f32 rows, or rows
/// encoded in a 2-byte format ([`Precision::Bf16`]/[`Precision::F16`]).
enum ResidentBuf {
    F32(RangeShared<f32>),
    U16(RangeShared<u16>),
}

impl ResidentBuf {
    /// The borrow registry guarding the buffer.  Both representations
    /// index claims by element, so range arithmetic is width-agnostic.
    fn registry(&self) -> &guard::Registry {
        match self {
            ResidentBuf::F32(b) => b.guard_registry(),
            ResidentBuf::U16(b) => b.guard_registry(),
        }
    }
}

impl ResidentStore {
    /// Take ownership of prebuilt factors (stored as raw f32).
    pub fn from_mat(m: Mat) -> ResidentStore {
        ResidentStore::from_mat_with(m, Precision::F32)
    }

    /// Take ownership of prebuilt factors, narrowing them into `prec`'s
    /// stored format (round-to-nearest-even for bf16/f16).
    pub fn from_mat_with(m: Mat, prec: Precision) -> ResidentStore {
        let (rows, k) = (m.rows, m.cols);
        let buf = match prec {
            Precision::F32 => ResidentBuf::F32(RangeShared::new(m.data)),
            _ => {
                let mut enc = vec![0u16; m.data.len()];
                prec.encode(&m.data, &mut enc);
                ResidentBuf::U16(RangeShared::new(enc))
            }
        };
        ResidentStore {
            rows,
            k,
            prec,
            buf,
            pinned: AtomicUsize::new(0),
            pinned_peak: AtomicUsize::new(0),
        }
    }

    /// An all-zero f32 store for the chunked builders to fill.
    pub fn zeroed(rows: usize, k: usize) -> ResidentStore {
        ResidentStore::zeroed_with(rows, k, Precision::F32)
    }

    /// An all-zero store in `prec`'s format (+0.0 encodes as all-zero
    /// bits in every supported format, so no conversion pass runs).
    pub fn zeroed_with(rows: usize, k: usize, prec: Precision) -> ResidentStore {
        match prec {
            Precision::F32 => ResidentStore::from_mat_with(Mat::zeros(rows, k), prec),
            _ => ResidentStore {
                rows,
                k,
                prec,
                buf: ResidentBuf::U16(RangeShared::new(vec![0u16; rows * k])),
                pinned: AtomicUsize::new(0),
                pinned_peak: AtomicUsize::new(0),
            },
        }
    }
}

impl FactorStore for ResidentStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.k
    }

    fn precision(&self) -> Precision {
        self.prec
    }

    unsafe fn write_rows(&self, start_row: usize, data: &[f32]) -> io::Result<()> {
        debug_assert_eq!(data.len() % self.k, 0);
        let (lo, hi) = (start_row * self.k, start_row * self.k + data.len());
        // RAII-scoped (not fire-and-forget) claim: a store write's borrow
        // provably ends when this call returns, so writes separated in
        // time must never conflict — but a live checkout pin over these
        // rows or a concurrent overlapping write panics here.
        let _claim = self.buf.registry().scoped_mut(lo, hi);
        match &self.buf {
            // SAFETY: caller promises disjoint concurrent windows (trait
            // contract, guard-checked above); bounds checked by the slice.
            ResidentBuf::F32(buf) => {
                unsafe { buf.slice_mut_unclaimed(lo, hi) }.copy_from_slice(data)
            }
            // encode-on-write: the f32 tile narrows straight into the
            // stored format, never materializing at f32 width.
            // SAFETY: as above.
            ResidentBuf::U16(buf) => {
                self.prec.encode(data, unsafe { buf.slice_mut_unclaimed(lo, hi) })
            }
        }
        Ok(())
    }

    unsafe fn read_rows(&self, start_row: usize, out: &mut [f32]) -> io::Result<()> {
        debug_assert_eq!(out.len() % self.k, 0);
        let (lo, hi) = (start_row * self.k, start_row * self.k + out.len());
        let _claim = self.buf.registry().scoped_shared(lo, hi);
        match &self.buf {
            // SAFETY: caller promises no overlapping concurrent writes
            // (trait contract, guard-checked above); bounds checked by
            // the slice.
            ResidentBuf::F32(buf) => out.copy_from_slice(unsafe { buf.slice_unclaimed(lo, hi) }),
            // SAFETY: as above.
            ResidentBuf::U16(buf) => {
                self.prec.decode(unsafe { buf.slice_unclaimed(lo, hi) }, out)
            }
        }
        Ok(())
    }

    unsafe fn fill_rows_with(
        &self,
        start_row: usize,
        n_rows: usize,
        arena: &ScratchArena,
        fill: &mut dyn FnMut(&mut [f32]),
    ) -> io::Result<()> {
        let (lo, hi) = (start_row * self.k, (start_row + n_rows) * self.k);
        match &self.buf {
            ResidentBuf::F32(buf) => {
                // copy-free: hand the builder our own row window directly.
                let _claim = buf.guard_registry().scoped_mut(lo, hi);
                // SAFETY: caller promises disjoint concurrent windows
                // (trait contract, guard-checked above); bounds checked
                // by the slice.
                fill(unsafe { buf.slice_mut_unclaimed(lo, hi) });
                Ok(())
            }
            ResidentBuf::U16(_) => {
                // builders produce f32 rows: stage one tile in arena
                // scratch and narrow through the write path.
                let mut tile = arena.take_f32(hi - lo);
                fill(&mut tile);
                // SAFETY: forwards this fn's own contract (disjoint
                // concurrent windows, no live checkout over them).
                unsafe { self.write_rows(start_row, &tile) }
            }
        }
    }

    fn checkout<'a>(
        &'a self,
        ranges: &[Range<u32>],
        arena: &'a ScratchArena,
    ) -> io::Result<Checkout<'a>> {
        assert!(!ranges.is_empty(), "empty checkout");
        let lo = ranges.iter().map(|r| r.start).min().unwrap() as usize;
        let hi = ranges.iter().map(|r| r.end).max().unwrap() as usize;
        assert!(hi <= self.rows, "checkout {lo}..{hi} out of 0..{}", self.rows);
        let k = self.k;
        let w = self.prec.bytes();
        // Pinned bytes are in store elements (`w` each) — the transient
        // f32 decode scratch of a low-precision checkout is owned and
        // accounted by the arena, not the store.
        let mut bytes = 0usize;
        let (ptr, len, lanes, dec_buf) = match &self.buf {
            ResidentBuf::F32(buf) => {
                let lanes = ranges
                    .iter()
                    .map(|r| {
                        assert!(r.start <= r.end, "inverted range");
                        bytes += (r.end - r.start) as usize * k * w;
                        Lane {
                            start: r.start,
                            rows: r.end - r.start,
                            off_rows: (r.start as usize) - lo,
                        }
                    })
                    .collect::<Vec<_>>();
                // SAFETY: lo·k is in bounds (hi ≤ rows was asserted
                // above); aliasing is governed by the Checkout accessor
                // contract.
                (unsafe { buf.ptr.add(lo * k) }, (hi - lo) * k, lanes, None)
            }
            ResidentBuf::U16(buf) => {
                // low-precision lanes decode packed into f32 arena
                // scratch (the spill layout); the store's own rows stay
                // encoded.
                let total_rows: usize = ranges.iter().map(|r| (r.end - r.start) as usize).sum();
                let mut dec = arena.take_f32(total_rows * k);
                let mut lanes = Vec::with_capacity(ranges.len());
                let mut off = 0usize;
                for r in ranges {
                    assert!(r.start <= r.end, "inverted range");
                    let rows = r.end - r.start;
                    bytes += rows as usize * k * w;
                    let (slo, shi) = (r.start as usize * k, r.end as usize * k);
                    let _claim = buf.guard_registry().scoped_shared(slo, shi);
                    // SAFETY: no overlapping write may be live (trait
                    // contract, guard-checked above); bounds checked by
                    // the slice.
                    self.prec.decode(
                        unsafe { buf.slice_unclaimed(slo, shi) },
                        &mut dec[off * k..(off + rows as usize) * k],
                    );
                    lanes.push(Lane { start: r.start, rows, off_rows: off });
                    off += rows as usize;
                }
                let ptr = dec.as_mut_ptr();
                let len = dec.len();
                (ptr, len, lanes, Some(dec))
            }
        };
        let pinned = self.pinned.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.pinned_peak.fetch_max(pinned, Ordering::Relaxed);
        // Pin the lane windows (element units) in the buffer's registry:
        // overlapping concurrent checkouts and store writes under a live
        // checkout panic with both sites.
        let pin = self.buf.registry().pin(
            &ranges
                .iter()
                .map(|r| r.start as usize * k..r.end as usize * k)
                .collect::<Vec<_>>(),
        );
        Ok(Checkout {
            ptr,
            len,
            k,
            lanes,
            bytes,
            _buf: dec_buf,
            span: guard::Registry::new("Checkout"),
            pin,
        })
    }

    fn release(&self, co: Checkout<'_>, dirty: bool) -> io::Result<()> {
        if let ResidentBuf::U16(buf) = &self.buf {
            if dirty {
                // the re-index mutated the f32 decode scratch, not the
                // store: narrow each lane back (round-to-nearest-even).
                for (i, lane) in co.lanes.iter().enumerate() {
                    // SAFETY: release owns `co` exclusively; no borrows
                    // remain.
                    let data = unsafe { co.lane(i) };
                    let slo = lane.start as usize * self.k;
                    // SAFETY: this checkout's live pin covers the window,
                    // excluding every other writer (overlapping checkouts
                    // and store writes panic against pins), and `release`
                    // holds `co` exclusively — no aliasing borrow exists;
                    // bounds checked by the slice.
                    self.prec
                        .encode(data, unsafe { buf.slice_mut_unclaimed(slo, slo + data.len()) });
                }
            }
        }
        // f32: in-place mutation already landed in the shared buffer
        self.pinned.fetch_sub(co.bytes, Ordering::Relaxed);
        co.pin.release();
        Ok(())
    }

    fn stats(&self) -> StoreStats {
        let bytes = self.rows * self.k * self.prec.bytes();
        StoreStats {
            resident_bytes: bytes,
            resident_peak: bytes,
            pinned_bytes: self.pinned.load(Ordering::Relaxed),
            pinned_peak: self.pinned_peak.load(Ordering::Relaxed),
            ..StoreStats::default()
        }
    }

    fn into_mat(self: Box<Self>) -> io::Result<Mat> {
        match self.buf {
            ResidentBuf::F32(buf) => Ok(Mat::from_vec(self.rows, self.k, buf.into_inner())),
            ResidentBuf::U16(buf) => {
                let enc = buf.into_inner();
                let mut m = Mat::zeros(self.rows, self.k);
                self.prec.decode(&enc, &mut m.data);
                Ok(m)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SpillStore
// ---------------------------------------------------------------------------

/// Distinguishes spill files of concurrent solves within one process.
static SPILL_FILE_ID: AtomicU64 = AtomicU64::new(0);

#[inline]
fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding and alignment ≥ u8; the spill file is
    // process-private native-endian scratch, never an interchange format.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast(), v.len() * 4) }
}

#[inline]
fn f32s_as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    // SAFETY: as above; any bit pattern is a valid f32.
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr().cast(), v.len() * 4) }
}

#[inline]
fn u16s_as_bytes(v: &[u16]) -> &[u8] {
    // SAFETY: u16 has no padding and alignment ≥ u8; the spill file is
    // process-private native-endian scratch, never an interchange format.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast(), v.len() * 2) }
}

#[inline]
fn u16s_as_bytes_mut(v: &mut [u16]) -> &mut [u8] {
    // SAFETY: as above; any bit pattern is a valid u16.
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr().cast(), v.len() * 2) }
}

/// A cached shard's payload, in the store's element format (encoded
/// `u16` for bf16/f16 — cache hits decode, exactly like file reads).
#[derive(Clone)]
enum ShardBuf {
    F32(std::sync::Arc<[f32]>),
    U16(std::sync::Arc<[u16]>),
}

impl ShardBuf {
    /// Stored bytes (true element width).
    fn bytes(&self) -> usize {
        match self {
            ShardBuf::F32(b) => b.len() * 4,
            ShardBuf::U16(b) => b.len() * 2,
        }
    }
}

/// One cached shard: a contiguous level range released by a dirty
/// checkout, kept resident until the LRU budget pushes it out.  The
/// buffer is refcounted so checkout hits can clone the handle under the
/// cache lock and copy/decode outside it.
struct Shard {
    start: u32,
    rows: u32,
    buf: ShardBuf,
    last_use: u64,
}

#[derive(Default)]
struct SpillState {
    /// Cache coherence invariant: every cached shard always agrees with
    /// the (write-through) spill file — a dirty release first drops any
    /// cached shard overlapping the released windows, then inserts the
    /// fresh ones.  Any containing shard is therefore valid to serve a
    /// checkout; no ordering or recency rule carries correctness.
    shards: Vec<Shard>,
    tick: u64,
    cached: usize,
    pinned: usize,
    resident_peak: usize,
    pinned_peak: usize,
}

/// The file-backed [`FactorStore`]: rows live in a process-private scratch
/// file (removed on drop); checkouts pack the requested level ranges into
/// one arena buffer; dirty releases write shards back (write-through) and
/// cache them under an LRU budget of `budget_bytes`.
pub struct SpillStore {
    path: PathBuf,
    rows: usize,
    k: usize,
    prec: Precision,
    budget: usize,
    file: PositionedFile,
    state: Mutex<SpillState>,
    bytes_written: AtomicUsize,
    reads: AtomicUsize,
    hits: AtomicUsize,
    /// Debug-only borrow registry over the store's row space (row units —
    /// the file has no element-granular aliasing to track).
    guard: guard::Registry,
}

impl SpillStore {
    /// Create an all-zero `rows × k` f32 store backed by a fresh scratch
    /// file under `dir` (created if absent), with a resident shard cache
    /// capped at `budget_bytes` (0 disables caching — every checkout
    /// reads the file).
    pub fn create(
        dir: impl AsRef<Path>,
        rows: usize,
        k: usize,
        budget_bytes: usize,
    ) -> io::Result<SpillStore> {
        SpillStore::create_with(dir, rows, k, budget_bytes, Precision::F32)
    }

    /// As [`SpillStore::create`], with rows stored in `prec`'s element
    /// format: the file, the shard cache, and every byte counter are in
    /// the true stored width, so a bf16 store spills and caches half the
    /// bytes of an f32 one.
    pub fn create_with(
        dir: impl AsRef<Path>,
        rows: usize,
        k: usize,
        budget_bytes: usize,
        prec: Precision,
    ) -> io::Result<SpillStore> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let id = SPILL_FILE_ID.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!("hiref-factors-{}-{id}.spill", std::process::id()));
        let file = OpenOptions::new().read(true).write(true).create_new(true).open(&path)?;
        file.set_len((rows * k * prec.bytes()) as u64)?;
        Ok(SpillStore {
            path,
            rows,
            k,
            prec,
            budget: budget_bytes,
            file: PositionedFile::new(file),
            state: Mutex::new(SpillState::default()),
            bytes_written: AtomicUsize::new(0),
            reads: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
            guard: guard::Registry::new("SpillStore"),
        })
    }

    /// Where the scratch file lives (removed when the store drops).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Positioned I/O (lock-free `pread`/`pwrite` on unix — see
    /// [`PositionedFile`]).
    fn read_at(&self, offset: u64, bytes: &mut [u8]) -> io::Result<()> {
        self.file.read_at(offset, bytes)
    }

    fn write_at(&self, offset: u64, bytes: &[u8]) -> io::Result<()> {
        self.file.write_at(offset, bytes)
    }

    /// Write already-encoded low-precision rows at `start_row` (row-unit
    /// guard claim; byte accounting in the stored width).
    fn write_encoded(&self, start_row: usize, enc: &[u16]) -> io::Result<()> {
        let _claim = self.guard.scoped_mut(start_row, start_row + enc.len() / self.k);
        self.write_at((start_row * self.k * 2) as u64, u16s_as_bytes(enc))?;
        self.bytes_written.fetch_add(enc.len() * 2, Ordering::Relaxed);
        Ok(())
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

impl FactorStore for SpillStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.k
    }

    fn precision(&self) -> Precision {
        self.prec
    }

    unsafe fn write_rows(&self, start_row: usize, data: &[f32]) -> io::Result<()> {
        debug_assert_eq!(data.len() % self.k, 0);
        assert!(start_row * self.k + data.len() <= self.rows * self.k, "write out of bounds");
        if self.prec != Precision::F32 {
            // encode-on-write (cold path — the chunked builders come in
            // through `fill_rows_with`, which stages in the arena).
            let mut enc = vec![0u16; data.len()];
            self.prec.encode(data, &mut enc);
            return self.write_encoded(start_row, &enc);
        }
        // Row-unit RAII claim: a concurrent overlapping write, or a write
        // under a live checkout pin of these rows, panics here (the file
        // itself would not corrupt, but the cache/checkout coherence
        // contract would be violated).
        let _claim = self.guard.scoped_mut(start_row, start_row + data.len() / self.k);
        self.write_at((start_row * self.k * 4) as u64, f32s_as_bytes(data))?;
        self.bytes_written.fetch_add(data.len() * 4, Ordering::Relaxed);
        Ok(())
    }

    unsafe fn read_rows(&self, start_row: usize, out: &mut [f32]) -> io::Result<()> {
        debug_assert_eq!(out.len() % self.k, 0);
        assert!(start_row * self.k + out.len() <= self.rows * self.k, "read out of bounds");
        let _claim = self.guard.scoped_shared(start_row, start_row + out.len() / self.k);
        match self.prec {
            Precision::F32 => {
                self.read_at((start_row * self.k * 4) as u64, f32s_as_bytes_mut(out))?
            }
            prec => {
                let mut enc = vec![0u16; out.len()];
                self.read_at((start_row * self.k * 2) as u64, u16s_as_bytes_mut(&mut enc))?;
                prec.decode(&enc, out);
            }
        }
        self.reads.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    unsafe fn fill_rows_with(
        &self,
        start_row: usize,
        n_rows: usize,
        arena: &ScratchArena,
        fill: &mut dyn FnMut(&mut [f32]),
    ) -> io::Result<()> {
        let mut buf = arena.take_f32(n_rows * self.k);
        fill(&mut buf);
        if self.prec == Precision::F32 {
            // SAFETY: forwards this fn's own contract (disjoint
            // concurrent windows, no live checkout over them).
            return unsafe { self.write_rows(start_row, &buf) };
        }
        // encode-on-write without the per-tile Vec of the write_rows cold
        // path: the narrowed tile stages in pooled arena scratch too.
        let mut enc = arena.take_u16(buf.len());
        self.prec.encode(&buf, &mut enc);
        self.write_encoded(start_row, &enc)
    }

    fn checkout<'a>(
        &'a self,
        ranges: &[Range<u32>],
        arena: &'a ScratchArena,
    ) -> io::Result<Checkout<'a>> {
        assert!(!ranges.is_empty(), "empty checkout");
        let k = self.k;
        let w = self.prec.bytes();
        let total_rows: usize = ranges.iter().map(|r| (r.end - r.start) as usize).sum();
        let mut guard = arena.take_f32(total_rows * k);
        // pinned bytes in store elements — the f32 decode scratch of a
        // low-precision checkout is the arena's to account
        let bytes = total_rows * k * w;
        let mut lanes = Vec::with_capacity(ranges.len());
        let mut misses: Vec<(usize, u32, u32)> = Vec::new();
        // (dest element offset, shard handle, source element offset, len)
        let mut hits: Vec<(usize, ShardBuf, usize, usize)> = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            let mut off = 0usize;
            for r in ranges {
                assert!(
                    r.start <= r.end && (r.end as usize) <= self.rows,
                    "checkout range {r:?} out of 0..{}",
                    self.rows
                );
                let rows = r.end - r.start;
                // any containing shard is coherent (see SpillState); only
                // the Arc handle is cloned under the lock — the memcpy
                // happens after it is released
                if let Some(sh) = st
                    .shards
                    .iter_mut()
                    .find(|s| s.start <= r.start && r.end <= s.start + s.rows)
                {
                    sh.last_use = tick;
                    let so = (r.start - sh.start) as usize * k;
                    hits.push((off * k, sh.buf.clone(), so, rows as usize * k));
                } else {
                    misses.push((off, r.start, rows));
                }
                lanes.push(Lane { start: r.start, rows, off_rows: off });
                off += rows as usize;
            }
            st.pinned += bytes;
            st.pinned_peak = st.pinned_peak.max(st.pinned);
            st.resident_peak = st.resident_peak.max(st.cached + st.pinned);
        }
        // copies and file reads happen outside the lock: pread is
        // positional and the shard handles are refcounted, so concurrent
        // per-block checkouts don't serialise on the cache
        for (dst, buf, so, len) in hits {
            match &buf {
                ShardBuf::F32(b) => guard[dst..dst + len].copy_from_slice(&b[so..so + len]),
                // cached shards hold encoded elements: widen straight
                // into the packed checkout window
                ShardBuf::U16(b) => self.prec.decode(&b[so..so + len], &mut guard[dst..dst + len]),
            }
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        for (off, start, rows) in misses {
            let len = rows as usize * k;
            let dst = &mut guard[off * k..off * k + len];
            let res = match self.prec {
                Precision::F32 => {
                    self.read_at((start as usize * k * 4) as u64, f32s_as_bytes_mut(dst))
                }
                prec => {
                    let mut enc = arena.take_u16(len);
                    let res =
                        self.read_at((start as usize * k * 2) as u64, u16s_as_bytes_mut(&mut enc));
                    if res.is_ok() {
                        prec.decode(&enc, dst);
                    }
                    res
                }
            };
            if let Err(e) = res {
                self.state.lock().unwrap().pinned -= bytes;
                return Err(e);
            }
            self.reads.fetch_add(1, Ordering::Relaxed);
        }
        let ptr = guard.as_mut_ptr();
        let len = guard.len();
        // Pin the row windows only now, after every read succeeded — the
        // truncated-file error path above must not leak a pin.
        let pin = self
            .guard
            .pin(&ranges.iter().map(|r| r.start as usize..r.end as usize).collect::<Vec<_>>());
        Ok(Checkout {
            ptr,
            len,
            k,
            lanes,
            bytes,
            _buf: Some(guard),
            span: guard::Registry::new("Checkout"),
            pin,
        })
    }

    fn release(&self, co: Checkout<'_>, dirty: bool) -> io::Result<()> {
        let k = self.k;
        let w = self.prec.bytes();
        let mut write_err = None;
        // Only a suffix of the released lanes can survive this release's
        // own LRU churn (inserts share one tick; earlier inserts are the
        // eviction victims), so copy only that suffix — not every
        // budget-fitting lane.
        let mut stage_from = co.lanes.len();
        if dirty {
            let mut acc = 0usize;
            for (i, lane) in co.lanes.iter().enumerate().rev() {
                let lane_bytes = lane.rows as usize * k * w;
                if lane_bytes == 0 || acc + lane_bytes > self.budget {
                    break;
                }
                acc += lane_bytes;
                stage_from = i;
            }
        }
        // staged outside the lock: (lane index, shard copy)
        let mut staged: Vec<(usize, ShardBuf)> = Vec::new();
        if dirty {
            // write-through: the file is always authoritative, which makes
            // cache eviction free and shard lookups coherent
            for (i, lane) in co.lanes.iter().enumerate() {
                // SAFETY: release owns `co` exclusively; no borrows remain.
                let data = unsafe { co.lane(i) };
                let offset = (lane.start as usize * k * w) as u64;
                // low precision narrows once (round-to-nearest-even): the
                // file write and the cached shard share the encoding
                let (res, buf) = match self.prec {
                    Precision::F32 => (
                        self.write_at(offset, f32s_as_bytes(data)),
                        (i >= stage_from).then(|| ShardBuf::F32(std::sync::Arc::from(data))),
                    ),
                    prec => {
                        let mut enc = vec![0u16; data.len()];
                        prec.encode(data, &mut enc);
                        let res = self.write_at(offset, u16s_as_bytes(&enc));
                        (res, (i >= stage_from).then(|| ShardBuf::U16(std::sync::Arc::from(enc))))
                    }
                };
                match res {
                    Ok(()) => {
                        self.bytes_written.fetch_add(data.len() * w, Ordering::Relaxed);
                        if let Some(buf) = buf {
                            staged.push((i, buf));
                        }
                    }
                    Err(e) => {
                        write_err = Some(e);
                        break;
                    }
                }
            }
        }
        let mut st = self.state.lock().unwrap();
        st.pinned -= co.bytes;
        if dirty {
            // coherence: drop every cached shard overlapping the released
            // windows — their copies of those rows are stale against the
            // file.  This runs even after a mid-loop write failure: lanes
            // written before the error already changed the file, so the
            // overlapping cache must go regardless (the run is doomed
            // anyway, but no path may ever serve stale rows).
            let mut freed = 0usize;
            st.shards.retain(|s| {
                let overlaps = co.lanes.iter().any(|l| {
                    s.start < l.start + l.rows && l.start < s.start + s.rows
                });
                if overlaps {
                    freed += s.buf.bytes();
                }
                !overlaps
            });
            st.cached -= freed;
        }
        if dirty && write_err.is_none() {
            st.tick += 1;
            let tick = st.tick;
            for (i, buf) in staged {
                let lane = &co.lanes[i];
                let lane_bytes = lane.rows as usize * k * w;
                while st.cached + lane_bytes > self.budget {
                    let victim = st
                        .shards
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, s)| s.last_use)
                        .map(|(i, _)| i);
                    match victim {
                        Some(v) => {
                            let s = st.shards.swap_remove(v);
                            st.cached -= s.buf.bytes();
                        }
                        None => break,
                    }
                }
                // staging guarantees lane_bytes ≤ budget and the eviction
                // loop only stops under-budget or on an empty cache, so
                // the insert below always fits
                debug_assert!(st.cached + lane_bytes <= self.budget);
                st.shards.push(Shard { start: lane.start, rows: lane.rows, buf, last_use: tick });
                st.cached += lane_bytes;
            }
            st.resident_peak = st.resident_peak.max(st.cached + st.pinned);
        }
        drop(st);
        // after the write-back loop (whose `co.lane(i)` reads require a
        // live pin), before the checkout is dropped
        co.pin.release();
        drop(co);
        match write_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn stats(&self) -> StoreStats {
        let st = self.state.lock().unwrap();
        StoreStats {
            spill_bytes_written: self.bytes_written.load(Ordering::Relaxed),
            spill_reads: self.reads.load(Ordering::Relaxed),
            cache_hits: self.hits.load(Ordering::Relaxed),
            resident_bytes: st.cached + st.pinned,
            resident_peak: st.resident_peak,
            pinned_bytes: st.pinned,
            pinned_peak: st.pinned_peak,
        }
    }

    fn into_mat(self: Box<Self>) -> io::Result<Mat> {
        let mut m = Mat::zeros(self.rows, self.k);
        match self.prec {
            Precision::F32 => self.read_at(0, f32s_as_bytes_mut(&mut m.data))?,
            prec => {
                let mut enc = vec![0u16; self.rows * self.k];
                self.read_at(0, u16s_as_bytes_mut(&mut enc))?;
                prec.decode(&enc, &mut m.data);
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_mat(seed: u64, n: usize, k: usize) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(n, k);
        rng.fill_normal(&mut m.data);
        m
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "hiref_store_{}_{}_{tag}",
            std::process::id(),
            SPILL_FILE_ID.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Populate a store with `m`'s rows through the builder write path.
    fn fill(store: &dyn FactorStore, m: &Mat) {
        // SAFETY: single-threaded test setup — no concurrent writes, no
        // live checkout.
        unsafe { store.write_rows(0, &m.data) }.unwrap();
    }

    #[test]
    fn resident_store_round_trips_and_checkout_is_zero_copy() {
        let m = rand_mat(0, 20, 3);
        let store = ResidentStore::zeroed(20, 3);
        fill(&store, &m);
        let mut out = vec![0.0f32; 4 * 3];
        // SAFETY: single-threaded — no concurrent writes or dirty checkout.
        unsafe { store.read_rows(5, &mut out) }.unwrap();
        assert_eq!(out, &m.data[15..27]);
        let arena = ScratchArena::new(1);
        let co = store.checkout(&[2..5, 9..12], &arena).unwrap();
        assert_eq!(co.lanes(), 2);
        // lanes are windows of the covering span at their absolute offsets
        assert_eq!(co.lane_row(0), 0);
        assert_eq!(co.lane_row(1), 7);
        // SAFETY: no exclusive borrow is live anywhere in the span.
        assert_eq!(unsafe { co.lane(0) }, &m.data[2 * 3..5 * 3]);
        // SAFETY: as above.
        assert_eq!(unsafe { co.lane(1) }, &m.data[9 * 3..12 * 3]);
        // zero-copy: no arena scratch was drawn
        assert_eq!(arena.peak_bytes(), 0);
        let st = store.stats();
        assert_eq!(st.pinned_bytes, 6 * 3 * 4);
        store.release(co, true).unwrap();
        assert_eq!(store.stats().pinned_bytes, 0);
        let got = Box::new(store).into_mat().unwrap();
        assert_eq!(got.data, m.data);
    }

    #[test]
    fn resident_checkout_mutation_lands_in_store() {
        let m = rand_mat(1, 10, 2);
        let store = ResidentStore::from_mat(m.clone());
        let arena = ScratchArena::new(1);
        let co = store.checkout(&[3..6], &arena).unwrap();
        // SAFETY: the only live borrow of the lane (single-threaded).
        unsafe { co.lane_mut(0) }.iter_mut().for_each(|v| *v = -1.0);
        store.release(co, true).unwrap();
        let got = Box::new(store).into_mat().unwrap();
        assert!(got.data[6..12].iter().all(|&v| v == -1.0));
        assert_eq!(got.data[..6], m.data[..6]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn spill_store_round_trips_bit_identically() {
        let dir = tmp_dir("roundtrip");
        let m = rand_mat(2, 37, 4);
        let store = SpillStore::create(&dir, 37, 4, 1 << 20).unwrap();
        fill(&store, &m);
        let mut out = vec![0.0f32; 5 * 4];
        // SAFETY: single-threaded — no concurrent writes or dirty checkout.
        unsafe { store.read_rows(7, &mut out) }.unwrap();
        for (a, b) in out.iter().zip(&m.data[28..48]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let arena = ScratchArena::new(1);
        let co = store.checkout(&[0..10, 20..37], &arena).unwrap();
        // SAFETY: no exclusive borrow is live anywhere in the span.
        assert_eq!(unsafe { co.lane(0) }, &m.data[..10 * 4]);
        // SAFETY: as above.
        assert_eq!(unsafe { co.lane(1) }, &m.data[20 * 4..]);
        // packed layout: lane 1 starts right after lane 0
        assert_eq!(co.lane_row(1), 10);
        store.release(co, false).unwrap();
        let path = store.path().to_path_buf();
        assert!(path.exists());
        let got = Box::new(store).into_mat().unwrap();
        assert_eq!(got.data, m.data);
        assert!(!path.exists(), "spill file must be removed on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn spill_dirty_release_persists_and_caches() {
        let dir = tmp_dir("dirty");
        let m = rand_mat(3, 16, 2);
        let store = SpillStore::create(&dir, 16, 2, 1 << 20).unwrap();
        fill(&store, &m);
        let arena = ScratchArena::new(1);
        let reads0 = store.stats().spill_reads;
        let co = store.checkout(&[4..8], &arena).unwrap();
        // SAFETY: the only live borrow of the lane (single-threaded).
        unsafe { co.lane_mut(0) }.iter_mut().for_each(|v| *v = 9.0);
        store.release(co, true).unwrap();
        // sub-range of the released shard: served from cache, no disk read
        let co = store.checkout(&[5..7], &arena).unwrap();
        // SAFETY: no exclusive borrow is live anywhere in the span.
        assert!(unsafe { co.lane(0) }.iter().all(|&v| v == 9.0));
        store.release(co, false).unwrap();
        let st = store.stats();
        assert_eq!(st.spill_reads, reads0 + 1, "second checkout must hit the cache");
        assert!(st.cache_hits >= 1);
        // the file too holds the mutation (write-through)
        let got = Box::new(store).into_mat().unwrap();
        assert!(got.data[8..16].iter().all(|&v| v == 9.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn dirty_release_invalidates_stale_overlapping_shards() {
        let dir = tmp_dir("coherence");
        let m = rand_mat(4, 8, 1);
        let store = SpillStore::create(&dir, 8, 1, 1 << 20).unwrap();
        fill(&store, &m);
        let arena = ScratchArena::new(1);
        // parent release caches 0..8
        let co = store.checkout(&[0..8], &arena).unwrap();
        store.release(co, true).unwrap();
        // child rewrites 0..4: the parent's cached copy of those rows is
        // now stale, so the dirty release must drop it (write-through
        // keeps the file fresh for the untouched half)
        let co = store.checkout(&[0..4], &arena).unwrap();
        // SAFETY: the only live borrow of the lane (single-threaded).
        unsafe { co.lane_mut(0) }.iter_mut().for_each(|v| *v = 5.0);
        store.release(co, true).unwrap();
        // a grandchild inside the child sees the child's fresh shard...
        let co = store.checkout(&[1..3], &arena).unwrap();
        // SAFETY: no exclusive borrow is live anywhere in the span.
        assert!(unsafe { co.lane(0) }.iter().all(|&v| v == 5.0));
        store.release(co, false).unwrap();
        // ...and a sibling in the untouched half — whose covering parent
        // shard was invalidated — reads correct rows back from the file
        let reads_before = store.stats().spill_reads;
        let co = store.checkout(&[5..7], &arena).unwrap();
        // SAFETY: as above.
        assert_eq!(unsafe { co.lane(0) }, &m.data[5..7]);
        store.release(co, false).unwrap();
        assert_eq!(store.stats().spill_reads, reads_before + 1, "parent shard must be gone");
        // even after LRU churn no stale data can ever be served: only
        // coherent shards remain cached
        let co = store.checkout(&[0..2], &arena).unwrap();
        // SAFETY: as above.
        assert!(unsafe { co.lane(0) }.iter().all(|&v| v == 5.0));
        store.release(co, false).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn pin_release_accounting_and_budget_invariant() {
        let dir = tmp_dir("pins");
        let n = 64usize;
        let k = 4usize;
        let budget = 24 * k * 4; // fits 24 rows of cache
        let store = SpillStore::create(&dir, n, k, budget).unwrap();
        fill(&store, &rand_mat(5, n, k));
        let arena = ScratchArena::new(1);
        let co_a = store.checkout(&[0..16], &arena).unwrap();
        let co_b = store.checkout(&[16..48], &arena).unwrap();
        let st = store.stats();
        assert_eq!(st.pinned_bytes, (16 + 32) * k * 4);
        assert_eq!(st.pinned_peak, (16 + 32) * k * 4);
        store.release(co_b, true).unwrap();
        store.release(co_a, true).unwrap();
        let st = store.stats();
        assert_eq!(st.pinned_bytes, 0);
        // the 32-row shard exceeds the 24-row budget and is never cached;
        // the 16-row shard fits
        assert!(st.resident_bytes <= budget, "cache {} over budget {budget}", st.resident_bytes);
        // the acceptance invariant: resident never exceeded budget + the
        // in-flight lane windows
        assert!(
            st.resident_peak <= budget + st.pinned_peak,
            "resident_peak {} > budget {budget} + pinned_peak {}",
            st.resident_peak,
            st.pinned_peak
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn zero_budget_forces_disk_reads_every_checkout() {
        let dir = tmp_dir("zero");
        let store = SpillStore::create(&dir, 32, 2, 0).unwrap();
        fill(&store, &rand_mat(6, 32, 2));
        let arena = ScratchArena::new(1);
        for _ in 0..3 {
            let co = store.checkout(&[0..32], &arena).unwrap();
            store.release(co, true).unwrap();
        }
        let st = store.stats();
        assert_eq!(st.spill_reads, 3, "every checkout must read the file");
        assert_eq!(st.cache_hits, 0);
        assert!(st.resident_peak <= st.pinned_peak);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn lru_eviction_prefers_least_recently_used() {
        let dir = tmp_dir("lru");
        let k = 1usize;
        // budget holds exactly two 8-row shards
        let store = SpillStore::create(&dir, 32, k, 16 * 4).unwrap();
        fill(&store, &rand_mat(7, 32, k));
        let arena = ScratchArena::new(1);
        for r in [0u32..8, 8..16] {
            let co = store.checkout(&[r], &arena).unwrap();
            store.release(co, true).unwrap();
        }
        // touch 0..8 so 8..16 becomes the LRU victim
        let co = store.checkout(&[0..8], &arena).unwrap();
        store.release(co, false).unwrap();
        let reads_before = store.stats().spill_reads;
        // caching 16..24 evicts 8..16
        let co = store.checkout(&[16..24], &arena).unwrap();
        store.release(co, true).unwrap();
        let co = store.checkout(&[0..8], &arena).unwrap(); // still cached
        store.release(co, false).unwrap();
        let co = store.checkout(&[8..16], &arena).unwrap(); // evicted: disk
        store.release(co, false).unwrap();
        let st = store.stats();
        assert_eq!(st.spill_reads, reads_before + 2, "16..24 miss + evicted 8..16");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn create_under_a_file_errors() {
        let dir = tmp_dir("badparent");
        let file_path = dir.join("iamafile");
        std::fs::write(&file_path, b"x").unwrap();
        let bad = file_path.join("sub");
        assert!(SpillStore::create(&bad, 8, 2, 0).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn truncated_file_surfaces_read_errors() {
        let dir = tmp_dir("trunc");
        let store = SpillStore::create(&dir, 16, 2, 0).unwrap();
        fill(&store, &rand_mat(8, 16, 2));
        // truncate behind the store's back: reads past EOF must error, not
        // panic (the mid-solve failure path)
        OpenOptions::new()
            .write(true)
            .open(store.path())
            .unwrap()
            .set_len(8)
            .unwrap();
        let arena = ScratchArena::new(1);
        let err = store.checkout(&[8..16], &arena).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // the failed checkout must not leak pinned bytes
        assert_eq!(store.stats().pinned_bytes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn fill_rows_with_matches_write_rows_on_both_stores() {
        let dir = tmp_dir("fillwith");
        let m = rand_mat(10, 12, 3);
        let res = ResidentStore::zeroed(12, 3);
        let sp = SpillStore::create(&dir, 12, 3, 0).unwrap();
        let arena = ScratchArena::new(1);
        for store in [&res as &dyn FactorStore, &sp as &dyn FactorStore] {
            // build in two tiles through the builder primitive
            for (start, rows) in [(0usize, 7usize), (7, 5)] {
                // SAFETY: tiles are disjoint and filled sequentially with
                // no live checkout.
                unsafe {
                    store
                        .fill_rows_with(start, rows, &arena, &mut |out| {
                            out.copy_from_slice(&m.data[start * 3..(start + rows) * 3]);
                        })
                        .unwrap();
                }
            }
            let mut got = vec![0.0f32; 12 * 3];
            // SAFETY: single-threaded — no concurrent writes.
            unsafe { store.read_rows(0, &mut got) }.unwrap();
            assert_eq!(got, m.data);
        }
        // the resident override is copy-free: no arena scratch drawn for
        // its fills (the spill default stages one tile per call)
        assert!(arena.peak_bytes() > 0, "spill default must stage in the arena");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn spill_and_resident_checkouts_agree_bitwise() {
        let dir = tmp_dir("agree");
        let m = rand_mat(9, 48, 5);
        let res = ResidentStore::from_mat(m.clone());
        let sp = SpillStore::create(&dir, 48, 5, 64).unwrap();
        fill(&sp, &m);
        let arena = ScratchArena::new(1);
        for ranges in [vec![0u32..48], vec![3..9, 9..15, 40..48]] {
            let a = res.checkout(&ranges, &arena).unwrap();
            let b = sp.checkout(&ranges, &arena).unwrap();
            for l in 0..ranges.len() {
                // SAFETY: no exclusive borrow is live in either span.
                let (la, lb) = unsafe { (a.lane(l), b.lane(l)) };
                assert_eq!(la.len(), lb.len());
                for (x, y) in la.iter().zip(lb) {
                    assert_eq!(x.to_bits(), y.to_bits(), "lane {l} diverges");
                }
            }
            res.release(a, false).unwrap();
            sp.release(b, false).unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn to_bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|v| v.to_bits()).collect()
    }

    /// Reference narrowing round-trip: what a store at `prec` must hand
    /// back after absorbing `xs`.
    fn narrowed(prec: Precision, xs: &[f32]) -> Vec<f32> {
        let mut enc = vec![0u16; xs.len()];
        prec.encode(xs, &mut enc);
        let mut dec = vec![0.0f32; xs.len()];
        prec.decode(&enc, &mut dec);
        dec
    }

    #[test]
    fn low_precision_resident_round_trips_through_the_convert_kernels() {
        for prec in [Precision::Bf16, Precision::F16] {
            let m = rand_mat(11, 20, 3);
            let want = narrowed(prec, &m.data);
            let store = ResidentStore::zeroed_with(20, 3, prec);
            assert_eq!(store.precision(), prec);
            fill(&store, &m);
            // stats are in the true element width
            assert_eq!(store.stats().resident_bytes, 20 * 3 * 2);
            let mut out = vec![0.0f32; 4 * 3];
            // SAFETY: single-threaded — no concurrent writes or checkout.
            unsafe { store.read_rows(5, &mut out) }.unwrap();
            assert_eq!(to_bits(&out), to_bits(&want[15..27]));
            let arena = ScratchArena::new(1);
            let co = store.checkout(&[2..5, 9..12], &arena).unwrap();
            // low-precision lanes are packed decode copies, not aliases
            assert_eq!(co.lane_row(1), 3);
            // SAFETY: no exclusive borrow is live anywhere in the span.
            assert_eq!(to_bits(unsafe { co.lane(0) }), to_bits(&want[2 * 3..5 * 3]));
            // SAFETY: as above.
            assert_eq!(to_bits(unsafe { co.lane(1) }), to_bits(&want[9 * 3..12 * 3]));
            assert_eq!(store.stats().pinned_bytes, 6 * 3 * 2);
            store.release(co, false).unwrap();
            assert!(arena.peak_bytes() > 0, "low-precision decode must stage in the arena");
            let got = Box::new(store).into_mat().unwrap();
            assert_eq!(to_bits(&got.data), to_bits(&want));
        }
    }

    #[test]
    fn dirty_release_reencodes_low_precision_lanes() {
        for prec in [Precision::Bf16, Precision::F16] {
            let m = rand_mat(12, 10, 2);
            let store = ResidentStore::from_mat_with(m.clone(), prec);
            let arena = ScratchArena::new(1);
            let co = store.checkout(&[3..6], &arena).unwrap();
            // SAFETY: the only live borrow of the lane (single-threaded).
            unsafe { co.lane_mut(0) }.iter_mut().for_each(|v| *v = 0.1);
            store.release(co, true).unwrap();
            let got = Box::new(store).into_mat().unwrap();
            // 0.1 is inexact in both formats: the store must hold its RNE
            // narrowing, not the f32 value
            let enc01 = narrowed(prec, &[0.1])[0];
            assert!(enc01 != 0.1);
            assert!(got.data[6..12].iter().all(|&v| v.to_bits() == enc01.to_bits()));
            // untouched rows keep their original encoding
            assert_eq!(to_bits(&got.data[..6]), to_bits(&narrowed(prec, &m.data[..6])));
        }
    }

    #[test]
    fn release_without_mutation_never_changes_stored_bits() {
        // decode → re-encode is the identity on stored values (tested
        // exhaustively at the kernel level), so checkout/release cycles —
        // clean or dirty — must be idempotent on the stored bits.
        for prec in [Precision::Bf16, Precision::F16] {
            let m = rand_mat(13, 16, 2);
            let store = ResidentStore::from_mat_with(m.clone(), prec);
            let want = narrowed(prec, &m.data);
            let arena = ScratchArena::new(1);
            for dirty in [false, true] {
                let co = store.checkout(&[0..16], &arena).unwrap();
                store.release(co, dirty).unwrap();
            }
            let got = Box::new(store).into_mat().unwrap();
            assert_eq!(to_bits(&got.data), to_bits(&want));
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn spill_and_resident_agree_bitwise_at_every_precision() {
        for prec in [Precision::F32, Precision::Bf16, Precision::F16] {
            let dir = tmp_dir(prec.as_str());
            let m = rand_mat(14, 48, 5);
            let res = ResidentStore::from_mat_with(m.clone(), prec);
            let sp = SpillStore::create_with(&dir, 48, 5, 64, prec).unwrap();
            fill(&sp, &m);
            let arena = ScratchArena::new(1);
            for ranges in [vec![0u32..48], vec![3..9, 9..15, 40..48]] {
                let a = res.checkout(&ranges, &arena).unwrap();
                let b = sp.checkout(&ranges, &arena).unwrap();
                for l in 0..ranges.len() {
                    // SAFETY: no exclusive borrow is live in either span.
                    let (la, lb) = unsafe { (a.lane(l), b.lane(l)) };
                    assert_eq!(to_bits(la), to_bits(lb), "{} lane {l} diverges", prec.as_str());
                }
                // dirty releases on identical data keep them in lockstep
                res.release(a, true).unwrap();
                sp.release(b, true).unwrap();
            }
            let ga = Box::new(res).into_mat().unwrap();
            let gb = Box::new(sp).into_mat().unwrap();
            assert_eq!(to_bits(&ga.data), to_bits(&gb.data));
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn spill_byte_accounting_uses_true_element_width() {
        let dir = tmp_dir("width");
        let n = 16usize;
        let k = 4usize;
        let m = rand_mat(15, n, k);
        let store = SpillStore::create_with(&dir, n, k, 1 << 20, Precision::Bf16).unwrap();
        assert_eq!(store.precision(), Precision::Bf16);
        fill(&store, &m);
        assert_eq!(store.stats().spill_bytes_written, n * k * 2);
        // the file itself is laid out at 2 bytes/element
        assert_eq!(std::fs::metadata(store.path()).unwrap().len(), (n * k * 2) as u64);
        let arena = ScratchArena::new(1);
        let co = store.checkout(&[0..8], &arena).unwrap();
        assert_eq!(store.stats().pinned_bytes, 8 * k * 2);
        store.release(co, true).unwrap();
        let st = store.stats();
        assert_eq!(st.pinned_bytes, 0);
        assert_eq!(st.spill_bytes_written, n * k * 2 + 8 * k * 2);
        // the re-admitted shard is cached at encoded width
        assert_eq!(st.resident_bytes, 8 * k * 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: spill files need real file I/O")]
    fn low_precision_cache_hits_decode_the_same_bits_as_disk() {
        let dir = tmp_dir("hitdec");
        let m = rand_mat(16, 24, 3);
        let store = SpillStore::create_with(&dir, 24, 3, 1 << 20, Precision::F16).unwrap();
        fill(&store, &m);
        let arena = ScratchArena::new(1);
        // miss: decoded from the file
        let co = store.checkout(&[4..12], &arena).unwrap();
        // SAFETY: no exclusive borrow is live anywhere in the span.
        let from_disk = unsafe { co.lane(0) }.to_vec();
        store.release(co, true).unwrap();
        // hit: decoded from the cached (still-encoded) shard
        let hits0 = store.stats().cache_hits;
        let co = store.checkout(&[4..12], &arena).unwrap();
        // SAFETY: as above.
        assert_eq!(to_bits(unsafe { co.lane(0) }), to_bits(&from_disk));
        store.release(co, false).unwrap();
        assert_eq!(store.stats().cache_hits, hits0 + 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Seeded store-level contract violations the [`guard`] layer must
    /// catch.  Pins are exempt from epoch pruning, so these detect
    /// deterministically (no retry loops needed).
    #[cfg(any(debug_assertions, feature = "guard"))]
    mod guard_negative {
        use super::*;

        #[test]
        #[should_panic(expected = "lanes overlap")]
        fn overlapping_checkout_lane_ranges_panic() {
            let store = ResidentStore::zeroed(16, 1);
            let arena = ScratchArena::new(1);
            let _ = store.checkout(&[0..8, 4..12], &arena);
        }

        #[test]
        #[should_panic(expected = "overlaps pinned")]
        fn overlapping_concurrent_checkouts_panic() {
            let store = ResidentStore::zeroed(16, 1);
            let arena = ScratchArena::new(1);
            let _a = store.checkout(&[0..8], &arena).unwrap();
            let _b = store.checkout(&[4..12], &arena);
        }

        #[test]
        #[should_panic(expected = "overlaps pinned")]
        fn write_rows_under_a_live_checkout_panics() {
            let store = ResidentStore::zeroed(16, 1);
            let arena = ScratchArena::new(1);
            let _co = store.checkout(&[0..8], &arena).unwrap();
            // SAFETY: deliberately violated — writing rows out from under
            // a live checkout is the seeded bug under test; the guard
            // must panic before the copy happens.
            let _ = unsafe { store.write_rows(2, &[1.0, 2.0]) };
        }
    }
}
