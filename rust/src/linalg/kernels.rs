//! Runtime-dispatched SIMD kernels for the LROT hot loop.
//!
//! Every FLOP of the solve path — offline `align`, streaming
//! `align_source`, and the `hiref serve` microbatcher — funnels through
//! five primitives: the two slice matmuls, the `fast_exp` sweep, the
//! masked row softmax, and the max-abs step-size reduction.  This module
//! gives each of them an explicit AVX2 (x86_64) and NEON (aarch64)
//! implementation next to the **verbatim scalar reference** ([`scalar`]),
//! picks one implementation per process at first use, and exposes the
//! choice ([`active`]) so stats lines and bench JSONs record what ran.
//! The same table carries the four precision-convert kernels
//! (f32 ↔ bf16/f16, round-to-nearest-even narrowing) that back
//! low-precision factor storage (`pool::store::Precision`): encode on
//! store write/release, decode on checkout, held to the identical
//! scalar-vs-SIMD bit-parity bar as the hot-loop primitives.
//!
//! # Dispatch rules
//!
//! The path is resolved **once**, on the first kernel call, and cached in
//! a [`OnceLock`]:
//!
//! 1. If `HIREF_KERNELS` is set to `scalar`, `avx2` or `neon`, that path
//!    is used — unless the host cannot run it, in which case a warning is
//!    printed and the scalar reference is used instead.  This is the
//!    testing/CI override (the perf-smoke job re-runs the suite with
//!    `HIREF_KERNELS=scalar` so both paths stay covered).
//! 2. Otherwise the host is probed: `avx2` on x86_64 when the CPU reports
//!    it, `neon` on aarch64, scalar everywhere else.
//!
//! # The column-lane bit-identity argument
//!
//! The repo-wide invariant — every execution strategy produces
//! bit-identical output — extends to the SIMD paths because vectorization
//! is laid out **across output columns**, never across a reduction:
//!
//! * Both matmuls reduce over the shared dimension `p` with `out[j] +=
//!   a[p] * b[p][j]`.  A SIMD lane owns output column `j` and performs
//!   *exactly* the scalar additions for that column, in the same `p`
//!   order; only independent columns run side by side.  The multiply and
//!   add are issued as **separate instructions (never FMA)** — Rust never
//!   contracts float expressions, so the scalar code rounds twice and the
//!   vector code must too.
//! * `fast_exp` is element-wise; the vector body mirrors the scalar
//!   operation sequence exactly (see [`avx2::exp8`] for the one subtle
//!   spot: emulating round-half-away-from-zero on x86).
//! * The softmax row **sum stays scalar**: the reference accumulates
//!   `sum` in index order interleaved with the exp sweep, and any
//!   vectorized reduction would re-associate it.  Only the row max, the
//!   exp sweep and the final scale are vectorized.  The row max *is*
//!   lane-folded, which can flip which of `-0.0`/`+0.0` wins a tied max —
//!   harmless, because `fast_exp(v - mx)` is exactly `1.0` for both zero
//!   signs and the padding-mask comparison treats them identically.
//! * `slice_max_abs` folds non-negative values, so the reduction is
//!   order-independent; NaN inputs are skipped by both paths (the scalar
//!   fold's `f32::max` returns the accumulator on NaN, matched by the
//!   vector min/max operand order).

use super::{fast_exp, MatView, NEG_LOGMASS};
use std::sync::OnceLock;

/// Which kernel implementation the process dispatched to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    Scalar,
    Avx2,
    Neon,
}

impl KernelPath {
    pub fn as_str(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Avx2 => "avx2",
            KernelPath::Neon => "neon",
        }
    }
}

/// One implementation of the five hot-loop primitives plus the four
/// precision-convert kernels (`pool::store`'s low-precision factor path:
/// encode on write/release, decode on checkout).
struct KernelOps {
    path: KernelPath,
    matmul: fn(MatView<'_>, MatView<'_>, &mut [f32]),
    vt_matmul: fn(MatView<'_>, MatView<'_>, &mut [f32]),
    exp_slice: fn(&[f32], &mut [f32]),
    max_abs: fn(&[f32]) -> f32,
    row_softmax: fn(MatView<'_>, &mut [f32]),
    enc_bf16: fn(&[f32], &mut [u16]),
    dec_bf16: fn(&[u16], &mut [f32]),
    enc_f16: fn(&[f32], &mut [u16]),
    dec_f16: fn(&[u16], &mut [f32]),
}

static SCALAR_OPS: KernelOps = KernelOps {
    path: KernelPath::Scalar,
    matmul: scalar::matmul_into_slice,
    vt_matmul: scalar::vt_matmul_into_slice,
    exp_slice: scalar::exp_slice,
    max_abs: scalar::slice_max_abs,
    row_softmax: scalar::row_softmax,
    enc_bf16: scalar::f32_to_bf16_slice,
    dec_bf16: scalar::bf16_to_f32_slice,
    enc_f16: scalar::f32_to_f16_slice,
    dec_f16: scalar::f16_to_f32_slice,
};

#[cfg(target_arch = "x86_64")]
static AVX2_OPS: KernelOps = KernelOps {
    path: KernelPath::Avx2,
    matmul: avx2::matmul_into_slice,
    vt_matmul: avx2::vt_matmul_into_slice,
    exp_slice: avx2::exp_slice,
    max_abs: avx2::slice_max_abs,
    row_softmax: avx2::row_softmax,
    enc_bf16: avx2::f32_to_bf16_slice,
    dec_bf16: avx2::bf16_to_f32_slice,
    enc_f16: avx2::f32_to_f16_slice,
    dec_f16: avx2::f16_to_f32_slice,
};

#[cfg(target_arch = "aarch64")]
static NEON_OPS: KernelOps = KernelOps {
    path: KernelPath::Neon,
    matmul: neon::matmul_into_slice,
    vt_matmul: neon::vt_matmul_into_slice,
    exp_slice: neon::exp_slice,
    max_abs: neon::slice_max_abs,
    row_softmax: neon::row_softmax,
    enc_bf16: neon::f32_to_bf16_slice,
    dec_bf16: neon::bf16_to_f32_slice,
    enc_f16: neon::f32_to_f16_slice,
    dec_f16: neon::f16_to_f32_slice,
};

static OPS: OnceLock<&'static KernelOps> = OnceLock::new();

#[inline]
fn ops() -> &'static KernelOps {
    OPS.get_or_init(resolve)
}

/// Resolve a path by name, returning `None` when the host can't run it.
fn by_name(name: &str) -> Option<&'static KernelOps> {
    match name {
        "scalar" => Some(&SCALAR_OPS),
        "avx2" => {
            #[cfg(target_arch = "x86_64")]
            if avx2::available() {
                return Some(&AVX2_OPS);
            }
            None
        }
        "neon" => {
            #[cfg(target_arch = "aarch64")]
            if neon::available() {
                return Some(&NEON_OPS);
            }
            None
        }
        _ => None,
    }
}

fn resolve() -> &'static KernelOps {
    // Under Miri the scalar reference is pinned unconditionally: vendor
    // intrinsics and runtime CPU-feature probes are not interpretable, and
    // the scalar kernels are the semantics the SIMD paths are proven
    // bit-identical to anyway (docs/kernels.md, "Miri").
    #[cfg(miri)]
    {
        &SCALAR_OPS
    }
    #[cfg(not(miri))]
    {
        if let Ok(want) = std::env::var("HIREF_KERNELS") {
            if let Some(o) = by_name(&want) {
                return o;
            }
            eprintln!(
                "hiref: HIREF_KERNELS={want} not available on this host \
                 (expected scalar|avx2|neon); using the scalar reference"
            );
            return &SCALAR_OPS;
        }
        detect()
    }
}

fn detect() -> &'static KernelOps {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2::available() {
            return &AVX2_OPS;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if neon::available() {
            return &NEON_OPS;
        }
    }
    &SCALAR_OPS
}

/// The kernel path this process dispatched to (resolving it on first call).
pub fn active() -> KernelPath {
    ops().path
}

// ---------------------------------------------------------------------------
// Dispatched entry points (called by the `linalg` wrappers)
// ---------------------------------------------------------------------------

/// Dispatched `C = A @ B` into a row-major slice.
#[inline]
pub fn matmul_into_slice(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
    (ops().matmul)(a, b, c)
}

/// Dispatched `out = Aᵀ B` into a row-major slice.
#[inline]
pub fn vt_matmul_into_slice(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
    (ops().vt_matmul)(a, b, out)
}

/// Dispatched element-wise `dst[i] = fast_exp(src[i])` over
/// `min(src.len(), dst.len())` elements (zip semantics, like the scalar
/// reference).
#[inline]
pub fn exp_slice(src: &[f32], dst: &mut [f32]) {
    (ops().exp_slice)(src, dst)
}

/// Dispatched max absolute entry of a slice.
#[inline]
pub fn slice_max_abs(xs: &[f32]) -> f32 {
    (ops().max_abs)(xs)
}

/// Dispatched masked row softmax of one batch item: `l` is the logits
/// view, `dst` its output window (`l.rows * l.cols` long).
#[inline]
pub fn row_softmax_item(l: MatView<'_>, dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), l.rows * l.cols);
    (ops().row_softmax)(l, dst)
}

/// Dispatched RNE narrowing `dst[i] = bf16(src[i])` (lengths must match).
#[inline]
pub fn f32_to_bf16_slice(src: &[f32], dst: &mut [u16]) {
    (ops().enc_bf16)(src, dst)
}

/// Dispatched exact widening `dst[i] = f32(bf16 src[i])`.
#[inline]
pub fn bf16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
    (ops().dec_bf16)(src, dst)
}

/// Dispatched RNE narrowing `dst[i] = f16(src[i])` (IEEE binary16).
#[inline]
pub fn f32_to_f16_slice(src: &[f32], dst: &mut [u16]) {
    (ops().enc_f16)(src, dst)
}

/// Dispatched exact widening `dst[i] = f32(f16 src[i])`.
#[inline]
pub fn f16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
    (ops().dec_f16)(src, dst)
}

// ---------------------------------------------------------------------------
// Scalar reference
// ---------------------------------------------------------------------------

/// The scalar reference kernels — the historical `linalg` implementations
/// moved here **verbatim** (plus the zero-sum softmax guard).  Every SIMD
/// path must be bit-identical to these; the parity tests below and the
/// `HIREF_KERNELS=scalar` CI leg enforce it.
pub mod scalar {
    use super::{fast_exp, MatView, NEG_LOGMASS};

    /// `C = A @ B` into a row-major slice.
    pub fn matmul_into_slice(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        assert_eq!(c.len(), a.rows * b.cols);
        c.fill(0.0);
        let n = b.cols;
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = &mut c[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b.data[p * n..(p + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }

    /// `out = Aᵀ B` into a row-major slice without materialising the
    /// transpose.
    pub fn vt_matmul_into_slice(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
        assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
        assert_eq!(out.len(), a.cols * b.cols);
        out.fill(0.0);
        let n = b.cols;
        for p in 0..a.rows {
            let arow = a.row(p);
            let brow = b.row(p);
            for (i, &av) in arow.iter().enumerate() {
                let orow = &mut out[i * n..(i + 1) * n];
                for (ov, &bv) in orow.iter_mut().zip(brow) {
                    *ov += av * bv;
                }
            }
        }
    }

    /// `dst[i] = fast_exp(src[i])` over `min(src.len(), dst.len())`.
    pub fn exp_slice(src: &[f32], dst: &mut [f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = fast_exp(s);
        }
    }

    /// Max absolute entry of a slice.
    pub fn slice_max_abs(xs: &[f32]) -> f32 {
        xs.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Masked row softmax of one batch item (`dst` is `l.rows * l.cols`).
    ///
    /// Rows whose max is `≤ NEG_LOGMASS / 2` (phantom padding) produce
    /// all-zero rows.  A second guard covers the *sum*: a zero sum would
    /// scale the row by `inf`.  For a non-empty unmasked row the sum is
    /// provably ≥ 1 — the max element contributes `fast_exp(0) == 1`
    /// exactly, and `fast_exp` never returns NaN or a negative — so the
    /// guard is belt-and-suspenders, but it turns any future drift into a
    /// well-defined zero row instead of an `inf` plan.
    pub fn row_softmax(l: MatView<'_>, dst: &mut [f32]) {
        for (p, row) in dst.chunks_mut(l.cols).enumerate() {
            let src = l.row(p);
            let mx = src.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            if !(mx > NEG_LOGMASS / 2.0) {
                row.fill(0.0);
                continue;
            }
            let mut sum = 0.0f32;
            for (d, &v) in row.iter_mut().zip(src) {
                let e = fast_exp(v - mx);
                *d = e;
                sum += e;
            }
            if !(sum > 0.0) {
                row.fill(0.0);
                continue;
            }
            let inv = 1.0 / sum;
            for d in row.iter_mut() {
                *d *= inv;
            }
        }
    }

    // -- precision converts (low-precision FactorStore element formats) --
    //
    // bf16 is the top 16 bits of an f32 (1+8+7), so widening is a shift
    // and narrowing is round-to-nearest-even on the dropped 16 bits.
    // f16 is IEEE binary16 (1+5+10): re-bias the exponent, RNE on the 13
    // dropped mantissa bits, with explicit subnormal/overflow handling.
    // NaN policy (both formats): truncate the payload and force the
    // quiet bit — the hardware convert instructions (x86 F16C, ARM FCVT)
    // quiet signalling NaNs the same way, which is what keeps the SIMD
    // paths bit-identical to these references.

    /// Narrow one f32 to bf16 (RNE on the dropped 16 bits).
    #[inline]
    pub fn f32_to_bf16(x: f32) -> u16 {
        let bits = x.to_bits();
        if x.is_nan() {
            // force the quiet bit so a low-bits-only NaN payload cannot
            // truncate to an infinity encoding
            return ((bits >> 16) as u16) | 0x0040;
        }
        let round = ((bits >> 16) & 1) + 0x7FFF;
        ((bits + round) >> 16) as u16
    }

    /// Widen one bf16 to f32 (exact).
    #[inline]
    pub fn bf16_to_f32(h: u16) -> f32 {
        f32::from_bits((h as u32) << 16)
    }

    /// Narrow one f32 to IEEE binary16 (RNE, subnormals, signed zeros).
    #[inline]
    pub fn f32_to_f16(x: f32) -> u16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x007F_FFFF;
        if exp == 0xFF {
            if man == 0 {
                return sign | 0x7C00; // infinity
            }
            return sign | 0x7C00 | 0x0200 | (man >> 13) as u16; // quieted NaN
        }
        let unbiased = exp - 127;
        if unbiased >= 16 {
            return sign | 0x7C00; // ≥ 2^16 > 65520: RNE overflows to inf
        }
        if unbiased >= -14 {
            // normal f16; the RNE carry may roll the exponent (1.11… →
            // 10.0…) and may roll exponent 30 into the infinity encoding
            // — both are exactly RNE's overflow behaviour
            let mut h = (((unbiased + 15) as u32) << 10) | (man >> 13);
            let rem = bits & 0x1FFF;
            if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
                h += 1;
            }
            return sign | h as u16;
        }
        if unbiased >= -25 {
            // subnormal f16: shift the 24-bit significand into place, RNE
            // on the dropped bits; a carry into bit 10 yields the
            // smallest normal, which is again exactly RNE
            let full = 0x0080_0000 | man;
            let shift = (-1 - unbiased) as u32; // 14..=24
            let mut h = full >> shift;
            let rem = full & ((1u32 << shift) - 1);
            let half = 1u32 << (shift - 1);
            if rem > half || (rem == half && (h & 1) == 1) {
                h += 1;
            }
            return sign | h as u16;
        }
        sign // magnitude < 2^-25 (f32 subnormals included): RNE to ±0
    }

    /// Widen one IEEE binary16 to f32 (exact).
    #[inline]
    pub fn f16_to_f32(h: u16) -> f32 {
        let sign = ((h as u32) & 0x8000) << 16;
        let exp = ((h >> 10) & 0x1F) as u32;
        let man = (h & 0x03FF) as u32;
        let bits = if exp == 0x1F {
            if man == 0 {
                sign | 0x7F80_0000 // infinity
            } else {
                sign | 0x7FC0_0000 | (man << 13) // quieted NaN, payload kept
            }
        } else if exp != 0 {
            sign | ((exp + 112) << 23) | (man << 13)
        } else if man != 0 {
            // subnormal: normalise into an f32 normal
            let n = 31 - man.leading_zeros(); // MSB position, 0..=9
            sign | ((n + 103) << 23) | ((man << (23 - n)) & 0x007F_FFFF)
        } else {
            sign // signed zero
        };
        f32::from_bits(bits)
    }

    /// `dst[i] = bf16(src[i])` (lengths must match).
    pub fn f32_to_bf16_slice(src: &[f32], dst: &mut [u16]) {
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f32_to_bf16(s);
        }
    }

    /// `dst[i] = f32(bf16 src[i])` (lengths must match).
    pub fn bf16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = bf16_to_f32(s);
        }
    }

    /// `dst[i] = f16(src[i])` (lengths must match).
    pub fn f32_to_f16_slice(src: &[f32], dst: &mut [u16]) {
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f32_to_f16(s);
        }
    }

    /// `dst[i] = f32(f16 src[i])` (lengths must match).
    pub fn f16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f16_to_f32(s);
        }
    }
}

// Polynomial constants of `linalg::fast_exp`, duplicated for the SIMD
// bodies.  MUST match `fast_exp` exactly — the parity tests sweep the
// full input range, so any drift fails the suite.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
mod poly {
    pub const C0: f32 = 1.000_000_0;
    pub const C1: f32 = 0.693_147_2;
    pub const C2: f32 = 0.240_226_51;
    pub const C3: f32 = 0.055_504_11;
    pub const C4: f32 = 0.009_618_13;
    pub const C5: f32 = 0.001_333_55;
}

// ---------------------------------------------------------------------------
// AVX2 (x86_64)
// ---------------------------------------------------------------------------

/// AVX2 kernels: 8-lane f32, unaligned loads (lane windows are arbitrary
/// offsets into shared strided buffers), scalar tails.  Bit-identical to
/// [`scalar`] by the column-lane layout argument in the module docs.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use super::poly::*;
    use super::{fast_exp, MatView, NEG_LOGMASS};
    use std::arch::x86_64::*;

    /// Whether the host CPU can run this path.
    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    /// `y[j] += a * x[j]` — the shared inner loop of both matmuls.  The
    /// multiply and add are separate instructions (never FMA): the scalar
    /// `*cv += av * bv` rounds the product before the add, and so must we.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy(av: f32, x: &[f32], y: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            debug_assert_eq!(x.len(), y.len());
            let n = y.len();
            let va = _mm256_set1_ps(av);
            let mut j = 0;
            while j + 8 <= n {
                let vx = _mm256_loadu_ps(x.as_ptr().add(j));
                let vy = _mm256_loadu_ps(y.as_mut_ptr().add(j));
                let prod = _mm256_mul_ps(va, vx);
                _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_add_ps(vy, prod));
                j += 8;
            }
            while j < n {
                y[j] += av * x[j];
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn matmul_impl(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            c.fill(0.0);
            let n = b.cols;
            for i in 0..a.rows {
                let arow = a.row(i);
                let crow = &mut c[i * n..(i + 1) * n];
                for (p, &av) in arow.iter().enumerate() {
                    axpy(av, &b.data[p * n..(p + 1) * n], crow);
                }
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn vt_matmul_impl(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            out.fill(0.0);
            let n = b.cols;
            for p in 0..a.rows {
                let arow = a.row(p);
                let brow = b.row(p);
                for (i, &av) in arow.iter().enumerate() {
                    axpy(av, brow, &mut out[i * n..(i + 1) * n]);
                }
            }
        }
    }

    /// 8-lane `fast_exp`, operation-for-operation the scalar body.
    ///
    /// The one non-obvious step: scalar `f32::round` rounds halves *away
    /// from zero*, and SSE/AVX only offer round-to-even, so `k` is built
    /// as truncate-then-bump — `t = trunc(y)`, add 1 where `y - t ≥ 0.5`,
    /// subtract 1 where `y - t ≤ -0.5`.  (The folklore `trunc(y + 0.5)`
    /// shortcut is wrong: for `y = 0.49999997`, `y + 0.5` rounds up to
    /// `1.0`.)  Lanes that scalar code would early-return as underflow
    /// (`y ≤ -126`) run through the pipeline with garbage and are masked
    /// to `+0.0` at the end — same result, no branch.
    #[target_feature(enable = "avx2")]
    // On toolchains where safe-to-call target-feature intrinsics make
    // this block redundant, the wrap is dead weight, not an error.
    #[allow(unused_unsafe)]
    unsafe fn exp8(x: __m256) -> __m256 {
        // SAFETY: value intrinsics only — sound whenever the target
        // feature is present, which the caller proves (the safe checked
        // entries assert `available()` before entering this module).
        unsafe {
            let y = _mm256_mul_ps(x, _mm256_set1_ps(std::f32::consts::LOG2_E));
            let under = _mm256_cmp_ps::<_CMP_LE_OQ>(y, _mm256_set1_ps(-126.0));
            // scalar `y.min(127.0)` returns 127.0 when y is NaN; min_ps
            // returns the SECOND operand on NaN, so (y, 127) matches.
            let y = _mm256_min_ps(y, _mm256_set1_ps(127.0));
            let t = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(y);
            let d = _mm256_sub_ps(y, t);
            let one = _mm256_set1_ps(1.0);
            let inc = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(d, _mm256_set1_ps(0.5)), one);
            let dec = _mm256_and_ps(_mm256_cmp_ps::<_CMP_LE_OQ>(d, _mm256_set1_ps(-0.5)), one);
            let k = _mm256_sub_ps(_mm256_add_ps(t, inc), dec);
            let f = _mm256_sub_ps(y, k);
            // Horner, innermost first, mul-then-add — scalar rounding order
            let mut p = _mm256_set1_ps(C5);
            p = _mm256_add_ps(_mm256_set1_ps(C4), _mm256_mul_ps(f, p));
            p = _mm256_add_ps(_mm256_set1_ps(C3), _mm256_mul_ps(f, p));
            p = _mm256_add_ps(_mm256_set1_ps(C2), _mm256_mul_ps(f, p));
            p = _mm256_add_ps(_mm256_set1_ps(C1), _mm256_mul_ps(f, p));
            p = _mm256_add_ps(_mm256_set1_ps(C0), _mm256_mul_ps(f, p));
            // 2^k through the exponent bits; k is integral so the (nearest)
            // cvt is exact.  Out-of-range lanes are underflow lanes — masked.
            let ki = _mm256_cvtps_epi32(k);
            let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(ki, _mm256_set1_epi32(127)));
            let r = _mm256_mul_ps(p, _mm256_castsi256_ps(bits));
            _mm256_andnot_ps(under, r)
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn exp_slice_impl(src: &[f32], dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let n = src.len().min(dst.len());
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_loadu_ps(src.as_ptr().add(j));
                _mm256_storeu_ps(dst.as_mut_ptr().add(j), exp8(v));
                j += 8;
            }
            while j < n {
                dst[j] = fast_exp(src[j]);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn max_abs_impl(xs: &[f32]) -> f32 {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            // |v| is non-negative, so the lane-folded max is order-independent.
            // max_ps(v, acc) returns acc when v is NaN — the scalar fold's
            // NaN-skip semantics.
            let sign = _mm256_set1_ps(-0.0);
            let mut acc = _mm256_setzero_ps();
            let n = xs.len();
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_andnot_ps(sign, _mm256_loadu_ps(xs.as_ptr().add(j)));
                acc = _mm256_max_ps(v, acc);
                j += 8;
            }
            let mut buf = [0.0f32; 8];
            _mm256_storeu_ps(buf.as_mut_ptr(), acc);
            let mut m = buf.iter().fold(0.0f32, |m, &v| m.max(v));
            while j < n {
                m = m.max(xs[j].abs());
                j += 1;
            }
            m
        }
    }

    /// Row max with the scalar fold's NaN-skip (`max_ps(v, acc)` operand
    /// order).  Tied `-0.0`/`+0.0` maxima may resolve to the other sign
    /// than the scalar left-to-right fold — washed out downstream (module
    /// docs).
    #[target_feature(enable = "avx2")]
    unsafe fn row_max(src: &[f32]) -> f32 {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
            let n = src.len();
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_loadu_ps(src.as_ptr().add(j));
                acc = _mm256_max_ps(v, acc);
                j += 8;
            }
            let mut buf = [0.0f32; 8];
            _mm256_storeu_ps(buf.as_mut_ptr(), acc);
            let mut m = buf.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            while j < n {
                m = m.max(src[j]);
                j += 1;
            }
            m
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn exp_sub(src: &[f32], mx: f32, dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let vm = _mm256_set1_ps(mx);
            let n = dst.len();
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_sub_ps(_mm256_loadu_ps(src.as_ptr().add(j)), vm);
                _mm256_storeu_ps(dst.as_mut_ptr().add(j), exp8(v));
                j += 8;
            }
            while j < n {
                dst[j] = fast_exp(src[j] - mx);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scale(xs: &mut [f32], inv: f32) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let vi = _mm256_set1_ps(inv);
            let n = xs.len();
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_mul_ps(_mm256_loadu_ps(xs.as_ptr().add(j)), vi);
                _mm256_storeu_ps(xs.as_mut_ptr().add(j), v);
                j += 8;
            }
            while j < n {
                xs[j] *= inv;
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn row_softmax_impl(l: MatView<'_>, dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            for (p, row) in dst.chunks_mut(l.cols).enumerate() {
                let src = l.row(p);
                let mx = row_max(src);
                if !(mx > NEG_LOGMASS / 2.0) {
                    row.fill(0.0);
                    continue;
                }
                exp_sub(src, mx, row);
                // the sum walks the stored values in index order — the scalar
                // reference accumulates sequentially, and a vector reduction
                // would re-associate the rounding
                let mut sum = 0.0f32;
                for &e in row.iter() {
                    sum += e;
                }
                if !(sum > 0.0) {
                    row.fill(0.0);
                    continue;
                }
                scale(row, 1.0 / sum);
            }
        }
    }

    /// Whether the host CPU additionally has the F16C convert unit.
    /// AVX2-without-F16C hosts exist (some early designs); the f16
    /// entries below fall back to the scalar reference there, which is
    /// bit-identical by definition.
    pub fn f16c_available() -> bool {
        is_x86_feature_detected!("f16c")
    }

    /// 8-lane bf16 narrowing: integer RNE add on the raw bits, NaN lanes
    /// blended to truncate-and-quiet — the scalar reference's exact
    /// operation sequence, per lane.
    #[target_feature(enable = "avx2")]
    // On toolchains where safe-to-call target-feature intrinsics make
    // this block redundant, the wrap is dead weight, not an error.
    #[allow(unused_unsafe)]
    unsafe fn enc_bf16_8(v: __m256i) -> __m128i {
        // SAFETY: value intrinsics only — sound whenever the target
        // feature is present, which the caller proves (the safe checked
        // entries assert `available()` before entering this module).
        unsafe {
            // NaN ⇔ (bits & 0x7FFF_FFFF) > 0x7F80_0000; both sides are
            // < 2^31 so the signed compare is the unsigned one
            let abs = _mm256_and_si256(v, _mm256_set1_epi32(0x7FFF_FFFF));
            let nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7F80_0000));
            let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(v), _mm256_set1_epi32(1));
            let bump = _mm256_add_epi32(lsb, _mm256_set1_epi32(0x7FFF));
            let rounded = _mm256_srli_epi32::<16>(_mm256_add_epi32(v, bump));
            let quiet = _mm256_or_si256(_mm256_srli_epi32::<16>(v), _mm256_set1_epi32(0x0040));
            let out32 = _mm256_blendv_epi8(rounded, quiet, nan);
            // pack the 8 ≤0xFFFF words into the low 128 bits (packus
            // interleaves per 128-bit lane: qwords [0,_,2,_] hold them)
            let packed = _mm256_packus_epi32(out32, out32);
            _mm256_castsi256_si128(_mm256_permute4x64_epi64::<0x08>(packed))
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn enc_bf16_impl(src: &[f32], dst: &mut [u16]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let n = src.len();
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_loadu_si256(src.as_ptr().add(j) as *const __m256i);
                _mm_storeu_si128(dst.as_mut_ptr().add(j) as *mut __m128i, enc_bf16_8(v));
                j += 8;
            }
            while j < n {
                dst[j] = super::scalar::f32_to_bf16(src[j]);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dec_bf16_impl(src: &[u16], dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let n = src.len();
            let mut j = 0;
            while j + 8 <= n {
                let h = _mm_loadu_si128(src.as_ptr().add(j) as *const __m128i);
                let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
                _mm256_storeu_si256(dst.as_mut_ptr().add(j) as *mut __m256i, w);
                j += 8;
            }
            while j < n {
                dst[j] = super::scalar::bf16_to_f32(src[j]);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn enc_f16_impl(src: &[f32], dst: &mut [u16]) {
        // SAFETY: the caller proves both target features are present (the
        // safe checked entries assert `available()` and `f16c_available()`),
        // and every pointer intrinsic stays in bounds: the vector loops
        // advance `j` only while `j + LANES <= n` over slices of length
        // ≥ `n`.
        unsafe {
            let n = src.len();
            let mut j = 0;
            while j + 8 <= n {
                let v = _mm256_loadu_ps(src.as_ptr().add(j));
                // hardware RNE convert; F16C quiets SNaNs and handles
                // subnormals regardless of MXCSR — the scalar reference's
                // exact semantics
                let h = _mm256_cvtps_ph::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(v);
                _mm_storeu_si128(dst.as_mut_ptr().add(j) as *mut __m128i, h);
                j += 8;
            }
            while j < n {
                dst[j] = super::scalar::f32_to_f16(src[j]);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn dec_f16_impl(src: &[u16], dst: &mut [f32]) {
        // SAFETY: the caller proves both target features are present (the
        // safe checked entries assert `available()` and `f16c_available()`),
        // and every pointer intrinsic stays in bounds: the vector loops
        // advance `j` only while `j + LANES <= n` over slices of length
        // ≥ `n`.
        unsafe {
            let n = src.len();
            let mut j = 0;
            while j + 8 <= n {
                let h = _mm_loadu_si128(src.as_ptr().add(j) as *const __m128i);
                _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_cvtph_ps(h));
                j += 8;
            }
            while j < n {
                dst[j] = super::scalar::f16_to_f32(src[j]);
                j += 1;
            }
        }
    }

    // -- safe checked entries (used by the dispatch table and the tests) --

    pub fn matmul_into_slice(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        assert_eq!(c.len(), a.rows * b.cols);
        // SAFETY: availability checked above.
        unsafe { matmul_impl(a, b, c) }
    }

    pub fn vt_matmul_into_slice(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
        assert_eq!(out.len(), a.cols * b.cols);
        // SAFETY: availability checked above.
        unsafe { vt_matmul_impl(a, b, out) }
    }

    pub fn exp_slice(src: &[f32], dst: &mut [f32]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        // SAFETY: availability checked above.
        unsafe { exp_slice_impl(src, dst) }
    }

    pub fn slice_max_abs(xs: &[f32]) -> f32 {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        // SAFETY: availability checked above.
        unsafe { max_abs_impl(xs) }
    }

    pub fn row_softmax(l: MatView<'_>, dst: &mut [f32]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(dst.len(), l.rows * l.cols, "softmax output shape mismatch");
        // SAFETY: availability checked above.
        unsafe { row_softmax_impl(l, dst) }
    }

    pub fn f32_to_bf16_slice(src: &[f32], dst: &mut [u16]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        // SAFETY: availability checked above.
        unsafe { enc_bf16_impl(src, dst) }
    }

    pub fn bf16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        // SAFETY: availability checked above.
        unsafe { dec_bf16_impl(src, dst) }
    }

    pub fn f32_to_f16_slice(src: &[f32], dst: &mut [u16]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        if !f16c_available() {
            return super::scalar::f32_to_f16_slice(src, dst);
        }
        // SAFETY: availability of avx2 and f16c checked above.
        unsafe { enc_f16_impl(src, dst) }
    }

    pub fn f16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
        assert!(available(), "avx2 kernels dispatched on a non-avx2 host");
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        if !f16c_available() {
            return super::scalar::f16_to_f32_slice(src, dst);
        }
        // SAFETY: availability of avx2 and f16c checked above.
        unsafe { dec_f16_impl(src, dst) }
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

/// NEON kernels: 4-lane f32 twin of [`avx2`], same layout and the same
/// bit-identity argument.  NEON is simpler in two spots: `vrndaq_f32`
/// rounds halves away from zero natively (no emulation), and
/// `vcvtq_s32_f32` truncates (exact on the integral `k`).
#[cfg(target_arch = "aarch64")]
pub mod neon {
    use super::poly::*;
    use super::{fast_exp, MatView, NEG_LOGMASS};
    use std::arch::aarch64::*;

    /// Whether the host CPU can run this path.
    pub fn available() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    /// `y[j] += a * x[j]` — separate mul and add, never `vfmaq_f32`
    /// (scalar `*cv += av * bv` rounds the product first).
    #[target_feature(enable = "neon")]
    unsafe fn axpy(av: f32, x: &[f32], y: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            debug_assert_eq!(x.len(), y.len());
            let n = y.len();
            let va = vdupq_n_f32(av);
            let mut j = 0;
            while j + 4 <= n {
                let vx = vld1q_f32(x.as_ptr().add(j));
                let vy = vld1q_f32(y.as_ptr().add(j));
                let prod = vmulq_f32(va, vx);
                vst1q_f32(y.as_mut_ptr().add(j), vaddq_f32(vy, prod));
                j += 4;
            }
            while j < n {
                y[j] += av * x[j];
                j += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn matmul_impl(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            c.fill(0.0);
            let n = b.cols;
            for i in 0..a.rows {
                let arow = a.row(i);
                let crow = &mut c[i * n..(i + 1) * n];
                for (p, &av) in arow.iter().enumerate() {
                    axpy(av, &b.data[p * n..(p + 1) * n], crow);
                }
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn vt_matmul_impl(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            out.fill(0.0);
            let n = b.cols;
            for p in 0..a.rows {
                let arow = a.row(p);
                let brow = b.row(p);
                for (i, &av) in arow.iter().enumerate() {
                    axpy(av, brow, &mut out[i * n..(i + 1) * n]);
                }
            }
        }
    }

    /// 4-lane `fast_exp`; see [`super::avx2::exp8`] for the annotated
    /// walk-through — this body differs only where NEON is more direct.
    #[target_feature(enable = "neon")]
    // On toolchains where safe-to-call target-feature intrinsics make
    // this block redundant, the wrap is dead weight, not an error.
    #[allow(unused_unsafe)]
    unsafe fn exp4(x: float32x4_t) -> float32x4_t {
        // SAFETY: value intrinsics only — sound whenever the target
        // feature is present, which the caller proves (the safe checked
        // entries assert `available()` before entering this module).
        unsafe {
            let y = vmulq_f32(x, vdupq_n_f32(std::f32::consts::LOG2_E));
            let under = vcleq_f32(y, vdupq_n_f32(-126.0));
            // scalar `y.min(127.0)` keeps y only when y < 127 and is 127 on
            // NaN; the compare-select reproduces exactly that.
            let c127 = vdupq_n_f32(127.0);
            let y = vbslq_f32(vcltq_f32(y, c127), y, c127);
            let k = vrndaq_f32(y); // round halves away from zero — scalar f32::round
            let f = vsubq_f32(y, k);
            let mut p = vdupq_n_f32(C5);
            p = vaddq_f32(vdupq_n_f32(C4), vmulq_f32(f, p));
            p = vaddq_f32(vdupq_n_f32(C3), vmulq_f32(f, p));
            p = vaddq_f32(vdupq_n_f32(C2), vmulq_f32(f, p));
            p = vaddq_f32(vdupq_n_f32(C1), vmulq_f32(f, p));
            p = vaddq_f32(vdupq_n_f32(C0), vmulq_f32(f, p));
            let ki = vcvtq_s32_f32(k); // truncating — exact on integral k
            let bits = vshlq_n_s32::<23>(vaddq_s32(ki, vdupq_n_s32(127)));
            let r = vmulq_f32(p, vreinterpretq_f32_s32(bits));
            // clear underflow lanes to +0.0 (bits & !mask)
            vreinterpretq_f32_u32(vbicq_u32(vreinterpretq_u32_f32(r), under))
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn exp_slice_impl(src: &[f32], dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let n = src.len().min(dst.len());
            let mut j = 0;
            while j + 4 <= n {
                let v = vld1q_f32(src.as_ptr().add(j));
                vst1q_f32(dst.as_mut_ptr().add(j), exp4(v));
                j += 4;
            }
            while j < n {
                dst[j] = fast_exp(src[j]);
                j += 1;
            }
        }
    }

    /// Lane max with scalar-fold NaN-skip: keep `v` only when `v > acc`
    /// (false on NaN ⇒ acc survives, as in `f32::max`).
    #[target_feature(enable = "neon")]
    // On toolchains where safe-to-call target-feature intrinsics make
    // this block redundant, the wrap is dead weight, not an error.
    #[allow(unused_unsafe)]
    unsafe fn lane_max(v: float32x4_t, acc: float32x4_t) -> float32x4_t {
        // SAFETY: value intrinsics only — sound whenever the target
        // feature is present, which the caller proves (the safe checked
        // entries assert `available()` before entering this module).
        unsafe {
            vbslq_f32(vcgtq_f32(v, acc), v, acc)
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn max_abs_impl(xs: &[f32]) -> f32 {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            let n = xs.len();
            let mut j = 0;
            while j + 4 <= n {
                acc = lane_max(vabsq_f32(vld1q_f32(xs.as_ptr().add(j))), acc);
                j += 4;
            }
            let mut buf = [0.0f32; 4];
            vst1q_f32(buf.as_mut_ptr(), acc);
            let mut m = buf.iter().fold(0.0f32, |m, &v| m.max(v));
            while j < n {
                m = m.max(xs[j].abs());
                j += 1;
            }
            m
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn row_max(src: &[f32]) -> f32 {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
            let n = src.len();
            let mut j = 0;
            while j + 4 <= n {
                acc = lane_max(vld1q_f32(src.as_ptr().add(j)), acc);
                j += 4;
            }
            let mut buf = [0.0f32; 4];
            vst1q_f32(buf.as_mut_ptr(), acc);
            let mut m = buf.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            while j < n {
                m = m.max(src[j]);
                j += 1;
            }
            m
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn exp_sub(src: &[f32], mx: f32, dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let vm = vdupq_n_f32(mx);
            let n = dst.len();
            let mut j = 0;
            while j + 4 <= n {
                let v = vsubq_f32(vld1q_f32(src.as_ptr().add(j)), vm);
                vst1q_f32(dst.as_mut_ptr().add(j), exp4(v));
                j += 4;
            }
            while j < n {
                dst[j] = fast_exp(src[j] - mx);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn scale(xs: &mut [f32], inv: f32) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let vi = vdupq_n_f32(inv);
            let n = xs.len();
            let mut j = 0;
            while j + 4 <= n {
                let v = vmulq_f32(vld1q_f32(xs.as_ptr().add(j)), vi);
                vst1q_f32(xs.as_mut_ptr().add(j), v);
                j += 4;
            }
            while j < n {
                xs[j] *= inv;
                j += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn row_softmax_impl(l: MatView<'_>, dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            for (p, row) in dst.chunks_mut(l.cols).enumerate() {
                let src = l.row(p);
                let mx = row_max(src);
                if !(mx > NEG_LOGMASS / 2.0) {
                    row.fill(0.0);
                    continue;
                }
                exp_sub(src, mx, row);
                // scalar sequential sum in index order (see avx2 twin)
                let mut sum = 0.0f32;
                for &e in row.iter() {
                    sum += e;
                }
                if !(sum > 0.0) {
                    row.fill(0.0);
                    continue;
                }
                scale(row, 1.0 / sum);
            }
        }
    }

    /// 4-lane bf16 narrowing: integer RNE add on the raw bits, NaN lanes
    /// selected to truncate-and-quiet — the scalar reference's exact
    /// operation sequence, per lane.
    #[target_feature(enable = "neon")]
    // On toolchains where safe-to-call target-feature intrinsics make
    // this block redundant, the wrap is dead weight, not an error.
    #[allow(unused_unsafe)]
    unsafe fn enc_bf16_4(v: uint32x4_t) -> uint16x4_t {
        // SAFETY: value intrinsics only — sound whenever the target
        // feature is present, which the caller proves (the safe checked
        // entries assert `available()` before entering this module).
        unsafe {
            let abs = vandq_u32(v, vdupq_n_u32(0x7FFF_FFFF));
            let nan = vcgtq_u32(abs, vdupq_n_u32(0x7F80_0000));
            let lsb = vandq_u32(vshrq_n_u32::<16>(v), vdupq_n_u32(1));
            let bump = vaddq_u32(lsb, vdupq_n_u32(0x7FFF));
            let rounded = vshrq_n_u32::<16>(vaddq_u32(v, bump));
            let quiet = vorrq_u32(vshrq_n_u32::<16>(v), vdupq_n_u32(0x0040));
            // narrowing move keeps the low 16 bits — all lanes are ≤ 0xFFFF
            vmovn_u32(vbslq_u32(nan, quiet, rounded))
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn enc_bf16_impl(src: &[f32], dst: &mut [u16]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let n = src.len();
            let mut j = 0;
            while j + 4 <= n {
                let v = vld1q_u32(src.as_ptr().add(j) as *const u32);
                vst1_u16(dst.as_mut_ptr().add(j), enc_bf16_4(v));
                j += 4;
            }
            while j < n {
                dst[j] = super::scalar::f32_to_bf16(src[j]);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dec_bf16_impl(src: &[u16], dst: &mut [f32]) {
        // SAFETY: the caller proves the target feature is present (the
        // safe checked entries assert `available()`), and every pointer
        // intrinsic stays in bounds: the vector loops advance `j` only
        // while `j + LANES <= n` over slices of length ≥ `n`.
        unsafe {
            let n = src.len();
            let mut j = 0;
            while j + 4 <= n {
                let h = vld1_u16(src.as_ptr().add(j));
                let w = vshlq_n_u32::<16>(vmovl_u16(h));
                vst1q_u32(dst.as_mut_ptr().add(j) as *mut u32, w);
                j += 4;
            }
            while j < n {
                dst[j] = super::scalar::bf16_to_f32(src[j]);
                j += 1;
            }
        }
    }

    // -- safe checked entries (used by the dispatch table and the tests) --

    pub fn matmul_into_slice(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        assert_eq!(c.len(), a.rows * b.cols);
        // SAFETY: availability checked above.
        unsafe { matmul_impl(a, b, c) }
    }

    pub fn vt_matmul_into_slice(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
        assert_eq!(out.len(), a.cols * b.cols);
        // SAFETY: availability checked above.
        unsafe { vt_matmul_impl(a, b, out) }
    }

    pub fn exp_slice(src: &[f32], dst: &mut [f32]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        // SAFETY: availability checked above.
        unsafe { exp_slice_impl(src, dst) }
    }

    pub fn slice_max_abs(xs: &[f32]) -> f32 {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        // SAFETY: availability checked above.
        unsafe { max_abs_impl(xs) }
    }

    pub fn row_softmax(l: MatView<'_>, dst: &mut [f32]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        assert_eq!(dst.len(), l.rows * l.cols, "softmax output shape mismatch");
        // SAFETY: availability checked above.
        unsafe { row_softmax_impl(l, dst) }
    }

    pub fn f32_to_bf16_slice(src: &[f32], dst: &mut [u16]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        // SAFETY: availability checked above.
        unsafe { enc_bf16_impl(src, dst) }
    }

    pub fn bf16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        assert_eq!(src.len(), dst.len(), "convert length mismatch");
        // SAFETY: availability checked above.
        unsafe { dec_bf16_impl(src, dst) }
    }

    /// f16 narrowing on aarch64 delegates to the scalar reference: the
    /// FCVTN hardware path needs the unstable `float16x4_t` vector type,
    /// and the scalar algorithm is bit-identical to it by construction
    /// (bf16 is the vectorised low-precision format on this arch).
    pub fn f32_to_f16_slice(src: &[f32], dst: &mut [u16]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        super::scalar::f32_to_f16_slice(src, dst)
    }

    /// See [`f32_to_f16_slice`]: scalar reference, same bits.
    pub fn f16_to_f32_slice(src: &[u16], dst: &mut [f32]) {
        assert!(available(), "neon kernels dispatched on a non-neon host");
        super::scalar::f16_to_f32_slice(src, dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn active_path_is_one_of_the_three() {
        let p = active();
        assert!(matches!(p.as_str(), "scalar" | "avx2" | "neon"));
        // dispatch is cached: second call returns the same path
        assert_eq!(active(), p);
    }

    #[test]
    fn by_name_resolves_scalar_everywhere() {
        assert_eq!(by_name("scalar").unwrap().path, KernelPath::Scalar);
        assert!(by_name("sse9000").is_none());
    }

    #[test]
    fn dispatched_kernels_match_scalar_reference() {
        // whatever path the host dispatched to must be bit-identical to
        // the scalar reference (trivially true when it IS scalar)
        let mut rng = Rng::new(77);
        let (m, k, n) = (5, 7, 13);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let av = MatView::from_slice(m, k, &a);
        let bv = MatView::from_slice(k, n, &b);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        matmul_into_slice(av, bv, &mut c1);
        scalar::matmul_into_slice(av, bv, &mut c2);
        assert_eq!(bits(&c1), bits(&c2));

        let mut e1 = vec![0.0f32; k * n];
        let mut e2 = vec![0.0f32; k * n];
        exp_slice(&b, &mut e1);
        scalar::exp_slice(&b, &mut e2);
        assert_eq!(bits(&e1), bits(&e2));
        assert_eq!(slice_max_abs(&b).to_bits(), scalar::slice_max_abs(&b).to_bits());
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn dispatched_converts_match_scalar_reference() {
        let mut rng = Rng::new(99);
        let mut xs = vec![0.0f32; 37];
        rng.fill_normal(&mut xs);
        let mut a = vec![0u16; 37];
        let mut b = vec![1u16; 37];
        f32_to_bf16_slice(&xs, &mut a);
        scalar::f32_to_bf16_slice(&xs, &mut b);
        assert_eq!(a, b);
        let mut da = vec![0.0f32; 37];
        let mut db = vec![1.0f32; 37];
        bf16_to_f32_slice(&a, &mut da);
        scalar::bf16_to_f32_slice(&b, &mut db);
        assert_eq!(bits(&da), bits(&db));
        f32_to_f16_slice(&xs, &mut a);
        scalar::f32_to_f16_slice(&xs, &mut b);
        assert_eq!(a, b);
        f16_to_f32_slice(&a, &mut da);
        scalar::f16_to_f32_slice(&b, &mut db);
        assert_eq!(bits(&da), bits(&db));
    }

    #[test]
    fn bf16_narrowing_is_rne_not_truncation() {
        use scalar::{bf16_to_f32, f32_to_bf16};
        // exactly representable values pass through
        assert_eq!(f32_to_bf16(1.0), 0x3F80);
        assert_eq!(f32_to_bf16(-2.0), 0xC000);
        // just above the halfway point rounds up — truncation would say 0x3F80
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        // exact ties round to even: odd mantissa bumps, even stays
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        // signed zeros and infinities survive
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
        // f32::MAX overflows to inf under RNE (bf16 max finite is 0x7F7F)
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80);
        // NaN stays NaN even when the payload lives only in the dropped
        // bits — naive truncation would yield an infinity encoding
        let h = f32_to_bf16(f32::from_bits(0x7F80_0001));
        assert_eq!(h, 0x7FC0); // quiet bit forced
        assert!(bf16_to_f32(h).is_nan());
        // f32 subnormals narrow to bf16 subnormals, exactly when aligned
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::from_bits(0x0001_0000))).to_bits(), 0x0001_0000);
    }

    #[test]
    fn f16_narrowing_handles_edges() {
        use scalar::{f16_to_f32, f32_to_f16};
        assert_eq!(f32_to_f16(1.0), 0x3C00);
        assert_eq!(f32_to_f16(-1.5), 0xBE00);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // max finite f16
        // RNE overflow boundary: below the 65520 midpoint keeps 65504
        assert_eq!(f32_to_f16(65519.0), 0x7BFF);
        assert_eq!(f32_to_f16(65520.0), 0x7C00); // tie rolls to inf
        assert_eq!(f32_to_f16(1.0e9), 0x7C00);
        // subnormal f16s: 2^-24 is the smallest; 2^-25 ties to even (zero)
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16(1.5 * 2.0f32.powi(-25)), 0x0001);
        assert_eq!(f32_to_f16(-(2.0f32.powi(-24))), 0x8001);
        // f32 subnormals underflow to the signed zero
        assert_eq!(f32_to_f16(f32::from_bits(0x0000_0001)), 0x0000);
        assert_eq!(f32_to_f16(-1.0e-40), 0x8000);
        // signed zeros, infinities, NaN quieting
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NAN), 0x7E00);
        assert!(f16_to_f32(f32_to_f16(f32::from_bits(0x7F80_0001))).is_nan());
        // the mantissa carry can roll the exponent: 1.11…1|1000 → 2.0
        assert_eq!(f32_to_f16(f32::from_bits(0x3FFF_F000)), 0x4000);
    }

    #[test]
    fn convert_roundtrip_identity_on_every_u16() {
        // widening is exact, so encode(decode(h)) must reproduce h for
        // every non-NaN pattern — RNE of a representable value is itself.
        // NaN patterns only need to stay NaN (encode quiets them).
        for h in 0..=u16::MAX {
            let w = scalar::bf16_to_f32(h);
            if w.is_nan() {
                assert!(scalar::bf16_to_f32(scalar::f32_to_bf16(w)).is_nan(), "bf16 {h:#06x}");
            } else {
                assert_eq!(scalar::f32_to_bf16(w), h, "bf16 {h:#06x}");
            }
            let w = scalar::f16_to_f32(h);
            if w.is_nan() {
                assert!(scalar::f16_to_f32(scalar::f32_to_f16(w)).is_nan(), "f16 {h:#06x}");
            } else {
                assert_eq!(scalar::f32_to_f16(w), h, "f16 {h:#06x}");
            }
        }
    }

    // -- SIMD-vs-scalar parity sweeps (skipped on hosts without the ISA) --

    // Not under Miri: `available()` needs runtime CPU-feature probes and
    // the SIMD bodies need vendor intrinsics, neither of which the
    // interpreter executes — dispatch is pinned to scalar there instead.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    use super::avx2 as simd;
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    use super::neon as simd;

    #[cfg(all(any(target_arch = "x86_64", target_arch = "aarch64"), not(miri)))]
    mod parity {
        use super::*;
        use crate::linalg::{fast_exp, NEG_LOGMASS};

        /// Interesting values: normals, huge/tiny magnitudes, the padding
        /// sentinel, signed zeros, NaN, infinities, and near-half `exp2`
        /// arguments that stress the rounding emulation.
        fn spice(rng: &mut Rng, xs: &mut [f32]) {
            const SPECIALS: &[f32] = &[
                0.0,
                -0.0,
                1.0,
                -1.0,
                NEG_LOGMASS,
                NEG_LOGMASS / 2.0,
                -4.9e8, // just above the mask threshold
                -126.0 * std::f32::consts::LN_2,
                -87.3,
                88.7,
                200.0,
                0.49999997 * std::f32::consts::LN_2,
                0.5 * std::f32::consts::LN_2,
                -0.5 * std::f32::consts::LN_2,
                f32::NAN,
                f32::INFINITY,
                f32::NEG_INFINITY,
                f32::MIN_POSITIVE,
                1.0e-40, // subnormal
            ];
            for v in xs.iter_mut() {
                if rng.next_below(4) == 0 {
                    *v = SPECIALS[rng.next_below(SPECIALS.len())];
                }
            }
        }

        /// An unaligned window of fresh random data: the returned range
        /// starts at an arbitrary (often odd) offset into the buffer, so
        /// no 16/32-byte alignment can be assumed — exactly the lane
        /// windows the strided batch state hands out.
        fn window(rng: &mut Rng, buf: &mut Vec<f32>, len: usize) -> std::ops::Range<usize> {
            let off = rng.next_below(9);
            buf.clear();
            buf.resize(off + len, 0.0);
            rng.fill_normal(&mut buf[..]);
            off..off + len
        }

        fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
            assert_eq!(got.len(), want.len(), "{what}: length");
            for (i, (g, w)) in got.iter().zip(want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "{what}: [{i}] {g} vs {w}");
            }
        }

        #[test]
        fn matmuls_bit_identical_across_ragged_shapes() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            let mut rng = Rng::new(0xD15BA7C4);
            let (mut abuf, mut bbuf) = (Vec::new(), Vec::new());
            // odd column counts straddle the 4/8-lane width; tiny rows /
            // inner dims hit the all-tail case
            for rows in [1usize, 2, 5, 16] {
                for inner in [1usize, 3, 8, 11] {
                    for cols in 1..=19 {
                        let ra = window(&mut rng, &mut abuf, rows * inner);
                        let rb = window(&mut rng, &mut bbuf, inner * cols);
                        spice(&mut rng, &mut abuf[ra.clone()]);
                        spice(&mut rng, &mut bbuf[rb.clone()]);
                        let a = MatView::from_slice(rows, inner, &abuf[ra.clone()]);
                        let b = MatView::from_slice(inner, cols, &bbuf[rb.clone()]);
                        let mut want = vec![1.0f32; rows * cols];
                        let mut got = vec![2.0f32; rows * cols];
                        scalar::matmul_into_slice(a, b, &mut want);
                        simd::matmul_into_slice(a, b, &mut got);
                        assert_bits_eq(&got, &want, &format!("matmul {rows}x{inner}x{cols}"));

                        // Aᵀ B with A: inner×rows (out rows×cols)
                        let at = MatView::from_slice(inner, rows, &abuf[ra]);
                        let bt = MatView::from_slice(inner, cols, &bbuf[rb]);
                        let mut want = vec![1.0f32; rows * cols];
                        let mut got = vec![2.0f32; rows * cols];
                        scalar::vt_matmul_into_slice(at, bt, &mut want);
                        simd::vt_matmul_into_slice(at, bt, &mut got);
                        assert_bits_eq(&got, &want, &format!("vt_matmul {inner}x{rows}x{cols}"));
                    }
                }
            }
        }

        #[test]
        fn exp_slice_bit_identical_incl_specials() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            let mut rng = Rng::new(0xE4B);
            let mut buf = Vec::new();
            for len in 0..=41 {
                for round in 0..8 {
                    let r = window(&mut rng, &mut buf, len);
                    // widen the range: mirror-descent logits span hundreds
                    for v in buf[r.clone()].iter_mut() {
                        *v *= 40.0 * (round as f32 + 1.0);
                    }
                    spice(&mut rng, &mut buf[r.clone()]);
                    let mut want = vec![1.0f32; len];
                    let mut got = vec![2.0f32; len];
                    scalar::exp_slice(&buf[r.clone()], &mut want);
                    simd::exp_slice(&buf[r], &mut got);
                    assert_bits_eq(&got, &want, &format!("exp_slice len {len}"));
                }
            }
        }

        #[test]
        fn exp_dense_sweep_bit_identical_to_fast_exp() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            // dense range walk including the underflow boundary and the
            // round-half-away edges fast_exp's k depends on
            let mut xs = Vec::new();
            let mut x = -130.0f32;
            while x < 130.0 {
                xs.push(x);
                x += 0.0031;
            }
            let mut got = vec![0.0f32; xs.len()];
            simd::exp_slice(&xs, &mut got);
            for (i, (&x, &g)) in xs.iter().zip(&got).enumerate() {
                assert_eq!(g.to_bits(), fast_exp(x).to_bits(), "[{i}] exp({x})");
            }
        }

        #[test]
        fn max_abs_bit_identical_with_nans_and_zeros() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            let mut rng = Rng::new(0x3A8);
            let mut buf = Vec::new();
            for len in 0..=41 {
                for _ in 0..8 {
                    let r = window(&mut rng, &mut buf, len);
                    spice(&mut rng, &mut buf[r.clone()]);
                    let want = scalar::slice_max_abs(&buf[r.clone()]);
                    let got = simd::slice_max_abs(&buf[r]);
                    assert_eq!(got.to_bits(), want.to_bits(), "max_abs len {len}");
                }
            }
        }

        #[test]
        fn converts_bit_identical_incl_specials() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            let mut rng = Rng::new(0xBF16);
            let mut buf = Vec::new();
            for len in 0..=41 {
                for round in 0..8 {
                    let r = window(&mut rng, &mut buf, len);
                    // magnitudes sweeping through f16's normal range, its
                    // subnormal floor, and past its overflow ceiling
                    for v in buf[r.clone()].iter_mut() {
                        *v *= 10.0f32.powi(round - 4);
                    }
                    spice(&mut rng, &mut buf[r.clone()]);
                    let mut want = vec![0u16; len];
                    let mut got = vec![1u16; len];
                    scalar::f32_to_bf16_slice(&buf[r.clone()], &mut want);
                    simd::f32_to_bf16_slice(&buf[r.clone()], &mut got);
                    assert_eq!(want, got, "bf16 encode len {len}");
                    scalar::f32_to_f16_slice(&buf[r.clone()], &mut want);
                    simd::f32_to_f16_slice(&buf[r], &mut got);
                    assert_eq!(want, got, "f16 encode len {len}");
                }
            }
        }

        #[test]
        fn decode_parity_is_exhaustive_over_u16() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            // every possible stored element, both formats
            let all: Vec<u16> = (0..=u16::MAX).collect();
            let mut want = vec![0.0f32; all.len()];
            let mut got = vec![1.0f32; all.len()];
            scalar::bf16_to_f32_slice(&all, &mut want);
            simd::bf16_to_f32_slice(&all, &mut got);
            assert_bits_eq(&got, &want, "bf16 decode");
            scalar::f16_to_f32_slice(&all, &mut want);
            simd::f16_to_f32_slice(&all, &mut got);
            assert_bits_eq(&got, &want, "f16 decode");
        }

        #[test]
        fn encode_parity_is_exhaustive_over_roundtripped_u16() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            // encode every exactly-representable value of each format —
            // together with the random/special sweeps this pins the SIMD
            // encoders at every exponent, both signs, and all NaN/inf
            // encodings
            let all: Vec<u16> = (0..=u16::MAX).collect();
            let mut wide = vec![0.0f32; all.len()];
            let mut want = vec![0u16; all.len()];
            let mut got = vec![1u16; all.len()];
            scalar::bf16_to_f32_slice(&all, &mut wide);
            scalar::f32_to_bf16_slice(&wide, &mut want);
            simd::f32_to_bf16_slice(&wide, &mut got);
            assert_eq!(want, got, "bf16 encode over all bf16 values");
            scalar::f16_to_f32_slice(&all, &mut wide);
            scalar::f32_to_f16_slice(&wide, &mut want);
            simd::f32_to_f16_slice(&wide, &mut got);
            assert_eq!(want, got, "f16 encode over all f16 values");
        }

        #[test]
        fn row_softmax_bit_identical_with_padded_rows() {
            if !simd::available() {
                eprintln!("skipping: SIMD path unavailable on this host");
                return;
            }
            let mut rng = Rng::new(0x50F7);
            let mut buf = Vec::new();
            for rows in [1usize, 3, 6] {
                for cols in 1..=19 {
                    for _ in 0..4 {
                        let r = window(&mut rng, &mut buf, rows * cols);
                        spice(&mut rng, &mut buf[r.clone()]);
                        // fully NEG-padded rows must zero out on both paths
                        if rows > 1 {
                            let base = r.start + (rows - 1) * cols;
                            buf[base..base + cols].fill(NEG_LOGMASS);
                        }
                        let l = MatView::from_slice(rows, cols, &buf[r.clone()]);
                        let mut want = vec![1.0f32; rows * cols];
                        let mut got = vec![2.0f32; rows * cols];
                        scalar::row_softmax(l, &mut want);
                        simd::row_softmax(l, &mut got);
                        assert_bits_eq(&got, &want, &format!("softmax {rows}x{cols}"));
                    }
                }
            }
        }
    }

    #[test]
    fn softmax_near_sentinel_rows_stay_finite() {
        // rows whose max barely clears the padding mask: the normalised
        // outputs must be finite (the mask row is exact-zero), on every
        // dispatch path
        let cols = 7;
        let mut data = vec![-4.9e8f32; cols]; // just above NEG_LOGMASS / 2
        data.extend_from_slice(&vec![NEG_LOGMASS; cols]); // masked row
        data.extend((0..cols).map(|j| -4.9e8 + j as f32)); // graded near-sentinel
        let l = MatView::from_slice(3, cols, &data);
        let mut out = vec![f32::NAN; 3 * cols];
        row_softmax_item(l, &mut out);
        for (i, v) in out.iter().enumerate() {
            assert!(v.is_finite(), "[{i}] = {v}");
        }
        // masked row is exactly zero; live rows are normalised
        assert!(out[cols..2 * cols].iter().all(|&v| v == 0.0));
        let s0: f32 = out[..cols].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-5, "row 0 sum {s0}");
    }

    #[test]
    fn softmax_zero_sum_guard_yields_zero_row_not_infs() {
        // the guard itself: scalar::row_softmax must never emit inf even
        // if a row's exp sweep summed to zero.  No representable input
        // reaches that state through the public API (the max element
        // contributes exactly 1.0), so drive the invariant indirectly:
        // single-element rows at the mask boundary.
        let data = [NEG_LOGMASS / 2.0 + 1.0, NEG_LOGMASS / 2.0, NEG_LOGMASS];
        let l = MatView::from_slice(3, 1, &data);
        let mut out = vec![f32::NAN; 3];
        scalar::row_softmax(l, &mut out);
        assert_eq!(out[0], 1.0); // unmasked: exp(0)/exp(0)
        assert_eq!(out[1], 0.0); // at the threshold: masked
        assert_eq!(out[2], 0.0); // sentinel: masked
    }
}
