//! # HiRef — Hierarchical Refinement Optimal Transport
//!
//! Production reproduction of *“Hierarchical Refinement: Optimal Transport
//! to Infinity and Beyond”* (Halmos, Gold, Liu, Raphael — ICML 2025).
//!
//! HiRef computes a **bijective, full-rank optimal-transport alignment**
//! between two equally sized datasets in **linear space** and
//! **log-linear time** by recursively refining co-clusters produced by
//! low-rank OT (LROT) sub-problems (paper Alg. 1/2, Prop. 3.1).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — recursion over co-clusters, rank-annealing
//!   schedule, balanced assignment, base-case exact solvers, baselines,
//!   datasets and metrics.  Rust only; Python never runs on this path.
//! * **L2 (python/compile/model.py)** — the LROT mirror-descent solver as
//!   a jitted JAX computation, AOT-lowered to HLO text per shape bucket.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   low-rank gradient and masked log-sum-exp, lowered into the same HLO.
//!
//! [`runtime`] loads the AOT artifacts through the PJRT C API (`xla`
//! crate) and serves LROT calls from compiled executables; a pure-Rust
//! fallback ([`solvers::lrot`]) covers shapes outside the bucket grid.
//!
//! ## Quick start
//!
//! ```no_run
//! use hiref::coordinator::hiref::{HiRef, HiRefConfig};
//! use hiref::data::synthetic;
//!
//! let (x, y) = synthetic::half_moon_s_curve(4096, 0);
//! let out = HiRef::new(HiRefConfig::default()).align(&x, &y).unwrap();
//! println!("primal W2^2 cost = {}", out.cost(&x, &y, hiref::costs::CostKind::SqEuclidean));
//! ```

pub mod cli;
pub mod coordinator;
pub mod costs;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod pool;
pub mod prng;
pub mod regress;
pub mod report;
pub mod runtime;
pub mod solvers;
