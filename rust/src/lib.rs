//! # HiRef — Hierarchical Refinement Optimal Transport
//!
//! Production reproduction of *“Hierarchical Refinement: Optimal Transport
//! to Infinity and Beyond”* (Halmos, Gold, Liu, Raphael — ICML 2025).
//!
//! HiRef computes a **bijective, full-rank optimal-transport alignment**
//! between two equally sized datasets in **linear space** and
//! **log-linear time** by recursively refining co-clusters produced by
//! low-rank OT (LROT) sub-problems (paper Alg. 1/2, Prop. 3.1).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — recursion over co-clusters, rank-annealing
//!   schedule, balanced assignment, base-case exact solvers, baselines,
//!   datasets and metrics.  Rust only; Python never runs on this path.
//! * **L2 (python/compile/model.py)** — the LROT mirror-descent solver as
//!   a jitted JAX computation, AOT-lowered to HLO text per shape bucket.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   low-rank gradient and masked log-sum-exp, lowered into the same HLO.
//!
//! [`runtime`] loads the AOT artifacts through the PJRT C API (behind the
//! `pjrt` cargo feature) and serves LROT calls from compiled executables;
//! a pure-Rust fallback ([`solvers::lrot`]) covers shapes outside the
//! bucket grid and stub builds.
//!
//! ## Zero-copy refinement core
//!
//! The hot path is built around three memory primitives so that the
//! paper's *linear space* claim holds by construction, not by accident:
//!
//! * **Contiguous block ranges** — the refinement hierarchy never
//!   materialises per-block index sets.  Each side keeps one working copy
//!   of the cost factors plus one `position → original id` permutation;
//!   after every balanced split the engine re-orders the parent's window
//!   in place so each child co-cluster is a contiguous `start..end`
//!   range.  A block is two `Range<u32>`s and a level — see
//!   [`coordinator::hiref`].
//! * **[`linalg::MatView`] / [`linalg::BatchView`]** — borrowed views
//!   over row-major buffers: a single row-range window, or a whole batch
//!   of `(row_range, cols)` strides over one shared buffer.  Cost
//!   construction ([`costs::dense_cost`]), LROT
//!   ([`solvers::lrot::solve_factored_in`]), the exact base-case solvers
//!   ([`solvers::exact`]) and balanced assignment
//!   ([`coordinator::assign`]) all accept views, so sub-blocks are
//!   sliced, never gathered (`Mat::gather_rows` survives only for dataset
//!   plumbing and test oracles).
//! * **[`pool::ScratchArena`]** — sharded, reusable `f32`/`u32` buffers
//!   checked out by capacity class.  LROT intermediates, the re-indexing
//!   scratch and base-case dense costs draw from it; peak bytes and
//!   freelist hit-rate are reported per run in
//!   [`coordinator::hiref::RunStats`].
//!
//! ## Level-synchronous batched execution
//!
//! Blocks at one scale of the hierarchy all have (nearly) identical
//! shape, and the contiguous range layout makes a whole level **one
//! strided batch** over the shared factor buffers.  The engine therefore
//! schedules *levels, not blocks*: each scale's same-shape block groups
//! are solved by one batched LROT call
//! ([`solvers::lrot::solve_factored_batch`] — a single mirror-descent
//! loop shared across all lanes, with per-lane convergence masks that
//! stop early-converged blocks paying matmuls), followed by one batched
//! balanced-assign / re-index pass and one batched exact pass over the
//! scale's base-case tiles.  Backend dispatch (native vs the PJRT AOT
//! runtime) happens at batch granularity.  The per-block work-queue path
//! survives behind [`api::HiRefBuilder::batching`]`(false)` for A/B runs
//! and is **bit-identical** — the per-block solver is literally the
//! 1-lane case of the batched loop, and per-block seeds are anchored on
//! each range's first original id, invariant to execution order.
//!
//! **Memory model — three tiers, every one bounded by construction:**
//!
//! 1. **Streaming ingestion, `O(chunk_rows · d)`** — the raw point
//!    clouds never need to be resident: chunked sources
//!    ([`data::stream::DatasetSource`]) feed the factor builders one
//!    tile per worker, and base-case blocks gather their ≤ `base_size`
//!    rows on demand.
//! 2. **Spillable factors, `O(spill_budget)`** — the per-side factor
//!    working copies live behind [`pool::FactorStore`]: fully resident
//!    by default ([`pool::ResidentStore`], zero-cost), or file-backed
//!    ([`pool::SpillStore`], via [`api::HiRefBuilder::spill_dir`]) so
//!    that only a bounded shard cache plus **one in-flight level batch's
//!    lane windows** occupy memory, with bit-identical output.  Either
//!    backend can store its elements at half width
//!    ([`pool::Precision::Bf16`]/[`pool::Precision::F16`], via
//!    [`api::HiRefBuilder::factor_precision`]): checkouts widen lane
//!    windows to f32 scratch and dirty releases narrow them back
//!    (round-to-nearest-even), so every byte in this tier — RAM, shard
//!    cache, spill file — is halved while the solve math stays f32.  See
//!    `docs/precision.md`.
//! 3. **Resident permutations, `O(n)`** — the position→id orders, the
//!    output bijection, and transient arena scratch that tracks one
//!    in-flight level (`O(n·r)` LROT state at any scale,
//!    `O(threads · base_size²)` dense tiles at the leaves).
//!
//! Nothing anywhere is quadratic in `n`.
//! [`coordinator::hiref::RunStats`] reports every tier: the batch shape
//! (`batches`, `lanes_max`, `batched_frac`), the arena counters, and the
//! spill counters (`spill_bytes_written`, `spill_reads`,
//! `resident_factor_bytes`).
//!
//! ## Streaming ingestion (beyond-RAM datasets)
//!
//! The solve path above never needs the raw point clouds except for (a)
//! building the cost factors and (b) the ≤ `base_size` rows of each leaf
//! block — so the clouds themselves need not be resident.
//! [`data::stream::DatasetSource`] is the chunked ingestion contract
//! (in-memory, generator-backed, or binary-file sources), the factor
//! builders have chunked twins ([`costs::factors_for_source`]) that sweep
//! sources in `chunk_rows`-sized arena tiles, and
//! [`coordinator::hiref::HiRef::align_source`] runs the full refinement
//! against sources, gathering base-case rows on demand:
//!
//! ```no_run
//! use hiref::api::HiRefBuilder;
//! use hiref::data::synthetic;
//!
//! // 2^20 points that never exist in memory: generated per row on demand
//! let (xs, ys) = synthetic::half_moon_s_curve_sources(1 << 20, 0);
//! let solver = HiRefBuilder::new().chunk_rows(1 << 16).build().unwrap();
//! let out = solver.align_source(&xs, &ys).unwrap();
//! assert!(out.is_bijection());
//! ```
//!
//! With spill configured too, the chunked builders write factor tiles
//! **straight into the [`pool::SpillStore`]** — the full factor matrices
//! never exist in memory at any point of the run, completing the
//! three-tier model above: tiles are `O(chunk_rows·d)`, factors are
//! `O(spill_budget)` + one level batch, and only the `O(n)` permutations
//! must stay resident.  The result is identical to the in-memory path
//! for any chunk size and any budget.  `cli align --chunk-rows
//! [--spill-dir]`, `examples/million_points.rs` and the
//! `bench_stream`/`bench_spill` profiles (`BENCH_stream.json`,
//! `BENCH_spill.json`) exercise these paths end to end.
//!
//! ## Quick start
//!
//! Construct HiRef through [`api::HiRefBuilder`] — the validated,
//! documented configuration path:
//!
//! ```no_run
//! use hiref::api::HiRefBuilder;
//! use hiref::costs::CostKind;
//! use hiref::data::synthetic;
//!
//! let (x, y) = synthetic::half_moon_s_curve(4096, 0);
//! let solver = HiRefBuilder::new().max_rank(16).base_size(256).build().unwrap();
//! let out = solver.align(&x, &y).unwrap();
//! assert!(out.is_bijection());
//! println!("primal W2² cost = {}", out.cost(&x, &y, CostKind::SqEuclidean));
//! ```
//!
//! The knobs that govern scale (all on [`api::HiRefBuilder`], mirrored by
//! `cli align` flags):
//!
//! | Knob | Memory tier it bounds | Default |
//! |---|---|---|
//! | `chunk_rows` | streaming ingestion tiles, `O(chunk_rows·d)` | 65536 |
//! | `spill_dir` | factor working copies → file-backed shards | off (resident) |
//! | `spill_budget_bytes` | resident spill-shard cache | 256 MiB |
//! | `factor_precision` | stored factor element width (f32/bf16/f16) | `f32` |
//! | `base_size` | leaf dense tiles, `O(threads · base_size²)` | 256 |
//! | `threads` | worker fan-out (and per-worker tiles) | all cores |
//! | `batching` | level-synchronous batched execution | on |
//! | `warmstart_levels` | coarse scales co-clustered without LROT | 0 (exact) |
//!
//! Every baseline the paper compares against is reachable through the
//! same uniform interface — a [`api::TransportSolver`] that maps a
//! [`api::TransportProblem`] to a [`api::Coupling`]:
//!
//! ```no_run
//! use hiref::api::{solver, TransportProblem, TransportSolver};
//! use hiref::costs::CostKind;
//! use hiref::data::synthetic;
//!
//! let (x, y) = synthetic::half_moon_s_curve(1024, 0);
//! let prob = TransportProblem::new(&x, &y, CostKind::SqEuclidean).with_seed(7);
//! for name in ["hiref", "sinkhorn", "minibatch"] {
//!     let solved = solver(name).unwrap().solve(&prob).unwrap();
//!     println!(
//!         "{name:9} cost={:.4} nnz={} ({})",
//!         solved.coupling.cost(&x, &y, CostKind::SqEuclidean),
//!         solved.coupling.nnz(),
//!         solved.coupling.kind_label(),
//!     );
//! }
//! ```
//!
//! ## Serving (`hiref serve`)
//!
//! For workloads that align the same or overlapping datasets repeatedly,
//! the [`serve`] subsystem keeps the expensive state resident in a
//! long-lived daemon (`hiref serve --listen 127.0.0.1:7878`, or
//! [`serve::serve`] in-process) speaking newline-delimited JSON over TCP
//! — see `docs/serve.md` for the wire protocol and a worked client:
//!
//! * **Sessions** — datasets are registered once, identified by a
//!   streaming content hash ([`data::stream::content_hash`]), and each
//!   `(x, y, cost config)` pair's cost factors are built once and
//!   archived in a [`pool::FactorStore`] under an LRU byte budget.  A
//!   warm solve does **zero factorisation work**.
//! * **Scheduling** — bounded worker pool + bounded admission queue
//!   (typed `overloaded` reply), per-request deadlines with typed
//!   `timeout` replies (cancellation polls only between batches, so no
//!   checkout or scratch leaks), and graceful drain on shutdown.
//! * **Cross-request microbatching** — same-shape LROT batches from
//!   different in-flight requests merge into one strided
//!   [`solvers::lrot::solve_factored_batch`] call.  Per-lane outputs are
//!   independent of batch composition and thread count, so every served
//!   permutation stays **bit-identical** to a solo offline
//!   [`coordinator::hiref::HiRef::align`].
//!
//! The host seam is [`coordinator::hiref::SolveHooks`]
//! ([`coordinator::hiref::HiRef::with_hooks`]): cancellation polling and
//! LROT batch interception, usable by any embedding, not just the TCP
//! server.
//!
//! ## Performance
//!
//! Besides the memory tiers above, two raw-speed layers sit under every
//! solver:
//!
//! * **SIMD kernel dispatch** ([`linalg::kernels`]) — the five hot
//!   linalg primitives (both matmuls, the `fast_exp` sweep, max-abs,
//!   masked row softmax) plus the four precision convert kernels
//!   (bf16/f16 widen and narrow) resolve once at startup to a scalar,
//!   AVX2 (x86_64) or NEON (aarch64) implementation.  The SIMD paths are
//!   **bit-identical** to the scalar reference (column-lane
//!   vectorisation, unchanged reduction order, no FMA), so every
//!   bit-identity invariant in the crate holds on every path.  Override
//!   with `HIREF_KERNELS=scalar|avx2|neon`; the active path is reported
//!   by `hiref solvers`, [`api::SolveStats::kernel_path`] and the serve
//!   `stats` verb.  See `docs/kernels.md`.
//! * **Persistent lane crews** ([`pool::LaneCrew`]) — a batched LROT
//!   call spawns `min(threads, lanes)` workers **once** and parks them
//!   between mirror-descent iterations, instead of respawning per
//!   iteration; [`coordinator::hiref::RunStats::iter_spawns`] records
//!   the spawn count per solve.
//! * **Cluster warmstart** ([`coordinator::warmstart`], opt-in via
//!   [`api::HiRefBuilder::warmstart_levels`] / `--warmstart-levels`) —
//!   the top scales of the hierarchy are co-clustered straight from the
//!   cost-factor rows (balanced k-means, no mirror descent), and the
//!   first exact scale below starts its descent pre-seeded with a lane
//!   clustering so converged lanes retire in half the iteration floor.
//!   The bijection stays exact and balanced; the coarse co-membership is
//!   approximate within a documented 5% relative-cost contract
//!   ([`coordinator::hiref::RunStats::level_stats`] records per-level
//!   iterations; see `docs/warmstart.md`).
//!
//! ## Choosing a solver
//!
//! | Registry name | Paper baseline | Output representation |
//! |---|---|---|
//! | `hiref` | Hierarchical Refinement (this paper) | [`api::Coupling::Bijection`] |
//! | `sinkhorn` | Cuturi 2013 (+ ε-schedule, Chen et al. 2023) | [`api::Coupling::Dense`] |
//! | `progot` | Kassraie et al. 2024 | [`api::Coupling::Dense`] |
//! | `minibatch` | Genevay et al. 2018; Fatras et al. 2020/21 | [`api::Coupling::Bijection`] |
//! | `mop` | Gerber & Maggioni 2017 | [`api::Coupling::Sparse`] |
//! | `lrot` | Scetbon et al. 2021 / FRLC | [`api::Coupling::LowRank`] |
//! | `exact` | Kuhn 1955 (Hungarian) / Bertsekas auction | [`api::Coupling::Bijection`] |
//!
//! See the [`api`] module docs for the full decision table and the
//! `solvers` CLI subcommand for the same information at the shell.
//!
//! ## Safety & verification
//!
//! All `unsafe` in the crate is confined to a small audited core — the
//! disjoint-range concurrency primitives ([`pool`]: `RangeShared`,
//! `SharedSlice`, the `FactorStore` checkouts) and the SIMD kernel bodies
//! ([`linalg::kernels`]) — and every block carries a `SAFETY:` comment
//! stating the exact invariant it relies on (enforced by
//! `clippy::undocumented_unsafe_blocks` in CI).  Modules that need no
//! unsafe are stamped `#![forbid(unsafe_code)]` so it cannot silently
//! spread.  The disjointness contracts themselves are machine-checked
//! three ways: the debug-only [`pool::guard`] race detector registers
//! every range borrow and panics on overlap with both claim sites named,
//! a `cargo miri test` CI lane interprets the pool/store/lrot/linalg
//! tests under Stacked Borrows (scalar kernels pinned under `cfg(miri)`),
//! and a `-Zsanitizer=thread` lane runs the concurrency tests.  The full
//! inventory — each unsafe surface, its contract, and which tool checks
//! it — lives in `docs/safety.md`.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod api;
pub mod cli;
pub mod coordinator;
pub mod costs;
pub mod data;
mod fsio;
pub mod linalg;
pub mod metrics;
pub mod pool;
pub mod prng;
pub mod regress;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod solvers;
