//! Minimal dense linear algebra: a row-major `Mat`, a borrowed [`MatView`]
//! over a row range, plus the handful of BLAS-1/3 operations the solvers
//! need.  No external dependencies.
//!
//! The five hot-loop primitives — [`matmul_into_slice`],
//! [`vt_matmul_into_slice`], [`exp_slice`], [`batch_row_softmax_into`] and
//! [`slice_max_abs`] — are **dispatched** through [`kernels`]: a runtime
//! choice between the verbatim scalar reference and explicit AVX2/NEON
//! implementations, resolved once per process and overridable with
//! `HIREF_KERNELS=scalar|avx2|neon`.  Every path is bit-identical (see
//! `kernels`' module docs for the column-lane argument), so the repo-wide
//! execution-strategy invariants are untouched by the dispatch.
//!
//! The solve path is **view-based**: once the global cost factors exist,
//! every sub-block is a [`MatView`] slice of them — `gather_rows` survives
//! only for dataset plumbing and tests, never for per-block refinement.
//!
//! On top of the single-matrix views sits the **strided batch layer**:
//! a [`BatchView`] names many matrices at once as `(row_range, cols)`
//! windows over one shared buffer (exactly how a level of the HiRef
//! hierarchy lays out its same-shape co-cluster factor blocks) — the
//! dispatch unit of the batched LROT solver and the PJRT boundary.  The
//! `batch_*` wrappers ([`batch_matmul_into`], [`batch_vt_matmul_into`],
//! [`batch_row_softmax_into`]) are the strided *reference form* of the
//! per-item operation: the LROT iteration loop applies the scalar
//! kernels ([`matmul_into_slice`] / [`vt_matmul_into_slice`]) directly
//! to each lane's persistent window — the same FLOPs in the same order,
//! which the wrappers' unit tests pin down — so external callers get the
//! batched form while the hot loop pays no per-iteration item plumbing.

pub mod kernels;

/// Row-major single-precision matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Borrowed row-major matrix: a zero-copy window over a `Mat` (or any
/// row-major `f32` buffer, e.g. a scratch-arena checkout).  `Copy`, so it
/// passes by value; every solver entry point accepts `impl Into<MatView>`
/// and therefore both `&Mat` and explicit views.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> MatView<'a> {
    /// View over a raw row-major buffer.
    #[inline]
    pub fn from_slice(rows: usize, cols: usize, data: &'a [f32]) -> MatView<'a> {
        assert_eq!(rows * cols, data.len(), "view shape mismatch");
        MatView { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Sub-view of rows `start..end` (zero-copy).
    #[inline]
    pub fn rows_range(&self, start: usize, end: usize) -> MatView<'a> {
        MatView::from_slice(end - start, self.cols, &self.data[start * self.cols..end * self.cols])
    }

    /// Materialise an owned copy (boundary with owning APIs only).
    pub fn to_mat(&self) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.to_vec() }
    }
}

impl<'a> From<&'a Mat> for MatView<'a> {
    #[inline]
    fn from(m: &'a Mat) -> MatView<'a> {
        MatView { rows: m.rows, cols: m.cols, data: &m.data }
    }
}

/// One item of a [`BatchView`]: a `(row_range, cols)` stride naming the
/// row-major window `rows.start * cols .. rows.end * cols` of the shared
/// buffer.  Items of one batch may differ in shape (ragged batches are
/// legal); the HiRef level scheduler groups same-shape blocks so its
/// batches are uniform.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchItem {
    /// Row window into the shared buffer (in rows, not elements).
    pub rows: std::ops::Range<usize>,
    /// Row stride / width of this item.
    pub cols: usize,
}

impl BatchItem {
    #[inline]
    pub fn new(rows: std::ops::Range<usize>, cols: usize) -> BatchItem {
        BatchItem { rows, cols }
    }

    /// Number of rows in this item.
    #[inline]
    pub fn nrows(&self) -> usize {
        self.rows.end - self.rows.start
    }

    /// First element offset into the shared buffer.
    #[inline]
    pub fn start(&self) -> usize {
        self.rows.start * self.cols
    }

    /// One-past-last element offset into the shared buffer.
    #[inline]
    pub fn end(&self) -> usize {
        self.rows.end * self.cols
    }
}

/// A batch of row-major matrices living in **one** shared `&[f32]` buffer,
/// each named by a [`BatchItem`] stride — zero-copy, `Copy`, and cheap to
/// re-slice.  This is the dispatch unit of the level-synchronous HiRef
/// engine: every co-cluster at a scale is a contiguous row range of the
/// shared factor working copies, so a whole level is one `BatchView`.
#[derive(Clone, Copy, Debug)]
pub struct BatchView<'a> {
    pub data: &'a [f32],
    pub items: &'a [BatchItem],
}

impl<'a> BatchView<'a> {
    /// Wrap `data` + per-item strides; every item window must be in
    /// bounds (checked once here, not per kernel call).
    pub fn new(data: &'a [f32], items: &'a [BatchItem]) -> BatchView<'a> {
        for (i, it) in items.iter().enumerate() {
            assert!(
                it.rows.start <= it.rows.end && it.end() <= data.len(),
                "batch item {i} ({:?} x{}) out of a {}-element buffer",
                it.rows,
                it.cols,
                data.len()
            );
        }
        BatchView { data, items }
    }

    /// Number of items (lanes) in the batch.
    #[inline]
    pub fn len(&self) -> usize {
        self.items.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Item `i` as a zero-copy [`MatView`].
    #[inline]
    pub fn item(&self, i: usize) -> MatView<'a> {
        let it = &self.items[i];
        MatView::from_slice(it.nrows(), it.cols, &self.data[it.start()..it.end()])
    }
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant-filled matrix.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow the whole matrix as a [`MatView`].
    #[inline]
    pub fn view(&self) -> MatView<'_> {
        MatView::from(self)
    }

    /// Zero-copy view of rows `start..end`.
    #[inline]
    pub fn row_range(&self, start: usize, end: usize) -> MatView<'_> {
        MatView::from_slice(end - start, self.cols, &self.data[start * self.cols..end * self.cols])
    }

    /// Gather the given rows into a new matrix.  Dataset plumbing and test
    /// oracles only — the refinement path slices [`MatView`]s instead of
    /// copying rows (see `coordinator::hiref`).
    pub fn gather_rows(&self, idx: &[u32]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i as usize));
        }
        out
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// C = A @ B (blocked ikj loop; LLVM vectorises the j-inner loop).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut c);
        c
    }

    /// A^T @ B without materialising the transpose.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "t_matmul shape mismatch");
        let mut c = Mat::zeros(self.cols, b.cols);
        let (k_dim, n) = (self.rows, b.cols);
        for p in 0..k_dim {
            let arow = self.row(p);
            let brow = b.row(p);
            for (i, &a) in arow.iter().enumerate() {
                let crow = &mut c.data[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += a * bv;
                }
            }
        }
        c
    }

    /// Frobenius inner product ⟨A, B⟩ (f64 accumulator).
    pub fn dot(&self, b: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Row sums (f64 accumulated, returned as f32).
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&v| v as f64).sum::<f64>() as f32)
            .collect()
    }

    /// Column sums.
    pub fn col_sums(&self) -> Vec<f32> {
        let mut s = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (acc, &v) in s.iter_mut().zip(self.row(i)) {
                *acc += v as f64;
            }
        }
        s.into_iter().map(|v| v as f32).collect()
    }
}

/// C = A @ B written into a preallocated `Mat` (hot path — lets callers
/// reuse gradient buffers without allocating).
pub fn matmul_into<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>, c: &mut Mat) {
    let (a, b) = (a.into(), b.into());
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    matmul_into_slice(a, b, &mut c.data);
}

/// C = A @ B written straight into a row-major slice (e.g. a scratch-arena
/// checkout): the allocation-free core of [`matmul_into`].  Dispatches to
/// the process's [`kernels`] path (scalar reference in
/// [`kernels::scalar::matmul_into_slice`]).
#[inline]
pub fn matmul_into_slice(a: MatView<'_>, b: MatView<'_>, c: &mut [f32]) {
    kernels::matmul_into_slice(a, b, c)
}

/// `out = Aᵀ B` into a row-major slice without materialising the
/// transpose (`A` is s×k, `B` is s×r, `out` is k×r).  Dispatches to the
/// process's [`kernels`] path (scalar reference in
/// [`kernels::scalar::vt_matmul_into_slice`]).
#[inline]
pub fn vt_matmul_into_slice(a: MatView<'_>, b: MatView<'_>, out: &mut [f32]) {
    kernels::vt_matmul_into_slice(a, b, out)
}

/// Element-wise `dst[i] = fast_exp(src[i])` over
/// `min(src.len(), dst.len())` elements — the factor-exponential sweep of
/// the LROT iteration, dispatched like the matmuls.
#[inline]
pub fn exp_slice(src: &[f32], dst: &mut [f32]) {
    kernels::exp_slice(src, dst)
}

// ---------------------------------------------------------------------------
// Batched kernels: iterate batch items in the inner loop
// ---------------------------------------------------------------------------
//
// Each kernel applies its per-matrix operation to every (a_i, b_i, out_i)
// triple of the batch, serially — parallelism belongs to the caller, who
// wraps ONE `pool::parallel_map` around disjoint lane subsets.  Outputs
// are per-item windows of one shared `out` buffer, described by
// `out_items`; windows must be pairwise disjoint (each is fully
// overwritten).  These are the strided REFERENCE form: since the LROT
// hot loop moved to persistent per-lane windows it calls the scalar
// `*_into_slice` kernels per lane directly (identical FLOPs/order — the
// unit tests below pin the equivalence), and the wrappers serve external
// batch consumers and the PJRT-boundary tests.

/// `C_i = A_i @ B_i` for every item `i` of the batch.
pub fn batch_matmul_into(a: BatchView<'_>, b: BatchView<'_>, out: &mut [f32], out_items: &[BatchItem]) {
    assert_eq!(a.len(), b.len(), "batch lane count mismatch");
    assert_eq!(a.len(), out_items.len(), "batch output count mismatch");
    for i in 0..a.len() {
        let o = &out_items[i];
        matmul_into_slice(a.item(i), b.item(i), &mut out[o.start()..o.end()]);
    }
}

/// `C_i = A_iᵀ B_i` for every item `i` of the batch (no transposes are
/// materialised — the strided core of the batched LROT gradient).
pub fn batch_vt_matmul_into(
    a: BatchView<'_>,
    b: BatchView<'_>,
    out: &mut [f32],
    out_items: &[BatchItem],
) {
    assert_eq!(a.len(), b.len(), "batch lane count mismatch");
    assert_eq!(a.len(), out_items.len(), "batch output count mismatch");
    for i in 0..a.len() {
        let o = &out_items[i];
        vt_matmul_into_slice(a.item(i), b.item(i), &mut out[o.start()..o.end()]);
    }
}

/// Log-mass sentinel for phantom-padding rows, shared by the whole stack:
/// `solvers::lrot::NEG` re-exports it (the constant lives here because
/// linalg sits below the solver layer and its masked kernels need it).
/// Mirrors `kernels/ref.py` NEG on the Python side.
pub const NEG_LOGMASS: f32 = -1.0e9;

/// Masked row softmax for every item of the batch: `out_i[p, z] =
/// exp(l[p, z] − m_p) / Σ_z exp(l[p, z] − m_p)` with `m_p` the row max.
/// Rows whose max is `≤ NEG_LOGMASS / 2` (phantom padding) produce
/// all-zero rows instead of NaN.
///
/// The third primitive of the strided batch-kernel family: a one-sweep
/// row-normalisation turning logit lanes into row-stochastic soft
/// assignments.  The LROT loop itself keeps its raw `exp` of
/// Sinkhorn-projected logits (rows there must sum to the *marginal*, not
/// to 1, and the AOT artifacts bake that exact arithmetic), so today this
/// kernel serves soft-assignment consumers and diagnostics rather than
/// the solve path — see the unit tests for its contract.
pub fn batch_row_softmax_into(
    logits: BatchView<'_>,
    out: &mut [f32],
    out_items: &[BatchItem],
) {
    assert_eq!(logits.len(), out_items.len(), "batch output count mismatch");
    for i in 0..logits.len() {
        let l = logits.item(i);
        let o = &out_items[i];
        assert_eq!(o.nrows(), l.rows, "softmax output shape mismatch");
        assert_eq!(o.cols, l.cols, "softmax output shape mismatch");
        kernels::row_softmax_item(l, &mut out[o.start()..o.end()]);
    }
}

/// Max absolute entry of a slice (step-size normalisation).  Dispatched
/// like the matmuls (scalar reference in
/// [`kernels::scalar::slice_max_abs`]).
#[inline]
pub fn slice_max_abs(xs: &[f32]) -> f32 {
    kernels::slice_max_abs(xs)
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = 0.0f64;
    for (a, b) in x.iter().zip(y) {
        let d = (*a - *b) as f64;
        s += d * d;
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn dist(x: &[f32], y: &[f32]) -> f64 {
    sq_dist(x, y).sqrt()
}

/// Stable log-sum-exp of a slice.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let mx = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    if !mx.is_finite() {
        return mx;
    }
    let s: f64 = xs.iter().map(|&v| ((v - mx) as f64).exp()).sum();
    mx + (s.ln() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let c1 = a.t_matmul(&b);
        let c2 = a.t().matmul(&b);
        assert_eq!(c1, c2);
    }

    #[test]
    fn gather_rows_picks_rows() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn row_range_view_is_zero_copy_window() {
        let a = Mat::from_vec(4, 2, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let v = a.row_range(1, 3);
        assert_eq!((v.rows, v.cols), (2, 2));
        assert_eq!(v.row(0), &[3., 4.]);
        assert_eq!(v.at(1, 1), 6.0);
        assert_eq!(v.to_mat().data, a.gather_rows(&[1, 2]).data);
        let sub = v.rows_range(1, 2);
        assert_eq!(sub.row(0), &[5., 6.]);
    }

    #[test]
    fn slice_matmuls_match_mat_matmuls() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(2, 3, vec![1., 0., 2., 0., 1., 3.]);
        let want = a.matmul(&b);
        let mut c = vec![0.0f32; 9];
        matmul_into_slice(a.view(), b.view(), &mut c);
        assert_eq!(c, want.data);
        // Aᵀ B through the slice kernel
        let bt = Mat::from_vec(3, 2, vec![1., 1., 1., 0., 0., 1.]);
        let want_t = a.t().matmul(&bt);
        let mut ct = vec![0.0f32; 4];
        vt_matmul_into_slice(a.view(), bt.view(), &mut ct);
        assert_eq!(ct, want_t.data);
        assert_eq!(slice_max_abs(&[-3.0, 2.0, 0.5]), 3.0);
    }

    #[test]
    fn batch_view_items_are_matviews() {
        // two stacked 2x3 blocks in one buffer
        let data: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let items = [BatchItem::new(0..2, 3), BatchItem::new(2..4, 3)];
        let b = BatchView::new(&data, &items);
        assert_eq!(b.len(), 2);
        assert!(!b.is_empty());
        assert_eq!(b.item(0).row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(b.item(1).row(0), &[6.0, 7.0, 8.0]);
        // ragged batches are legal
        let ragged = [BatchItem::new(0..1, 3), BatchItem::new(1..4, 3)];
        let b = BatchView::new(&data, &ragged);
        assert_eq!((b.item(0).rows, b.item(1).rows), (1, 3));
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn batch_view_rejects_out_of_bounds_items() {
        let data = vec![0.0f32; 6];
        let items = [BatchItem::new(0..3, 3)]; // needs 9 elements
        let _ = BatchView::new(&data, &items);
    }

    #[test]
    fn batch_matmuls_match_scalar_kernels_per_lane() {
        let mut rng = 1u64;
        let mut next = move || {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((rng >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        // 3 lanes of (s x k) stacked in one buffer, k = 2, s = {2, 3, 2}
        let (k, r) = (2usize, 3usize);
        let a_data: Vec<f32> = (0..7 * k).map(|_| next()).collect();
        let b_data: Vec<f32> = (0..7 * r).map(|_| next()).collect();
        let a_items =
            [BatchItem::new(0..2, k), BatchItem::new(2..5, k), BatchItem::new(5..7, k)];
        let b_items =
            [BatchItem::new(0..2, r), BatchItem::new(2..5, r), BatchItem::new(5..7, r)];
        let a = BatchView::new(&a_data, &a_items);
        let b = BatchView::new(&b_data, &b_items);
        // Aᵀ B per lane: k x r outputs, stacked densely
        let out_items =
            [BatchItem::new(0..k, r), BatchItem::new(k..2 * k, r), BatchItem::new(2 * k..3 * k, r)];
        let mut got = vec![0.0f32; 3 * k * r];
        batch_vt_matmul_into(a, b, &mut got, &out_items);
        for l in 0..3 {
            let mut want = vec![0.0f32; k * r];
            vt_matmul_into_slice(a.item(l), b.item(l), &mut want);
            let o = &out_items[l];
            assert_eq!(&got[o.start()..o.end()], &want[..], "vt lane {l}");
        }
        // A_i @ W_i with W the k x r products just computed
        let w = BatchView::new(&got, &out_items);
        let c_items =
            [BatchItem::new(0..2, r), BatchItem::new(2..5, r), BatchItem::new(5..7, r)];
        let mut c = vec![0.0f32; 7 * r];
        batch_matmul_into(a, w, &mut c, &c_items);
        for l in 0..3 {
            let mut want = vec![0.0f32; a.item(l).rows * r];
            matmul_into_slice(a.item(l), w.item(l), &mut want);
            let o = &c_items[l];
            assert_eq!(&c[o.start()..o.end()], &want[..], "mm lane {l}");
        }
    }

    #[test]
    fn batch_row_softmax_normalises_and_masks() {
        const NEG: f32 = -1.0e9;
        let data = vec![
            0.0, 1.0, 2.0, // lane 0 row 0
            NEG, NEG, NEG, // lane 0 row 1: padding
            5.0, 5.0, 5.0, // lane 1 row 0: ties
        ];
        let items = [BatchItem::new(0..2, 3), BatchItem::new(2..3, 3)];
        let b = BatchView::new(&data, &items);
        let out_items = [BatchItem::new(0..2, 3), BatchItem::new(2..3, 3)];
        let mut out = vec![f32::NAN; 9];
        batch_row_softmax_into(b, &mut out, &out_items);
        // row 0: softmax of [0,1,2] — increasing, sums to 1
        let s: f32 = out[0..3].iter().sum();
        assert!((s - 1.0).abs() < 1e-5, "sum {s}");
        assert!(out[0] < out[1] && out[1] < out[2]);
        // padding row is exactly zero, not NaN
        assert_eq!(&out[3..6], &[0.0, 0.0, 0.0]);
        // tied row: uniform
        for &v in &out[6..9] {
            assert!((v - 1.0 / 3.0).abs() < 1e-5, "{v}");
        }
    }

    #[test]
    fn row_col_sums() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.row_sums(), vec![6.0, 15.0]);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn logsumexp_stable() {
        let v = logsumexp(&[1000.0, 1000.0]);
        assert!((v - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
    }

    #[test]
    fn dot_is_frobenius() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![2., 0., 0., 2.]);
        assert_eq!(a.dot(&b), 10.0);
    }

    #[test]
    fn distances() {
        assert_eq!(sq_dist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }
}

/// Invert a small symmetric positive-definite matrix by Gauss–Jordan
/// with partial pivoting (intended for k ≤ 128 normal-equation systems).
pub fn invert_spd(m: &Mat) -> Mat {
    let n = m.rows;
    assert_eq!(n, m.cols);
    let mut a = m.clone();
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        *inv.at_mut(i, i) = 1.0;
    }
    for col in 0..n {
        let mut piv = col;
        for r in col + 1..n {
            if a.at(r, col).abs() > a.at(piv, col).abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..n {
                let t = a.at(col, j);
                *a.at_mut(col, j) = a.at(piv, j);
                *a.at_mut(piv, j) = t;
                let t = inv.at(col, j);
                *inv.at_mut(col, j) = inv.at(piv, j);
                *inv.at_mut(piv, j) = t;
            }
        }
        let d = a.at(col, col);
        let d = if d.abs() < 1e-12 { 1e-12_f32.copysign(d) } else { d };
        for j in 0..n {
            *a.at_mut(col, j) /= d;
            *inv.at_mut(col, j) /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a.at(r, col);
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                let av = a.at(col, j);
                let iv = inv.at(col, j);
                *a.at_mut(r, j) -= f * av;
                *inv.at_mut(r, j) -= f * iv;
            }
        }
    }
    inv
}

/// Fast `exp` for f32 via exp2 range reduction + degree-5 polynomial.
/// Max relative error ≈ 7e-6 — indistinguishable from libm for the
/// mirror-descent softmax weights, ~4× faster on scalar code and
/// auto-vectorisable (no table lookups; one underflow branch).
#[inline(always)]
pub fn fast_exp(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    let y = x * LOG2E;
    if y <= -126.0 {
        return 0.0; // underflow (incl. the NEG padding sentinel)
    }
    let y = y.min(127.0);
    let k = y.round();
    let f = y - k; // f in [-0.5, 0.5]
    // 2^f by minimax-ish polynomial (Taylor in ln2 refined)
    const C0: f32 = 1.000_000_0;
    const C1: f32 = 0.693_147_2;
    const C2: f32 = 0.240_226_51;
    const C3: f32 = 0.055_504_11;
    const C4: f32 = 0.009_618_13;
    const C5: f32 = 0.001_333_55;
    let p = C0 + f * (C1 + f * (C2 + f * (C3 + f * (C4 + f * C5))));
    // scale by 2^k through the exponent bits
    let bits = ((k as i32 + 127) as u32) << 23;
    p * f32::from_bits(bits)
}

#[cfg(test)]
mod fast_exp_tests {
    use super::fast_exp;

    #[test]
    fn accuracy_across_range() {
        let mut worst = 0.0f64;
        let mut x = -80.0f32;
        while x < 80.0 {
            let got = fast_exp(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            worst = worst.max(rel);
            x += 0.0137;
        }
        assert!(worst < 1e-5, "worst rel error {worst}");
    }

    #[test]
    fn extremes_do_not_blow_up() {
        assert_eq!(fast_exp(-1.0e9), 0.0);
        assert!(fast_exp(200.0).is_finite());
        assert!((fast_exp(0.0) - 1.0).abs() < 1e-6);
    }
}
