//! Hand-rolled CLI (the vendored crate universe has no clap).
//!
//! `hiref <subcommand> [--flag value ...]`; see [`print_usage`] or run
//! `hiref help`.  The benches (`cargo bench`) regenerate the paper tables;
//! this binary is the interactive entry point for one-off runs.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::annealing;
use crate::coordinator::hiref::{BackendKind, HiRef, HiRefConfig};
use crate::costs::CostKind;
use crate::data::synthetic::Synthetic;
use crate::metrics;
use crate::report::{f4, Table};
use crate::runtime::PjrtEngine;
use crate::solvers::minibatch::{self, MiniBatchConfig};

/// Parsed `--key value` flags plus positional arguments.
pub struct Flags {
    pub positional: Vec<String>,
    pub named: HashMap<String, String>,
}

impl Flags {
    /// Parse flags from raw args (after the subcommand).
    pub fn parse(args: &[String]) -> Result<Flags> {
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else {
                    let v = args
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{key} missing a value"))?;
                    named.insert(key.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Flags { positional, named })
    }

    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.named.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| anyhow!("could not parse --{key} {v}")),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.named.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

/// Build a [`HiRefConfig`] from common flags.
pub fn config_from_flags(flags: &Flags) -> Result<HiRefConfig> {
    let mut cfg = HiRefConfig::default();
    cfg.max_rank = flags.get("max-rank", cfg.max_rank)?;
    cfg.base_size = flags.get("base-size", cfg.base_size)?;
    cfg.seed = flags.get("seed", cfg.seed)?;
    cfg.threads = flags.get("threads", cfg.threads)?;
    if let Some(d) = flags.named.get("depth") {
        cfg.max_depth = Some(d.parse()?);
    }
    cfg.artifacts_dir = PathBuf::from(flags.get_str("artifacts", "artifacts"));
    cfg.cost = match flags.get_str("cost", "sq").as_str() {
        "sq" | "w2" | "sqeuclidean" => CostKind::SqEuclidean,
        "euclid" | "w1" | "euclidean" => CostKind::Euclidean,
        other => bail!("unknown --cost {other} (use sq|euclid)"),
    };
    cfg.backend = match flags.get_str("backend", "auto").as_str() {
        "auto" => BackendKind::Auto,
        "native" => BackendKind::Native,
        "pjrt" => BackendKind::Pjrt,
        other => bail!("unknown --backend {other} (use auto|native|pjrt)"),
    };
    Ok(cfg)
}

/// Generate the dataset named by `--dataset` at size `--n`.
pub fn dataset_from_flags(flags: &Flags) -> Result<(crate::linalg::Mat, crate::linalg::Mat)> {
    let n: usize = flags.get("n", 1024)?;
    let seed: u64 = flags.get("seed", 0)?;
    let name = flags.get_str("dataset", "halfmoon");
    if let Some(ds) = Synthetic::parse(&name) {
        return Ok(ds.generate(n, seed));
    }
    match name.as_str() {
        "imagenet-sim" => {
            let d: usize = flags.get("dim", 256)?;
            Ok(crate::data::embeddings::imagenet_like(n, d, 100, seed))
        }
        "merfish-sim" => {
            let (s, t) = crate::data::transcriptomics::merfish_pair(n, seed);
            Ok((s.spatial, t.spatial))
        }
        other => bail!("unknown --dataset {other}"),
    }
}

/// Entry point for the binary.
pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "align" => cmd_align(&flags),
        "compare" => cmd_compare(&flags),
        "schedule" => cmd_schedule(&flags),
        "buckets" => cmd_buckets(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            bail!("unknown subcommand: {other}")
        }
    }
}

fn cmd_align(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let (x, y) = dataset_from_flags(flags)?;
    let kind = cfg.cost;
    let solver = HiRef::new(cfg);
    let out = solver.align(&x, &y)?;
    assert!(out.is_bijection(), "internal error: output not a bijection");
    println!("n            = {}", x.rows);
    println!("schedule     = {:?}", out.schedule);
    println!("primal cost  = {}", f4(out.cost(&x, &y, kind)));
    println!("nonzeros     = {} (vs n² = {})", x.rows, x.rows * x.rows);
    println!("lrot calls   = {} ({} pjrt, {} native)", out.stats.lrot_calls,
             out.stats.pjrt_calls, out.stats.native_calls);
    println!("base blocks  = {}", out.stats.base_calls);
    println!("elapsed      = {:.3}s", out.stats.elapsed.as_secs_f64());
    Ok(())
}

fn cmd_compare(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let (x, y) = dataset_from_flags(flags)?;
    let kind = cfg.cost;
    let mut table = Table::new(vec!["Method", "Primal cost", "Seconds"]);

    let solver = HiRef::new(cfg.clone());
    let (out, secs) = crate::report::timed(|| solver.align(&x, &y));
    let out = out?;
    table.row(vec!["HiRef".to_string(), f4(out.cost(&x, &y, kind)), format!("{secs:.2}")]);

    for b in [128usize, 1024] {
        if b < x.rows {
            let (perm, secs) = crate::report::timed(|| {
                minibatch::solve(&x, &y, kind, &MiniBatchConfig { batch: b, seed: cfg.seed, ..Default::default() })
            });
            table.row(vec![
                format!("MB {b}"),
                f4(metrics::bijection_cost(&x, &y, &perm, kind)),
                format!("{secs:.2}"),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn cmd_schedule(flags: &Flags) -> Result<()> {
    let n: usize = flags.get("n", 1 << 20)?;
    let base: usize = flags.get("base-size", 256)?;
    let max_rank: usize = flags.get("max-rank", 16)?;
    let depth = flags.named.get("depth").map(|d| d.parse()).transpose()?;
    let sched = annealing::optimal_rank_schedule(n, base, max_rank, depth);
    println!("n = {n}, base = {base}, max_rank = {max_rank}");
    println!("schedule         = {sched:?}");
    println!("effective ranks  = {:?}", annealing::effective_ranks(&sched));
    println!("LROT-call proxy  = {}", annealing::schedule_cost(&sched));
    Ok(())
}

fn cmd_buckets(flags: &Flags) -> Result<()> {
    let dir = PathBuf::from(flags.get_str("artifacts", "artifacts"));
    let engine = PjrtEngine::load(&dir)?;
    let mut table = Table::new(vec!["s", "r", "k", "outer", "inner", "path"]);
    for b in engine.buckets() {
        table.row(vec![
            b.s.to_string(),
            b.r.to_string(),
            b.k.to_string(),
            b.outer.to_string(),
            b.inner.to_string(),
            b.path.file_name().unwrap().to_string_lossy().into_owned(),
        ]);
    }
    table.print();
    Ok(())
}

fn print_usage() {
    println!(
        "hiref — Hierarchical Refinement OT (ICML 2025 reproduction)

USAGE: hiref <command> [flags]

COMMANDS
  align     run HiRef on a dataset and report cost/stats
  compare   HiRef vs mini-batch baselines on a dataset
  schedule  print the optimal rank-annealing schedule for given n
  buckets   list AOT artifact buckets (artifacts/manifest.tsv)
  help      this message

COMMON FLAGS
  --dataset checkerboard|maf|halfmoon|imagenet-sim|merfish-sim
  --n <int>             dataset size                 [1024]
  --cost sq|euclid      ground cost                  [sq]
  --backend auto|native|pjrt                         [auto]
  --max-rank <int>      annealing max rank C         [16]
  --base-size <int>     exact base-case block Q      [256]
  --depth <int>         cap hierarchy depth
  --seed <int>                                       [0]
  --threads <int>                                    [all cores]
  --artifacts <dir>                                  [artifacts]"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(args: &[&str]) -> Flags {
        Flags::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let f = flags(&["pos1", "--n", "42", "--cost=euclid", "pos2"]);
        assert_eq!(f.positional, vec!["pos1", "pos2"]);
        assert_eq!(f.get::<usize>("n", 0).unwrap(), 42);
        assert_eq!(f.get_str("cost", ""), "euclid");
    }

    #[test]
    fn missing_value_errors() {
        let args = vec!["--n".to_string()];
        assert!(Flags::parse(&args).is_err());
    }

    #[test]
    fn config_from_flags_defaults() {
        let f = flags(&[]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.cost, CostKind::SqEuclidean);
        assert_eq!(cfg.backend, BackendKind::Auto);
    }

    #[test]
    fn config_rejects_bad_cost() {
        let f = flags(&["--cost", "manhattan"]);
        assert!(config_from_flags(&f).is_err());
    }

    #[test]
    fn dataset_parsing() {
        let f = flags(&["--dataset", "checkerboard", "--n", "64"]);
        let (x, y) = dataset_from_flags(&f).unwrap();
        assert_eq!(x.rows, 64);
        assert_eq!(y.rows, 64);
    }
}
