//! Hand-rolled CLI (the vendored crate universe has no clap).
//!
//! `hiref <subcommand> [--flag value ...]`; see [`print_usage`] or run
//! `hiref help`.  The benches (`cargo bench`) regenerate the paper tables;
//! this binary is the interactive entry point for one-off runs.  Every
//! subcommand that solves dispatches through the unified
//! [`crate::api::SolverRegistry`], so `--solver <name>` selects any
//! registered backend uniformly.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;

use crate::api::{self, HiRefBuilder, HiRefSolver, TransportProblem, TransportSolver};
use crate::coordinator::annealing;
use crate::coordinator::hiref::{BackendKind, HiRefConfig};
use crate::costs::CostKind;
use crate::data::stream::InMemorySource;
use crate::data::synthetic::Synthetic;
use crate::metrics;
use crate::pool::Precision;
use crate::report::{f4, Table};

/// CLI-level error: a message for the terminal.
#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl From<api::SolveError> for CliError {
    fn from(e: api::SolveError) -> Self {
        CliError(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, CliError>;

fn err(msg: impl Into<String>) -> CliError {
    CliError(msg.into())
}

/// Parsed `--key value` flags plus positional arguments.
pub struct Flags {
    pub positional: Vec<String>,
    pub named: HashMap<String, String>,
}

impl Flags {
    /// Parse flags from raw args (after the subcommand).
    pub fn parse(args: &[String]) -> Result<Flags> {
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else {
                    let v = args
                        .get(i + 1)
                        .ok_or_else(|| err(format!("flag --{key} missing a value")))?;
                    named.insert(key.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Flags { positional, named })
    }

    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.named.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| err(format!("could not parse --{key} {v}"))),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.named.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Read an enum-like flag, reporting the list of valid values when the
    /// given one is not among `choices` (case-insensitive).
    pub fn get_choice(&self, key: &str, default: &str, choices: &[&str]) -> Result<String> {
        let v = self.get_str(key, default).to_ascii_lowercase();
        if choices.iter().any(|c| c.eq_ignore_ascii_case(&v)) {
            Ok(v)
        } else {
            Err(err(format!(
                "unknown --{key} {v} (valid values: {})",
                choices.join("|")
            )))
        }
    }
}

/// Valid `--cost` spellings (first of each group is canonical).
const COST_CHOICES: [&str; 6] = ["sq", "sqeuclidean", "w2", "euclid", "euclidean", "w1"];
/// Valid `--backend` values.
const BACKEND_CHOICES: [&str; 3] = ["auto", "native", "pjrt"];
/// Valid `--batching` values.
const BATCHING_CHOICES: [&str; 2] = ["on", "off"];
/// Valid `--factor-precision` values.
const PRECISION_CHOICES: [&str; 3] = ["f32", "bf16", "f16"];
/// Valid `--dataset` values.
const DATASET_CHOICES: [&str; 8] = [
    "halfmoon",
    "halfmoon-scurve",
    "checkerboard",
    "checker",
    "maf",
    "moons-rings",
    "imagenet-sim",
    "merfish-sim",
];

/// Parse a byte count with an optional `k`/`m`/`g` suffix (KiB/MiB/GiB,
/// case-insensitive): `--spill-budget 64m`, `--spill-budget 4096`.
///
/// Counts beyond `u64::MAX` (or this platform's `usize::MAX`) are a
/// typed [`api::SolveError::InvalidConfig`] — never a silent wrap —
/// distinct from the not-a-number parse error.
pub fn parse_bytes(v: &str) -> Result<usize> {
    let s = v.trim().to_ascii_lowercase();
    let (num, mult) = match s.as_bytes().last() {
        Some(&b'k') => (&s[..s.len() - 1], 1u128 << 10),
        Some(&b'm') => (&s[..s.len() - 1], 1u128 << 20),
        Some(&b'g') => (&s[..s.len() - 1], 1u128 << 30),
        _ => (s.as_str(), 1u128),
    };
    let overflow =
        || CliError::from(api::SolveError::InvalidConfig(format!("byte count {v} overflows u64")));
    // parse into u128 so a digit string just past u64::MAX is still
    // classified as overflow, not as "could not parse"
    let n: u128 = match num.trim().parse::<u128>() {
        Ok(n) => n,
        Err(e) if matches!(e.kind(), std::num::IntErrorKind::PosOverflow) => {
            return Err(overflow())
        }
        Err(_) => {
            return Err(err(format!("could not parse byte count {v} (use e.g. 4096, 64m, 1g)")))
        }
    };
    let total = n.checked_mul(mult).ok_or_else(overflow)?;
    if total > u64::MAX as u128 || total > usize::MAX as u128 {
        return Err(overflow());
    }
    Ok(total as usize)
}

/// Parse a `--cost` value into a [`CostKind`] (case-insensitive); the
/// error lists the valid spellings.
pub fn parse_cost(v: &str) -> Result<CostKind> {
    match v.to_ascii_lowercase().as_str() {
        "sq" | "w2" | "sqeuclidean" => Ok(CostKind::SqEuclidean),
        "euclid" | "w1" | "euclidean" => Ok(CostKind::Euclidean),
        other => Err(err(format!(
            "unknown --cost {other} (valid values: {})",
            COST_CHOICES.join("|")
        ))),
    }
}

/// Build a validated [`HiRefConfig`] from common flags (via
/// [`HiRefBuilder`], so inconsistent combinations are rejected up front).
pub fn config_from_flags(flags: &Flags) -> Result<HiRefConfig> {
    let d = HiRefConfig::default();
    let base_size = flags.get("base-size", d.base_size)?;
    // default cutoff tracks a shrunken base size; an explicit flag above
    // the base size is rejected by the builder
    let cutoff = flags.get("hungarian-cutoff", d.hungarian_cutoff.min(base_size))?;
    let mut b = HiRefBuilder::new()
        .max_rank(flags.get("max-rank", d.max_rank)?)
        .base_size(base_size)
        .hungarian_cutoff(cutoff)
        .seed(flags.get("seed", d.seed)?)
        .threads(flags.get("threads", d.threads)?)
        .chunk_rows(flags.get("chunk-rows", d.chunk_rows)?)
        .artifacts_dir(PathBuf::from(flags.get_str("artifacts", "artifacts")))
        .cost(parse_cost(&flags.get_str("cost", "sq"))?);
    if let Some(depth) = flags.named.get("depth") {
        let depth: usize = depth
            .parse()
            .map_err(|_| err(format!("could not parse --depth {depth}")))?;
        b = b.max_depth(depth);
    }
    b = b.backend(match flags.get_choice("backend", "auto", &BACKEND_CHOICES)?.as_str() {
        "native" => BackendKind::Native,
        "pjrt" => BackendKind::Pjrt,
        _ => BackendKind::Auto,
    });
    b = b.batching(flags.get_choice("batching", "on", &BATCHING_CHOICES)? == "on");
    b = b.warmstart_levels(flags.get("warmstart-levels", d.warmstart_levels)?);
    let prec = flags.get_choice("factor-precision", "f32", &PRECISION_CHOICES)?;
    b = b.factor_precision(
        Precision::parse(&prec).expect("get_choice admits only listed precisions"),
    );
    if let Some(dir) = flags.named.get("spill-dir") {
        b = b.spill_dir(PathBuf::from(dir));
    }
    if let Some(budget) = flags.named.get("spill-budget") {
        // a budget without a directory is rejected by the builder
        b = b.spill_budget_bytes(parse_bytes(budget)?);
    }
    Ok(b.build_config()?)
}

/// Generate the dataset named by `--dataset` at size `--n`.
pub fn dataset_from_flags(flags: &Flags) -> Result<(crate::linalg::Mat, crate::linalg::Mat)> {
    let n: usize = flags.get("n", 1024)?;
    let seed: u64 = flags.get("seed", 0)?;
    let name = flags.get_str("dataset", "halfmoon");
    if let Some(ds) = Synthetic::parse(&name) {
        return Ok(ds.generate(n, seed));
    }
    match name.as_str() {
        "imagenet-sim" => {
            let d: usize = flags.get("dim", 256)?;
            Ok(crate::data::embeddings::imagenet_like(n, d, 100, seed))
        }
        "merfish-sim" => {
            let (s, t) = crate::data::transcriptomics::merfish_pair(n, seed);
            Ok((s.spatial, t.spatial))
        }
        other => Err(err(format!(
            "unknown --dataset {other} (valid values: {})",
            DATASET_CHOICES.join("|")
        ))),
    }
}

/// Resolve one solver name (alias- and case-insensitive): HiRef picks up
/// the HiRef flags; every other registered solver runs with its default
/// configuration.  Unknown names error with the list of valid solvers.
fn named_solver(name: &str, cfg: &HiRefConfig) -> Result<Box<dyn TransportSolver>> {
    if api::canonical_name(name) == "hiref" {
        Ok(Box::new(HiRefSolver { cfg: cfg.clone() }))
    } else {
        Ok(api::solver(name)?)
    }
}

/// Resolve `--solver <name>`.
fn solver_from_flags(flags: &Flags, cfg: &HiRefConfig) -> Result<Box<dyn TransportSolver>> {
    named_solver(&flags.get_str("solver", "hiref"), cfg)
}

/// Entry point for the binary.
pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "align" => cmd_align(&flags),
        "compare" => cmd_compare(&flags),
        "convert" => cmd_convert(&flags),
        "serve" => cmd_serve(&flags),
        "solvers" => cmd_solvers(),
        "schedule" => cmd_schedule(&flags),
        "buckets" => cmd_buckets(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(err(format!("unknown subcommand: {other}")))
        }
    }
}

fn cmd_align(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let (x, y) = dataset_from_flags(flags)?;
    let kind = cfg.cost;
    let seed = cfg.seed;
    let solver_name = api::canonical_name(&flags.get_str("solver", "hiref"));
    let streaming = flags.named.contains_key("chunk-rows");
    if streaming && solver_name != "hiref" {
        return Err(err(format!(
            "--chunk-rows selects the HiRef streaming ingestion path and is not \
             supported by --solver {solver_name} (valid with: hiref)"
        )));
    }
    // silently ignoring these would let users believe they benchmarked
    // the spill path — reject the combination like --chunk-rows above
    if (flags.named.contains_key("spill-dir") || flags.named.contains_key("spill-budget"))
        && solver_name != "hiref"
    {
        return Err(err(format!(
            "--spill-dir/--spill-budget configure HiRef's factor spill storage and are \
             not supported by --solver {solver_name} (valid with: hiref)"
        )));
    }
    if flags.named.contains_key("warmstart-levels") && solver_name != "hiref" {
        return Err(err(format!(
            "--warmstart-levels configures HiRef's cluster-warmstart path and is \
             not supported by --solver {solver_name} (valid with: hiref)"
        )));
    }
    let (solved, describe) = if streaming {
        // `--chunk-rows` routes HiRef through the streaming ingestion
        // path: chunked factorisation + on-demand base-case gathers.
        let solver = HiRefSolver { cfg: cfg.clone() };
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        (
            solver.solve_source(&xs, &ys, kind, seed)?,
            format!(
                "streaming ingestion, chunk_rows = {} — {}",
                cfg.chunk_rows,
                solver.describe()
            ),
        )
    } else {
        let solver = solver_from_flags(flags, &cfg)?;
        let prob = TransportProblem::new(&x, &y, kind).with_seed(seed);
        (solver.solve(&prob)?, solver.describe().to_string())
    };
    println!("solver        = {} ({})", solved.stats.solver, describe);
    println!("n             = {}", x.rows);
    println!("coupling      = {}", solved.coupling.kind_label());
    println!("primal cost   = {}", f4(metrics::coupling_cost(&x, &y, &solved.coupling, kind)));
    // counting a low-rank plan's nonzeros streams the implied n×m matrix;
    // skip it beyond evaluation scales so `align` stays linear-time
    let (rows, cols) = solved.coupling.shape();
    match &solved.coupling {
        api::Coupling::LowRank { .. } if rows.saturating_mul(cols) > 50_000_000 => {
            println!("nonzeros      = (skipped: implied {rows}×{cols} plan too large to stream)");
        }
        _ => println!("nonzeros      = {} (vs n² = {})", solved.coupling.nnz(), rows * rows),
    }
    println!("marginal err  = {:.2e}", solved.coupling.marginal_error());
    if let Some(rs) = &solved.stats.hiref {
        println!(
            "lrot calls    = {} ({} pjrt, {} native)",
            rs.lrot_calls, rs.pjrt_calls, rs.native_calls
        );
        println!("base blocks   = {}", rs.base_calls);
        if rs.batches > 0 {
            println!(
                "batches       = {} (widest {} lanes, {:.0}% of blocks in multi-lane batches)",
                rs.batches,
                rs.lanes_max,
                rs.batched_frac * 100.0
            );
        } else {
            println!("batches       = 0 (per-block execution)");
        }
        if rs.cluster_calls > 0 {
            println!(
                "warmstart     = {} lane clusterings ({} native LROT iters total)",
                rs.cluster_calls, rs.lrot_iters
            );
        }
        if !rs.level_stats.is_empty() {
            let mut lv = Table::new(vec!["Level", "Blocks", "Lanes", "LROT iters", "ms", "Warm"]);
            for ls in &rs.level_stats {
                lv.row(vec![
                    ls.level.to_string(),
                    ls.blocks.to_string(),
                    ls.lanes.to_string(),
                    ls.lrot_iters.to_string(),
                    format!("{:.1}", ls.elapsed.as_secs_f64() * 1e3),
                    if ls.warmstarted { "yes" } else { "-" }.to_string(),
                ]);
            }
            lv.print();
        }
        println!(
            "scratch peak  = {} (arena hit rate {:.1}%)",
            metrics::human_bytes(rs.peak_scratch_bytes),
            rs.arena_hit_rate() * 100.0
        );
        println!(
            "factors       = {} ({})",
            rs.factor_precision,
            metrics::human_bytes(rs.factor_bytes)
        );
        println!("kernels       = {} ({} iter spawns)", rs.kernel_path, rs.iter_spawns);
        if cfg.spill.is_some() {
            println!(
                "spill         = wrote {}, {} shard reads, resident factor peak {}",
                metrics::human_bytes(rs.spill_bytes_written),
                rs.spill_reads,
                metrics::human_bytes(rs.resident_factor_bytes)
            );
        }
    }
    println!("elapsed       = {:.3}s", solved.stats.elapsed.as_secs_f64());
    Ok(())
}

fn cmd_compare(flags: &Flags) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    let (x, y) = dataset_from_flags(flags)?;
    let kind = cfg.cost;
    let names = flags.get_str("solvers", "hiref,minibatch,mop");
    // spill flags only affect hiref: with no hiref in the list they would
    // be a silent no-op, so reject that combination (same class of guard
    // as --chunk-rows on `align`)
    let hiref_only = |what: &str| {
        let any_hiref = names
            .split(',')
            .map(str::trim)
            .any(|n| api::canonical_name(n) == "hiref");
        if any_hiref {
            Ok(())
        } else {
            Err(err(format!("{what} but --solvers {names} does not include hiref")))
        }
    };
    if flags.named.contains_key("spill-dir") || flags.named.contains_key("spill-budget") {
        hiref_only("--spill-dir/--spill-budget configure HiRef's factor spill storage")?;
    }
    if flags.named.contains_key("warmstart-levels") {
        hiref_only("--warmstart-levels configures HiRef's cluster-warmstart path")?;
    }
    let prob = TransportProblem::new(&x, &y, kind).with_seed(cfg.seed);

    let mut table = Table::new(vec!["Solver", "Coupling", "Primal cost", "nnz", "Iters", "Seconds"]);
    for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let solver = named_solver(name, &cfg)?;
        let solved = solver.solve(&prob)?;
        // HiRef reports native mirror-descent iterations (the quantity
        // the warmstart path reduces); other solvers their own count
        let iters = solved.stats.hiref.as_ref().map_or(solved.stats.iterations, |rs| rs.lrot_iters);
        table.row(vec![
            solved.stats.solver.to_string(),
            solved.coupling.kind_label().to_string(),
            f4(metrics::coupling_cost(&x, &y, &solved.coupling, kind)),
            solved.coupling.nnz().to_string(),
            iters.to_string(),
            format!("{:.2}", solved.stats.elapsed.as_secs_f64()),
        ]);
    }
    table.print();
    Ok(())
}

/// `hiref convert --input points.npy --output points.bin [--dim d]` —
/// re-encode a dataset file as the raw little-endian f32 `.bin` format
/// every streaming entry point reads.  `.npy` inputs (v1/v2, C-order
/// `<f4`/`<f8`) are parsed from their header; raw inputs need `--dim`.
fn cmd_convert(flags: &Flags) -> Result<()> {
    use crate::data::stream::{convert_to_bin, BinFileSource, DatasetSource};
    use crate::pool::ScratchArena;
    let input = flags
        .named
        .get("input")
        .ok_or_else(|| err("convert needs --input <file> (.npy or raw .bin)"))?;
    let output = flags
        .named
        .get("output")
        .ok_or_else(|| err("convert needs --output <file>"))?;
    let dim_flag: usize = flags.get("dim", 0)?;
    let is_npy = input.to_ascii_lowercase().ends_with(".npy");
    let src = if is_npy {
        BinFileSource::open_npy(input).map_err(|e| err(e.to_string()))?
    } else if dim_flag > 0 {
        BinFileSource::open(input, dim_flag).map_err(|e| err(e.to_string()))?
    } else {
        return Err(err("raw (non-.npy) input needs --dim <columns>"));
    };
    // the row/dim sanity check: an explicit --dim must agree with the
    // parsed npy header
    if dim_flag > 0 && src.dim() != dim_flag {
        return Err(err(format!(
            "--dim {dim_flag} does not match the npy header dim {}",
            src.dim()
        )));
    }
    let chunk: usize = flags.get("chunk-rows", 1usize << 16)?;
    if chunk == 0 {
        return Err(err("--chunk-rows must be >= 1"));
    }
    let arena = ScratchArena::new(1);
    let rows = convert_to_bin(&src, output, chunk, &arena).map_err(|e| err(e.to_string()))?;
    // hash the written file, not the input source: the printed id is
    // exactly what `hiref serve` computes when this .bin is registered
    let written = BinFileSource::open(output, src.dim()).map_err(|e| err(e.to_string()))?;
    let hash = crate::data::stream::content_hash_hex(&written, chunk, &arena)
        .map_err(|e| err(e.to_string()))?;
    println!(
        "wrote {output}: {rows} rows × {} dims ({}), content hash {hash}",
        src.dim(),
        metrics::human_bytes(rows * src.dim() * 4)
    );
    Ok(())
}

/// `hiref serve --listen 127.0.0.1:7878 [...]` — run the alignment
/// service until a client sends the `shutdown` verb (which drains
/// in-flight work).  Solver flags (`--cost`, `--max-rank`, …) configure
/// the shared solver; see `docs/serve.md` for the wire protocol.
fn cmd_serve(flags: &Flags) -> Result<()> {
    use std::time::Duration;
    let solver = config_from_flags(flags)?;
    let workers = flags.get("workers", 2usize)?;
    let cfg = crate::serve::ServeConfig {
        listen: flags.get_str("listen", "127.0.0.1:7878"),
        workers,
        queue_depth: flags.get("queue-depth", 32usize)?,
        session_budget: match flags.named.get("session-budget") {
            Some(v) => parse_bytes(v)?,
            None => 256 << 20,
        },
        session_spill_dir: flags.named.get("session-spill-dir").map(PathBuf::from),
        micro_window: Duration::from_millis(flags.get("microbatch-window-ms", 2u64)?),
        solver,
    };
    let handle = crate::serve::serve(cfg)?;
    println!(
        "hiref serve listening on {} ({workers} workers; send {{\"verb\":\"shutdown\"}} to stop)",
        handle.addr()
    );
    handle.wait();
    println!("hiref serve: drained and stopped");
    Ok(())
}

fn cmd_solvers() -> Result<()> {
    let reg = api::SolverRegistry::with_defaults();
    let mut table = Table::new(vec!["Name", "Description"]);
    for s in reg.iter() {
        table.row(vec![s.name().to_string(), s.describe().to_string()]);
    }
    table.print();
    println!(
        "\nlinalg kernels: {} (override with HIREF_KERNELS=scalar|avx2|neon)",
        crate::linalg::kernels::active().as_str()
    );
    println!(
        "factor storage: --factor-precision f32|bf16|f16 [f32] — bf16/f16 \
         store HiRef's factor working copies at half width (f32 compute; \
         see docs/precision.md)"
    );
    println!("\nUse any name with `hiref align --solver <name>` or");
    println!("`hiref compare --solvers a,b,c`.");
    Ok(())
}

fn cmd_schedule(flags: &Flags) -> Result<()> {
    let n: usize = flags.get("n", 1 << 20)?;
    let base: usize = flags.get("base-size", 256)?;
    let max_rank: usize = flags.get("max-rank", 16)?;
    let depth = match flags.named.get("depth") {
        None => None,
        Some(d) => Some(
            d.parse::<usize>()
                .map_err(|_| err(format!("could not parse --depth {d}")))?,
        ),
    };
    let sched = annealing::optimal_rank_schedule(n, base, max_rank, depth);
    println!("n = {n}, base = {base}, max_rank = {max_rank}");
    println!("schedule         = {sched:?}");
    println!("effective ranks  = {:?}", annealing::effective_ranks(&sched));
    println!("LROT-call proxy  = {}", annealing::schedule_cost(&sched));
    Ok(())
}

fn cmd_buckets(flags: &Flags) -> Result<()> {
    let dir = PathBuf::from(flags.get_str("artifacts", "artifacts"));
    // manifest introspection works in stub builds too; only execution
    // needs the `pjrt` feature
    let buckets = crate::runtime::load_manifest(&dir)?;
    let mut table = Table::new(vec!["s", "r", "k", "outer", "inner", "path"]);
    for b in &buckets {
        table.row(vec![
            b.s.to_string(),
            b.r.to_string(),
            b.k.to_string(),
            b.outer.to_string(),
            b.inner.to_string(),
            b.path.file_name().unwrap().to_string_lossy().into_owned(),
        ]);
    }
    table.print();
    Ok(())
}

fn print_usage() {
    println!(
        "hiref — Hierarchical Refinement OT (ICML 2025 reproduction)

USAGE: hiref <command> [flags]

COMMANDS
  align     run one solver on a dataset and report cost/stats
  compare   run several solvers on a dataset through the uniform API
  convert   re-encode a dataset (.npy or raw) as raw LE-f32 .bin and
            print its content hash (the serve dataset id)
            (--input a.npy --output a.bin [--dim d] [--chunk-rows n])
  serve     run the alignment service (NDJSON over TCP; warm factor
            sessions + cross-request microbatching — see docs/serve.md)
            (--listen addr [--workers n] [--queue-depth n]
             [--session-budget n] [--session-spill-dir d]
             [--microbatch-window-ms n] + solver flags)
  solvers   list the registered solvers (HiRef + all paper baselines)
  schedule  print the optimal rank-annealing schedule for given n
  buckets   list AOT artifact buckets (artifacts/manifest.tsv)
  help      this message

COMMON FLAGS
  --solver hiref|sinkhorn|progot|minibatch|mop|lrot|exact   [hiref]
  --solvers a,b,c       solver list for `compare`  [hiref,minibatch,mop]
  --dataset checkerboard|maf|halfmoon|imagenet-sim|merfish-sim
  --n <int>             dataset size                 [1024]
  --cost sq|euclid      ground cost                  [sq]
  --backend auto|native|pjrt                         [auto]
  --batching on|off     level-synchronous batched execution (off =
                        per-block work-queue path, for A/B)      [on]
  --warmstart-levels <int>  cluster-warmstart the top k scales (coarse
                        co-clustering without LROT + warm-started
                        descent below — see docs/warmstart.md)   [0]
  --factor-precision f32|bf16|f16   stored factor element format (bf16/
                        f16 halve factor RAM/spill bytes; f32 compute
                        throughout — see docs/precision.md)      [f32]
  --max-rank <int>      annealing max rank C         [16]
  --base-size <int>     exact base-case block Q      [256]
  --hungarian-cutoff <int>  Hungarian/auction crossover (≤ base-size)
  --chunk-rows <int>    on `align`: route HiRef through the streaming
                        ingestion path with this tile size     [65536]
  --spill-dir <dir>     spill the factor working copies to scratch files
                        under <dir> (bit-identical output; only O(n)
                        permutations stay resident)
  --spill-budget <n>    resident spill-cache cap in bytes (k/m/g
                        suffixes; needs --spill-dir)           [256m]
  --depth <int>         cap hierarchy depth
  --seed <int>                                       [0]
  --threads <int>                                    [all cores]
  --artifacts <dir>                                  [artifacts]"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(args: &[&str]) -> Flags {
        Flags::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parse_flags_and_positionals() {
        let f = flags(&["pos1", "--n", "42", "--cost=euclid", "pos2"]);
        assert_eq!(f.positional, vec!["pos1", "pos2"]);
        assert_eq!(f.get::<usize>("n", 0).unwrap(), 42);
        assert_eq!(f.get_str("cost", ""), "euclid");
    }

    #[test]
    fn missing_value_errors() {
        let args = vec!["--n".to_string()];
        assert!(Flags::parse(&args).is_err());
    }

    #[test]
    fn config_from_flags_defaults() {
        let f = flags(&[]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.cost, CostKind::SqEuclidean);
        assert_eq!(cfg.backend, BackendKind::Auto);
    }

    #[test]
    fn config_rejects_bad_cost_listing_choices() {
        let f = flags(&["--cost", "manhattan"]);
        let e = config_from_flags(&f).unwrap_err();
        assert!(e.0.contains("valid values"), "{e}");
        assert!(e.0.contains("euclid"), "{e}");
    }

    #[test]
    fn bad_backend_lists_choices() {
        let f = flags(&["--backend", "cuda"]);
        let e = config_from_flags(&f).unwrap_err();
        assert!(e.0.contains("auto|native|pjrt"), "{e}");
    }

    #[test]
    fn batching_flag_reaches_config() {
        assert!(config_from_flags(&flags(&[])).unwrap().batching);
        assert!(config_from_flags(&flags(&["--batching", "on"])).unwrap().batching);
        assert!(!config_from_flags(&flags(&["--batching", "off"])).unwrap().batching);
        let e = config_from_flags(&flags(&["--batching", "maybe"])).unwrap_err();
        assert!(e.0.contains("on|off"), "{e}");
    }

    #[test]
    fn bad_solver_lists_choices() {
        let f = flags(&["--solver", "simplex"]);
        let cfg = HiRefConfig::default();
        let e = solver_from_flags(&f, &cfg).unwrap_err();
        assert!(e.0.contains("hiref"), "{e}");
        assert!(e.0.contains("sinkhorn"), "{e}");
    }

    #[test]
    fn solver_flag_selects_registry_entry() {
        let cfg = HiRefConfig::default();
        let f = flags(&["--solver", "minibatch"]);
        assert_eq!(solver_from_flags(&f, &cfg).unwrap().name(), "minibatch");
        let f = flags(&[]);
        assert_eq!(solver_from_flags(&f, &cfg).unwrap().name(), "hiref");
    }

    #[test]
    fn solver_aliases_and_case_resolve_uniformly() {
        // `align --solver` and `compare --solvers` share named_solver, so
        // aliases and case variants behave identically in both
        let mut cfg = HiRefConfig::default();
        cfg.base_size = 32;
        cfg.hungarian_cutoff = 32;
        assert_eq!(named_solver("mb", &cfg).unwrap().name(), "minibatch");
        assert_eq!(named_solver("frlc", &cfg).unwrap().name(), "lrot");
        // a case-variant HiRef still picks up the HiRef flags
        let s = named_solver("HiRef", &cfg).unwrap();
        assert_eq!(s.name(), "hiref");
    }

    #[test]
    fn small_base_size_clamps_default_cutoff() {
        let f = flags(&["--base-size", "64"]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.base_size, 64);
        assert!(cfg.hungarian_cutoff <= 64);
        // but an explicit oversized cutoff is rejected
        let f = flags(&["--base-size", "64", "--hungarian-cutoff", "128"]);
        assert!(config_from_flags(&f).is_err());
    }

    #[test]
    fn chunk_rows_rejected_for_non_hiref_solvers() {
        // silently ignoring the flag would let users believe they
        // benchmarked the streaming path — reject the combination
        let f = flags(&["--solver", "sinkhorn", "--chunk-rows", "64", "--n", "16"]);
        let e = cmd_align(&f).unwrap_err();
        assert!(e.0.contains("chunk-rows"), "{e}");
        assert!(e.0.contains("sinkhorn"), "{e}");
    }

    #[test]
    fn chunk_rows_flag_reaches_config() {
        let f = flags(&["--chunk-rows", "4096"]);
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.chunk_rows, 4096);
        // zero is rejected by the builder
        let f = flags(&["--chunk-rows", "0"]);
        assert!(config_from_flags(&f).is_err());
        // default when absent
        let cfg = config_from_flags(&flags(&[])).unwrap();
        assert_eq!(cfg.chunk_rows, HiRefConfig::default().chunk_rows);
    }

    #[test]
    fn parse_bytes_accepts_suffixes() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("64M").unwrap(), 64 << 20);
        assert_eq!(parse_bytes("1g").unwrap(), 1 << 30);
        // uppercase suffixes are equivalent to lowercase
        assert_eq!(parse_bytes("2K").unwrap(), 2 << 10);
        assert_eq!(parse_bytes("3G").unwrap(), 3usize << 30);
        assert!(parse_bytes("lots").is_err());
        assert!(parse_bytes("12q").is_err());
    }

    #[test]
    fn parse_bytes_rejects_overflow_as_invalid_config() {
        // u64::MAX + 1 as a bare digit string, and a suffixed count whose
        // product overflows: both must be the typed InvalidConfig error,
        // not a wrapped value or a generic parse failure
        for v in ["18446744073709551616", "20000000000g", "999999999999999999999999999999999"] {
            let e = parse_bytes(v).unwrap_err();
            assert!(e.0.contains("invalid configuration"), "{v}: {e}");
            assert!(e.0.contains("overflows"), "{v}: {e}");
        }
        // the largest representable count still parses
        if usize::MAX as u128 >= u64::MAX as u128 {
            assert_eq!(parse_bytes("18446744073709551615").unwrap(), u64::MAX as usize);
        }
    }

    #[test]
    fn spill_flags_reach_config_and_are_validated() {
        let cfg = config_from_flags(&flags(&["--spill-dir", "/tmp/sp", "--spill-budget", "2m"]))
            .unwrap();
        let sc = cfg.spill.unwrap();
        assert_eq!(sc.dir, PathBuf::from("/tmp/sp"));
        assert_eq!(sc.budget_bytes, 2 << 20);
        // dir alone: default budget
        let cfg = config_from_flags(&flags(&["--spill-dir", "/tmp/sp"])).unwrap();
        assert!(cfg.spill.unwrap().budget_bytes > 0);
        // budget without dir is inconsistent
        assert!(config_from_flags(&flags(&["--spill-budget", "1m"])).is_err());
        // no flags: resident
        assert!(config_from_flags(&flags(&[])).unwrap().spill.is_none());
    }

    #[test]
    fn spill_flags_rejected_for_non_hiref_solvers() {
        let f = flags(&["--solver", "sinkhorn", "--spill-dir", "/tmp/sp", "--n", "16"]);
        let e = cmd_align(&f).unwrap_err();
        assert!(e.0.contains("spill"), "{e}");
        assert!(e.0.contains("sinkhorn"), "{e}");
        let f = flags(&["--solver", "exact", "--spill-budget", "1m", "--n", "16"]);
        let e = cmd_align(&f).unwrap_err();
        assert!(e.0.contains("spill"), "{e}");
        // compare: rejected only when no hiref solver is in the list
        let f = flags(&["--solvers", "sinkhorn,mop", "--spill-dir", "/tmp/sp", "--n", "16"]);
        let e = cmd_compare(&f).unwrap_err();
        assert!(e.0.contains("spill"), "{e}");
    }

    #[test]
    fn convert_requires_input_output_and_dim_consistency() {
        assert!(cmd_convert(&flags(&[])).is_err());
        assert!(cmd_convert(&flags(&["--input", "a.bin"])).is_err());
        // raw input without --dim is rejected
        let e = cmd_convert(&flags(&["--input", "a.bin", "--output", "b.bin"])).unwrap_err();
        assert!(e.0.contains("--dim"), "{e}");
    }

    #[test]
    fn convert_round_trips_a_real_npy_file() {
        use crate::data::stream::{write_bin, BinFileSource, DatasetSource};
        // build a raw .bin, convert it (raw → raw exercises the same
        // driver), and verify the row/dim report
        let dir = std::env::temp_dir();
        let src_path = dir.join(format!("hiref_cli_conv_{}.bin", std::process::id()));
        let dst_path = dir.join(format!("hiref_cli_conv_out_{}.bin", std::process::id()));
        let mut m = crate::linalg::Mat::zeros(11, 3);
        crate::prng::Rng::new(1).fill_normal(&mut m.data);
        write_bin(&src_path, &m).unwrap();
        cmd_convert(&flags(&[
            "--input",
            src_path.to_str().unwrap(),
            "--output",
            dst_path.to_str().unwrap(),
            "--dim",
            "3",
            "--chunk-rows",
            "4",
        ]))
        .unwrap();
        let out = BinFileSource::open(&dst_path, 3).unwrap();
        assert_eq!(out.rows(), 11);
        let _ = std::fs::remove_file(&src_path);
        let _ = std::fs::remove_file(&dst_path);
    }

    #[test]
    fn unknown_dataset_lists_choices() {
        let f = flags(&["--dataset", "mnist"]);
        let e = dataset_from_flags(&f).unwrap_err();
        assert!(e.0.contains("merfish-sim"), "{e}");
    }

    #[test]
    fn dataset_parsing() {
        let f = flags(&["--dataset", "checkerboard", "--n", "64"]);
        let (x, y) = dataset_from_flags(&f).unwrap();
        assert_eq!(x.rows, 64);
        assert_eq!(y.rows, 64);
    }

    #[test]
    fn advertised_choices_all_parse() {
        // drift guard: every spelling listed in an error message must be
        // accepted by the corresponding parser
        for c in COST_CHOICES {
            assert!(parse_cost(c).is_ok(), "listed --cost {c} rejected");
        }
        for d in DATASET_CHOICES {
            let f = flags(&["--dataset", d, "--n", "16"]);
            assert!(dataset_from_flags(&f).is_ok(), "listed --dataset {d} rejected");
        }
        for s in crate::api::SOLVER_NAMES {
            assert!(
                named_solver(s, &HiRefConfig::default()).is_ok(),
                "listed --solver {s} rejected"
            );
        }
        for p in PRECISION_CHOICES {
            assert!(
                Precision::parse(p).is_some(),
                "listed --factor-precision {p} rejected"
            );
        }
    }

    #[test]
    fn warmstart_flag_reaches_config() {
        let f = flags(&["--warmstart-levels", "2"]);
        assert_eq!(config_from_flags(&f).unwrap().warmstart_levels, 2);
        // absent: the exact path
        assert_eq!(config_from_flags(&flags(&[])).unwrap().warmstart_levels, 0);
        assert!(config_from_flags(&flags(&["--warmstart-levels", "two"])).is_err());
    }

    #[test]
    fn factor_precision_flag_reaches_config() {
        let f = flags(&["--factor-precision", "bf16"]);
        assert_eq!(config_from_flags(&f).unwrap().factor_precision, Precision::Bf16);
        // default stays f32; junk is rejected with the valid list
        assert_eq!(config_from_flags(&flags(&[])).unwrap().factor_precision, Precision::F32);
        let e = config_from_flags(&flags(&["--factor-precision", "f64"])).unwrap_err();
        assert!(e.to_string().contains("bf16"), "{e}");
    }
}
