//! PJRT runtime: loads the AOT-compiled LROT artifacts and serves them to
//! the coordinator.
//!
//! The build path is `make artifacts` → `python/compile/aot.py` lowers the
//! L2 model (with L1 Pallas kernels inlined) to HLO **text** per shape
//! bucket, listed in `artifacts/manifest.tsv`.  Here we
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute` — the exact pattern of
//! /opt/xla-example/load_hlo, multiplexed over buckets.
//!
//! The `xla` crate's client wraps an `Rc`, so it is confined to a single
//! **service thread**; callers talk to it through an mpsc channel.  That
//! serialises submissions, but PJRT's CPU backend parallelises each
//! execution internally, and HiRef's fan-out keeps the native backend
//! saturated with the many small blocks while the service thread handles
//! the large ones — see EXPERIMENTS.md §Perf.
//!
//! A sub-problem of `active ≤ s` points runs on bucket `(s, r, k)` by
//! padding: phantom rows get log-mass `NEG` (they receive exactly zero
//! coupling mass — see `python/tests/test_model.py`) and factor columns
//! are zero-padded (exact for inner products).
//!
//! **Feature gating:** the `xla` crate only exists in artifact-enabled
//! environments, so all execution paths live behind the `pjrt` cargo
//! feature.  The default build compiles a stub whose [`PjrtEngine::load`]
//! fails with a descriptive [`SolveError::Backend`]; `BackendKind::Auto`
//! then degrades to the native LROT solver, and `BackendKind::Pjrt`
//! surfaces a typed error at align time.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use crate::api::SolveError;
use crate::linalg::{BatchView, Mat, MatView};

/// Runtime failures are [`SolveError::Backend`] — one typed error enum
/// across the whole solver stack.
pub type Result<T> = std::result::Result<T, SolveError>;

fn rerr(msg: impl Into<String>) -> SolveError {
    SolveError::Backend(msg.into())
}

/// One AOT bucket from the manifest.
#[derive(Clone, Debug)]
pub struct BucketSpec {
    pub s: usize,
    pub r: usize,
    pub k: usize,
    pub outer: usize,
    pub inner: usize,
    pub gamma: f32,
    pub tau: f32,
    pub path: PathBuf,
}

#[allow(dead_code)] // Lrot is only constructed by the pjrt-gated submit path
enum Request {
    Lrot {
        bucket: usize,
        /// Flat f32 inputs in artifact order: U, V, loga, logb, noise_q, noise_r.
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    Shutdown,
}

/// Handle to the PJRT service thread.  Cheap to share behind an `Arc`.
pub struct PjrtEngine {
    buckets: Vec<BucketSpec>,
    tx: Mutex<mpsc::Sender<Request>>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    executions: AtomicUsize,
}

/// Parse `manifest.tsv` in `dir` into bucket specs without starting any
/// execution backend — works in stub builds too (CLI `buckets`, reports).
pub fn load_manifest(dir: &Path) -> Result<Vec<BucketSpec>> {
    parse_manifest(dir)
}

/// Parse `manifest.tsv` in `dir` into bucket specs.
fn parse_manifest(dir: &Path) -> Result<Vec<BucketSpec>> {
    let manifest = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&manifest)
        .map_err(|e| rerr(format!("reading {}: {e}", manifest.display())))?;
    fn field<T: std::str::FromStr>(cols: &[&str], idx: usize, ln: usize) -> Result<T> {
        cols[idx]
            .parse::<T>()
            .map_err(|_| rerr(format!("manifest line {ln}: bad field {:?}", cols[idx])))
    }
    let mut buckets = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 8 {
            return Err(rerr(format!("manifest line {} malformed: {line}", ln + 1)));
        }
        buckets.push(BucketSpec {
            s: field(&cols, 0, ln + 1)?,
            r: field(&cols, 1, ln + 1)?,
            k: field(&cols, 2, ln + 1)?,
            outer: field(&cols, 3, ln + 1)?,
            inner: field(&cols, 4, ln + 1)?,
            gamma: field(&cols, 5, ln + 1)?,
            tau: field(&cols, 6, ln + 1)?,
            path: dir.join(cols[7]),
        });
    }
    if buckets.is_empty() {
        return Err(rerr(format!("manifest {} lists no buckets", manifest.display())));
    }
    Ok(buckets)
}

impl PjrtEngine {
    /// Parse `manifest.tsv` in `dir` and start the service thread.
    /// Executables compile lazily on first use of each bucket.
    ///
    /// Without the `pjrt` feature this always fails (the stub runtime has
    /// nothing to execute artifacts with); `BackendKind::Auto` callers
    /// degrade to the native solver.
    pub fn load(dir: &Path) -> Result<PjrtEngine> {
        let buckets = parse_manifest(dir)?;
        #[cfg(not(feature = "pjrt"))]
        {
            return Err(rerr(format!(
                "built without the `pjrt` feature: cannot execute the {} artifact bucket(s) in {} \
                 (rebuild with `--features pjrt` and the `xla` dependency)",
                buckets.len(),
                dir.display()
            )));
        }
        #[cfg(feature = "pjrt")]
        {
            let specs = buckets.clone();
            let (tx, rx) = mpsc::channel::<Request>();
            let worker = std::thread::Builder::new()
                .name("pjrt-service".into())
                .spawn(move || service_loop(specs, rx))
                .map_err(|e| rerr(format!("spawning pjrt service thread: {e}")))?;
            Ok(PjrtEngine {
                buckets,
                tx: Mutex::new(tx),
                worker: Mutex::new(Some(worker)),
                executions: AtomicUsize::new(0),
            })
        }
    }

    /// All buckets (for CLI/report introspection).
    pub fn buckets(&self) -> &[BucketSpec] {
        &self.buckets
    }

    /// Number of executions served so far.
    pub fn executions(&self) -> usize {
        self.executions.load(Ordering::Relaxed)
    }

    /// Smallest bucket that fits `(active, rank, k)`; `None` if the grid
    /// has no match (the coordinator then falls back to the native
    /// solver).  A bucket "fits" if `s ≥ active`, `r == rank`, `k ≥ width`
    /// — and wastes less than 4× padding (otherwise native is faster).
    pub fn find_bucket(&self, active: usize, rank: usize, width: usize) -> Option<usize> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| b.s >= active && b.r == rank && b.k >= width)
            .filter(|(_, b)| b.s <= active.saturating_mul(4).max(256))
            .min_by_key(|(_, b)| (b.s, b.k))
            .map(|(i, _)| i)
    }

    /// Solve an LROT sub-problem on the AOT path.  `u`/`v` are the cost
    /// factors restricted to this co-cluster (`active_x`/`active_y` rows),
    /// passed as borrowed [`MatView`]s — the coordinator slices its
    /// contiguous working buffers, so no factor rows are copied to get
    /// here (padding into the bucket shape below is the first copy).
    /// Returns `Ok(None)` when no bucket fits (always, in stub builds).
    #[cfg_attr(not(feature = "pjrt"), allow(unused_variables))]
    pub fn lrot<'a, 'b>(
        &self,
        u: impl Into<MatView<'a>>,
        v: impl Into<MatView<'b>>,
        active_x: usize,
        active_y: usize,
        rank: usize,
        seed: u64,
    ) -> Result<Option<(Mat, Mat)>> {
        let (u, v) = (u.into(), v.into());
        debug_assert_eq!(u.cols, v.cols);
        #[cfg(not(feature = "pjrt"))]
        {
            return Ok(None);
        }
        #[cfg(feature = "pjrt")]
        {
            let active = active_x.max(active_y);
            let Some(bi) = self.find_bucket(active, rank, u.cols) else {
                return Ok(None);
            };
            let b = &self.buckets[bi];
            let (s, k, r) = (b.s, b.k, b.r);

            // --- pad inputs into bucket shape --------------------------------
            let pad_mat = |m: MatView<'_>, rows: usize| -> Vec<f32> {
                let mut out = vec![0.0f32; s * k];
                for i in 0..rows {
                    out[i * k..i * k + m.cols].copy_from_slice(m.row(i));
                }
                out
            };
            let neg = crate::solvers::lrot::NEG;
            let log_marg = |active: usize| -> Vec<f32> {
                let la = -(active as f32).ln();
                (0..s).map(|i| if i < active { la } else { neg }).collect()
            };
            let mut rng = crate::prng::Rng::new(seed ^ 0xA07);
            let mut noise_q = vec![0.0f32; s * r];
            let mut noise_r = vec![0.0f32; s * r];
            rng.fill_normal(&mut noise_q);
            rng.fill_normal(&mut noise_r);

            let inputs = vec![
                pad_mat(u, active_x),
                pad_mat(v, active_y),
                log_marg(active_x),
                log_marg(active_y),
                noise_q,
                noise_r,
            ];

            let (reply_tx, reply_rx) = mpsc::channel();
            {
                let tx = self.tx.lock().unwrap();
                tx.send(Request::Lrot { bucket: bi, inputs, reply: reply_tx })
                    .map_err(|_| rerr("pjrt service thread died"))?;
            }
            let (qf, rf) = reply_rx
                .recv()
                .map_err(|_| rerr("pjrt service dropped reply"))??;
            self.executions.fetch_add(1, Ordering::Relaxed);

            // --- trim to active rows ------------------------------------------
            let trim = |flat: Vec<f32>, rows: usize| -> Mat {
                let mut m = Mat::zeros(rows, r);
                for i in 0..rows {
                    m.row_mut(i).copy_from_slice(&flat[i * r..(i + 1) * r]);
                }
                m
            };
            Ok(Some((trim(qf, active_x), trim(rf, active_y))))
        }
    }

    /// Batched twin of [`PjrtEngine::lrot`], matching the native
    /// [`crate::solvers::lrot::solve_factored_batch`] signature shape:
    /// lane `l` is the factor pair `(u.item(l), v.item(l))` with actives
    /// `active[l]` and seed `seeds[l]`.  Dispatch is **all-or-nothing at
    /// batch granularity**: the bucket is resolved once for the batch's
    /// shape (the level scheduler groups same-shape blocks), and
    /// `Ok(None)` means the whole batch should run on the native backend
    /// — no partially-PJRT levels (always the case in stub builds).
    #[cfg_attr(not(feature = "pjrt"), allow(unused_variables))]
    pub fn lrot_batch(
        &self,
        u: BatchView<'_>,
        v: BatchView<'_>,
        active: &[(usize, usize)],
        rank: usize,
        seeds: &[u64],
    ) -> Result<Option<Vec<(Mat, Mat)>>> {
        debug_assert_eq!(u.len(), v.len());
        debug_assert_eq!(u.len(), active.len());
        debug_assert_eq!(u.len(), seeds.len());
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(None)
        }
        #[cfg(feature = "pjrt")]
        {
            if u.is_empty() {
                return Ok(Some(Vec::new()));
            }
            // resolve the bucket once for the whole batch before doing any
            // work: the widest lane decides, and one miss sends the whole
            // level group to the native solver.
            let widest = active
                .iter()
                .map(|&(ax, ay)| ax.max(ay))
                .max()
                .unwrap_or(0);
            let width = u.items.iter().map(|it| it.cols).max().unwrap_or(0);
            if self.find_bucket(widest, rank, width).is_none() {
                return Ok(None);
            }
            let mut outs = Vec::with_capacity(u.len());
            for l in 0..u.len() {
                match self.lrot(u.item(l), v.item(l), active[l].0, active[l].1, rank, seeds[l])? {
                    Some(qr) => outs.push(qr),
                    // a narrower lane missing its bucket would leave the
                    // batch half-solved; treat it as a whole-batch miss
                    None => return Ok(None),
                }
            }
            Ok(Some(outs))
        }
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// The service loop owns the (non-Send) PJRT client and compiled
/// executables; it runs until `Shutdown` or channel closure.
#[cfg(feature = "pjrt")]
fn service_loop(specs: Vec<BucketSpec>, rx: mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Drain requests with errors so callers fall back to native.
            for req in rx.iter() {
                if let Request::Lrot { reply, .. } = req {
                    let _ = reply.send(Err(rerr(format!("PJRT client failed: {e}"))));
                }
            }
            return;
        }
    };
    let mut compiled: std::collections::HashMap<usize, xla::PjRtLoadedExecutable> =
        std::collections::HashMap::new();

    for req in rx.iter() {
        match req {
            Request::Shutdown => break,
            Request::Lrot { bucket, inputs, reply } => {
                let result = serve_one(&client, &specs, &mut compiled, bucket, inputs);
                let _ = reply.send(result);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn serve_one(
    client: &xla::PjRtClient,
    specs: &[BucketSpec],
    compiled: &mut std::collections::HashMap<usize, xla::PjRtLoadedExecutable>,
    bucket: usize,
    inputs: Vec<Vec<f32>>,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let spec = &specs[bucket];
    if !compiled.contains_key(&bucket) {
        let path = spec
            .path
            .to_str()
            .ok_or_else(|| rerr("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| rerr(format!("parsing {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| rerr(format!("compiling {path}: {e}")))?;
        compiled.insert(bucket, exe);
    }
    let exe = compiled.get(&bucket).unwrap();

    let (s, k, r) = (spec.s as i64, spec.k as i64, spec.r as i64);
    let shapes: [[i64; 2]; 6] =
        [[s, k], [s, k], [s, 1], [s, 1], [s, r], [s, r]];
    let mut literals = Vec::with_capacity(6);
    for (buf, shape) in inputs.iter().zip(&shapes) {
        let lit = xla::Literal::vec1(buf);
        let lit = if shape[1] == 1 {
            lit // 1-D parameter: keep vector shape
        } else {
            lit.reshape(&[shape[0], shape[1]])
                .map_err(|e| rerr(format!("reshape: {e}")))?
        };
        literals.push(lit);
    }
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| rerr(format!("execute: {e}")))?[0][0]
        .to_literal_sync()
        .map_err(|e| rerr(format!("to_literal: {e}")))?;
    let (ql, rl) = result
        .to_tuple2()
        .map_err(|e| rerr(format!("expected 2-tuple output: {e}")))?;
    let qf = ql.to_vec::<f32>().map_err(|e| rerr(format!("q to_vec: {e}")))?;
    let rf = rl.to_vec::<f32>().map_err(|e| rerr(format!("r to_vec: {e}")))?;
    Ok((qf, rf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_bucket_prefers_smallest_fit() {
        let engine = PjrtEngine {
            buckets: vec![
                BucketSpec { s: 256, r: 2, k: 4, outer: 1, inner: 1, gamma: 1.0, tau: 0.0, path: "a".into() },
                BucketSpec { s: 1024, r: 2, k: 4, outer: 1, inner: 1, gamma: 1.0, tau: 0.0, path: "b".into() },
                BucketSpec { s: 1024, r: 8, k: 4, outer: 1, inner: 1, gamma: 1.0, tau: 0.0, path: "c".into() },
            ],
            tx: Mutex::new(mpsc::channel().0),
            worker: Mutex::new(None),
            executions: AtomicUsize::new(0),
        };
        assert_eq!(engine.find_bucket(200, 2, 4), Some(0));
        assert_eq!(engine.find_bucket(300, 2, 4), Some(1));
        assert_eq!(engine.find_bucket(300, 8, 4), Some(2));
        assert_eq!(engine.find_bucket(300, 16, 4), None);
        // padding waste > 4x rejected
        assert_eq!(engine.find_bucket(10, 8, 4), None);
        // width larger than bucket rejected
        assert_eq!(engine.find_bucket(300, 2, 64), None);
    }

    #[test]
    fn missing_manifest_is_a_typed_error() {
        let err = PjrtEngine::load(Path::new("definitely/not/a/dir")).unwrap_err();
        assert!(err.to_string().contains("manifest.tsv"), "{err}");
    }

    #[test]
    fn stub_lrot_batch_defers_to_native() {
        // without the pjrt feature, batch dispatch must report "no bucket"
        // so the coordinator runs the whole batch on the native solver
        let engine = PjrtEngine {
            buckets: vec![BucketSpec {
                s: 256,
                r: 2,
                k: 4,
                outer: 1,
                inner: 1,
                gamma: 1.0,
                tau: 0.0,
                path: "a".into(),
            }],
            tx: Mutex::new(mpsc::channel().0),
            worker: Mutex::new(None),
            executions: AtomicUsize::new(0),
        };
        let data = vec![0.0f32; 16];
        let items = [crate::linalg::BatchItem::new(0..4, 4)];
        let u = BatchView::new(&data, &items);
        let got = engine.lrot_batch(u, u, &[(4, 4)], 2, &[7]).unwrap();
        #[cfg(not(feature = "pjrt"))]
        assert!(got.is_none());
        #[cfg(feature = "pjrt")]
        let _ = got; // execution-path coverage lives in tests/runtime_pjrt.rs
    }
}
