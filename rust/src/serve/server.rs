//! The `hiref serve` daemon: TCP accept loop, per-connection NDJSON
//! dispatch, and the solve job that ties sessions, scheduling, and
//! microbatching together.
//!
//! One thread per connection reads requests and writes replies in
//! request order; solve work itself runs on the bounded [`Scheduler`]
//! pool, so connection count does not set CPU concurrency.  Graceful
//! shutdown (`shutdown` verb or [`ServerHandle::shutdown`]) stops
//! admission, drains everything already admitted, then half-closes every
//! connection's *read* side — blocked readers wake with EOF while replies
//! still in flight go out on the intact write side.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::ServeMetrics;
use super::protocol::{self, Json};
use super::scheduler::{JobHooks, Microbatcher, Rejected, Scheduler};
use super::session::{DatasetEntry, DatasetRegistry, SessionCache};
use crate::api::SolveError;
use crate::coordinator::hiref::{HiRef, HiRefConfig};
use crate::costs::{self, CostKind};
use crate::data::stream::BinFileSource;
use crate::linalg::Mat;
use crate::pool::ScratchArena;

/// Everything `hiref serve` needs to run.
pub struct ServeConfig {
    /// Bind address (`127.0.0.1:0` picks a free port; see
    /// [`ServerHandle::addr`]).
    pub listen: String,
    /// Solver configuration shared by every request (must have
    /// `batching` enabled — the microbatcher intercepts the batched
    /// dispatch path).
    pub solver: HiRefConfig,
    /// Worker threads executing solves.
    pub workers: usize,
    /// Admitted-but-not-started solves allowed before requests are
    /// refused with a typed `overloaded` reply.
    pub queue_depth: usize,
    /// Byte budget for warm session factor archives (LRU beyond it).
    pub session_budget: usize,
    /// Archive factors in spill files under this directory instead of
    /// resident memory.
    pub session_spill_dir: Option<PathBuf>,
    /// Cross-request microbatch collection window (zero disables
    /// merging; every batch then solves solo, still bit-identically).
    pub micro_window: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            listen: "127.0.0.1:0".to_string(),
            solver: HiRefConfig::default(),
            workers: 2,
            queue_depth: 32,
            session_budget: 256 << 20,
            session_spill_dir: None,
            micro_window: Duration::from_millis(2),
        }
    }
}

/// A finished solve, as handed from the worker back to the connection
/// thread that owns the reply.
struct SolveDone {
    perm: Vec<u32>,
    warm: bool,
    elapsed_ms: f64,
}

/// One-shot reply slot: the worker fills it, the connection thread waits.
#[derive(Default)]
struct ReplySlot {
    state: Mutex<Option<Result<SolveDone, SolveError>>>,
    cv: Condvar,
}

impl ReplySlot {
    fn fill(&self, r: Result<SolveDone, SolveError>) {
        *self.state.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }

    fn take(&self) -> Result<SolveDone, SolveError> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.take() {
                return r;
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

/// Shared state of one serve instance.
pub struct Server {
    solver_cfg: HiRefConfig,
    registry: DatasetRegistry,
    sessions: SessionCache,
    micro: Arc<Microbatcher>,
    sched: Arc<Scheduler>,
    metrics: Arc<ServeMetrics>,
    stopping: AtomicBool,
    addr: SocketAddr,
    /// Read-half handles of live connections, for shutdown wakeup.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
    arena: ScratchArena,
}

/// Handle to a running server: its bound address plus the accept/worker
/// threads to join on exit.
pub struct ServerHandle {
    server: Arc<Server>,
    accept: Option<JoinHandle<()>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// Bind and start serving; returns once the listener is live.
pub fn serve(cfg: ServeConfig) -> Result<ServerHandle, SolveError> {
    if !cfg.solver.batching {
        return Err(SolveError::InvalidConfig(
            "serve requires the level-synchronous batched execution path (batching = true)".into(),
        ));
    }
    if cfg.solver.record_scales {
        return Err(SolveError::InvalidConfig(
            "record_scales retains O(n log n) diagnostics per request; disable it for serving"
                .into(),
        ));
    }
    let listener = TcpListener::bind(&cfg.listen)
        .map_err(|e| SolveError::Backend(format!("bind {}: {e}", cfg.listen)))?;
    let addr = listener.local_addr().map_err(SolveError::from)?;
    let metrics = Arc::new(ServeMetrics::default());
    let threads = cfg.solver.threads.max(1);
    let server = Arc::new(Server {
        registry: DatasetRegistry::new(cfg.solver.chunk_rows),
        sessions: SessionCache::new(
            cfg.session_budget,
            cfg.session_spill_dir.clone(),
            cfg.solver.factor_precision,
            Arc::clone(&metrics),
        ),
        micro: Arc::new(Microbatcher::new(cfg.micro_window, threads, Arc::clone(&metrics))),
        sched: Scheduler::new(cfg.workers, cfg.queue_depth, Arc::clone(&metrics)),
        metrics,
        stopping: AtomicBool::new(false),
        addr,
        conns: Mutex::new(HashMap::new()),
        next_conn: AtomicU64::new(0),
        arena: ScratchArena::new(threads),
        solver_cfg: cfg.solver,
    });
    let conn_handles = Arc::new(Mutex::new(Vec::new()));
    let accept = {
        let server = Arc::clone(&server);
        let handles = Arc::clone(&conn_handles);
        std::thread::Builder::new()
            .name("hiref-serve-accept".into())
            .spawn(move || server.accept_loop(listener, &handles))
            .expect("spawn accept thread")
    };
    Ok(ServerHandle { server, accept: Some(accept), conn_handles })
}

impl ServerHandle {
    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.server.addr
    }

    /// Service counters (same numbers as the `stats` verb).
    pub fn metrics(&self) -> &ServeMetrics {
        &self.server.metrics
    }

    /// Initiate graceful shutdown from the host side (equivalent to the
    /// `shutdown` protocol verb; idempotent).
    pub fn shutdown(&self) {
        self.server.shutdown();
    }

    /// Shut down (if not already) and join every server thread.
    pub fn join(self) {
        self.server.shutdown();
        self.wait();
    }

    /// Join every server thread **without** initiating shutdown — blocks
    /// until some client sends the `shutdown` verb (the `hiref serve`
    /// foreground mode).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conn_handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Server {
    fn accept_loop(self: Arc<Server>, listener: TcpListener, handles: &Mutex<Vec<JoinHandle<()>>>) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.stopping.load(Ordering::Acquire) {
                        return; // the shutdown wake-up connection
                    }
                    let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = stream.try_clone() {
                        self.conns.lock().unwrap().insert(id, clone);
                    }
                    let server = Arc::clone(&self);
                    let h = std::thread::Builder::new()
                        .name(format!("hiref-serve-conn-{id}"))
                        .spawn(move || {
                            server.handle_conn(stream);
                            server.conns.lock().unwrap().remove(&id);
                        })
                        .expect("spawn connection thread");
                    handles.lock().unwrap().push(h);
                }
                Err(_) => {
                    if self.stopping.load(Ordering::Acquire) {
                        return;
                    }
                }
            }
        }
    }

    /// Stop admission, drain admitted work, wake blocked readers.
    fn shutdown(&self) {
        if self.stopping.swap(true, Ordering::AcqRel) {
            return;
        }
        self.sched.drain();
        // half-close the read side of every connection: idle readers see
        // EOF, replies still being written go out untouched
        for s in self.conns.lock().unwrap().values() {
            let _ = s.shutdown(Shutdown::Read);
        }
        // wake the accept loop
        let _ = TcpStream::connect(self.addr);
    }

    fn handle_conn(self: &Arc<Server>, stream: TcpStream) {
        let mut reader = match stream.try_clone() {
            Ok(s) => BufReader::new(s),
            Err(_) => return,
        };
        let mut writer = stream;
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => return, // EOF or reset
                Ok(_) => {}
            }
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.dispatch(&line);
            if write_line(&mut writer, &reply).is_err() {
                return;
            }
        }
    }

    /// One request line in, one reply line out.
    fn dispatch(self: &Arc<Server>, line: &str) -> String {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let req = match protocol::parse(line) {
            Ok(v) => v,
            Err(e) => return protocol::reply_err(None, "bad_request", &e),
        };
        let id = req.get("id").cloned();
        let id = id.as_ref();
        match req.str_field("verb") {
            Some("ping") => protocol::reply_ok(id, vec![("pong".into(), Json::Bool(true))]),
            Some("register") => self.handle_register(id, &req),
            Some("solve") => self.handle_solve(id, &req),
            Some("stats") => self.handle_stats(id),
            Some("shutdown") => {
                // drain first so in-flight replies precede the half-close;
                // our own reply goes out after (write side stays open)
                self.shutdown();
                protocol::reply_ok(id, vec![("stopped".into(), Json::Bool(true))])
            }
            Some(other) => {
                protocol::reply_err(id, "unknown_verb", &format!("unknown verb '{other}'"))
            }
            None => protocol::reply_err(id, "bad_request", "missing string field 'verb'"),
        }
    }

    fn handle_register(&self, id: Option<&Json>, req: &Json) -> String {
        let registered = if let Some(rows) = req.get("rows") {
            match mat_from_rows(rows) {
                Ok(m) => self.registry.register_mem(m, &self.arena),
                Err(msg) => return protocol::reply_err(id, "bad_request", &msg),
            }
        } else if let Some(path) = req.str_field("path") {
            let opened = if path.ends_with(".npy") {
                BinFileSource::open_npy(path)
            } else {
                match req.u64_field("dim") {
                    Some(d) if d > 0 => BinFileSource::open(path, d as usize),
                    _ => {
                        return protocol::reply_err(
                            id,
                            "bad_request",
                            "registering a .bin path requires a positive 'dim'",
                        )
                    }
                }
            };
            match opened {
                Ok(src) => self.registry.register_file(src, &self.arena),
                Err(e) => return protocol::reply_solve_err(id, &SolveError::from(e)),
            }
        } else {
            return protocol::reply_err(id, "bad_request", "register needs 'rows' or 'path'");
        };
        match registered {
            Ok((ds_id, entry, new)) => protocol::reply_ok(
                id,
                vec![
                    ("dataset".into(), Json::Str(ds_id)),
                    ("rows".into(), Json::Num(entry.rows() as f64)),
                    ("dim".into(), Json::Num(entry.dim() as f64)),
                    ("new".into(), Json::Bool(new)),
                ],
            ),
            Err(e) => protocol::reply_solve_err(id, &SolveError::from(e)),
        }
    }

    fn handle_solve(self: &Arc<Server>, id: Option<&Json>, req: &Json) -> String {
        if self.stopping.load(Ordering::Acquire) {
            return protocol::reply_err(id, "shutting_down", "server is draining");
        }
        let (dx, dy) = match (self.lookup(req, "x"), self.lookup(req, "y")) {
            (Ok(a), Ok(b)) => (a, b),
            (Err(r), _) | (_, Err(r)) => return reply_for_lookup(id, r),
        };
        let deadline = req.u64_field("deadline_ms").map(|ms| Instant::now() + Duration::from_millis(ms));
        let slot = Arc::new(ReplySlot::default());
        let job_slot = Arc::clone(&slot);
        let server = Arc::clone(self);
        let admitted = self.sched.submit(move || {
            job_slot.fill(server.run_solve(&dx, &dy, deadline));
        });
        match admitted {
            Err(Rejected::Overloaded) => {
                self.metrics.overloaded.fetch_add(1, Ordering::Relaxed);
                protocol::reply_err(id, "overloaded", "admission queue is full")
            }
            Err(Rejected::ShuttingDown) => {
                protocol::reply_err(id, "shutting_down", "server is draining")
            }
            Ok(()) => {
                self.metrics.solves.fetch_add(1, Ordering::Relaxed);
                match slot.take() {
                    Ok(done) => {
                        self.metrics.solves_ok.fetch_add(1, Ordering::Relaxed);
                        protocol::reply_ok(
                            id,
                            vec![
                                (
                                    "perm".into(),
                                    Json::Arr(
                                        done.perm.iter().map(|&j| Json::Num(j as f64)).collect(),
                                    ),
                                ),
                                ("warm".into(), Json::Bool(done.warm)),
                                ("elapsed_ms".into(), Json::Num(done.elapsed_ms)),
                            ],
                        )
                    }
                    Err(e) => {
                        if e == SolveError::Cancelled {
                            self.metrics.timeouts.fetch_add(1, Ordering::Relaxed);
                        } else {
                            self.metrics.solve_errors.fetch_add(1, Ordering::Relaxed);
                        }
                        protocol::reply_solve_err(id, &e)
                    }
                }
            }
        }
    }

    /// The worker-side solve: warm factors, hooks, streamed points.
    fn run_solve(
        &self,
        dx: &DatasetEntry,
        dy: &DatasetEntry,
        deadline: Option<Instant>,
    ) -> Result<SolveDone, SolveError> {
        let t0 = Instant::now();
        // a request that aged out in the queue never starts
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(SolveError::Cancelled);
        }
        // shape errors are cheap to detect — fail before factorising so a
        // doomed pair never occupies a session slot
        if dx.rows() != dy.rows() {
            return Err(SolveError::ShapeMismatch { n: dx.rows(), m: dy.rows() });
        }
        if dx.dim() != dy.dim() {
            return Err(SolveError::DimMismatch { dx: dx.dim(), dy: dy.dim() });
        }
        let cfg = &self.solver_cfg;
        let key = session_key(dx.hash(), dy.hash(), cfg);
        let (fu, fv, warm) = self.sessions.get_or_build(key, || {
            let arena = ScratchArena::new(cfg.threads.max(1));
            dx.with_source(|sx| {
                dy.with_source(|sy| {
                    costs::factors_for_source(
                        sx,
                        sy,
                        cfg.cost,
                        cfg.indyk_width,
                        cfg.seed,
                        cfg.chunk_rows,
                        &arena,
                        cfg.threads.max(1),
                    )
                    .map_err(SolveError::from)
                })
            })
        })?;
        // register with the microbatcher for the whole solve, so lane
        // leaders know how many co-travellers may still join
        let _guard = self.micro.begin_solve();
        let hooks = JobHooks { deadline, micro: Some(Arc::clone(&self.micro)) };
        let solver = HiRef::new(cfg.clone()).with_hooks(Arc::new(hooks));
        let out = dx.with_source(|sx| {
            dy.with_source(|sy| solver.align_prefactored_source(fu, fv, sx, sy))
        })?;
        self.metrics
            .spill_bytes_written
            .fetch_add(out.stats.spill_bytes_written, Ordering::Relaxed);
        self.metrics.spill_reads.fetch_add(out.stats.spill_reads, Ordering::Relaxed);
        let warm_levels = out.stats.level_stats.iter().filter(|ls| ls.warmstarted).count();
        self.metrics.warm_levels.fetch_add(warm_levels, Ordering::Relaxed);
        self.metrics.warm_lanes.fetch_add(out.stats.cluster_calls, Ordering::Relaxed);
        self.metrics.lrot_iters.fetch_add(out.stats.lrot_iters, Ordering::Relaxed);
        let elapsed = t0.elapsed();
        self.metrics.record_latency(elapsed);
        Ok(SolveDone { perm: out.perm, warm, elapsed_ms: elapsed.as_secs_f64() * 1e3 })
    }

    fn handle_stats(&self, id: Option<&Json>) -> String {
        let mut stats = match self.metrics.to_json() {
            Json::Obj(fields) => fields,
            _ => unreachable!("metrics serialise to an object"),
        };
        let sess = self.sessions.stats();
        stats.push(("sessions".into(), Json::Num(sess.sessions as f64)));
        stats.push(("session_bytes".into(), Json::Num(sess.bytes as f64)));
        stats.push(("session_pinned_bytes".into(), Json::Num(sess.pinned_bytes as f64)));
        stats.push((
            "session_spill_bytes_written".into(),
            Json::Num(sess.spill_bytes_written as f64),
        ));
        stats.push(("session_spill_reads".into(), Json::Num(sess.spill_reads as f64)));
        stats.push(("datasets".into(), Json::Num(self.registry.len() as f64)));
        protocol::reply_ok(id, vec![("stats".into(), Json::Obj(stats))])
    }

    fn lookup(&self, req: &Json, field: &str) -> Result<Arc<DatasetEntry>, LookupErr> {
        let id = req.str_field(field).ok_or(LookupErr::Missing(field.to_string()))?;
        self.registry.get(id).ok_or_else(|| LookupErr::Unknown(id.to_string()))
    }
}

enum LookupErr {
    Missing(String),
    Unknown(String),
}

fn reply_for_lookup(id: Option<&Json>, r: LookupErr) -> String {
    match r {
        LookupErr::Missing(f) => {
            protocol::reply_err(id, "bad_request", &format!("solve needs string field '{f}'"))
        }
        LookupErr::Unknown(ds) => protocol::reply_err(
            id,
            "unknown_dataset",
            &format!("no dataset registered under '{ds}'"),
        ),
    }
}

/// What the prebuilt factors depend on besides the data: the cost
/// config.  Anything else (LROT hyper-parameters, thread count, base
/// size) does not change the factor matrices.
fn session_key(hx: u64, hy: u64, cfg: &HiRefConfig) -> u64 {
    let kind = match cfg.cost {
        CostKind::Euclidean => 1u64,
        CostKind::SqEuclidean => 2u64,
    };
    // the stored element format changes the archived bits, so two servers'
    // worth of configs must never share a session
    let prec = match cfg.factor_precision {
        crate::pool::Precision::F32 => 0u64,
        crate::pool::Precision::Bf16 => 1u64,
        crate::pool::Precision::F16 => 2u64,
    };
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in [hx, hy, kind, cfg.indyk_width as u64, cfg.seed, prec] {
        for &b in &w.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Inline `rows: [[f32; d]; n]` → matrix, with shape validation.
fn mat_from_rows(rows: &Json) -> Result<Mat, String> {
    let rows = rows.as_arr().ok_or("'rows' must be an array of arrays")?;
    if rows.is_empty() {
        return Err("'rows' must be nonempty".to_string());
    }
    let dim = rows[0].as_arr().map(<[Json]>::len).unwrap_or(0);
    if dim == 0 {
        return Err("'rows' entries must be nonempty arrays".to_string());
    }
    let mut m = Mat::zeros(rows.len(), dim);
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().ok_or_else(|| format!("row {i} is not an array"))?;
        if row.len() != dim {
            return Err(format!("row {i} has {} values, expected {dim}", row.len()));
        }
        for (j, v) in row.iter().enumerate() {
            m.data[i * dim + j] =
                v.as_f64().ok_or_else(|| format!("row {i} value {j} is not a number"))? as f32;
        }
    }
    Ok(m)
}

fn write_line(w: &mut TcpStream, reply: &str) -> io::Result<()> {
    w.write_all(reply.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_from_rows_validates() {
        let ok = protocol::parse(r#"{"rows":[[1,2],[3,4],[5,6]]}"#).unwrap();
        let m = mat_from_rows(ok.get("rows").unwrap()).unwrap();
        assert_eq!((m.rows, m.cols), (3, 2));
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        for bad in [r#"{"rows":[]}"#, r#"{"rows":[[1,2],[3]]}"#, r#"{"rows":[1,2]}"#] {
            let v = protocol::parse(bad).unwrap();
            assert!(mat_from_rows(v.get("rows").unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn session_key_separates_cost_configs() {
        let base = HiRefConfig::default();
        let k0 = session_key(1, 2, &base);
        assert_eq!(k0, session_key(1, 2, &base.clone()));
        assert_ne!(k0, session_key(2, 1, &base), "sides are ordered");
        let mut flipped = base.clone();
        flipped.cost = CostKind::Euclidean;
        assert_ne!(k0, session_key(1, 2, &flipped));
        let mut seeded = base.clone();
        seeded.seed = 7;
        assert_ne!(k0, session_key(1, 2, &seeded));
        let mut narrowed = base.clone();
        narrowed.factor_precision = crate::pool::Precision::Bf16;
        assert_ne!(k0, session_key(1, 2, &narrowed), "precision changes the archived bits");
        let mut lrot_only = base;
        lrot_only.lrot.outer += 5;
        assert_eq!(k0, session_key(1, 2, &lrot_only), "LROT params don't touch factors");
    }

    #[test]
    fn serve_rejects_unbatched_configs() {
        let mut cfg = ServeConfig::default();
        cfg.solver.batching = false;
        match serve(cfg) {
            Err(SolveError::InvalidConfig(msg)) => assert!(msg.contains("batching")),
            Err(e) => panic!("expected InvalidConfig, got {e:?}"),
            Ok(_) => panic!("an unbatched config must be rejected"),
        }
    }
}
