//! `hiref serve`: a long-lived alignment service with warm factor
//! caching and cross-request microbatching.
//!
//! The offline CLI pays the full pipeline on every invocation — process
//! start, dataset ingestion, cost factorisation, solve.  For workloads
//! that align the same (or overlapping) datasets repeatedly, almost all
//! of that is reusable.  This subsystem keeps it resident:
//!
//! * **Protocol** ([`protocol`]) — newline-delimited JSON over TCP.
//!   Every request carries a client `id` echoed on the reply; failures
//!   are *typed* (`{"ok":false,"error":{"kind":...}}`) with kinds mapped
//!   1:1 from [`crate::api::SolveError`], plus service-level kinds
//!   (`overloaded`, `shutting_down`, `unknown_dataset`, `bad_request`).
//!   Hand-rolled parser/writer — the crate stays dependency-free.
//! * **Sessions** ([`session`]) — datasets are registered once and
//!   identified by their streaming FNV-1a content hash
//!   ([`crate::data::stream::content_hash`]); prebuilt cost factors are
//!   archived per `(x, y, cost config)` in a
//!   [`crate::pool::FactorStore`] (resident or spill-backed) under an
//!   LRU byte budget.  A warm solve performs **zero factorisation
//!   work** — it re-materialises the archive and goes straight to
//!   refinement ([`crate::coordinator::hiref::HiRef::align_prefactored_source`]).
//! * **Scheduling** ([`scheduler`]) — a bounded worker pool behind a
//!   bounded admission queue (typed `overloaded` rejection, graceful
//!   drain on shutdown), per-request deadlines enforced through
//!   [`crate::coordinator::hiref::SolveHooks::cancelled`] (typed
//!   `timeout` reply, no leaked checkouts or scratch), and a
//!   [`scheduler::Microbatcher`] that merges same-shape LROT batches
//!   from different in-flight requests into one strided
//!   [`crate::solvers::lrot::solve_factored_batch`] call.
//! * **Metrics** ([`metrics`]) — the `stats` verb: requests, cache
//!   hits/misses, microbatched lane fraction, queue depth, spill
//!   traffic, p50/p99 solve latency.
//!
//! **Bit-identity.** Every served permutation is bit-identical to a solo
//! offline [`crate::coordinator::hiref::HiRef::align`] on the same data
//! and config: warm archives return the exact bytes that were built
//! ([`crate::pool::FactorStore`]'s contract), per-lane LROT outputs are
//! independent of `threads` and of which other lanes share a batch
//! (asserted in the LROT tests), and cancellation only fires between
//! batches.  The serve integration tests assert the end-to-end property
//! across concurrent clients, cache temperature, and merged lanes.

pub mod metrics;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod session;

pub use metrics::ServeMetrics;
pub use protocol::Json;
pub use scheduler::{JobHooks, Microbatcher, Rejected, Scheduler};
pub use server::{serve, ServeConfig, Server, ServerHandle};
pub use session::{DatasetRegistry, SessionCache, SessionCacheStats};
