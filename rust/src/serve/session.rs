//! Warm sessions: datasets registered once, factors built once.
//!
//! Two layers, both keyed by *content*:
//!
//! * [`DatasetRegistry`] — datasets registered over the protocol (inline
//!   rows or a server-side file path), identified by their streaming
//!   FNV-1a [`content_hash`].  Registering the same bytes twice — from
//!   memory or from a `.bin` file — yields the same id, so clients can
//!   treat the id as a cache key without coordinating.
//! * [`SessionCache`] — prebuilt cost factors per `(x, y, cost config)`
//!   tuple, stored in a [`FactorStore`] (resident, or spilled to disk when
//!   the server runs with a spill directory) and evicted LRU under a byte
//!   budget.  A warm hit materialises the archived factors and performs
//!   **zero** factorisation work — the property the serve integration
//!   tests assert through the `factor_builds` counter.
//!
//! The cache lock is held across a cold build on purpose: concurrent
//! requests for the same pair serialise on it and every follower wakes up
//! to a warm hit, so a thundering herd factorises exactly once.

use std::collections::HashMap;
use std::io;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use super::metrics::ServeMetrics;
use crate::api::SolveError;
use crate::data::stream::{content_hash, DatasetSource, InMemorySource};
use crate::data::BinFileSource;
use crate::linalg::Mat;
use crate::pool::{FactorStore, Precision, ResidentStore, ScratchArena, SpillStore};

// ---------------------------------------------------------------------------
// DatasetRegistry
// ---------------------------------------------------------------------------

/// Backing storage of a registered dataset.
enum DatasetData {
    /// Rows shipped inline over the protocol.
    Mem(Mat),
    /// A server-side `.bin`/`.npy` file, read on demand (beyond-RAM
    /// datasets never materialise).
    File(BinFileSource),
}

/// One registered dataset: shape, content hash, and a way to view it as a
/// [`DatasetSource`] for the streaming factor builders.
pub struct DatasetEntry {
    hash: u64,
    rows: usize,
    dim: usize,
    data: DatasetData,
}

impl DatasetEntry {
    /// FNV-1a content hash (the registry id, as an integer).
    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// Number of points.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Ambient dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Run `f` with this dataset as a borrowed [`DatasetSource`].
    pub fn with_source<R>(&self, f: impl FnOnce(&dyn DatasetSource) -> R) -> R {
        match &self.data {
            DatasetData::Mem(m) => f(&InMemorySource::new(m)),
            DatasetData::File(b) => f(b),
        }
    }
}

/// Content-addressed dataset table: id = 16 hex digits of the streaming
/// content hash.  Re-registration of identical content is a no-op that
/// returns the existing entry.
pub struct DatasetRegistry {
    map: Mutex<HashMap<String, Arc<DatasetEntry>>>,
    chunk_rows: usize,
}

impl DatasetRegistry {
    /// `chunk_rows` bounds hashing memory (`O(chunk_rows · dim)`).
    pub fn new(chunk_rows: usize) -> DatasetRegistry {
        DatasetRegistry { map: Mutex::new(HashMap::new()), chunk_rows }
    }

    /// Register inline rows.  Returns `(id, entry, was_new)`.
    pub fn register_mem(
        &self,
        m: Mat,
        arena: &ScratchArena,
    ) -> io::Result<(String, Arc<DatasetEntry>, bool)> {
        let hash = content_hash(&InMemorySource::new(&m), self.chunk_rows, arena)?;
        let (rows, dim) = (m.rows, m.cols);
        self.insert(hash, rows, dim, DatasetData::Mem(m))
    }

    /// Register a server-side file already opened as a source.
    pub fn register_file(
        &self,
        src: BinFileSource,
        arena: &ScratchArena,
    ) -> io::Result<(String, Arc<DatasetEntry>, bool)> {
        let hash = content_hash(&src, self.chunk_rows, arena)?;
        let (rows, dim) = (src.rows(), src.dim());
        self.insert(hash, rows, dim, DatasetData::File(src))
    }

    fn insert(
        &self,
        hash: u64,
        rows: usize,
        dim: usize,
        data: DatasetData,
    ) -> io::Result<(String, Arc<DatasetEntry>, bool)> {
        let id = format!("{hash:016x}");
        let mut map = self.map.lock().unwrap();
        if let Some(existing) = map.get(&id) {
            return Ok((id, Arc::clone(existing), false));
        }
        let entry = Arc::new(DatasetEntry { hash, rows, dim, data });
        map.insert(id.clone(), Arc::clone(&entry));
        Ok((id, entry, true))
    }

    /// Look an id up (16 hex digits, as returned by registration).
    pub fn get(&self, id: &str) -> Option<Arc<DatasetEntry>> {
        self.map.lock().unwrap().get(id).cloned()
    }

    /// Number of registered datasets.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// SessionCache
// ---------------------------------------------------------------------------

/// One warm session: both factor archives plus LRU bookkeeping.
struct Session {
    fu: Box<dyn FactorStore>,
    fv: Box<dyn FactorStore>,
    bytes: usize,
    last_use: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<u64, Session>,
    tick: u64,
    bytes: usize,
    /// Spill counters of evicted sessions, folded in so the totals stay
    /// monotonic across evictions.
    retired_spill_bytes: usize,
    retired_spill_reads: usize,
}

/// Point-in-time cache counters for the `stats` verb and the tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionCacheStats {
    /// Live sessions.
    pub sessions: usize,
    /// Archive bytes accounted against the budget.
    pub bytes: usize,
    /// Bytes currently pinned by checkouts across all archives (0 unless a
    /// solve is mid-flight; the timeout test asserts it returns to 0).
    pub pinned_bytes: usize,
    /// Spill bytes written by session archives, including evicted ones.
    pub spill_bytes_written: usize,
    /// Spill shard reads by session archives, including evicted ones.
    pub spill_reads: usize,
}

/// LRU cache of prebuilt factor archives keyed by
/// `(x hash, y hash, cost config)` — see `session_key` in the server.
pub struct SessionCache {
    inner: Mutex<Inner>,
    budget_bytes: usize,
    spill_dir: Option<PathBuf>,
    precision: Precision,
    metrics: Arc<ServeMetrics>,
}

impl SessionCache {
    /// `budget_bytes` caps archived factor bytes (RAM for resident
    /// archives, disk when `spill_dir` routes them to scratch files); at
    /// least the most recent session is always kept.  Archives hold
    /// elements at `precision` and the budget charges that true width, so
    /// a bf16 server fits twice the pairs of an f32 one.
    pub fn new(
        budget_bytes: usize,
        spill_dir: Option<PathBuf>,
        precision: Precision,
        metrics: Arc<ServeMetrics>,
    ) -> SessionCache {
        SessionCache {
            inner: Mutex::new(Inner::default()),
            budget_bytes,
            spill_dir,
            precision,
            metrics,
        }
    }

    /// Fetch the factors for `key`, building them with `build` on a cold
    /// miss.  Returns `(fu, fv, warm)`; `warm == true` means `build` did
    /// not run (the zero-factorisation fast path).
    pub fn get_or_build(
        &self,
        key: u64,
        build: impl FnOnce() -> Result<(Mat, Mat), SolveError>,
    ) -> Result<(Mat, Mat, bool), SolveError> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(s) = inner.map.get_mut(&key) {
            s.last_use = tick;
            let fu = materialise(s.fu.as_ref())?;
            let fv = materialise(s.fv.as_ref())?;
            self.metrics.session_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((fu, fv, true));
        }
        // Cold: factorise while holding the lock, so concurrent requests
        // for the same pair wait here and wake up warm.
        self.metrics.session_misses.fetch_add(1, Ordering::Relaxed);
        self.metrics.factor_builds.fetch_add(1, Ordering::Relaxed);
        let (fu, fv) = build()?;
        // the budget charges what the archive actually holds: 2-byte
        // elements at bf16/f16, so half the bytes per session
        let bytes = (fu.data.len() + fv.data.len()) * self.precision.bytes();
        let session = Session {
            fu: self.archive(&fu)?,
            fv: self.archive(&fv)?,
            bytes,
            last_use: tick,
        };
        // Low precision narrows on archive, so hand the cold request the
        // decoded bits too — every warm hit then replays the cold solve
        // exactly (the per-precision bit-identity invariant).
        let (fu, fv) = match self.precision {
            Precision::F32 => (fu, fv),
            _ => (materialise(session.fu.as_ref())?, materialise(session.fv.as_ref())?),
        };
        inner.bytes += bytes;
        inner.map.insert(key, session);
        self.evict(&mut inner);
        Ok((fu, fv, false))
    }

    /// Copy a freshly built factor matrix into its archive form.
    fn archive(&self, m: &Mat) -> Result<Box<dyn FactorStore>, SolveError> {
        match &self.spill_dir {
            None => Ok(Box::new(ResidentStore::from_mat_with(m.clone(), self.precision))),
            Some(dir) => {
                // Budget 0: the archive is a pure file — warm hits read it
                // back, so resident memory stays O(1) per idle session.
                let store = SpillStore::create_with(dir, m.rows, m.cols, 0, self.precision)?;
                // SAFETY: the store was just created; no checkout exists.
                unsafe { store.write_rows(0, &m.data)? };
                Ok(Box::new(store))
            }
        }
    }

    /// Evict least-recently-used sessions until under budget (always
    /// keeping at least one — the session just used).
    fn evict(&self, inner: &mut Inner) {
        while inner.bytes > self.budget_bytes && inner.map.len() > 1 {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_use)
                .map(|(k, _)| *k)
                .expect("map is nonempty");
            let s = inner.map.remove(&victim).expect("victim exists");
            inner.bytes -= s.bytes;
            let (fu, fv) = (s.fu.stats(), s.fv.stats());
            inner.retired_spill_bytes += fu.spill_bytes_written + fv.spill_bytes_written;
            inner.retired_spill_reads += fu.spill_reads + fv.spill_reads;
            self.metrics.session_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current counters (live sessions + retired spill totals).
    pub fn stats(&self) -> SessionCacheStats {
        let inner = self.inner.lock().unwrap();
        let mut st = SessionCacheStats {
            sessions: inner.map.len(),
            bytes: inner.bytes,
            pinned_bytes: 0,
            spill_bytes_written: inner.retired_spill_bytes,
            spill_reads: inner.retired_spill_reads,
        };
        for s in inner.map.values() {
            for f in [s.fu.stats(), s.fv.stats()] {
                st.pinned_bytes += f.pinned_bytes;
                st.spill_bytes_written += f.spill_bytes_written;
                st.spill_reads += f.spill_reads;
            }
        }
        st
    }
}

/// Read a full archive back into a matrix for a warm solve.
fn materialise(store: &dyn FactorStore) -> Result<Mat, SolveError> {
    let mut m = Mat::zeros(store.rows(), store.cols());
    // SAFETY: session archives are never checked out between solves (the
    // cache hands out materialised copies, not the stores themselves), so
    // no live writer or dirty checkout can overlap this read.
    unsafe { store.read_rows(0, &mut m.data)? };
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn mat(rows: usize, cols: usize, seed: u32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for (i, v) in m.data.iter_mut().enumerate() {
            *v = ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) % 1000) as f32 / 7.0;
        }
        m
    }

    fn cache(budget: usize, spill: Option<PathBuf>) -> SessionCache {
        cache_at(budget, spill, Precision::F32)
    }

    fn cache_at(budget: usize, spill: Option<PathBuf>, prec: Precision) -> SessionCache {
        SessionCache::new(budget, spill, prec, Arc::new(ServeMetrics::default()))
    }

    #[test]
    fn warm_hit_skips_build_and_round_trips() {
        let c = cache(usize::MAX, None);
        let builds = AtomicUsize::new(0);
        let build = |seed: u32| {
            builds.fetch_add(1, Ordering::Relaxed);
            Ok((mat(8, 3, seed), mat(8, 3, seed + 1)))
        };
        let (fu0, fv0, warm0) = c.get_or_build(42, || build(7)).unwrap();
        let (fu1, fv1, warm1) = c.get_or_build(42, || build(9)).unwrap();
        assert!(!warm0);
        assert!(warm1, "second fetch must be warm");
        assert_eq!(builds.load(Ordering::Relaxed), 1, "build ran twice");
        assert_eq!(fu0.data, fu1.data);
        assert_eq!(fv0.data, fv1.data);
        assert_eq!(c.stats().sessions, 1);
        assert_eq!(c.stats().pinned_bytes, 0);
    }

    #[test]
    fn lru_evicts_oldest_under_budget() {
        // each session: 2 × 8×3 × 4 bytes = 192; budget fits one only
        let c = cache(200, None);
        let b = |s: u32| move || Ok((mat(8, 3, s), mat(8, 3, s + 1)));
        c.get_or_build(1, b(10)).unwrap();
        c.get_or_build(2, b(20)).unwrap();
        let st = c.stats();
        assert_eq!(st.sessions, 1, "budget holds one session");
        assert!(st.bytes <= 200);
        assert_eq!(c.metrics.session_evictions.load(Ordering::Relaxed), 1);
        // key 1 was evicted, key 2 is warm
        let (_, _, warm2) = c.get_or_build(2, b(99)).unwrap();
        assert!(warm2);
        let (_, _, warm1) = c.get_or_build(1, b(10)).unwrap();
        assert!(!warm1, "evicted session rebuilds");
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: session spill dirs need real file I/O")]
    fn spilled_sessions_round_trip_bit_identically() {
        let dir = std::env::temp_dir().join(format!("hiref_serve_sess_{}", std::process::id()));
        let c = cache(usize::MAX, Some(dir.clone()));
        let fu = mat(17, 5, 3);
        let fv = mat(17, 5, 4);
        let (a, b, _) = c.get_or_build(7, || Ok((fu.clone(), fv.clone()))).unwrap();
        let (a2, b2, warm) = c.get_or_build(7, || unreachable!("must be warm")).unwrap();
        assert!(warm);
        assert_eq!(a.data, fu.data);
        assert_eq!(b.data, fv.data);
        assert_eq!(a2.data, fu.data);
        assert_eq!(b2.data, fv.data);
        let st = c.stats();
        assert!(st.spill_bytes_written >= 2 * 17 * 5 * 4, "archives hit the spill file");
        assert!(st.spill_reads > 0, "warm hit read the spill file");
        assert_eq!(st.pinned_bytes, 0);
        drop(c);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bf16_sessions_charge_half_the_budget_and_stay_self_consistent() {
        // satellite: the budget charges the true archived element width —
        // a budget that evicts at two f32 sessions holds two bf16 ones
        let b = |s: u32| move || Ok((mat(8, 3, s), mat(8, 3, s + 1)));
        let budget = 2 * 2 * 8 * 3 * 4 - 1; // one byte short of two f32 sessions
        let f32_cache = cache(budget, None);
        f32_cache.get_or_build(1, b(10)).unwrap();
        f32_cache.get_or_build(2, b(20)).unwrap();
        assert_eq!(f32_cache.stats().sessions, 1, "two f32 sessions exceed the budget");
        let bf16_cache = cache_at(budget, None, Precision::Bf16);
        bf16_cache.get_or_build(1, b(10)).unwrap();
        bf16_cache.get_or_build(2, b(20)).unwrap();
        let st = bf16_cache.stats();
        assert_eq!(st.sessions, 2, "half-width archives fit twice the pairs");
        assert_eq!(st.bytes, 2 * 2 * 8 * 3 * 2);
        // cold returns the archived (narrowed) bits, so warm == cold
        let (fu0, fv0, warm0) = bf16_cache.get_or_build(3, b(30)).unwrap();
        let (fu1, fv1, warm1) = bf16_cache.get_or_build(3, || unreachable!()).unwrap();
        assert!(!warm0);
        assert!(warm1);
        assert_eq!(fu0.data, fu1.data, "warm hit must replay the cold bits");
        assert_eq!(fv0.data, fv1.data);
        // and those bits really are quantised, not the builder's f32s
        let raw = mat(8, 3, 30);
        assert_ne!(fu0.data, raw.data, "bf16 archive must narrow the factors");
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: session spill dirs need real file I/O")]
    fn bf16_spilled_sessions_warm_equals_cold() {
        let dir =
            std::env::temp_dir().join(format!("hiref_serve_bf16_{}", std::process::id()));
        let c = cache_at(usize::MAX, Some(dir.clone()), Precision::Bf16);
        let fu = mat(17, 5, 3);
        let fv = mat(17, 5, 4);
        let (a, b, _) = c.get_or_build(7, || Ok((fu.clone(), fv.clone()))).unwrap();
        let (a2, b2, warm) = c.get_or_build(7, || unreachable!("must be warm")).unwrap();
        assert!(warm);
        assert_eq!(a.data, a2.data);
        assert_eq!(b.data, b2.data);
        let st = c.stats();
        // the spill file holds 2-byte elements
        assert_eq!(st.bytes, 2 * 17 * 5 * 2);
        assert!(st.spill_bytes_written >= 2 * 17 * 5 * 2);
        assert!(st.spill_bytes_written < 2 * 17 * 5 * 4, "archives wrote at f32 width");
        drop(c);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn build_errors_do_not_poison_the_cache() {
        let c = cache(usize::MAX, None);
        let err = c.get_or_build(5, || Err(SolveError::EmptyInput));
        assert_eq!(err.unwrap_err(), SolveError::EmptyInput);
        assert_eq!(c.stats().sessions, 0);
        let (_, _, warm) = c.get_or_build(5, || Ok((mat(4, 2, 1), mat(4, 2, 2)))).unwrap();
        assert!(!warm, "failed build leaves the key cold");
    }

    #[test]
    fn registry_is_content_addressed() {
        let arena = ScratchArena::new(1);
        let reg = DatasetRegistry::new(16);
        let m = mat(40, 4, 11);
        let (id1, e1, new1) = reg.register_mem(m.clone(), &arena).unwrap();
        let (id2, _e2, new2) = reg.register_mem(m.clone(), &arena).unwrap();
        assert_eq!(id1, id2, "same content, same id");
        assert!(new1);
        assert!(!new2, "re-registration dedupes");
        assert_eq!(reg.len(), 1);
        assert_eq!(id1, format!("{:016x}", e1.hash()));
        assert_eq!((e1.rows(), e1.dim()), (40, 4));
        assert!(reg.get(&id1).is_some());
        assert!(reg.get("ffffffffffffffff").is_none());
        // different content gets a different id
        let (id3, _, new3) = reg.register_mem(mat(40, 4, 12), &arena).unwrap();
        assert_ne!(id1, id3);
        assert!(new3);
        assert_eq!(reg.len(), 2);
    }
}
