//! Service-level counters behind the `stats` protocol verb.
//!
//! Everything is lock-free atomics except the latency reservoir (a small
//! ring under a mutex, touched once per finished solve).  Per-run solver
//! diagnostics stay in [`crate::coordinator::hiref::RunStats`]; this
//! module aggregates the *service* view across requests: admission and
//! backpressure, session-cache effectiveness, cross-request microbatch
//! shape, spill traffic, and p50/p99 solve latency.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::protocol::Json;

/// Latency samples kept for the percentile estimate (a ring: the stats
/// verb reports percentiles over the most recent `LAT_CAP` solves).
const LAT_CAP: usize = 4096;

#[derive(Default)]
struct LatRing {
    samples_us: Vec<u64>,
    next: usize,
}

/// Counters of one serve instance.  All monotonic unless noted.
#[derive(Default)]
pub struct ServeMetrics {
    /// Protocol requests received (every verb, every connection).
    pub requests: AtomicUsize,
    /// Solve requests admitted to the queue.
    pub solves: AtomicUsize,
    /// Solves that returned an alignment.
    pub solves_ok: AtomicUsize,
    /// Solves that returned a typed error (excluding timeouts).
    pub solve_errors: AtomicUsize,
    /// Solve requests rejected at admission (queue full).
    pub overloaded: AtomicUsize,
    /// Deadline expiries — queued past the deadline or cancelled mid-solve.
    pub timeouts: AtomicUsize,
    /// Warm-session factor reuses (zero factorisation work).
    pub session_hits: AtomicUsize,
    /// Cold pairs that had to factorise.
    pub session_misses: AtomicUsize,
    /// Sessions evicted by the LRU byte budget.
    pub session_evictions: AtomicUsize,
    /// Factorisation passes actually run (== `session_misses`; kept
    /// separate so the zero-factorisation-when-warm property is asserted
    /// against the builder itself, not cache bookkeeping).
    pub factor_builds: AtomicUsize,
    /// Current admission-queue depth (gauge).
    pub queue_depth: AtomicUsize,
    /// High-water mark of `queue_depth`.
    pub queue_peak: AtomicUsize,
    /// LROT batch submissions reaching the microbatcher.
    pub micro_calls: AtomicUsize,
    /// Merged cross-request solves issued (≥ 2 participants).
    pub micro_merged_calls: AtomicUsize,
    /// Lanes through the microbatcher, total.
    pub micro_lanes: AtomicUsize,
    /// Lanes that shared a merged solve with another request's lanes.
    pub micro_merged_lanes: AtomicUsize,
    /// Spill bytes written across served solves (from `RunStats`).
    pub spill_bytes_written: AtomicUsize,
    /// Spill shard reads across served solves (from `RunStats`).
    pub spill_reads: AtomicUsize,
    /// Cluster-warmstarted hierarchy levels across served solves (from
    /// `RunStats::level_stats` — 0 unless requests set `warmstart_levels`).
    pub warm_levels: AtomicUsize,
    /// Lane clusterings run by the warmstart engine (from `RunStats`).
    pub warm_lanes: AtomicUsize,
    /// Native mirror-descent iterations across served solves (from
    /// `RunStats` — the quantity warmstarting reduces).
    pub lrot_iters: AtomicUsize,
    lat: Mutex<LatRing>,
}

impl ServeMetrics {
    /// Record one finished solve's wall latency.
    pub fn record_latency(&self, elapsed: Duration) {
        let us = elapsed.as_micros().min(u128::from(u64::MAX)) as u64;
        let mut g = self.lat.lock().unwrap();
        if g.samples_us.len() < LAT_CAP {
            g.samples_us.push(us);
        } else {
            let i = g.next;
            g.samples_us[i] = us;
        }
        g.next = (g.next + 1) % LAT_CAP;
    }

    /// (p50, p99) of recent solve latencies, in milliseconds (0.0 when no
    /// solve has finished yet).  Nearest-rank on the retained window.
    pub fn latency_percentiles_ms(&self) -> (f64, f64) {
        let mut s = self.lat.lock().unwrap().samples_us.clone();
        if s.is_empty() {
            return (0.0, 0.0);
        }
        s.sort_unstable();
        let rank = |p: f64| -> f64 {
            let idx = ((p * s.len() as f64).ceil() as usize).clamp(1, s.len()) - 1;
            s[idx] as f64 / 1e3
        };
        (rank(0.50), rank(0.99))
    }

    /// Fraction of microbatcher lanes that rode a merged cross-request
    /// solve (0.0 before any batch was submitted).
    pub fn microbatched_lane_frac(&self) -> f64 {
        let lanes = self.micro_lanes.load(Ordering::Relaxed);
        if lanes == 0 {
            0.0
        } else {
            self.micro_merged_lanes.load(Ordering::Relaxed) as f64 / lanes as f64
        }
    }

    /// Raise `queue_peak` to at least `depth`.
    pub fn note_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// The `stats` verb's counter object.
    pub fn to_json(&self) -> Json {
        let ld = |c: &AtomicUsize| Json::Num(c.load(Ordering::Relaxed) as f64);
        let (p50, p99) = self.latency_percentiles_ms();
        Json::Obj(vec![
            ("requests".into(), ld(&self.requests)),
            ("solves".into(), ld(&self.solves)),
            ("solves_ok".into(), ld(&self.solves_ok)),
            ("solve_errors".into(), ld(&self.solve_errors)),
            ("overloaded".into(), ld(&self.overloaded)),
            ("timeouts".into(), ld(&self.timeouts)),
            ("session_hits".into(), ld(&self.session_hits)),
            ("session_misses".into(), ld(&self.session_misses)),
            ("session_evictions".into(), ld(&self.session_evictions)),
            ("factor_builds".into(), ld(&self.factor_builds)),
            ("queue_depth".into(), ld(&self.queue_depth)),
            ("queue_peak".into(), ld(&self.queue_peak)),
            ("micro_calls".into(), ld(&self.micro_calls)),
            ("micro_merged_calls".into(), ld(&self.micro_merged_calls)),
            ("micro_lanes".into(), ld(&self.micro_lanes)),
            ("micro_merged_lanes".into(), ld(&self.micro_merged_lanes)),
            ("microbatched_lane_frac".into(), Json::Num(self.microbatched_lane_frac())),
            ("spill_bytes_written".into(), ld(&self.spill_bytes_written)),
            ("spill_reads".into(), ld(&self.spill_reads)),
            ("warm_levels".into(), ld(&self.warm_levels)),
            ("warm_lanes".into(), ld(&self.warm_lanes)),
            ("lrot_iters".into(), ld(&self.lrot_iters)),
            ("latency_p50_ms".into(), Json::Num(p50)),
            ("latency_p99_ms".into(), Json::Num(p99)),
            // which kernel implementation every solve in this process
            // dispatched to (scalar/avx2/neon) — so load-test records and
            // `stats` probes know what actually ran
            ("kernel_path".into(), Json::Str(crate::linalg::kernels::active().as_str().into())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_nearest_rank() {
        let m = ServeMetrics::default();
        assert_eq!(m.latency_percentiles_ms(), (0.0, 0.0));
        for ms in 1..=100u64 {
            m.record_latency(Duration::from_millis(ms));
        }
        let (p50, p99) = m.latency_percentiles_ms();
        assert_eq!(p50, 50.0);
        assert_eq!(p99, 99.0);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let m = ServeMetrics::default();
        for _ in 0..(LAT_CAP + 10) {
            m.record_latency(Duration::from_millis(7));
        }
        assert_eq!(m.lat.lock().unwrap().samples_us.len(), LAT_CAP);
        assert_eq!(m.latency_percentiles_ms().0, 7.0);
    }

    #[test]
    fn lane_fraction_and_json_shape() {
        let m = ServeMetrics::default();
        assert_eq!(m.microbatched_lane_frac(), 0.0);
        m.micro_lanes.store(8, Ordering::Relaxed);
        m.micro_merged_lanes.store(6, Ordering::Relaxed);
        assert!((m.microbatched_lane_frac() - 0.75).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.u64_field("micro_lanes"), Some(8));
        assert!(j.get("latency_p99_ms").is_some());
        // warmstart counters are present (and zero on an untouched service)
        assert_eq!(j.u64_field("warm_levels"), Some(0));
        assert_eq!(j.u64_field("warm_lanes"), Some(0));
        assert_eq!(j.u64_field("lrot_iters"), Some(0));
    }
}
