//! Admission control and cross-request microbatching.
//!
//! Two pieces, both deliberately small:
//!
//! * [`Scheduler`] — a bounded worker pool behind a bounded admission
//!   queue.  Admission fails fast with a typed rejection
//!   ([`Rejected::Overloaded`]) instead of queueing unboundedly, and
//!   [`Scheduler::drain`] performs the graceful-shutdown contract: stop
//!   admitting, finish everything already admitted, then join the
//!   workers.
//! * [`Microbatcher`] — merges same-shape LROT batches from *different*
//!   in-flight solves into one strided
//!   [`lrot::solve_factored_batch`] call.  The engine already batches all
//!   same-scale blocks of one solve ([`crate::coordinator::hiref`]'s
//!   level-synchronous dispatch); this extends that across requests.  Lane
//!   solves are independent of `threads` and of which other lanes share
//!   the batch (asserted in the LROT tests), so the merge is
//!   **bit-identical** to solo execution by construction — the serve
//!   integration tests re-assert it end to end against offline
//!   [`crate::coordinator::hiref::HiRef::align`].
//!
//! Merging protocol: the first submission for a shape becomes the lane
//! *leader* and opens a collection window; later same-shape submissions
//! join the open slot.  The leader closes the window early once every
//! in-flight solve has joined (nobody else can arrive — each solve
//! submits at most one batch at a time), merges the staged lanes, runs
//! one strided solve, and hands each participant its slice.  Lock order
//! is `slots → slot.state`, everywhere.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::ServeMetrics;
use crate::coordinator::hiref::SolveHooks;
use crate::linalg::{BatchItem, BatchView, Mat};
use crate::pool::ScratchArena;
use crate::solvers::lrot::{self, LrotConfig};

// ---------------------------------------------------------------------------
// Microbatcher
// ---------------------------------------------------------------------------

/// One request's staged lanes inside an open slot.
struct Pending {
    u: Vec<f32>,
    v: Vec<f32>,
    lanes: usize,
    seeds: Vec<u64>,
}

#[derive(Default)]
struct SlotState {
    pendings: Vec<Pending>,
    results: Vec<Option<Vec<(Mat, Mat)>>>,
    done: bool,
}

/// An open collection window for one LROT shape.
struct Slot {
    cfg: LrotConfig,
    len: usize,
    k: usize,
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Merges same-shape LROT batches from concurrent solves.  See the
/// module docs for the protocol.
pub struct Microbatcher {
    window: Duration,
    threads: usize,
    arena: ScratchArena,
    /// Solves currently in flight (potential joiners) — leaders close
    /// their window early once every one of them has joined.
    active: AtomicUsize,
    slots: Mutex<HashMap<u64, Arc<Slot>>>,
    metrics: Arc<ServeMetrics>,
}

/// RAII registration of one in-flight solve with the microbatcher;
/// dropping it (solve finished, failed, or cancelled) un-counts the
/// solve and wakes any leader waiting for it.
pub struct SolveGuard {
    micro: Arc<Microbatcher>,
}

impl Drop for SolveGuard {
    fn drop(&mut self) {
        self.micro.active.fetch_sub(1, Ordering::AcqRel);
        // wake leaders: their "everyone joined" threshold just dropped
        let slots = self.micro.slots.lock().unwrap();
        for slot in slots.values() {
            let _st = slot.state.lock().unwrap();
            slot.cv.notify_all();
        }
    }
}

/// FNV-1a over the batch shape + solver hyper-parameters: only batches
/// that would be solved with identical per-lane geometry may merge.
fn shape_key(len: usize, k: usize, cfg: &LrotConfig) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in [
        len as u64,
        k as u64,
        cfg.rank as u64,
        cfg.outer as u64,
        cfg.inner as u64,
        u64::from(cfg.gamma.to_bits()),
        u64::from(cfg.tau.to_bits()),
    ] {
        for &b in &w.to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Stage a batch view's lanes into one owned contiguous buffer.
fn pack(view: BatchView<'_>) -> Vec<f32> {
    let mut out = Vec::new();
    for it in view.items {
        out.extend_from_slice(&view.data[it.start()..it.end()]);
    }
    out
}

impl Microbatcher {
    /// `window` caps how long a lane leader waits for co-travellers;
    /// `Duration::ZERO` disables merging (every batch solves solo).
    pub fn new(window: Duration, threads: usize, metrics: Arc<ServeMetrics>) -> Microbatcher {
        Microbatcher {
            window,
            threads: threads.max(1),
            arena: ScratchArena::new(threads.max(1)),
            active: AtomicUsize::new(0),
            slots: Mutex::new(HashMap::new()),
            metrics,
        }
    }

    /// Register a solve as in flight for the guard's lifetime.
    pub fn begin_solve(self: &Arc<Self>) -> SolveGuard {
        self.active.fetch_add(1, Ordering::AcqRel);
        SolveGuard { micro: Arc::clone(self) }
    }

    /// Solve one same-shape batch, possibly merged with batches of other
    /// in-flight solves.  Bit-identical to a solo
    /// [`lrot::solve_factored_batch`] call regardless of merging.
    pub fn submit(
        &self,
        u: BatchView<'_>,
        v: BatchView<'_>,
        active_rows: usize,
        cfg: &LrotConfig,
        seeds: &[u64],
    ) -> Vec<(Mat, Mat)> {
        let lanes = u.len();
        if lanes == 0 {
            return Vec::new();
        }
        self.metrics.micro_calls.fetch_add(1, Ordering::Relaxed);
        self.metrics.micro_lanes.fetch_add(lanes, Ordering::Relaxed);
        // nothing to merge with: skip staging copies and window latency
        if self.window.is_zero() || self.active.load(Ordering::Acquire) <= 1 {
            return self.solve_here(u, v, active_rows, cfg, seeds);
        }
        let len = active_rows;
        let k = if lanes == 0 { 0 } else { u.items[0].cols };
        let key = shape_key(len, k, cfg);
        let pending = Pending { u: pack(u), v: pack(v), lanes, seeds: seeds.to_vec() };

        // join an open slot or lead a new one (push happens under BOTH
        // locks, so a leader that removed the slot from the map has
        // already seen every joiner)
        let mut slots = self.slots.lock().unwrap();
        if let Some(slot) = slots.get(&key).map(Arc::clone) {
            if slot.len == len && slot.k == k && same_cfg(&slot.cfg, cfg) {
                let my_idx = {
                    let mut st = slot.state.lock().unwrap();
                    debug_assert!(!st.done, "joined a closed slot");
                    st.pendings.push(pending);
                    st.results.push(None);
                    slot.cv.notify_all();
                    st.pendings.len() - 1
                };
                drop(slots);
                return self.wait_result(&slot, my_idx);
            }
            // 64-bit key collision between distinct shapes: solve solo
            drop(slots);
            return self.solve_here(u, v, active_rows, cfg, seeds);
        }
        let slot = Arc::new(Slot {
            cfg: cfg.clone(),
            len,
            k,
            state: Mutex::new(SlotState::default()),
            cv: Condvar::new(),
        });
        {
            let mut st = slot.state.lock().unwrap();
            st.pendings.push(pending);
            st.results.push(None);
        }
        slots.insert(key, Arc::clone(&slot));
        drop(slots);
        self.lead(key, &slot)
    }

    /// Leader path: wait out the window (closing early once every
    /// in-flight solve joined), seal the slot, run the merged solve, and
    /// distribute the per-participant slices.
    fn lead(&self, key: u64, slot: &Arc<Slot>) -> Vec<(Mat, Mat)> {
        let deadline = Instant::now() + self.window;
        {
            let mut st = slot.state.lock().unwrap();
            loop {
                if st.pendings.len() >= self.active.load(Ordering::Acquire) {
                    break; // everyone who could join has
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, _) = slot.cv.wait_timeout(st, deadline - now).unwrap();
                st = g;
            }
        }
        // seal: remove from the map first (under `slots` alone), so no
        // new joiner can reach the slot, then take the staged lanes
        {
            let mut slots = self.slots.lock().unwrap();
            let removed = slots.remove(&key);
            debug_assert!(removed.is_some(), "leader's slot vanished");
        }
        let pendings = std::mem::take(&mut slot.state.lock().unwrap().pendings);
        let parts = pendings.len();
        let total: usize = pendings.iter().map(|p| p.lanes).sum();
        if parts >= 2 {
            self.metrics.micro_merged_calls.fetch_add(1, Ordering::Relaxed);
            self.metrics.micro_merged_lanes.fetch_add(total, Ordering::Relaxed);
        }

        // merge: uniform lanes (len rows × k cols on both sides — block
        // co-clusters are square and share one factor width per scale)
        let lane_elems = slot.len * slot.k;
        let mut ud = Vec::with_capacity(total * lane_elems);
        let mut vd = Vec::with_capacity(total * lane_elems);
        let mut seeds = Vec::with_capacity(total);
        for p in &pendings {
            ud.extend_from_slice(&p.u);
            vd.extend_from_slice(&p.v);
            seeds.extend_from_slice(&p.seeds);
        }
        let items: Vec<BatchItem> =
            (0..total).map(|l| BatchItem::new(l * slot.len..(l + 1) * slot.len, slot.k)).collect();
        let actives = vec![(slot.len, slot.len); total];
        let outs = lrot::solve_factored_batch(
            BatchView::new(&ud, &items),
            BatchView::new(&vd, &items),
            &actives,
            &slot.cfg,
            &seeds,
            &self.arena,
            self.threads,
        );

        // distribute + wake the joiners; the leader is participant 0
        let mut iter = outs.into_iter().map(|o| (o.q, o.r));
        let mut mine = Vec::new();
        {
            let mut st = slot.state.lock().unwrap();
            for (i, p) in pendings.iter().enumerate() {
                let slice: Vec<(Mat, Mat)> = iter.by_ref().take(p.lanes).collect();
                if i == 0 {
                    mine = slice;
                } else {
                    st.results[i] = Some(slice);
                }
            }
            st.done = true;
            slot.cv.notify_all();
        }
        mine
    }

    /// Joiner path: block until the leader distributes.
    fn wait_result(&self, slot: &Slot, my_idx: usize) -> Vec<(Mat, Mat)> {
        let mut st = slot.state.lock().unwrap();
        loop {
            if st.done {
                return st.results[my_idx].take().expect("leader distributed every slice");
            }
            st = slot.cv.wait(st).unwrap();
        }
    }

    /// Unmerged local solve (passthrough and collision fallback).
    fn solve_here(
        &self,
        u: BatchView<'_>,
        v: BatchView<'_>,
        active_rows: usize,
        cfg: &LrotConfig,
        seeds: &[u64],
    ) -> Vec<(Mat, Mat)> {
        let actives = vec![(active_rows, active_rows); u.len()];
        lrot::solve_factored_batch(u, v, &actives, cfg, seeds, &self.arena, self.threads)
            .into_iter()
            .map(|o| (o.q, o.r))
            .collect()
    }
}

fn same_cfg(a: &LrotConfig, b: &LrotConfig) -> bool {
    a.rank == b.rank
        && a.outer == b.outer
        && a.inner == b.inner
        && a.gamma.to_bits() == b.gamma.to_bits()
        && a.tau.to_bits() == b.tau.to_bits()
}

// ---------------------------------------------------------------------------
// JobHooks
// ---------------------------------------------------------------------------

/// Per-request [`SolveHooks`]: a deadline that cancels the run between
/// batches, and an optional microbatcher that takes over LROT dispatch.
pub struct JobHooks {
    /// Absolute deadline; `None` means the request never times out.
    pub deadline: Option<Instant>,
    /// Cross-request lane merger; `None` solves every batch locally.
    pub micro: Option<Arc<Microbatcher>>,
}

impl SolveHooks for JobHooks {
    fn cancelled(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    fn lrot_batch(
        &self,
        u: BatchView<'_>,
        v: BatchView<'_>,
        active: usize,
        cfg: &LrotConfig,
        seeds: &[u64],
    ) -> Option<Vec<(Mat, Mat)>> {
        self.micro.as_ref().map(|m| m.submit(u, v, active, cfg, seeds))
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// Why a job was refused at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The admission queue is at capacity.
    Overloaded,
    /// The server is draining; no new work is admitted.
    ShuttingDown,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct SchedState {
    queue: VecDeque<Job>,
    stopping: bool,
}

/// Bounded worker pool with bounded admission and graceful drain.
pub struct Scheduler {
    state: Mutex<SchedState>,
    cv: Condvar,
    cap: usize,
    workers: Mutex<Vec<JoinHandle<()>>>,
    metrics: Arc<ServeMetrics>,
}

impl Scheduler {
    /// Spawn `workers` threads consuming a queue of at most `cap` waiting
    /// jobs (jobs being executed don't count against `cap`).
    pub fn new(workers: usize, cap: usize, metrics: Arc<ServeMetrics>) -> Arc<Scheduler> {
        let sched = Arc::new(Scheduler {
            state: Mutex::new(SchedState { queue: VecDeque::new(), stopping: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
            workers: Mutex::new(Vec::new()),
            metrics,
        });
        let mut handles = sched.workers.lock().unwrap();
        for i in 0..workers.max(1) {
            let s = Arc::clone(&sched);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("hiref-serve-worker-{i}"))
                    .spawn(move || s.worker_loop())
                    .expect("spawn worker"),
            );
        }
        drop(handles);
        sched
    }

    fn worker_loop(&self) {
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if let Some(job) = st.queue.pop_front() {
                        self.metrics.note_queue_depth(st.queue.len());
                        break Some(job);
                    }
                    if st.stopping {
                        break None; // drained: queue empty and no more admits
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            match job {
                Some(job) => job(),
                None => return,
            }
        }
    }

    /// Admit a job, or refuse with a typed reason.  Never blocks.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<(), Rejected> {
        let mut st = self.state.lock().unwrap();
        if st.stopping {
            return Err(Rejected::ShuttingDown);
        }
        if st.queue.len() >= self.cap {
            return Err(Rejected::Overloaded);
        }
        st.queue.push_back(Box::new(job));
        self.metrics.note_queue_depth(st.queue.len());
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Graceful shutdown: stop admitting, run everything already queued,
    /// join the workers.  Idempotent.
    pub fn drain(&self) {
        {
            let mut st = self.state.lock().unwrap();
            st.stopping = true;
        }
        self.cv.notify_all();
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn metrics() -> Arc<ServeMetrics> {
        Arc::new(ServeMetrics::default())
    }

    /// A deterministic little factor batch: `lanes` lanes of `len × k`.
    fn batch_data(lanes: usize, len: usize, k: usize, seed: u64) -> (Vec<f32>, Vec<BatchItem>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> =
            (0..lanes * len * k).map(|_| (rng.next_u64() % 997) as f32 / 331.0).collect();
        let items = (0..lanes).map(|l| BatchItem::new(l * len..(l + 1) * len, k)).collect();
        (data, items)
    }

    fn solo(
        data: &(Vec<f32>, Vec<BatchItem>),
        vdata: &(Vec<f32>, Vec<BatchItem>),
        len: usize,
        cfg: &LrotConfig,
        seeds: &[u64],
    ) -> Vec<(Mat, Mat)> {
        let arena = ScratchArena::new(2);
        lrot::solve_factored_batch(
            BatchView::new(&data.0, &data.1),
            BatchView::new(&vdata.0, &vdata.1),
            &vec![(len, len); data.1.len()],
            cfg,
            seeds,
            &arena,
            2,
        )
        .into_iter()
        .map(|o| (o.q, o.r))
        .collect()
    }

    fn assert_outs_eq(a: &[(Mat, Mat)], b: &[(Mat, Mat)]) {
        assert_eq!(a.len(), b.len());
        for ((q1, r1), (q2, r2)) in a.iter().zip(b) {
            assert_eq!(q1.data, q2.data, "Q drifted");
            assert_eq!(r1.data, r2.data, "R drifted");
        }
    }

    #[test]
    fn merged_submissions_are_bit_identical_to_solo() {
        let (len, k) = (8, 4);
        let cfg = LrotConfig { rank: 2, outer: 12, inner: 6, gamma: 8.0, tau: 0.01 };
        let a_u = batch_data(2, len, k, 11);
        let a_v = batch_data(2, len, k, 12);
        let b_u = batch_data(3, len, k, 13);
        let b_v = batch_data(3, len, k, 14);
        let a_seeds = [101u64, 102];
        let b_seeds = [201u64, 202, 203];
        let want_a = solo(&a_u, &a_v, len, &cfg, &a_seeds);
        let want_b = solo(&b_u, &b_v, len, &cfg, &b_seeds);

        let m = Arc::new(Microbatcher::new(Duration::from_millis(2000), 2, metrics()));
        // both guards exist before either submit, so the leader's
        // "everyone joined" close fires deterministically at 2 parts
        let ga = m.begin_solve();
        let gb = m.begin_solve();
        let (got_a, got_b) = std::thread::scope(|s| {
            let ma = Arc::clone(&m);
            let mb = Arc::clone(&m);
            let (cfg_a, cfg_b) = (&cfg, &cfg);
            let ta = s.spawn(move || {
                let out = ma.submit(
                    BatchView::new(&a_u.0, &a_u.1),
                    BatchView::new(&a_v.0, &a_v.1),
                    len,
                    cfg_a,
                    &a_seeds,
                );
                drop(ga);
                out
            });
            let tb = s.spawn(move || {
                let out = mb.submit(
                    BatchView::new(&b_u.0, &b_u.1),
                    BatchView::new(&b_v.0, &b_v.1),
                    len,
                    cfg_b,
                    &b_seeds,
                );
                drop(gb);
                out
            });
            (ta.join().unwrap(), tb.join().unwrap())
        });
        assert_outs_eq(&got_a, &want_a);
        assert_outs_eq(&got_b, &want_b);
        assert_eq!(m.metrics.micro_calls.load(Ordering::Relaxed), 2);
        assert_eq!(m.metrics.micro_lanes.load(Ordering::Relaxed), 5);
        assert_eq!(m.metrics.micro_merged_calls.load(Ordering::Relaxed), 1, "one merged solve");
        assert_eq!(m.metrics.micro_merged_lanes.load(Ordering::Relaxed), 5, "all lanes rode it");
        assert!(m.slots.lock().unwrap().is_empty(), "slot sealed and removed");
    }

    #[test]
    fn lone_or_windowless_submissions_pass_through() {
        let (len, k) = (8, 4);
        let cfg = LrotConfig { rank: 2, outer: 10, inner: 5, gamma: 8.0, tau: 0.01 };
        let u = batch_data(2, len, k, 5);
        let v = batch_data(2, len, k, 6);
        let seeds = [7u64, 8];
        let want = solo(&u, &v, len, &cfg, &seeds);
        // no guard registered → instant passthrough
        let m = Arc::new(Microbatcher::new(Duration::from_millis(2000), 2, metrics()));
        let got = m.submit(BatchView::new(&u.0, &u.1), BatchView::new(&v.0, &v.1), len, &cfg, &seeds);
        assert_outs_eq(&got, &want);
        // zero window → passthrough even with other solves in flight
        let m0 = Arc::new(Microbatcher::new(Duration::ZERO, 2, metrics()));
        let _g1 = m0.begin_solve();
        let _g2 = m0.begin_solve();
        let got0 =
            m0.submit(BatchView::new(&u.0, &u.1), BatchView::new(&v.0, &v.1), len, &cfg, &seeds);
        assert_outs_eq(&got0, &want);
        for m in [&m, &m0] {
            assert_eq!(m.metrics.micro_merged_calls.load(Ordering::Relaxed), 0);
            assert_eq!(m.metrics.micro_calls.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn leader_window_expires_without_joiners() {
        // two solves in flight, but only one submits: the leader must
        // time its window out and solve alone (no deadlock, no merge)
        let (len, k) = (4, 3);
        let cfg = LrotConfig { rank: 2, outer: 6, inner: 4, gamma: 8.0, tau: 0.01 };
        let u = batch_data(1, len, k, 1);
        let v = batch_data(1, len, k, 2);
        let want = solo(&u, &v, len, &cfg, &[9]);
        let m = Arc::new(Microbatcher::new(Duration::from_millis(20), 2, metrics()));
        let _g1 = m.begin_solve();
        let _g2 = m.begin_solve(); // never submits
        let got = m.submit(BatchView::new(&u.0, &u.1), BatchView::new(&v.0, &v.1), len, &cfg, &[9]);
        assert_outs_eq(&got, &want);
        assert_eq!(m.metrics.micro_merged_calls.load(Ordering::Relaxed), 0);
        assert!(m.slots.lock().unwrap().is_empty());
    }

    #[test]
    fn scheduler_overload_is_typed_and_deterministic() {
        let met = metrics();
        let sched = Scheduler::new(1, 1, Arc::clone(&met));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let running = Arc::new((Mutex::new(false), Condvar::new()));
        let (g, r) = (Arc::clone(&gate), Arc::clone(&running));
        // occupy the single worker until we open the gate
        sched
            .submit(move || {
                *r.0.lock().unwrap() = true;
                r.1.notify_all();
                let mut open = g.0.lock().unwrap();
                while !*open {
                    open = g.1.wait(open).unwrap();
                }
            })
            .unwrap();
        {
            let mut started = running.0.lock().unwrap();
            while !*started {
                started = running.1.wait(started).unwrap();
            }
        }
        let ran = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&ran);
        sched.submit(move || { r2.fetch_add(1, Ordering::Relaxed); }).unwrap(); // fills the queue
        let r3 = Arc::clone(&ran);
        assert_eq!(
            sched.submit(move || { r3.fetch_add(1, Ordering::Relaxed); }),
            Err(Rejected::Overloaded)
        );
        *gate.0.lock().unwrap() = true;
        gate.1.notify_all();
        sched.drain();
        assert_eq!(ran.load(Ordering::Relaxed), 1, "queued job ran, rejected job did not");
        assert_eq!(sched.submit(|| {}), Err(Rejected::ShuttingDown));
        assert!(met.queue_peak.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn drain_finishes_admitted_work() {
        let sched = Scheduler::new(2, 64, metrics());
        let ran = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let r = Arc::clone(&ran);
            sched.submit(move || { r.fetch_add(1, Ordering::Relaxed); }).unwrap();
        }
        sched.drain();
        assert_eq!(ran.load(Ordering::Relaxed), 16, "drain ran every admitted job");
    }
}
