//! Wire protocol of `hiref serve`: **newline-delimited JSON over TCP**.
//!
//! One request per line, one reply per line, always in order.  Every
//! request is a JSON object with a `"verb"` and an optional `"id"` the
//! server echoes verbatim into the reply, so clients may correlate
//! replies however they like.  Replies are `{"id":…, "ok":true, …}` or
//! `{"id":…, "ok":false, "error":{"kind":…, "message":…}}` — the `kind`
//! is a stable machine-matchable string mapped from
//! [`SolveError`] (plus the protocol-level kinds `overloaded`,
//! `timeout`, `bad_request`, `unknown_verb`, `unknown_dataset`,
//! `shutting_down`).
//!
//! The vendored crate universe has no serde, so this module carries a
//! small hand-rolled JSON value type ([`Json`]), parser and writer —
//! complete for the protocol's needs (objects, arrays, escaped strings
//! incl. `\uXXXX` surrogate pairs, f64 numbers, bools, null) and
//! hardened with a nesting-depth cap.  See `docs/serve.md` for the full
//! protocol reference with a worked client example.

#![forbid(unsafe_code)]

use crate::api::SolveError;

/// Maximum nesting depth [`parse`] accepts — a cheap guard against
/// stack-exhaustion from adversarial input on a listening socket.
const MAX_DEPTH: usize = 64;

/// A JSON value.  Object fields keep insertion order (`Vec`, not a map):
/// replies render deterministically and duplicate keys are a client bug
/// surfaced by [`Json::get`] returning the first match.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// First field named `key` of an object (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Field `key` as a string.
    pub fn str_field(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Json::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Field `key` as a non-negative integer (rejects fractions and
    /// anything beyond exact-f64 range).
    pub fn u64_field(&self, key: &str) -> Option<u64> {
        match self.get(key) {
            Some(&Json::Num(n)) if n >= 0.0 && n.fract() == 0.0 && n <= 9.007_199_254_740_992e15 => {
                Some(n as u64)
            }
            _ => None,
        }
    }

    /// The value as an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The value as an f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            &Json::Num(n) => Some(n),
            _ => None,
        }
    }

    /// Serialise (compact, single line — ready for the wire).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // integral values print as integers (permutation ids,
                    // counters); Rust's f64 Display round-trips the rest
                    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null"); // NaN/inf have no JSON spelling
                }
            }
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one JSON document (the whole input must be consumed).
pub fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, lit: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(format!("expected `{lit}` at offset {}", self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') => self.eat("null").map(|()| Json::Null),
            Some(b't') => self.eat("true").map(|()| Json::Bool(true)),
            Some(b'f') => self.eat("false").map(|()| Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(format!("expected `,` or `]` at offset {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    if self.peek() != Some(b':') {
                        return Err(format!("expected `:` at offset {}", self.i));
                    }
                    self.i += 1;
                    self.skip_ws();
                    let v = self.value(depth + 1)?;
                    fields.push((k, v));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(format!("expected `,` or `}}` at offset {}", self.i)),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.peek() != Some(b'"') {
            return Err(format!("expected string at offset {}", self.i));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: a low surrogate must follow
                                self.eat("\\u").map_err(|_| "lone high surrogate".to_string())?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("invalid low surrogate".into());
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| format!("invalid codepoint {cp:#x}"))?,
                            );
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                _ => {
                    // copy the full UTF-8 sequence this byte starts
                    let start = self.i - 1;
                    while self.peek().is_some_and(|b| b & 0xC0 == 0x80) {
                        self.i += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| format!("bad \\u escape `{s}`"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{s}` at offset {start}"))
    }
}

// ---------------------------------------------------------------------------
// Reply construction + error kinds
// ---------------------------------------------------------------------------

/// The stable machine-matchable kind string of a [`SolveError`] (the
/// protocol-level kinds `overloaded`/`timeout`/`bad_request`/… are minted
/// directly by the server, not mapped from solver errors —
/// [`SolveError::Cancelled`] is the one exception: a deadline observed
/// mid-solve surfaces as `timeout`).
pub fn error_kind(e: &SolveError) -> &'static str {
    match e {
        SolveError::ShapeMismatch { .. } => "shape_mismatch",
        SolveError::DimMismatch { .. } => "dim_mismatch",
        SolveError::EmptyInput => "empty_input",
        SolveError::NotSquare { .. } => "not_square",
        SolveError::InvalidConfig(_) => "invalid_config",
        SolveError::UnknownSolver { .. } => "unknown_solver",
        SolveError::Backend(_) => "backend",
        SolveError::Cancelled => "timeout",
        SolveError::IncompleteAssignment { .. } => "incomplete_assignment",
    }
}

/// A success reply: `{"id":…, "ok":true, <fields>}`.
pub fn reply_ok(id: Option<&Json>, fields: Vec<(String, Json)>) -> String {
    let mut obj = vec![
        ("id".to_string(), id.cloned().unwrap_or(Json::Null)),
        ("ok".to_string(), Json::Bool(true)),
    ];
    obj.extend(fields);
    Json::Obj(obj).render()
}

/// A typed error reply: `{"id":…, "ok":false, "error":{"kind":…, "message":…}}`.
pub fn reply_err(id: Option<&Json>, kind: &str, message: &str) -> String {
    Json::Obj(vec![
        ("id".to_string(), id.cloned().unwrap_or(Json::Null)),
        ("ok".to_string(), Json::Bool(false)),
        (
            "error".to_string(),
            Json::Obj(vec![
                ("kind".to_string(), Json::Str(kind.to_string())),
                ("message".to_string(), Json::Str(message.to_string())),
            ]),
        ),
    ])
    .render()
}

/// [`reply_err`] from a typed [`SolveError`].
pub fn reply_solve_err(id: Option<&Json>, e: &SolveError) -> String {
    reply_err(id, error_kind(e), &e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(src: &str) -> Json {
        let v = parse(src).unwrap();
        let rendered = v.render();
        assert_eq!(parse(&rendered).unwrap(), v, "render/parse drift for {src}");
        v
    }

    #[test]
    fn parses_the_protocol_shapes() {
        let v = round_trip(r#"{"id":7,"verb":"solve","x":"ab12","deadline_ms":250}"#);
        assert_eq!(v.str_field("verb"), Some("solve"));
        assert_eq!(v.u64_field("id"), Some(7));
        assert_eq!(v.u64_field("deadline_ms"), Some(250));
        assert_eq!(v.u64_field("x"), None);
        let v = round_trip(r#"{"rows":[[1.5,-2],[3e2,0.25]],"empty":[],"none":null,"t":true}"#);
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].as_arr().unwrap()[0].as_f64(), Some(300.0));
        assert_eq!(v.get("none"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = round_trip(r#""a\"b\\c\n\t\u00e9 \ud83e\udd80""#);
        assert_eq!(v, Json::Str("a\"b\\c\n\té 🦀".to_string()));
        // control characters render as escapes
        assert_eq!(Json::Str("\u{1}".into()).render(), r#""\u0001""#);
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\" 1}",
            "tru",
            "\"unterminated",
            "1 2",
            "{\"a\":}",
            "\"\\ud800x\"",
            "nan",
        ] {
            assert!(parse(bad).is_err(), "accepted malformed `{bad}`");
        }
        // the depth cap holds
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err());
        assert!(parse(&("[".repeat(10) + &"]".repeat(10))).is_ok());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(4096.0).render(), "4096");
        assert_eq!(Json::Num(-3.0).render(), "-3");
        assert_eq!(Json::Num(0.5).render(), "0.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn replies_have_the_documented_shape() {
        let id = Json::Num(3.0);
        let ok = reply_ok(Some(&id), vec![("rows".into(), Json::Num(8.0))]);
        let v = parse(&ok).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.u64_field("rows"), Some(8));
        let err = reply_err(None, "overloaded", "queue full");
        let v = parse(&err).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(v.get("error").unwrap().str_field("kind"), Some("overloaded"));
        // SolveError mapping: every variant has a stable kind
        assert_eq!(error_kind(&SolveError::Cancelled), "timeout");
        assert_eq!(error_kind(&SolveError::EmptyInput), "empty_input");
        let v = parse(&reply_solve_err(None, &SolveError::ShapeMismatch { n: 3, m: 5 })).unwrap();
        assert_eq!(v.get("error").unwrap().str_field("kind"), Some("shape_mismatch"));
    }
}
