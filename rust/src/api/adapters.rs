//! [`TransportSolver`] implementations for HiRef and every baseline the
//! paper benchmarks against.  Each adapter owns the legacy solver's
//! configuration struct as a public field, so callers can tune any solver
//! and still drive it through the uniform interface.

use std::time::Instant;

use crate::coordinator::hiref::{HiRef, HiRefConfig};
use crate::costs::{self, CostKind};
use crate::data::stream::DatasetSource;
use crate::solvers::exact;
use crate::solvers::lrot::{self, LrotConfig};
use crate::solvers::minibatch::{self, MiniBatchConfig};
use crate::solvers::mop;
use crate::solvers::progot::{self, ProgOtConfig};
use crate::solvers::sinkhorn::{self, SinkhornConfig};

use super::coupling::Coupling;
use super::error::SolveError;
use super::problem::{Solved, SolveStats, TransportProblem, TransportSolver};

/// Hierarchical Refinement (the paper's contribution).  The problem's
/// `kind`/`seed` override the config's `cost`/`seed` fields so one adapter
/// serves every instance uniformly.
#[derive(Clone, Debug, Default)]
pub struct HiRefSolver {
    pub cfg: HiRefConfig,
}

impl HiRefSolver {
    /// Streaming solve: both point clouds arrive as chunked
    /// [`DatasetSource`]s and are never materialised in full
    /// ([`HiRef::align_source`]).  Not part of [`TransportSolver`] —
    /// [`TransportProblem`] carries borrowed matrices — but returns the
    /// same uniform [`Solved`] so downstream reporting is shared.
    pub fn solve_source(
        &self,
        x: &dyn DatasetSource,
        y: &dyn DatasetSource,
        kind: CostKind,
        seed: u64,
    ) -> Result<Solved, SolveError> {
        let mut cfg = self.cfg.clone();
        cfg.cost = kind;
        cfg.seed = seed;
        let t0 = Instant::now();
        let out = HiRef::new(cfg).align_source(x, y)?;
        Ok(Solved {
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: out.schedule.len(),
                hiref: Some(out.stats.clone()),
            },
            coupling: Coupling::Bijection(out.perm),
        })
    }
}

impl TransportSolver for HiRefSolver {
    fn name(&self) -> &'static str {
        "hiref"
    }

    fn describe(&self) -> &'static str {
        "Hierarchical Refinement (this paper): bijection, linear space, log-linear time"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        prob.require_equal_sizes()?;
        let mut cfg = self.cfg.clone();
        cfg.cost = prob.kind;
        cfg.seed = prob.seed;
        let t0 = Instant::now();
        let solver = HiRef::new(cfg);
        let out = match prob.factors {
            // caller-supplied factors skip the factorisation pass
            Some((u, v)) => solver.align_prefactored(u.clone(), v.clone(), prob.x, prob.y)?,
            None => solver.align(prob.x, prob.y)?,
        };
        Ok(Solved {
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: out.schedule.len(),
                hiref: Some(out.stats.clone()),
            },
            coupling: Coupling::Bijection(out.perm),
        })
    }
}

/// Log-domain Sinkhorn (Cuturi 2013) — the dense entropic baseline.
#[derive(Clone, Debug, Default)]
pub struct SinkhornSolver {
    pub cfg: SinkhornConfig,
}

impl TransportSolver for SinkhornSolver {
    fn name(&self) -> &'static str {
        "sinkhorn"
    }

    fn describe(&self) -> &'static str {
        "Sinkhorn (Cuturi 2013): dense entropic coupling, quadratic memory"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        let t0 = Instant::now();
        let c = prob.cost_matrix();
        let out = sinkhorn::solve(&c, &self.cfg);
        Ok(Solved {
            coupling: Coupling::Dense(out.coupling),
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: out.iters,
                hiref: None,
            },
        })
    }
}

/// ProgOT (Kassraie et al. 2024) — progressive entropic baseline.
///
/// Ignores `TransportProblem::cost`: each stage displaces the source
/// points along the barycentric map and re-derives the stage cost, so a
/// fixed precomputed matrix cannot be reused.
#[derive(Clone, Debug, Default)]
pub struct ProgOtSolver {
    pub cfg: ProgOtConfig,
}

impl TransportSolver for ProgOtSolver {
    fn name(&self) -> &'static str {
        "progot"
    }

    fn describe(&self) -> &'static str {
        "ProgOT (Kassraie et al. 2024): progressive entropic coupling, dense"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        let t0 = Instant::now();
        let plan = progot::solve(prob.x, prob.y, prob.kind, &self.cfg);
        Ok(Solved {
            coupling: Coupling::Dense(plan),
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: self.cfg.stages,
                hiref: None,
            },
        })
    }
}

/// Mini-batch OT (Genevay et al. 2018; Fatras et al. 2020/21).
#[derive(Clone, Debug, Default)]
pub struct MiniBatchSolver {
    pub cfg: MiniBatchConfig,
}

impl TransportSolver for MiniBatchSolver {
    fn name(&self) -> &'static str {
        "minibatch"
    }

    fn describe(&self) -> &'static str {
        "Mini-batch OT (Fatras et al. 2020/21): biased block-diagonal bijection"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        let n = prob.require_equal_sizes()?;
        let mut cfg = self.cfg.clone();
        cfg.seed = prob.seed;
        let t0 = Instant::now();
        let perm = minibatch::solve(prob.x, prob.y, prob.kind, &cfg);
        Ok(Solved {
            coupling: Coupling::Bijection(perm),
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: n.div_ceil(cfg.batch.clamp(1, n)),
                hiref: None,
            },
        })
    }
}

/// MOP multiscale OT (Gerber & Maggioni 2017).
#[derive(Clone, Debug, Default)]
pub struct MopSolver;

impl TransportSolver for MopSolver {
    fn name(&self) -> &'static str {
        "mop"
    }

    fn describe(&self) -> &'static str {
        "MOP (Gerber & Maggioni 2017): multiscale sparse coupling"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        prob.require_equal_sizes()?;
        let t0 = Instant::now();
        let (sc, _cost) = mop::solve_sparse(prob.x, prob.y, prob.kind);
        Ok(Solved {
            coupling: Coupling::Sparse(sc),
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: 0,
                hiref: None,
            },
        })
    }
}

/// Low-rank OT (Scetbon et al. 2021 / FRLC) as a standalone baseline.
#[derive(Clone, Debug)]
pub struct LrotSolver {
    pub cfg: LrotConfig,
    /// Factor width for non-factorisable costs (Indyk et al. 2019).
    pub indyk_width: usize,
}

impl Default for LrotSolver {
    fn default() -> Self {
        LrotSolver { cfg: LrotConfig { rank: 8, ..LrotConfig::default() }, indyk_width: 32 }
    }
}

impl TransportSolver for LrotSolver {
    fn name(&self) -> &'static str {
        "lrot"
    }

    fn describe(&self) -> &'static str {
        "Low-rank OT (Scetbon et al. 2021 / FRLC): factored coupling, linear space"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        if self.cfg.rank < 1 {
            return Err(SolveError::InvalidConfig("lrot rank must be >= 1".into()));
        }
        let t0 = Instant::now();
        // caller-supplied factors skip the factorisation pass — and are
        // only borrowed (solve_factored reads views), never cloned
        let computed;
        let (u, v) = match prob.factors {
            Some((u, v)) => (u, v),
            None => {
                computed =
                    costs::factors_for(prob.x, prob.y, prob.kind, self.indyk_width, prob.seed);
                (&computed.0, &computed.1)
            }
        };
        let rank = self.cfg.rank.min(prob.x.rows).min(prob.y.rows).max(1);
        let cfg = LrotConfig { rank, ..self.cfg.clone() };
        let out = lrot::solve_factored(u, v, prob.x.rows, prob.y.rows, &cfg, prob.seed);
        Ok(Solved {
            coupling: Coupling::LowRank {
                q: out.q,
                r: out.r,
                diag: vec![1.0 / rank as f64; rank],
            },
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: cfg.outer,
                hiref: None,
            },
        })
    }
}

/// Exact assignment (Hungarian below the cutoff, ε-scaling auction above)
/// — the paper's dual-simplex stand-in.
#[derive(Clone, Debug)]
pub struct ExactSolver {
    /// Instances up to this size use Hungarian; larger ones the auction.
    pub hungarian_cutoff: usize,
}

impl Default for ExactSolver {
    fn default() -> Self {
        ExactSolver { hungarian_cutoff: 512 }
    }
}

impl TransportSolver for ExactSolver {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn describe(&self) -> &'static str {
        "Exact assignment (Hungarian / auction): optimal bijection, cubic time"
    }

    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError> {
        prob.validate()?;
        let n = prob.require_equal_sizes()?;
        let t0 = Instant::now();
        let c = prob.cost_matrix();
        let perm = if n <= self.hungarian_cutoff {
            exact::hungarian(&c)
        } else {
            exact::auction(&c, 1.0)
        };
        Ok(Solved {
            coupling: Coupling::Bijection(perm),
            stats: SolveStats {
                solver: self.name(),
                elapsed: t0.elapsed(),
                iterations: 0,
                hiref: None,
            },
        })
    }
}
