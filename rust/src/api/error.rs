//! Typed errors for the unified solver API.
//!
//! Every fallible path in the solver stack returns [`SolveError`] — the
//! crate carries no `anyhow`-style dynamic errors, so callers (the CLI,
//! services routing workloads to backends) can match on the failure mode.

use std::fmt;

/// Everything that can go wrong constructing or running a transport solve.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SolveError {
    /// The two datasets have different sizes where a one-to-one alignment
    /// requires equal ones.
    ShapeMismatch { n: usize, m: usize },
    /// The two datasets live in different ambient dimensions.
    DimMismatch { dx: usize, dy: usize },
    /// One of the datasets is empty.
    EmptyInput,
    /// A bijection was requested from a non-square coupling.
    NotSquare { n: usize, m: usize },
    /// A configuration value was rejected at build time.
    InvalidConfig(String),
    /// No solver registered under this name.
    UnknownSolver { name: String, known: Vec<String> },
    /// A backend (e.g. the PJRT runtime) is unavailable or failed.
    Backend(String),
    /// The run was aborted by its host before completing — a per-request
    /// deadline or service shutdown observed through
    /// [`crate::coordinator::hiref::SolveHooks::cancelled`].  The serve
    /// protocol maps this to its typed `timeout` reply.
    Cancelled,
    /// The refinement recursion finished without pairing every point — a
    /// solver-internal invariant violation (balanced splits must partition
    /// both sides), surfaced as a typed error instead of a silent
    /// `u32::MAX` entry in the output permutation.
    IncompleteAssignment { n: usize, unassigned: usize },
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolveError::ShapeMismatch { n, m } => {
                write!(f, "datasets must be equal-sized and nonempty (got {n} vs {m} points)")
            }
            SolveError::DimMismatch { dx, dy } => {
                write!(f, "dimension mismatch: {dx} vs {dy}")
            }
            SolveError::EmptyInput => write!(f, "empty input dataset"),
            SolveError::NotSquare { n, m } => {
                write!(f, "cannot round a {n}x{m} coupling to a bijection (needs n = m)")
            }
            SolveError::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            SolveError::UnknownSolver { name, known } => {
                write!(f, "unknown solver '{name}' (valid solvers: {})", known.join(", "))
            }
            SolveError::Backend(msg) => write!(f, "backend error: {msg}"),
            SolveError::Cancelled => {
                write!(f, "solve cancelled by host (deadline exceeded or shutdown)")
            }
            SolveError::IncompleteAssignment { n, unassigned } => {
                write!(
                    f,
                    "refinement left {unassigned} of {n} points unassigned \
                     (internal invariant violation — please report)"
                )
            }
        }
    }
}

impl std::error::Error for SolveError {}

/// Mid-solve dataset I/O failures (fallible [`crate::data::stream`]
/// sources) surface as [`SolveError::Backend`] so solve paths can `?`
/// straight through.
impl From<std::io::Error> for SolveError {
    fn from(e: std::io::Error) -> SolveError {
        SolveError::Backend(format!("dataset I/O: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_lists_known_solvers() {
        let e = SolveError::UnknownSolver {
            name: "simplex".into(),
            known: vec!["hiref".into(), "sinkhorn".into()],
        };
        let msg = e.to_string();
        assert!(msg.contains("simplex"));
        assert!(msg.contains("hiref, sinkhorn"));
    }

    #[test]
    fn display_shape_mismatch() {
        let e = SolveError::ShapeMismatch { n: 3, m: 5 };
        assert!(e.to_string().contains("3 vs 5"));
    }

    #[test]
    fn display_incomplete_assignment() {
        let e = SolveError::IncompleteAssignment { n: 100, unassigned: 3 };
        let msg = e.to_string();
        assert!(msg.contains("3 of 100"), "{msg}");
    }
}
