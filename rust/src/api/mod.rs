//! The unified solver API: **one interface for HiRef and every baseline**.
//!
//! The paper's comparison (HiRef vs. Sinkhorn, ProgOT, mini-batch, MOP and
//! low-rank OT) is expressed through two abstractions:
//!
//! * [`Coupling`] — the one output type.  Following the factored-coupling
//!   view of Scetbon et al. 2021 and the HiRef output invariant (a
//!   bijection with `n` nonzeros, paper §3.4), a bijection, a dense plan,
//!   low-rank factors and a sparse entry list are all representations of
//!   the same object, with uniform `cost` / `marginal_error` / `entropy` /
//!   `nnz` / `to_bijection` accessors.
//! * [`TransportSolver`] — the one solver interface:
//!   `solve(&TransportProblem) -> Result<Solved, SolveError>`, implemented
//!   by [`HiRefSolver`] and all six solvers in `rust/src/solvers/`,
//!   reachable by name through [`SolverRegistry`] / [`solver`].
//!
//! # Choosing a solver
//!
//! | Registry name | Paper baseline | Output | Scaling |
//! |---|---|---|---|
//! | `hiref` | Hierarchical Refinement (this paper) | bijection | linear space, `O(n log n)` |
//! | `sinkhorn` | Cuturi 2013 (+ ε-schedule) | dense | `O(n²)` memory |
//! | `progot` | Kassraie et al. 2024 | dense | `O(n²)` memory |
//! | `minibatch` | Genevay 2018 / Fatras 2020-21 | bijection | linear, biased |
//! | `mop` | Gerber & Maggioni 2017 | sparse | linear, least accurate |
//! | `lrot` | Scetbon 2021 / FRLC | low-rank | linear space |
//! | `exact` | Kuhn 1955 / Bertsekas auction | bijection | `O(n³)`, optimal |
//!
//! # Example
//!
//! ```
//! use hiref::api::{solver, TransportProblem, TransportSolver};
//! use hiref::costs::CostKind;
//! use hiref::data::synthetic;
//!
//! let (x, y) = synthetic::half_moon_s_curve(96, 0);
//! let prob = TransportProblem::new(&x, &y, CostKind::SqEuclidean).with_seed(7);
//! let solved = solver("minibatch").unwrap().solve(&prob).unwrap();
//! let cost = solved.coupling.cost(&x, &y, CostKind::SqEuclidean);
//! assert!(cost.is_finite() && solved.coupling.nnz() == 96);
//! ```

#![forbid(unsafe_code)]

pub mod adapters;
pub mod builder;
pub mod coupling;
pub mod error;
pub mod problem;
pub mod registry;

pub use adapters::{
    ExactSolver, HiRefSolver, LrotSolver, MiniBatchSolver, MopSolver, ProgOtSolver,
    SinkhornSolver,
};
pub use builder::HiRefBuilder;
pub use coupling::{Coupling, SparseCoupling, NNZ_THRESH};
pub use error::SolveError;
pub use problem::{Solved, SolveStats, TransportProblem, TransportSolver};
pub use registry::{canonical_name, solver, SolverRegistry, SOLVER_NAMES};
