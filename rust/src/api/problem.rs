//! The [`TransportProblem`] → [`TransportSolver`] → [`Solved`] contract.
//!
//! A problem bundles everything a solver needs — the two point clouds, the
//! ground cost, a seed, and optionally a precomputed dense cost matrix so
//! several dense baselines can share one `O(n·m)` build.  A solver turns
//! it into a [`Coupling`] plus uniform diagnostics.

use std::borrow::Cow;
use std::time::Duration;

use crate::coordinator::hiref::{LevelStat, RunStats};
use crate::costs::{self, CostKind};
use crate::linalg::Mat;

use super::coupling::Coupling;
use super::error::SolveError;

/// One transport instance: `x` (n×d) to `y` (m×d) under `kind`.
#[derive(Clone, Copy)]
pub struct TransportProblem<'a> {
    pub x: &'a Mat,
    pub y: &'a Mat,
    pub kind: CostKind,
    /// Seed threaded into every stochastic solver (LROT noise, mini-batch
    /// partitions, HiRef per-block streams).
    pub seed: u64,
    /// Optional precomputed dense cost matrix (n×m).  Solvers whose input
    /// *is* a fixed cost matrix (Sinkhorn, exact assignment) use it
    /// instead of re-deriving `C`; solvers that iterate on transformed
    /// points (ProgOT displaces the source each stage) or never
    /// materialise `C` at all (HiRef, LROT, MOP, mini-batch) ignore it.
    pub cost: Option<&'a Mat>,
    /// Optional precomputed low-rank cost factors `C ≈ U Vᵀ` (n×k and
    /// m×k).  Factor-consuming solvers (HiRef, LROT) use them instead of
    /// re-factorising — e.g. built once by the chunked streaming builders
    /// ([`costs::factors_for_source`]) and shared across several solves;
    /// dense-cost solvers ignore them.
    pub factors: Option<(&'a Mat, &'a Mat)>,
}

impl<'a> TransportProblem<'a> {
    /// A problem with seed 0 and no precomputed cost.
    pub fn new(x: &'a Mat, y: &'a Mat, kind: CostKind) -> Self {
        TransportProblem { x, y, kind, seed: 0, cost: None, factors: None }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_cost(mut self, cost: &'a Mat) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Attach precomputed low-rank cost factors `C ≈ u · vᵀ` (shapes
    /// validated by [`TransportProblem::validate`]).
    pub fn with_factors(mut self, u: &'a Mat, v: &'a Mat) -> Self {
        self.factors = Some((u, v));
        self
    }

    /// Structural validation shared by every solver.
    pub fn validate(&self) -> Result<(), SolveError> {
        if self.x.rows == 0 || self.y.rows == 0 {
            return Err(SolveError::EmptyInput);
        }
        if self.x.cols != self.y.cols {
            return Err(SolveError::DimMismatch { dx: self.x.cols, dy: self.y.cols });
        }
        if let Some(c) = self.cost {
            if (c.rows, c.cols) != (self.x.rows, self.y.rows) {
                return Err(SolveError::InvalidConfig(format!(
                    "precomputed cost is {}x{} but the problem is {}x{}",
                    c.rows, c.cols, self.x.rows, self.y.rows
                )));
            }
        }
        if let Some((u, v)) = self.factors {
            if u.rows != self.x.rows || v.rows != self.y.rows || u.cols != v.cols {
                return Err(SolveError::InvalidConfig(format!(
                    "precomputed factors are {}x{} / {}x{} but the problem is {} x {} points",
                    u.rows, u.cols, v.rows, v.cols, self.x.rows, self.y.rows
                )));
            }
        }
        Ok(())
    }

    /// `n` when the instance is square (bijective solvers), else an error.
    pub fn require_equal_sizes(&self) -> Result<usize, SolveError> {
        if self.x.rows != self.y.rows {
            return Err(SolveError::ShapeMismatch { n: self.x.rows, m: self.y.rows });
        }
        Ok(self.x.rows)
    }

    /// The dense cost matrix: the precomputed one when supplied, otherwise
    /// freshly built (`O(n·m)` — dense baselines only).
    pub fn cost_matrix(&self) -> Cow<'a, Mat> {
        match self.cost {
            Some(c) => Cow::Borrowed(c),
            None => Cow::Owned(costs::dense_cost(self.x, self.y, self.kind)),
        }
    }
}

/// Uniform per-solve diagnostics.
#[derive(Clone, Debug)]
pub struct SolveStats {
    /// Registry name of the solver that produced the result.
    pub solver: &'static str,
    pub elapsed: Duration,
    /// Solver-specific iteration count (Sinkhorn sweeps, ProgOT stages,
    /// HiRef hierarchy depth, mini-batch count); 0 when not meaningful.
    pub iterations: usize,
    /// HiRef's detailed counters when the solver was HiRef.
    pub hiref: Option<RunStats>,
}

impl SolveStats {
    /// Peak scratch-arena bytes of a HiRef solve — the transient term of
    /// its memory model (linear in `n` at the top of the hierarchy,
    /// `O(threads · base_size²)` at the leaves); 0 for solvers without an
    /// arena.
    pub fn peak_scratch_bytes(&self) -> usize {
        self.hiref.as_ref().map_or(0, |rs| rs.peak_scratch_bytes)
    }

    /// Bytes a HiRef solve wrote to its factor spill files (0 for
    /// resident runs and non-HiRef solvers).
    pub fn spill_bytes_written(&self) -> usize {
        self.hiref.as_ref().map_or(0, |rs| rs.spill_bytes_written)
    }

    /// Factor shard reads a HiRef solve served from its spill files (0
    /// for resident runs and non-HiRef solvers).
    pub fn spill_reads(&self) -> usize {
        self.hiref.as_ref().map_or(0, |rs| rs.spill_reads)
    }

    /// Peak resident factor bytes of a HiRef solve: the full working
    /// copies when resident, `≤ spill_budget + one level batch's lane
    /// windows` when spilled; 0 for non-HiRef solvers.
    pub fn resident_factor_bytes(&self) -> usize {
        self.hiref.as_ref().map_or(0, |rs| rs.resident_factor_bytes)
    }

    /// The kernel implementation the solve's linalg primitives dispatched
    /// to — `"scalar"`, `"avx2"` or `"neon"` (see
    /// [`crate::linalg::kernels`]).  Every solver funnels through the
    /// dispatched kernels, so this is reported even for non-HiRef solves.
    pub fn kernel_path(&self) -> &'static str {
        self.hiref
            .as_ref()
            .map_or_else(|| crate::linalg::kernels::active().as_str(), |rs| rs.kernel_path)
    }

    /// Per-level execution records of a HiRef solve — blocks, lanes,
    /// native mirror-descent iterations, wall time and whether the level
    /// was cluster-warmstarted (see `HiRefConfig::warmstart_levels`);
    /// empty for non-HiRef solvers and per-block (unbatched) runs.
    pub fn level_stats(&self) -> &[LevelStat] {
        self.hiref.as_ref().map_or(&[], |rs| &rs.level_stats)
    }

    /// Stored element format of a HiRef solve's factor working copies —
    /// `"f32"`, `"bf16"` or `"f16"` (see
    /// [`crate::pool::Precision`]); `"f32"` for non-HiRef solvers, which
    /// never narrow.
    pub fn factor_precision(&self) -> &'static str {
        self.hiref
            .as_ref()
            .map_or(crate::pool::Precision::F32.as_str(), |rs| rs.factor_precision)
    }
}

/// A coupling plus how it was obtained.
#[derive(Clone, Debug)]
pub struct Solved {
    pub coupling: Coupling,
    pub stats: SolveStats,
}

/// The one interface every solver implements — HiRef and all five paper
/// baselines.  Obtain implementations from
/// [`super::registry::SolverRegistry`] or [`super::registry::solver`].
pub trait TransportSolver: Send + Sync {
    /// Registry name ("hiref", "sinkhorn", ...).
    fn name(&self) -> &'static str;

    /// One-line description mapping the solver to its paper baseline.
    fn describe(&self) -> &'static str;

    /// Solve the instance, returning a [`Coupling`] plus diagnostics.
    fn solve(&self, prob: &TransportProblem<'_>) -> Result<Solved, SolveError>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn validation_catches_structural_errors() {
        let mut rng = Rng::new(0);
        let x = rand_mat(&mut rng, 8, 2);
        let y3 = rand_mat(&mut rng, 8, 3);
        let y10 = rand_mat(&mut rng, 10, 2);
        let empty = Mat::zeros(0, 2);

        assert!(TransportProblem::new(&x, &x, CostKind::SqEuclidean).validate().is_ok());
        assert_eq!(
            TransportProblem::new(&x, &y3, CostKind::SqEuclidean).validate(),
            Err(SolveError::DimMismatch { dx: 2, dy: 3 })
        );
        assert_eq!(
            TransportProblem::new(&x, &empty, CostKind::SqEuclidean).validate(),
            Err(SolveError::EmptyInput)
        );
        let p = TransportProblem::new(&x, &y10, CostKind::SqEuclidean);
        assert!(p.validate().is_ok());
        assert_eq!(p.require_equal_sizes(), Err(SolveError::ShapeMismatch { n: 8, m: 10 }));
    }

    #[test]
    fn factor_shape_validation() {
        let mut rng = Rng::new(2);
        let x = rand_mat(&mut rng, 8, 2);
        let y = rand_mat(&mut rng, 8, 2);
        let (u, v) = costs::factors_for(&x, &y, CostKind::SqEuclidean, 8, 0);
        let p = TransportProblem::new(&x, &y, CostKind::SqEuclidean).with_factors(&u, &v);
        assert!(p.validate().is_ok());
        let bad = Mat::zeros(7, u.cols);
        let p = TransportProblem::new(&x, &y, CostKind::SqEuclidean).with_factors(&bad, &v);
        assert!(matches!(p.validate(), Err(SolveError::InvalidConfig(_))));
    }

    #[test]
    fn cost_matrix_prefers_precomputed() {
        let mut rng = Rng::new(1);
        let x = rand_mat(&mut rng, 4, 2);
        let y = rand_mat(&mut rng, 5, 2);
        let c = costs::dense_cost(&x, &y, CostKind::Euclidean);
        let p = TransportProblem::new(&x, &y, CostKind::Euclidean).with_cost(&c);
        assert!(p.validate().is_ok());
        let got = p.cost_matrix();
        assert_eq!(got.as_ref(), &c);
        // shape-mismatched precomputed cost is rejected
        let bad = Mat::zeros(4, 4);
        let p = TransportProblem::new(&x, &y, CostKind::Euclidean).with_cost(&bad);
        assert!(matches!(p.validate(), Err(SolveError::InvalidConfig(_))));
    }
}
