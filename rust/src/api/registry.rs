//! Name-keyed registry over every [`TransportSolver`], so the CLI, the
//! benches and the tests dispatch workloads with one string —
//! `hiref::api::solver("sinkhorn")` — instead of hand-wiring six
//! incompatible call sites.

use super::adapters::{
    ExactSolver, HiRefSolver, LrotSolver, MiniBatchSolver, MopSolver, ProgOtSolver,
    SinkhornSolver,
};
use super::error::SolveError;
use super::problem::TransportSolver;

/// Canonical registry names: HiRef plus every baseline in
/// `rust/src/solvers/`.
pub const SOLVER_NAMES: [&str; 7] =
    ["hiref", "sinkhorn", "progot", "minibatch", "mop", "lrot", "exact"];

/// Resolve user-facing aliases and case to the canonical registry name
/// (returns the lowercased input unchanged when it is not an alias).
pub fn canonical_name(name: &str) -> String {
    canonical(name)
}

fn canonical(name: &str) -> String {
    let lower = name.trim().to_ascii_lowercase();
    match lower.as_str() {
        "mb" | "mini-batch" => "minibatch".into(),
        "lot" | "frlc" | "low-rank" | "lowrank" => "lrot".into(),
        "hungarian" | "auction" | "assignment" => "exact".into(),
        "entropic" => "sinkhorn".into(),
        _ => lower,
    }
}

/// Construct a default-configured boxed solver by (possibly aliased) name.
pub fn solver(name: &str) -> Result<Box<dyn TransportSolver>, SolveError> {
    match canonical(name).as_str() {
        "hiref" => Ok(Box::new(HiRefSolver::default())),
        "sinkhorn" => Ok(Box::new(SinkhornSolver::default())),
        "progot" => Ok(Box::new(ProgOtSolver::default())),
        "minibatch" => Ok(Box::new(MiniBatchSolver::default())),
        "mop" => Ok(Box::new(MopSolver)),
        "lrot" => Ok(Box::new(LrotSolver::default())),
        "exact" => Ok(Box::new(ExactSolver::default())),
        _ => Err(SolveError::UnknownSolver {
            name: name.to_string(),
            known: SOLVER_NAMES.iter().map(|s| s.to_string()).collect(),
        }),
    }
}

/// An ordered collection of named solvers.
pub struct SolverRegistry {
    entries: Vec<Box<dyn TransportSolver>>,
}

impl SolverRegistry {
    /// An empty registry (register custom solvers manually).
    pub fn empty() -> SolverRegistry {
        SolverRegistry { entries: Vec::new() }
    }

    /// The full default registry: HiRef plus all five baselines plus the
    /// exact reference solver, each with its default configuration.
    pub fn with_defaults() -> SolverRegistry {
        let mut reg = SolverRegistry::empty();
        for name in SOLVER_NAMES {
            reg.register(solver(name).expect("default solver"));
        }
        reg
    }

    /// Add (or replace, on name collision) a solver.
    pub fn register(&mut self, s: Box<dyn TransportSolver>) {
        if let Some(slot) = self.entries.iter_mut().find(|e| e.name() == s.name()) {
            *slot = s;
        } else {
            self.entries.push(s);
        }
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    /// Look up a solver by (possibly aliased) name.
    pub fn get(&self, name: &str) -> Result<&dyn TransportSolver, SolveError> {
        let canon = canonical(name);
        self.entries
            .iter()
            .find(|e| e.name() == canon)
            .map(|e| e.as_ref())
            .ok_or_else(|| SolveError::UnknownSolver {
                name: name.to_string(),
                known: self.entries.iter().map(|e| e.name().to_string()).collect(),
            })
    }

    /// Iterate over the registered solvers.
    pub fn iter(&self) -> impl Iterator<Item = &dyn TransportSolver> {
        self.entries.iter().map(|e| e.as_ref())
    }
}

impl Default for SolverRegistry {
    fn default() -> Self {
        SolverRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_cover_every_solver_module() {
        let reg = SolverRegistry::with_defaults();
        let names = reg.names();
        for want in SOLVER_NAMES {
            assert!(names.contains(&want), "missing {want}");
        }
        assert_eq!(names.len(), SOLVER_NAMES.len());
    }

    #[test]
    fn aliases_resolve() {
        let reg = SolverRegistry::with_defaults();
        assert_eq!(reg.get("MB").unwrap().name(), "minibatch");
        assert_eq!(reg.get("frlc").unwrap().name(), "lrot");
        assert_eq!(reg.get("hungarian").unwrap().name(), "exact");
        assert_eq!(solver("Sinkhorn").unwrap().name(), "sinkhorn");
    }

    #[test]
    fn unknown_name_lists_known_solvers() {
        let err = solver("simplex").unwrap_err();
        match err {
            SolveError::UnknownSolver { name, known } => {
                assert_eq!(name, "simplex");
                assert_eq!(known.len(), 7);
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn register_replaces_on_name_collision() {
        let mut reg = SolverRegistry::with_defaults();
        let n = reg.names().len();
        reg.register(solver("hiref").unwrap());
        assert_eq!(reg.names().len(), n);
    }
}
