//! Validated construction of [`HiRef`]: the documented way to configure
//! the engine.  Every setter is chainable; [`HiRefBuilder::build`] rejects
//! inconsistent configurations (zero-sized base blocks, a Hungarian
//! cutoff above the base size, a zero thread count, ...) before any work
//! starts, with a typed [`SolveError::InvalidConfig`].

use std::path::PathBuf;

use crate::coordinator::hiref::{BackendKind, HiRef, HiRefConfig, SpillConfig, DEFAULT_SPILL_BUDGET};
use crate::costs::CostKind;
use crate::pool::Precision;
use crate::solvers::lrot::LrotConfig;

use super::error::SolveError;

/// Builder for [`HiRef`] / [`HiRefConfig`].
///
/// ```
/// use hiref::api::HiRefBuilder;
/// use hiref::coordinator::hiref::BackendKind;
///
/// let solver = HiRefBuilder::new()
///     .max_rank(8)
///     .base_size(128)
///     .backend(BackendKind::Native)
///     .build()
///     .unwrap();
/// # let _ = solver;
/// ```
#[derive(Clone, Debug, Default)]
pub struct HiRefBuilder {
    cfg: HiRefConfig,
    spill_dir: Option<PathBuf>,
    spill_budget: Option<usize>,
}

impl HiRefBuilder {
    /// Start from [`HiRefConfig::default`].
    pub fn new() -> HiRefBuilder {
        HiRefBuilder::default()
    }

    /// Ground cost (paper uses both `‖·‖₂` and `‖·‖₂²`).
    pub fn cost(mut self, kind: CostKind) -> Self {
        self.cfg.cost = kind;
        self
    }

    /// Maximal intermediate rank C of the annealing schedule (≥ 2).
    pub fn max_rank(mut self, c: usize) -> Self {
        self.cfg.max_rank = c;
        self
    }

    /// Maximal base-case block Q sealed by the exact solver (≥ 1).
    pub fn base_size(mut self, q: usize) -> Self {
        self.cfg.base_size = q;
        self
    }

    /// Cap the hierarchy depth κ.
    pub fn max_depth(mut self, depth: usize) -> Self {
        self.cfg.max_depth = Some(depth);
        self
    }

    /// Base blocks up to this size use Hungarian; larger ones the auction.
    /// Must not exceed `base_size`.
    pub fn hungarian_cutoff(mut self, cutoff: usize) -> Self {
        self.cfg.hungarian_cutoff = cutoff;
        self
    }

    /// LROT sub-solver hyper-parameters (rank is overridden per scale).
    pub fn lrot(mut self, cfg: LrotConfig) -> Self {
        self.cfg.lrot = cfg;
        self
    }

    /// Factor width for non-factorisable costs (Indyk et al. 2019).
    pub fn indyk_width(mut self, k: usize) -> Self {
        self.cfg.indyk_width = k;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Worker threads for the co-cluster fan-out (≥ 1).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// LROT backend: native mirror descent, PJRT artifacts, or auto.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    /// Where the AOT artifacts live (`manifest.tsv` + `*.hlo.txt`).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Record the co-clustering Γ_t at every scale (Fig. S3 diagnostics).
    pub fn record_scales(mut self, record: bool) -> Self {
        self.cfg.record_scales = record;
        self
    }

    /// Tile size (rows) for the streaming ingestion path
    /// ([`HiRef::align_source`]): chunked factorisation holds one
    /// `chunk_rows×d` tile at a time (≥ 1).
    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.cfg.chunk_rows = rows;
        self
    }

    /// Level-synchronous batched execution (default `true`): every
    /// same-shape group of blocks at a scale is solved as one strided
    /// LROT batch.  `false` selects the per-block work-queue path —
    /// bit-identical output, kept selectable for A/B comparison.
    pub fn batching(mut self, on: bool) -> Self {
        self.cfg.batching = on;
        self
    }

    /// Cluster-warmstart the top `levels` scales of the hierarchy
    /// (default 0: the exact path, bit-identical to prior releases).
    /// Clustered scales co-cluster straight from the cost-factor rows —
    /// no LROT solve — and the first exact scale below them starts its
    /// mirror descent from a clustering of its lanes.  The bijection
    /// stays exact and balanced; only coarse co-membership is
    /// approximate (contract: docs/warmstart.md).
    pub fn warmstart_levels(mut self, levels: usize) -> Self {
        self.cfg.warmstart_levels = levels;
        self
    }

    /// Stored element format of the factor working copies (default
    /// [`Precision::F32`], bit-identical to prior releases).  `Bf16`/`F16`
    /// halve the resident/spill factor footprint; the solve path still
    /// accumulates in f32 — lane windows are widened on checkout and
    /// narrowed (round-to-nearest-even) on dirty release.
    pub fn factor_precision(mut self, prec: Precision) -> Self {
        self.cfg.factor_precision = prec;
        self
    }

    /// Spill the factor working copies to scratch files under `dir` so
    /// only the `O(n)` permutations (plus the bounded shard cache and one
    /// in-flight level batch) stay resident.  Output is bit-identical to
    /// the resident default.
    pub fn spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self
    }

    /// Cap on resident spill-cache bytes (both sides together; default
    /// 256 MiB; 0 disables caching entirely).  Requires
    /// [`HiRefBuilder::spill_dir`].
    pub fn spill_budget_bytes(mut self, bytes: usize) -> Self {
        self.spill_budget = Some(bytes);
        self
    }

    /// Validate and return the configuration.
    pub fn build_config(self) -> Result<HiRefConfig, SolveError> {
        let mut cfg = self.cfg;
        cfg.spill = match (self.spill_dir, self.spill_budget) {
            (None, None) => None,
            (None, Some(_)) => {
                return Err(SolveError::InvalidConfig(
                    "spill_budget_bytes requires spill_dir (no spill directory configured)"
                        .into(),
                ))
            }
            (Some(dir), budget) => {
                Some(SpillConfig { dir, budget_bytes: budget.unwrap_or(DEFAULT_SPILL_BUDGET) })
            }
        };
        if cfg.base_size == 0 {
            return Err(SolveError::InvalidConfig(
                "base_size must be >= 1 (got 0)".into(),
            ));
        }
        if cfg.max_rank < 2 {
            return Err(SolveError::InvalidConfig(format!(
                "max_rank must be >= 2 (got {}): a refinement scale must split a block",
                cfg.max_rank
            )));
        }
        if cfg.hungarian_cutoff > cfg.base_size {
            return Err(SolveError::InvalidConfig(format!(
                "hungarian_cutoff ({}) exceeds base_size ({}): blocks that large never reach the base case",
                cfg.hungarian_cutoff, cfg.base_size
            )));
        }
        if cfg.threads == 0 {
            return Err(SolveError::InvalidConfig("threads must be >= 1 (got 0)".into()));
        }
        if cfg.indyk_width == 0 {
            return Err(SolveError::InvalidConfig("indyk_width must be >= 1 (got 0)".into()));
        }
        if cfg.max_depth == Some(0) {
            return Err(SolveError::InvalidConfig(
                "max_depth = 0 forbids any refinement; omit the cap instead".into(),
            ));
        }
        if cfg.lrot.outer == 0 || cfg.lrot.inner == 0 {
            return Err(SolveError::InvalidConfig(
                "lrot outer/inner iteration counts must be >= 1".into(),
            ));
        }
        if cfg.chunk_rows == 0 {
            return Err(SolveError::InvalidConfig(
                "chunk_rows must be >= 1 (got 0)".into(),
            ));
        }
        if !(cfg.lrot.gamma > 0.0) {
            return Err(SolveError::InvalidConfig(format!(
                "lrot gamma must be positive (got {})",
                cfg.lrot.gamma
            )));
        }
        Ok(cfg)
    }

    /// Validate and construct the solver.
    pub fn build(self) -> Result<HiRef, SolveError> {
        Ok(HiRef::new(self.build_config()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(HiRefBuilder::new().build_config().is_ok());
    }

    #[test]
    fn rejects_zero_base_size() {
        let err = HiRefBuilder::new().base_size(0).build_config().unwrap_err();
        assert!(matches!(err, SolveError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn rejects_cutoff_above_base_size() {
        let err = HiRefBuilder::new()
            .base_size(64)
            .hungarian_cutoff(128)
            .build_config()
            .unwrap_err();
        assert!(err.to_string().contains("hungarian_cutoff"), "{err}");
        // consistent pair passes
        assert!(HiRefBuilder::new()
            .base_size(64)
            .hungarian_cutoff(64)
            .build_config()
            .is_ok());
    }

    #[test]
    fn rejects_degenerate_rank_threads_depth() {
        assert!(HiRefBuilder::new().max_rank(1).build_config().is_err());
        assert!(HiRefBuilder::new().threads(0).build_config().is_err());
        assert!(HiRefBuilder::new().max_depth(0).build_config().is_err());
        assert!(HiRefBuilder::new().indyk_width(0).build_config().is_err());
        assert!(HiRefBuilder::new().chunk_rows(0).build_config().is_err());
        assert_eq!(
            HiRefBuilder::new().chunk_rows(4096).build_config().unwrap().chunk_rows,
            4096
        );
    }

    #[test]
    fn setters_reach_the_config() {
        let cfg = HiRefBuilder::new()
            .max_rank(4)
            .base_size(32)
            .hungarian_cutoff(16)
            .seed(9)
            .threads(2)
            .max_depth(3)
            .record_scales(true)
            .batching(false)
            .factor_precision(Precision::Bf16)
            .warmstart_levels(2)
            .artifacts_dir("some/dir")
            .build_config()
            .unwrap();
        assert_eq!(cfg.max_rank, 4);
        assert_eq!(cfg.base_size, 32);
        assert_eq!(cfg.hungarian_cutoff, 16);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.max_depth, Some(3));
        assert!(cfg.record_scales);
        assert!(!cfg.batching);
        assert_eq!(cfg.factor_precision, Precision::Bf16);
        assert_eq!(cfg.warmstart_levels, 2);
        assert_eq!(cfg.artifacts_dir, std::path::PathBuf::from("some/dir"));
    }

    #[test]
    fn warmstart_defaults_off() {
        assert_eq!(HiRefBuilder::new().build_config().unwrap().warmstart_levels, 0);
    }

    #[test]
    fn factor_precision_defaults_to_f32() {
        assert_eq!(
            HiRefBuilder::new().build_config().unwrap().factor_precision,
            Precision::F32
        );
    }

    #[test]
    fn batching_defaults_on() {
        assert!(HiRefBuilder::new().build_config().unwrap().batching);
    }

    #[test]
    fn spill_knobs_validated_and_reach_config() {
        // budget without a directory is inconsistent
        let err = HiRefBuilder::new().spill_budget_bytes(1 << 20).build_config().unwrap_err();
        assert!(matches!(err, SolveError::InvalidConfig(_)), "{err}");
        // no knobs: resident factors
        assert!(HiRefBuilder::new().build_config().unwrap().spill.is_none());
        // dir alone gets the default budget
        let cfg = HiRefBuilder::new().spill_dir("/tmp/hiref-spill").build_config().unwrap();
        let sc = cfg.spill.unwrap();
        assert_eq!(sc.dir, std::path::PathBuf::from("/tmp/hiref-spill"));
        assert_eq!(sc.budget_bytes, DEFAULT_SPILL_BUDGET);
        // dir + budget (0 is legal: cache disabled)
        let cfg = HiRefBuilder::new()
            .spill_dir("d")
            .spill_budget_bytes(0)
            .build_config()
            .unwrap();
        assert_eq!(cfg.spill.unwrap().budget_bytes, 0);
    }
}
