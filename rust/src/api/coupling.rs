//! One output type for every solver: the [`Coupling`] enum.
//!
//! The paper's central comparison runs six solvers whose raw outputs are
//! four different objects — a bijection (HiRef, mini-batch, exact), a
//! dense matrix (Sinkhorn, ProgOT), low-rank factors (LROT/FRLC), and a
//! sparse entry list (MOP).  All of them *represent* a coupling
//! `P ∈ Π(1/n, 1/m)`; this module gives them a shared type with uniform
//! accessors (`cost`, `marginal_error`, `entropy`, `nnz`, `to_bijection`)
//! so benches, tests and the CLI never special-case a representation.

use crate::costs::{self, CostKind};
use crate::linalg::Mat;
use crate::metrics;
use crate::solvers::{mop, sinkhorn};

use super::error::SolveError;

/// Threshold under which a coupling entry counts as zero (the paper's
/// Table S3 convention).
pub const NNZ_THRESH: f64 = 1e-8;

/// A coupling stored as an explicit sparse entry list `(i, j, mass)`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseCoupling {
    /// Source size (rows of the implied dense plan).
    pub n: usize,
    /// Target size (columns of the implied dense plan).
    pub m: usize,
    /// `(source index, target index, mass)` triples; masses sum to 1.
    pub entries: Vec<(u32, u32, f64)>,
}

impl SparseCoupling {
    /// Total transported mass (1 for a feasible coupling).
    pub fn total_mass(&self) -> f64 {
        self.entries.iter().map(|e| e.2).sum()
    }
}

/// Every coupling representation produced by a registered solver.
///
/// | Variant | Producers | Storage |
/// |---|---|---|
/// | `Bijection` | HiRef, mini-batch, exact | `O(n)` |
/// | `Dense` | Sinkhorn, ProgOT | `O(n·m)` |
/// | `LowRank` | LROT / FRLC baselines | `O((n+m)·r)` |
/// | `Sparse` | MOP multiscale | `O(nnz)` |
#[derive(Clone, Debug)]
pub enum Coupling {
    /// `perm[i] = j` pairs `x_i ↔ y_j` with mass `1/n` each — the HiRef
    /// output invariant (paper §3.4): exactly `n` nonzeros.
    Bijection(Vec<u32>),
    /// Dense `n×m` plan (quadratic memory; baselines only).
    Dense(Mat),
    /// Factored plan `P = Q diag(1/g) Rᵀ` with inner marginal `g = diag`.
    LowRank { q: Mat, r: Mat, diag: Vec<f64> },
    /// Explicit sparse entry list.
    Sparse(SparseCoupling),
}

impl Coupling {
    /// `(n, m)` — the shape of the implied dense plan.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Coupling::Bijection(p) => (p.len(), p.len()),
            Coupling::Dense(p) => (p.rows, p.cols),
            Coupling::LowRank { q, r, .. } => (q.rows, r.rows),
            Coupling::Sparse(sc) => (sc.n, sc.m),
        }
    }

    /// Short label for reports ("bijection", "dense", ...).
    pub fn kind_label(&self) -> &'static str {
        match self {
            Coupling::Bijection(_) => "bijection",
            Coupling::Dense(_) => "dense",
            Coupling::LowRank { .. } => "low-rank",
            Coupling::Sparse(_) => "sparse",
        }
    }

    /// Primal transport cost `⟨C, P⟩` under the ground cost `kind`.
    ///
    /// Linear time/space for bijections and sparse plans; `O(n·m)` for
    /// dense plans (streamed, the cost matrix is never materialised);
    /// low-rank plans use the exact `d+2` factorisation for squared
    /// Euclidean (linear) and fall back to an `O(n·m·r)` stream otherwise.
    pub fn cost(&self, x: &Mat, y: &Mat, kind: CostKind) -> f64 {
        match self {
            Coupling::Bijection(p) => metrics::bijection_cost(x, y, p, kind),
            Coupling::Dense(p) => {
                debug_assert_eq!((p.rows, p.cols), (x.rows, y.rows));
                let mut s = 0.0f64;
                for i in 0..p.rows {
                    let xi = x.row(i);
                    for (j, &pv) in p.row(i).iter().enumerate() {
                        if pv != 0.0 {
                            s += pv as f64 * kind.pair(xi, y.row(j));
                        }
                    }
                }
                s
            }
            Coupling::LowRank { q, r, diag } => match kind {
                CostKind::SqEuclidean => {
                    let (u, v) = costs::factor::sq_euclidean_factors(x, y);
                    lowrank_factored_cost(&u, &v, q, r, diag)
                }
                CostKind::Euclidean => {
                    let rank = q.cols;
                    let mut s = 0.0f64;
                    for i in 0..q.rows {
                        let qi = q.row(i);
                        let xi = x.row(i);
                        for j in 0..r.rows {
                            let rj = r.row(j);
                            let mut p = 0.0f64;
                            for z in 0..rank {
                                p += qi[z] as f64 * rj[z] as f64 / diag[z];
                            }
                            if p != 0.0 {
                                s += p * kind.pair(xi, y.row(j));
                            }
                        }
                    }
                    s
                }
            },
            Coupling::Sparse(sc) => sc
                .entries
                .iter()
                .map(|&(i, j, mass)| mass * kind.pair(x.row(i as usize), y.row(j as usize)))
                .sum(),
        }
    }

    /// Worst relative violation of the uniform marginal constraints.
    ///
    /// For a bijection this *verifies* the invariant rather than assuming
    /// it: a permutation with duplicate or out-of-range targets reports a
    /// violation ≥ 1 (each row always carries mass `1/n`, so only the
    /// column marginals can break).
    pub fn marginal_error(&self) -> f64 {
        match self {
            Coupling::Bijection(p) => {
                let n = p.len();
                let mut hits = vec![0u32; n];
                let mut worst = 0.0f64;
                for &j in p {
                    if (j as usize) < n {
                        hits[j as usize] += 1;
                    } else {
                        worst = 1.0;
                    }
                }
                for c in hits {
                    worst = worst.max((c as f64 - 1.0).abs());
                }
                worst
            }
            Coupling::Dense(p) => metrics::marginal_violation(p),
            Coupling::LowRank { q, r, diag } => {
                let (n, m) = (q.rows as f64, r.rows as f64);
                let mut worst = 0.0f64;
                for s in q.row_sums() {
                    worst = worst.max((s as f64 * n - 1.0).abs());
                }
                for s in r.row_sums() {
                    worst = worst.max((s as f64 * m - 1.0).abs());
                }
                for (z, &s) in q.col_sums().iter().enumerate() {
                    worst = worst.max((s as f64 / diag[z] - 1.0).abs());
                }
                for (z, &s) in r.col_sums().iter().enumerate() {
                    worst = worst.max((s as f64 / diag[z] - 1.0).abs());
                }
                worst
            }
            Coupling::Sparse(sc) => {
                let mut row = vec![0.0f64; sc.n];
                let mut col = vec![0.0f64; sc.m];
                for &(i, j, mass) in &sc.entries {
                    row[i as usize] += mass;
                    col[j as usize] += mass;
                }
                let mut worst = 0.0f64;
                for s in row {
                    worst = worst.max((s * sc.n as f64 - 1.0).abs());
                }
                for s in col {
                    worst = worst.max((s * sc.m as f64 - 1.0).abs());
                }
                worst
            }
        }
    }

    /// Shannon entropy `−Σ p log p` of the plan (Table S3 convention:
    /// exactly `ln n` for a bijection).  Like [`Coupling::nnz`], this
    /// streams the implied dense plan for low-rank couplings (`O(n·m·r)`).
    pub fn entropy(&self) -> f64 {
        match self {
            Coupling::Bijection(p) => metrics::bijection_entropy(p.len()),
            Coupling::Dense(p) => metrics::coupling_entropy(p),
            Coupling::LowRank { q, r, diag } => {
                let rank = q.cols;
                let mut h = 0.0f64;
                for i in 0..q.rows {
                    let qi = q.row(i);
                    for j in 0..r.rows {
                        let rj = r.row(j);
                        let mut p = 0.0f64;
                        for z in 0..rank {
                            p += qi[z] as f64 * rj[z] as f64 / diag[z];
                        }
                        if p > 0.0 {
                            h -= p * p.ln();
                        }
                    }
                }
                h
            }
            Coupling::Sparse(sc) => {
                let mut h = 0.0f64;
                for &(_, _, mass) in &sc.entries {
                    if mass > 0.0 {
                        h -= mass * mass.ln();
                    }
                }
                h
            }
        }
    }

    /// Number of entries above [`NNZ_THRESH`] — the paper's structural
    /// linear-vs-quadratic storage comparison (Table S3).
    ///
    /// `O(n)` for bijections/sparse plans, `O(n·m)` for dense plans, and
    /// `O(n·m·r)` for low-rank plans (the implied dense plan is streamed,
    /// not stored) — evaluation scales only for the latter two.
    pub fn nnz(&self) -> usize {
        match self {
            Coupling::Bijection(p) => p.len(),
            Coupling::Dense(p) => metrics::nonzeros(p, NNZ_THRESH as f32),
            Coupling::LowRank { q, r, diag } => {
                let rank = q.cols;
                let mut count = 0usize;
                for i in 0..q.rows {
                    let qi = q.row(i);
                    for j in 0..r.rows {
                        let rj = r.row(j);
                        let mut p = 0.0f64;
                        for z in 0..rank {
                            p += qi[z] as f64 * rj[z] as f64 / diag[z];
                        }
                        if p > NNZ_THRESH {
                            count += 1;
                        }
                    }
                }
                count
            }
            Coupling::Sparse(sc) => sc.entries.iter().filter(|e| e.2 > NNZ_THRESH).count(),
        }
    }

    /// Round to a one-to-one map (errors on non-square couplings).
    ///
    /// Bijections pass through; dense and low-rank plans round by the
    /// confidence-ordered greedy of [`sinkhorn::round_to_bijection`]
    /// (low-rank plans materialise the dense plan first — `O(n²)`, use at
    /// evaluation scales only); sparse plans round by decreasing mass.
    pub fn to_bijection(&self) -> Result<Vec<u32>, SolveError> {
        let (n, m) = self.shape();
        if n != m {
            return Err(SolveError::NotSquare { n, m });
        }
        match self {
            Coupling::Bijection(p) => Ok(p.clone()),
            Coupling::Dense(p) => Ok(sinkhorn::round_to_bijection(p)),
            Coupling::LowRank { q, r, diag } => {
                let rank = q.cols;
                let mut p = Mat::zeros(q.rows, r.rows);
                for i in 0..q.rows {
                    let qi = q.row(i);
                    let prow = p.row_mut(i);
                    for (j, pv) in prow.iter_mut().enumerate() {
                        let rj = r.row(j);
                        let mut acc = 0.0f64;
                        for z in 0..rank {
                            acc += qi[z] as f64 * rj[z] as f64 / diag[z];
                        }
                        *pv = acc as f32;
                    }
                }
                Ok(sinkhorn::round_to_bijection(&p))
            }
            Coupling::Sparse(sc) => Ok(mop::round_sparse_to_bijection(sc)),
        }
    }
}

/// `⟨C, Q diag(1/g) Rᵀ⟩` through cost factors `C = U Vᵀ`, in
/// `O((n+m)·k·r)` — the same contraction as `lrot::lowrank_cost`
/// generalised to a non-uniform inner marginal `g`.
fn lowrank_factored_cost(u: &Mat, v: &Mat, q: &Mat, r: &Mat, diag: &[f64]) -> f64 {
    let uq = u.t_matmul(q); // k×r
    let vr = v.t_matmul(r); // k×r
    let mut s = 0.0f64;
    for z in 0..q.cols {
        let mut dz = 0.0f64;
        for k in 0..uq.rows {
            dz += uq.at(k, z) as f64 * vr.at(k, z) as f64;
        }
        s += dz / diag[z];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::dense_cost;
    use crate::prng::Rng;
    use crate::solvers::lrot;

    fn toy(n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 2);
        let mut y = Mat::zeros(n, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        (x, y)
    }

    #[test]
    fn bijection_identity_cost_zero() {
        let (x, _) = toy(16, 0);
        let c = Coupling::Bijection((0..16).collect());
        assert_eq!(c.cost(&x, &x, CostKind::SqEuclidean), 0.0);
        assert_eq!(c.marginal_error(), 0.0);
        assert_eq!(c.nnz(), 16);
        assert_eq!(c.to_bijection().unwrap().len(), 16);
    }

    #[test]
    fn dense_cost_matches_legacy_path() {
        let (x, y) = toy(24, 1);
        let kind = CostKind::SqEuclidean;
        let c = dense_cost(&x, &y, kind);
        let mut p = Mat::full(24, 24, 1.0 / (24.0 * 24.0));
        *p.at_mut(0, 0) += 0.001;
        let want = metrics::dense_cost_of(&c, &p);
        let got = Coupling::Dense(p).cost(&x, &y, kind);
        let rel = (got - want).abs() / want.abs().max(1e-12);
        assert!(rel < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn lowrank_cost_matches_legacy_path() {
        let (x, y) = toy(32, 2);
        let (u, v) = costs::factor::sq_euclidean_factors(&x, &y);
        let out = lrot::solve_factored(&u, &v, 32, 32, &lrot::LrotConfig::default(), 3);
        let want = lrot::lowrank_cost(&u, &v, &out.q, &out.r);
        let rank = out.q.cols;
        let cpl = Coupling::LowRank {
            q: out.q,
            r: out.r,
            diag: vec![1.0 / rank as f64; rank],
        };
        let got = cpl.cost(&x, &y, CostKind::SqEuclidean);
        let rel = (got - want).abs() / want.abs().max(1e-12);
        assert!(rel < 1e-9, "{got} vs {want}");
        assert!(cpl.marginal_error() < 0.05);
        let perm = cpl.to_bijection().unwrap();
        let mut seen = vec![false; 32];
        for &j in &perm {
            assert!(!std::mem::replace(&mut seen[j as usize], true));
        }
    }

    #[test]
    fn broken_bijection_is_detected() {
        // duplicate target (0 twice, 1 missing) must not report feasible
        let bad = Coupling::Bijection(vec![0, 0, 2]);
        assert!(bad.marginal_error() >= 1.0);
        // out-of-range target likewise
        let oob = Coupling::Bijection(vec![0, 1, 9]);
        assert!(oob.marginal_error() >= 1.0);
        let ok = Coupling::Bijection(vec![2, 0, 1]);
        assert_eq!(ok.marginal_error(), 0.0);
    }

    #[test]
    fn sparse_mass_and_rounding() {
        let sc = SparseCoupling {
            n: 3,
            m: 3,
            entries: vec![(0, 1, 1.0 / 3.0), (1, 0, 1.0 / 3.0), (2, 2, 1.0 / 3.0)],
        };
        assert!((sc.total_mass() - 1.0).abs() < 1e-12);
        let cpl = Coupling::Sparse(sc);
        assert!(cpl.marginal_error() < 1e-12);
        assert_eq!(cpl.nnz(), 3);
        assert_eq!(cpl.to_bijection().unwrap(), vec![1, 0, 2]);
        assert!((cpl.entropy() - 3.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn non_square_rounding_errors() {
        let cpl = Coupling::Dense(Mat::full(2, 3, 1.0 / 6.0));
        assert_eq!(cpl.to_bijection(), Err(SolveError::NotSquare { n: 2, m: 3 }));
        assert_eq!(cpl.shape(), (2, 3));
    }

    #[test]
    fn dense_entropy_and_nnz_match_metrics() {
        let (x, y) = toy(16, 4);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let sk = sinkhorn::solve(&c, &Default::default());
        let want_h = metrics::coupling_entropy(&sk.coupling);
        let want_nnz = metrics::nonzeros(&sk.coupling, NNZ_THRESH as f32);
        let cpl = Coupling::Dense(sk.coupling);
        assert_eq!(cpl.entropy(), want_h);
        assert_eq!(cpl.nnz(), want_nnz);
    }
}
