//! `hiref` binary — Layer-3 coordinator CLI.
//!
//! All heavy lifting lives in the library; see `hiref help`.

#![forbid(unsafe_code)]

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = hiref::cli::run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
