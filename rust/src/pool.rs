//! Scoped thread-pool primitives and the scratch memory arena.
//!
//! The vendored universe has no rayon/tokio, so HiRef's fan-out over
//! independent co-cluster sub-problems uses `std::thread::scope` with a
//! shared atomic work cursor.  Tasks are compute-bound and coarse-grained
//! (one LROT solve each), so a simple self-scheduling loop is within noise
//! of a work-stealing deque.
//!
//! Three memory primitives keep the solve path allocation-free after
//! setup:
//!
//! * [`ScratchArena`] — sharded freelists of `f32`/`u32` buffers checked
//!   out by power-of-two capacity class.  LROT inner iterations, balanced
//!   assignment reordering and base-case dense-cost construction draw from
//!   it instead of `Vec::with_capacity`, and it reports peak bytes and
//!   hit-rate for [`crate::coordinator::hiref::RunStats`].
//! * [`RangeShared`] — a buffer whose **disjoint** ranges are mutated
//!   concurrently by workers (the in-place recursive re-indexing of the
//!   refinement hierarchy: each co-cluster owns exactly its `start..end`).
//! * [`SharedSlice`] — the borrowed twin of [`RangeShared`]: the same
//!   disjoint-range contract over an existing `&mut [T]` (e.g. a
//!   scratch-arena checkout or a `Mat`'s backing vector), so batched
//!   kernels and parallel tile sweeps can write lane/row windows from
//!   several workers without taking ownership of the buffer.
//! * [`WorkQueue`] — a condvar-parked dynamic queue (no spin): idle
//!   workers sleep until a push or global completion wakes them.  Since
//!   the level-synchronous batch scheduler became the default
//!   (`coordinator::hiref`), this serves the `batching(false)` per-block
//!   A/B path.
//! * [`LaneCrew`] — a persistent worker team for iteration loops: spawned
//!   **once** per batched solve ([`with_lane_crew`]), parked on a condvar
//!   round barrier between iterations, and handed the same static chunk
//!   partition every round.  Replaces per-iteration `thread::scope`
//!   spawning in the batched LROT loop (O(iters·threads) →
//!   O(threads) spawns per batch, counted by [`crew_spawns`]).
//!
//! On top of these sits [`store::FactorStore`] — the ownership
//! abstraction for the per-side cost-factor working copies, with a
//! zero-cost resident implementation ([`store::ResidentStore`], a
//! [`RangeShared`] underneath) and a file-backed spillable one
//! ([`store::SpillStore`]) so that only the `O(n)` permutations must stay
//! resident.
//!
//! Every disjointness contract above is machine-checked in debug builds
//! by [`guard`] — a borrow registry that panics with both claim sites the
//! moment two overlapping windows are live, and compiles to nothing in
//! release (see `docs/safety.md`).

pub mod guard;
pub mod store;

pub use store::{Checkout, FactorStore, Precision, ResidentStore, SpillStore, StoreStats};

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Number of worker threads to use: `HIREF_THREADS` env var, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HIREF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------------
// RangeShared: disjoint-range shared mutation
// ---------------------------------------------------------------------------

/// A `Vec<T>` shared across worker threads that hand-partition it into
/// pairwise-disjoint index ranges.
///
/// The refinement hierarchy guarantees disjointness structurally: every
/// queued block owns a `start..end` range, children exactly partition the
/// parent's range, and a range is only touched by the single worker
/// processing its block.
///
/// All accessors are `unsafe`: the **caller** promises that no two
/// concurrently live borrows overlap and that no shared borrow is used
/// while an overlapping exclusive borrow exists.
pub struct RangeShared<T> {
    data: UnsafeCell<Vec<T>>,
    ptr: *mut T,
    len: usize,
    guard: guard::Registry,
}

// SAFETY: exclusive access is coordinated by the caller-supplied
// disjointness contract on `slice`/`slice_mut` (T: Send covers handing
// ranges to workers); `slice` additionally allows *concurrent shared*
// borrows of the same range from several threads, which is only sound
// when `&T` itself is thread-safe — hence T: Sync as well.
unsafe impl<T: Send + Sync> Sync for RangeShared<T> {}
unsafe impl<T: Send> Send for RangeShared<T> {}

impl<T> RangeShared<T> {
    pub fn new(data: Vec<T>) -> RangeShared<T> {
        let len = data.len();
        let data = UnsafeCell::new(data);
        // SAFETY: the cell is exclusively owned here (no other reference
        // exists yet).  The buffer pointer is derived *after* the Vec
        // reached its final place so it stays valid under Miri's aliasing
        // models (moving a Vec may retag its internal unique pointer,
        // invalidating raw pointers derived before the move).
        let ptr = unsafe { (*data.get()).as_mut_ptr() };
        RangeShared { data, ptr, len, guard: guard::Registry::new("RangeShared") }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Shared view of `start..end`.  Bounds are checked in release builds
    /// too — an out-of-range window would be silent heap corruption, and
    /// the check is O(1) per block, not per element.  Debug builds also
    /// register the window with the [`guard`] registry, so an overlapping
    /// exclusive claim panics with both claim sites.
    ///
    /// # Safety
    /// No concurrently live *exclusive* borrow may overlap `start..end`.
    #[inline]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn slice(&self, start: usize, end: usize) -> &[T] {
        self.guard.claim_shared(start, end);
        // SAFETY: bounds asserted below the claim; aliasing is the
        // caller's contract (no overlapping exclusive borrow), checked in
        // debug builds by the guard claim above.
        unsafe { self.slice_unclaimed(start, end) }
    }

    /// [`RangeShared::slice`] without a guard claim — for internal callers
    /// (e.g. [`store::ResidentStore`]) that register their own RAII-scoped
    /// claims on [`RangeShared::guard_registry`] instead, with lifetimes
    /// the fire-and-forget claim model cannot express.
    ///
    /// # Safety
    /// Same contract as [`RangeShared::slice`].
    #[inline]
    pub(crate) unsafe fn slice_unclaimed(&self, start: usize, end: usize) -> &[T] {
        assert!(start <= end && end <= self.len, "range {start}..{end} out of 0..{}", self.len);
        // SAFETY: in-bounds by the assert above; aliasing is the caller's
        // contract.
        unsafe { std::slice::from_raw_parts(self.ptr.add(start), end - start) }
    }

    /// Exclusive view of `start..end`.  Bounds checked in release builds
    /// (see [`RangeShared::slice`]); debug builds register the window with
    /// the [`guard`] registry.
    ///
    /// # Safety
    /// No concurrently live borrow of any kind may overlap `start..end`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn slice_mut(&self, start: usize, end: usize) -> &mut [T] {
        self.guard.claim_mut(start, end);
        // SAFETY: bounds asserted below the claim; aliasing is the
        // caller's contract (no overlapping borrow of any kind), checked
        // in debug builds by the guard claim above.
        unsafe { self.slice_mut_unclaimed(start, end) }
    }

    /// [`RangeShared::slice_mut`] without a guard claim — see
    /// [`RangeShared::slice_unclaimed`].
    ///
    /// # Safety
    /// Same contract as [`RangeShared::slice_mut`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice_mut_unclaimed(&self, start: usize, end: usize) -> &mut [T] {
        assert!(start <= end && end <= self.len, "range {start}..{end} out of 0..{}", self.len);
        // SAFETY: in-bounds by the assert above; aliasing is the caller's
        // contract.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }

    /// The guard registry tracking this buffer's claims (element units).
    /// Internal callers that bypass the claiming accessors register their
    /// RAII-scoped claims and checkout pins here.
    pub(crate) fn guard_registry(&self) -> &guard::Registry {
        &self.guard
    }

    /// Reclaim the underlying vector (all borrows must have ended).
    pub fn into_inner(self) -> Vec<T> {
        self.data.into_inner()
    }
}

// ---------------------------------------------------------------------------
// SharedSlice: borrowed disjoint-range shared mutation
// ---------------------------------------------------------------------------

/// The borrowed twin of [`RangeShared`]: wraps an existing `&mut [T]`
/// (scratch-arena checkout, `Mat` backing storage, ...) so that worker
/// threads which hand-partition it into pairwise-disjoint index ranges can
/// write their windows concurrently.  Nothing is moved or reallocated —
/// when the wrapper goes out of scope the original borrow resumes.
///
/// All accessors are `unsafe` under the same contract as [`RangeShared`]:
/// the **caller** promises that no two concurrently live borrows overlap
/// and that no shared borrow is used while an overlapping exclusive borrow
/// exists.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    guard: guard::Registry,
    _borrow: PhantomData<&'a mut [T]>,
}

// SAFETY: same argument as RangeShared — exclusive access is coordinated
// by the caller-supplied disjointness contract; `slice` allows concurrent
// shared borrows, which demands T: Sync on top of T: Send.
unsafe impl<T: Send + Sync> Sync for SharedSlice<'_, T> {}
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(data: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            guard: guard::Registry::new("SharedSlice"),
            _borrow: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Shared view of `start..end`.  Bounds checked in release builds too
    /// (an out-of-range window would be silent heap corruption); debug
    /// builds register the window with the [`guard`] registry.
    ///
    /// # Safety
    /// No concurrently live *exclusive* borrow may overlap `start..end`.
    #[inline]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn slice(&self, start: usize, end: usize) -> &[T] {
        assert!(start <= end && end <= self.len, "range {start}..{end} out of 0..{}", self.len);
        self.guard.claim_shared(start, end);
        // SAFETY: in-bounds by the assert above; aliasing is the caller's
        // contract (no overlapping exclusive borrow), checked in debug
        // builds by the guard claim.
        unsafe { std::slice::from_raw_parts(self.ptr.add(start), end - start) }
    }

    /// Exclusive view of `start..end`.  Bounds checked in release builds;
    /// debug builds register the window with the [`guard`] registry.
    ///
    /// # Safety
    /// No concurrently live borrow of any kind may overlap `start..end`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    #[cfg_attr(any(debug_assertions, feature = "guard"), track_caller)]
    pub unsafe fn slice_mut(&self, start: usize, end: usize) -> &mut [T] {
        assert!(start <= end && end <= self.len, "range {start}..{end} out of 0..{}", self.len);
        self.guard.claim_mut(start, end);
        // SAFETY: in-bounds by the assert above; aliasing is the caller's
        // contract (no overlapping borrow of any kind), checked in debug
        // builds by the guard claim.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }
}

// ---------------------------------------------------------------------------
// ScratchArena: reusable per-worker buffers by capacity class
// ---------------------------------------------------------------------------

/// Smallest buffer capacity handed out (avoids churning tiny classes).
const MIN_SCRATCH: usize = 64;
/// Capacity classes are powers of two up to 2^47 elements.
const NUM_CLASSES: usize = 48;
/// Per-shard, per-class freelist depth cap; beyond it buffers are freed.
const MAX_POOLED: usize = 64;

fn class_of(len: usize) -> usize {
    len.max(MIN_SCRATCH).next_power_of_two().trailing_zeros() as usize
}

struct Shard {
    f32s: Vec<Vec<Vec<f32>>>,
    u32s: Vec<Vec<Vec<u32>>>,
    u16s: Vec<Vec<Vec<u16>>>,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            f32s: (0..NUM_CLASSES).map(|_| Vec::new()).collect(),
            u32s: (0..NUM_CLASSES).map(|_| Vec::new()).collect(),
            u16s: (0..NUM_CLASSES).map(|_| Vec::new()).collect(),
        }
    }
}

/// Reusable scratch buffers checked out by capacity class.
///
/// Freelists are sharded by worker thread (thread-id hash), so steady-state
/// checkouts hit a shard no other worker touches — effectively a
/// per-worker pool with shared accounting.  `peak_bytes` is the high-water
/// mark of simultaneously checked-out capacity; it tracks the blocks in
/// flight, peaking at the top of the HiRef hierarchy (root LROT buffers,
/// linear in the block size) and settling to `O(threads · base_size²)`
/// once the recursion reaches the leaves — see the memory model in
/// [`crate`]'s crate docs.
pub struct ScratchArena {
    shards: Vec<Mutex<Shard>>,
    live_bytes: AtomicUsize,
    peak_bytes: AtomicUsize,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl ScratchArena {
    /// An arena sized for `workers` concurrent threads.
    pub fn new(workers: usize) -> ScratchArena {
        let shards = workers.max(1).next_power_of_two();
        ScratchArena {
            shards: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            live_bytes: AtomicUsize::new(0),
            peak_bytes: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    fn shard_idx(&self) -> usize {
        // Checkouts are frequent (every LROT intermediate), so the
        // thread-dependent part is hashed once per thread and cached.
        thread_local! {
            static THREAD_HASH: u64 = {
                use std::hash::{Hash, Hasher};
                let mut h = std::collections::hash_map::DefaultHasher::new();
                std::thread::current().id().hash(&mut h);
                h.finish()
            };
        }
        (THREAD_HASH.with(|h| *h) as usize) & (self.shards.len() - 1)
    }

    fn account_take(&self, bytes: usize) {
        let live = self.live_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak_bytes.fetch_max(live, Ordering::Relaxed);
    }

    /// High-water mark of simultaneously checked-out scratch capacity.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    /// Checkouts served from a freelist (no allocation).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Checkouts that had to allocate a fresh buffer.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fraction of checkouts served without allocating (1.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            1.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

macro_rules! scratch_impl {
    ($guard:ident, $take:ident, $elem:ty, $pool:ident, $zero:expr) => {
        /// A checked-out scratch buffer; derefs to `[T]` of the requested
        /// length (zero-filled) and returns to its shard's freelist on drop.
        pub struct $guard<'a> {
            arena: &'a ScratchArena,
            shard: usize,
            class: usize,
            buf: Option<Vec<$elem>>,
        }

        impl ScratchArena {
            /// Check out a zeroed buffer of `len` elements.
            pub fn $take(&self, len: usize) -> $guard<'_> {
                let class = class_of(len);
                let cap = 1usize << class;
                let shard = self.shard_idx();
                let pooled = self.shards[shard].lock().unwrap().$pool[class].pop();
                let mut buf = match pooled {
                    Some(b) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        b
                    }
                    None => {
                        self.misses.fetch_add(1, Ordering::Relaxed);
                        Vec::with_capacity(cap)
                    }
                };
                buf.clear();
                buf.resize(len, $zero);
                self.account_take(cap * std::mem::size_of::<$elem>());
                $guard { arena: self, shard, class, buf: Some(buf) }
            }
        }

        impl $guard<'_> {
            /// Take ownership of the buffer (it leaves the arena for good;
            /// used to hand solver outputs out without a copy).
            pub fn detach(mut self) -> Vec<$elem> {
                let buf = self.buf.take().expect("scratch buffer already taken");
                self.arena
                    .live_bytes
                    .fetch_sub((1usize << self.class) * std::mem::size_of::<$elem>(), Ordering::Relaxed);
                buf
            }
        }

        impl std::ops::Deref for $guard<'_> {
            type Target = [$elem];
            #[inline]
            fn deref(&self) -> &[$elem] {
                self.buf.as_deref().expect("scratch buffer already taken")
            }
        }

        impl std::ops::DerefMut for $guard<'_> {
            #[inline]
            fn deref_mut(&mut self) -> &mut [$elem] {
                self.buf.as_deref_mut().expect("scratch buffer already taken")
            }
        }

        impl Drop for $guard<'_> {
            fn drop(&mut self) {
                if let Some(buf) = self.buf.take() {
                    self.arena
                        .live_bytes
                        .fetch_sub((1usize << self.class) * std::mem::size_of::<$elem>(), Ordering::Relaxed);
                    let mut shard = self.arena.shards[self.shard].lock().unwrap();
                    if shard.$pool[self.class].len() < MAX_POOLED {
                        shard.$pool[self.class].push(buf);
                    }
                }
            }
        }
    };
}

scratch_impl!(ScratchF32, take_f32, f32, f32s, 0.0f32);
scratch_impl!(ScratchU32, take_u32, u32, u32s, 0u32);
// u16 staging for the low-precision factor stores: encoded bf16/f16 rows
// on their way to a spill file or shard cache (see `store::Precision`).
scratch_impl!(ScratchU16, take_u16, u16, u16s, 0u16);

// ---------------------------------------------------------------------------
// parallel_map
// ---------------------------------------------------------------------------

/// Write-only disjoint-slot sink for [`parallel_map`]: every index is
/// claimed by exactly one worker via an atomic cursor, so all access is
/// exclusive and `T: Send` suffices (no shared reads ever happen, unlike
/// [`RangeShared`], whose `Sync` therefore also demands `T: Sync`).
struct SlotWriter<T>(*mut Option<T>);

// SAFETY: workers only `write` to indices they exclusively claimed.
unsafe impl<T: Send> Sync for SlotWriter<T> {}

impl<T> SlotWriter<T> {
    /// # Safety
    /// `i` must be in bounds and claimed by exactly one worker.
    unsafe fn write(&self, i: usize, v: T) {
        // SAFETY: in-bounds and exclusively claimed per this fn's
        // contract, so the write cannot race or alias.
        unsafe { *self.0.add(i) = Some(v) };
    }
}

/// Apply `f` to every index `0..n` across `threads` workers, collecting
/// results in index order.  `f` must be `Sync`; per-item state should be
/// created inside the closure.  Workers write results straight into their
/// claimed slot — the atomic cursor hands each index to exactly one
/// worker, so the writeback needs no lock at all.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = SlotWriter(out.as_mut_ptr());
    let cursor = AtomicUsize::new(0);
    // Claims made by the caller before the fan-out (and by the short-lived
    // workers inside it) belong to borrows that end at these boundaries:
    // retire them so they cannot collide with the workers' windows.
    guard::advance_epoch();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: the cursor hands index i to exactly one worker,
                // and i < n is in bounds.
                unsafe { slots.write(i, v) };
            });
        }
    });
    guard::advance_epoch();
    out.into_iter().map(|v| v.expect("worker missed a slot")).collect()
}

// ---------------------------------------------------------------------------
// LaneCrew: persistent workers with a round barrier
// ---------------------------------------------------------------------------

/// Process-wide count of crew worker threads ever spawned.  The batched
/// LROT loop's acceptance property — spawns per batch == `min(threads,
/// lanes)`, not iterations × threads — is proven by benches/tests as a
/// delta of this counter around a solve.  (The counter is global, so the
/// delta is exact only when no concurrent solve runs — true for the
/// benches and the solo CLI path; concurrent serve solves see the sum.)
static CREW_SPAWNS: AtomicUsize = AtomicUsize::new(0);

/// Total crew worker threads spawned by this process so far.
pub fn crew_spawns() -> usize {
    CREW_SPAWNS.load(Ordering::Relaxed)
}

/// Shared round state between the submitting thread and the crew workers.
///
/// `job` is a lifetime-erased pointer to the submitter's closure: it is
/// published under the mutex together with the incremented `round`, and
/// the submitter blocks until `remaining` drops to zero before the
/// closure goes out of scope — so the pointer is only ever dereferenced
/// while the borrow it came from is alive.
struct CrewRound {
    round: u64,
    n_chunks: usize,
    job: Option<*const (dyn Fn(usize) + Sync)>,
    /// Workers yet to acknowledge the current round.
    remaining: usize,
    /// Workers currently blocked in `Condvar::wait` (the no-busy-wait
    /// regression probe).
    parked: usize,
    shutdown: bool,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

// SAFETY: the raw `job` pointer is only dereferenced by workers between
// round publication and the final acknowledgement, during which the
// submitter provably keeps the referent alive (it is blocked in `run`).
unsafe impl Send for CrewRound {}

/// A persistent team of workers executing synchronized **rounds**: each
/// [`run`](LaneCrew::run) hands every worker `w < n_chunks` the chunk
/// index `w` of a caller-fixed partition, then blocks until all workers
/// acknowledge.  Workers park on a condvar between rounds — no spinning —
/// and live for the whole enclosing [`with_lane_crew`] scope, so an
/// iteration loop pays thread-spawn cost once instead of per iteration.
///
/// The chunk→worker assignment is static (worker `w` always runs chunk
/// `w`), so a loop that partitions its lanes the same way every iteration
/// gets the identical work division — and therefore identical results —
/// as the historical spawn-per-iteration code.
pub struct LaneCrew {
    workers: usize,
    state: Mutex<CrewRound>,
    work: Condvar,
    done: Condvar,
}

impl LaneCrew {
    fn new(workers: usize) -> Self {
        LaneCrew {
            workers,
            state: Mutex::new(CrewRound {
                round: 0,
                n_chunks: 0,
                job: None,
                remaining: 0,
                parked: 0,
                shutdown: false,
                panic: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        }
    }

    /// Number of chunks a caller may partition into: at least 1 (an
    /// inline, worker-less crew still runs jobs on the submitter).
    pub fn width(&self) -> usize {
        self.workers.max(1)
    }

    /// Workers currently parked in `Condvar::wait` between rounds.
    pub fn parked_workers(&self) -> usize {
        self.state.lock().unwrap().parked
    }

    /// Run one round: `job(c)` for every chunk `c in 0..n_chunks`,
    /// concurrently across the crew, returning once all chunks finished.
    /// `n_chunks` must not exceed [`width`](LaneCrew::width) — the static
    /// assignment runs chunk `c` on worker `c`.  A panicking job is
    /// resumed on the submitting thread after the round completes.
    pub fn run(&self, n_chunks: usize, job: &(dyn Fn(usize) + Sync)) {
        if n_chunks == 0 {
            return;
        }
        if self.workers == 0 || n_chunks <= 1 {
            // inline: a 1-chunk round (or a worker-less crew) pays no
            // synchronisation at all
            for c in 0..n_chunks {
                job(c);
            }
            return;
        }
        assert!(
            n_chunks <= self.workers,
            "round of {n_chunks} chunks exceeds crew width {}",
            self.workers
        );
        // A round boundary ends every borrow of the previous round (the
        // submitter blocks until all workers acknowledge), so claims from
        // earlier rounds — possibly on lane windows a different worker
        // owns this round — must not linger in the guard registry.
        guard::advance_epoch();
        {
            let mut st = self.state.lock().unwrap();
            debug_assert_eq!(st.remaining, 0, "previous round still in flight");
            // SAFETY (lifetime erasure): the pointer outlives this call
            // only inside `st.job`, which is cleared below before `run`
            // returns; workers dereference it exclusively while
            // `remaining > 0`, i.e. while this frame is still blocked.
            st.job = Some(unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(job)
            });
            st.n_chunks = n_chunks;
            st.remaining = self.workers;
            st.round += 1;
            self.work.notify_all();
            while st.remaining > 0 {
                st = self.done.wait(st).unwrap();
            }
            st.job = None;
            let panic = st.panic.take();
            drop(st);
            // All workers acknowledged: the round's borrows are over, so
            // retire their claims before the submitter touches the same
            // windows (finalisation reads lanes the workers just wrote).
            guard::advance_epoch();
            if let Some(p) = panic {
                std::panic::resume_unwind(p);
            }
        }
    }

    fn worker_loop(&self, w: usize) {
        let mut seen = 0u64;
        loop {
            let (job, n_chunks) = {
                let mut st = self.state.lock().unwrap();
                while st.round == seen && !st.shutdown {
                    st.parked += 1;
                    st = self.work.wait(st).unwrap();
                    st.parked -= 1;
                }
                if st.shutdown && st.round == seen {
                    return;
                }
                seen = st.round;
                (st.job.expect("published round without a job"), st.n_chunks)
            };
            let result = if w < n_chunks {
                // SAFETY: `remaining > 0` for this round until we
                // acknowledge below, so the submitter still borrows the
                // closure (see `run`).
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe { (*job)(w) }))
            } else {
                Ok(())
            };
            let mut st = self.state.lock().unwrap();
            if let Err(p) = result {
                st.panic.get_or_insert(p);
            }
            st.remaining -= 1;
            if st.remaining == 0 {
                self.done.notify_all();
            }
        }
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.work.notify_all();
    }
}

/// Spawn a crew of `width` persistent workers, run `f` with it, and tear
/// the workers down when `f` returns.  `width <= 1` builds a worker-less
/// crew that executes rounds inline on the caller — zero spawns, zero
/// synchronisation — so the serial path stays exactly the historical
/// serial code.
pub fn with_lane_crew<R>(width: usize, f: impl FnOnce(&LaneCrew) -> R) -> R {
    if width <= 1 {
        return f(&LaneCrew::new(0));
    }
    let crew = LaneCrew::new(width);
    CREW_SPAWNS.fetch_add(width, Ordering::Relaxed);
    struct Stop<'a>(&'a LaneCrew);
    impl Drop for Stop<'_> {
        fn drop(&mut self) {
            self.0.shutdown();
        }
    }
    std::thread::scope(|s| {
        // shut the workers down even if `f` unwinds, or the scope would
        // join forever against parked threads
        let _stop = Stop(&crew);
        for w in 0..width {
            let crew = &crew;
            s.spawn(move || crew.worker_loop(w));
        }
        f(&crew)
    })
}

// ---------------------------------------------------------------------------
// WorkQueue
// ---------------------------------------------------------------------------

/// Run a dynamic work queue: `pop` items until empty, where processing an
/// item may push new items.  Used by the HiRef recursion (each refinement
/// step enqueues its child co-clusters).
///
/// Idle workers **park on a condvar** instead of spinning: a momentarily
/// empty queue (all items in flight with children still to come) costs no
/// CPU; `push` wakes one sleeper, and the worker that retires the final
/// item wakes everyone so the pool can exit.
pub struct WorkQueue<T> {
    state: Mutex<QueueState<T>>,
    cv: Condvar,
}

struct QueueState<T> {
    items: Vec<T>,
    in_flight: usize,
}

impl<T: Send> WorkQueue<T> {
    pub fn new(initial: Vec<T>) -> Self {
        WorkQueue { state: Mutex::new(QueueState { items: initial, in_flight: 0 }), cv: Condvar::new() }
    }

    /// Push a new work item, waking one parked worker.
    pub fn push(&self, item: T) {
        self.state.lock().unwrap().items.push(item);
        self.cv.notify_one();
    }

    /// Process items with `threads` workers until the queue drains.
    /// `f` receives the item and the queue (to push children).
    pub fn run<F>(&self, threads: usize, f: F)
    where
        F: Fn(T, &Self) + Sync,
        T: Send,
    {
        let threads = threads.max(1);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let item = {
                        let mut st = self.state.lock().unwrap();
                        loop {
                            if let Some(it) = st.items.pop() {
                                st.in_flight += 1;
                                break Some(it);
                            }
                            if st.in_flight == 0 {
                                break None; // globally done
                            }
                            // Queue momentarily empty but items in flight
                            // may still push children: park, don't spin.
                            st = self.cv.wait(st).unwrap();
                        }
                    };
                    let Some(it) = item else {
                        // Wake any sibling still parked so it observes
                        // completion and exits too.
                        self.cv.notify_all();
                        break;
                    };
                    f(it, self);
                    let mut st = self.state.lock().unwrap();
                    st.in_flight -= 1;
                    if st.in_flight == 0 && st.items.is_empty() {
                        drop(st);
                        self.cv.notify_all();
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_single_thread() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn parallel_map_non_copy_results() {
        let got = parallel_map(64, 4, |i| vec![i as u32; 3]);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v, &vec![i as u32; 3]);
        }
    }

    #[test]
    fn work_queue_processes_recursive_pushes() {
        // Binary-tree expansion: item = remaining depth; each item of depth
        // d pushes two items of depth d-1.  Total leaves = 2^D.
        let sum = AtomicU64::new(0);
        let q = WorkQueue::new(vec![6u32]);
        q.run(4, |d, q| {
            if d == 0 {
                sum.fetch_add(1, Ordering::Relaxed);
            } else {
                q.push(d - 1);
                q.push(d - 1);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn work_queue_many_workers_few_items_terminates() {
        // Far more workers than work: idle workers must park (not spin)
        // while the single chain of slow items trickles through, and the
        // pool must still shut down cleanly when the last item retires.
        let hits = AtomicU64::new(0);
        let q = WorkQueue::new(vec![3u32]);
        q.run(32, |d, q| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            hits.fetch_add(1, Ordering::Relaxed);
            if d > 0 {
                q.push(d - 1); // one child: queue is empty most of the time
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn work_queue_empty_initial_exits_immediately() {
        let q: WorkQueue<u32> = WorkQueue::new(Vec::new());
        q.run(8, |_, _| unreachable!("no items to process"));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn shared_slice_disjoint_writes_into_borrowed_buffer() {
        let mut buf = vec![0u32; 64];
        {
            let shared = SharedSlice::new(&mut buf);
            std::thread::scope(|s| {
                for w in 0..4 {
                    let shared = &shared;
                    s.spawn(move || {
                        // SAFETY: worker w owns exactly [w*16, (w+1)*16) —
                        // the windows are pairwise disjoint.
                        let part = unsafe { shared.slice_mut(w * 16, (w + 1) * 16) };
                        for (o, v) in part.iter_mut().enumerate() {
                            *v = (w * 16 + o) as u32;
                        }
                    });
                }
            });
            assert_eq!(shared.len(), 64);
            assert!(!shared.is_empty());
        }
        // the original borrow resumes with the workers' writes in place
        let want: Vec<u32> = (0..64).collect();
        assert_eq!(buf, want);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn shared_slice_bounds_checked() {
        let mut buf = vec![0u8; 4];
        let shared = SharedSlice::new(&mut buf);
        // SAFETY: no other borrow is live; the call must die on the
        // bounds assert before any pointer arithmetic happens.
        let _ = unsafe { shared.slice(2, 5) };
    }

    #[test]
    fn range_shared_disjoint_writes() {
        let shared = RangeShared::new(vec![0u32; 100]);
        std::thread::scope(|s| {
            for w in 0..4 {
                let shared = &shared;
                s.spawn(move || {
                    // SAFETY: worker w owns range [w*25, (w+1)*25) — the
                    // windows are pairwise disjoint.
                    let part = unsafe { shared.slice_mut(w * 25, (w + 1) * 25) };
                    for (o, v) in part.iter_mut().enumerate() {
                        *v = (w * 25 + o) as u32;
                    }
                });
            }
        });
        let out = shared.into_inner();
        let want: Vec<u32> = (0..100).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn arena_reuses_buffers_and_tracks_peak() {
        let arena = ScratchArena::new(1);
        {
            let a = arena.take_f32(100); // class 128 -> 512 bytes
            assert_eq!(a.len(), 100);
            assert!(a.iter().all(|&v| v == 0.0));
            assert_eq!(arena.peak_bytes(), 128 * 4);
            assert_eq!(arena.misses(), 1);
        }
        {
            let mut b = arena.take_f32(90); // same class: freelist hit
            b[0] = 7.0;
            assert_eq!(arena.hits(), 1);
            let c = arena.take_u32(10); // u32 pool is separate
            assert_eq!(c.len(), 10);
            assert_eq!(arena.misses(), 2);
            assert_eq!(arena.peak_bytes(), 128 * 4 + MIN_SCRATCH * 4);
        }
        // peak survives after everything is returned
        assert_eq!(arena.peak_bytes(), 128 * 4 + MIN_SCRATCH * 4);
        assert!(arena.hit_rate() > 0.3);
    }

    #[test]
    fn arena_detach_hands_buffer_out() {
        let arena = ScratchArena::new(2);
        let mut g = arena.take_f32(10);
        g[3] = 5.0;
        let v = g.detach();
        assert_eq!(v[3], 5.0);
        assert_eq!(v.len(), 10);
        // detached buffers never come back: next take is a miss again
        let _ = arena.take_f32(10);
        assert_eq!(arena.misses(), 2);
    }

    #[test]
    fn arena_zeroes_reused_buffers() {
        let arena = ScratchArena::new(1);
        {
            let mut a = arena.take_f32(64);
            a.iter_mut().for_each(|v| *v = 9.0);
        }
        let b = arena.take_f32(64);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lane_crew_runs_every_chunk_exactly_once_per_round() {
        let rounds = 50usize;
        let width = 4usize;
        let counts: Vec<AtomicU64> = (0..width).map(|_| AtomicU64::new(0)).collect();
        with_lane_crew(width, |crew| {
            assert_eq!(crew.width(), width);
            for round in 0..rounds {
                // vary the chunk count: full rounds, partial rounds, and
                // the 1-chunk inline fast path
                let n_chunks = 1 + round % width;
                crew.run(n_chunks, &|c| {
                    assert!(c < n_chunks);
                    counts[c].fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        // chunk c ran in every round with n_chunks > c
        for (c, cnt) in counts.iter().enumerate() {
            let want = (0..rounds).filter(|r| 1 + r % width > c).count() as u64;
            assert_eq!(cnt.load(Ordering::Relaxed), want, "chunk {c}");
        }
    }

    #[test]
    fn lane_crew_reuses_the_same_workers_across_rounds() {
        // the O(threads)-spawns-per-batch property, proven without the
        // process-global counter (which concurrent tests also bump): 200
        // rounds must execute on exactly `width` distinct worker threads.
        // The exact `crew_spawns` delta is asserted by bench_kernels,
        // which owns its whole process.
        let width = 3usize;
        let ids = Mutex::new(std::collections::HashSet::new());
        with_lane_crew(width, |crew| {
            for _ in 0..200 {
                crew.run(width, &|_| {
                    ids.lock().unwrap().insert(std::thread::current().id());
                });
            }
        });
        assert_eq!(ids.into_inner().unwrap().len(), width);
    }

    #[test]
    fn lane_crew_width_one_is_inline_on_the_calling_thread() {
        let me = std::thread::current().id();
        let hits = AtomicU64::new(0);
        with_lane_crew(1, |crew| {
            assert_eq!(crew.width(), 1);
            assert_eq!(crew.parked_workers(), 0);
            for _ in 0..10 {
                crew.run(1, &|c| {
                    assert_eq!(c, 0);
                    // no workers exist: rounds run on the submitter
                    assert_eq!(std::thread::current().id(), me);
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn lane_crew_workers_park_between_rounds() {
        // the no-busy-wait regression probe: between rounds every worker
        // must sit inside Condvar::wait (counted by `parked`), not spin
        let width = 4usize;
        with_lane_crew(width, |crew| {
            crew.run(width, &|_| {});
            // workers re-park after acknowledging; give them a moment
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
            while crew.parked_workers() < width {
                assert!(
                    std::time::Instant::now() < deadline,
                    "workers failed to park: {} of {width}",
                    crew.parked_workers()
                );
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            assert_eq!(crew.parked_workers(), width);
            // and they still wake for the next round
            let hits = AtomicU64::new(0);
            crew.run(width, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), width as u64);
        });
    }

    #[test]
    fn lane_crew_propagates_worker_panics() {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            with_lane_crew(2, |crew| {
                crew.run(2, &|c| {
                    if c == 1 {
                        panic!("lane worker exploded");
                    }
                });
            });
        }));
        let msg = *caught.expect_err("panic must propagate").downcast::<&str>().unwrap();
        assert_eq!(msg, "lane worker exploded");
    }

    /// Seeded contract violations the [`guard`] registry must catch.
    /// Only meaningful when the detector is compiled in.
    #[cfg(any(debug_assertions, feature = "guard"))]
    mod guard_negative {
        use super::*;
        use std::sync::Barrier;

        /// An unrelated concurrent test can bump the global guard epoch
        /// between the two seeded claims and prune the first one (the
        /// documented miss-not-false-positive tradeoff), so each seeded
        /// race retries until caught; a broken guard exhausts the retries
        /// and dies with a non-matching message instead.
        const SEED_ATTEMPTS: usize = 64;

        #[test]
        #[should_panic(expected = "conflicts with")]
        fn overlapping_shared_slice_windows_across_threads_panic() {
            for _ in 0..SEED_ATTEMPTS {
                let mut buf = vec![0u32; 32];
                let shared = SharedSlice::new(&mut buf);
                // Both threads claim [8, 24) mutably.  The barrier makes
                // the overlap cross-thread-concurrent (a sequential
                // same-thread reborrow would be legal); whichever claims
                // second dies, and the panic is re-raised here.
                let barrier = Barrier::new(2);
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..2)
                        .map(|_| {
                            let (shared, barrier) = (&shared, &barrier);
                            s.spawn(move || {
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                    barrier.wait();
                                    // SAFETY: deliberately violated — this
                                    // is the seeded overlap the guard must
                                    // catch before any write happens.
                                    let _w = unsafe { shared.slice_mut(8, 24) };
                                }))
                            })
                        })
                        .collect();
                    for h in handles {
                        if let Err(p) = h.join().expect("worker thread itself must not die") {
                            std::panic::resume_unwind(p);
                        }
                    }
                });
            }
            panic!("guard never caught the seeded SharedSlice overlap");
        }

        #[test]
        #[should_panic(expected = "conflicts with")]
        fn wrong_lane_crew_chunk_partition_panics() {
            // A deliberately-wrong partition: chunk c claims [c, c+3), so
            // chunks 0 and 1 overlap on [1, 3).  Claims from one round
            // share an epoch and outlive the closure call, so the guard
            // catches the overlap regardless of worker timing; the crew
            // re-raises the panic on the submitter.
            for _ in 0..SEED_ATTEMPTS {
                let out = RangeShared::new(vec![0u8; 8]);
                let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    with_lane_crew(2, |crew| {
                        crew.run(2, &|c| {
                            // SAFETY: deliberately violated — overlapping
                            // windows across crew workers are the seeded
                            // bug under test.
                            let w = unsafe { out.slice_mut(c, c + 3) };
                            w[0] = c as u8;
                        });
                    });
                }));
                if let Err(p) = got {
                    std::panic::resume_unwind(p);
                }
            }
            panic!("guard never caught the seeded crew overlap");
        }
    }
}
