//! Scoped thread-pool primitives.
//!
//! The vendored universe has no rayon/tokio, so HiRef's fan-out over
//! independent co-cluster sub-problems uses `std::thread::scope` with a
//! shared atomic work cursor.  Tasks are compute-bound and coarse-grained
//! (one LROT solve each), so a simple self-scheduling loop is within noise
//! of a work-stealing deque.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `HIREF_THREADS` env var, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HIREF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every index `0..n` across `threads` workers, collecting
/// results in index order.  `f` must be `Sync`; per-item state should be
/// created inside the closure.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    // SAFETY-free approach: each worker collects (idx, value) locally and
    // a mutex-guarded writeback fills the output vector.
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                    // Flush periodically to bound memory for huge n.
                    if local.len() >= 64 {
                        let mut guard = slots.lock().unwrap();
                        for (j, v) in local.drain(..) {
                            guard[j] = Some(v);
                        }
                    }
                }
                let mut guard = slots.lock().unwrap();
                for (j, v) in local.drain(..) {
                    guard[j] = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker missed a slot")).collect()
}

/// Run a dynamic work queue: `pop` items until empty, where processing an
/// item may push new items.  Used by the HiRef recursion (each refinement
/// step enqueues its child co-clusters).
pub struct WorkQueue<T> {
    items: Mutex<Vec<T>>,
    in_flight: AtomicUsize,
}

impl<T: Send> WorkQueue<T> {
    pub fn new(initial: Vec<T>) -> Self {
        WorkQueue { items: Mutex::new(initial), in_flight: AtomicUsize::new(0) }
    }

    /// Push a new work item.
    pub fn push(&self, item: T) {
        self.items.lock().unwrap().push(item);
    }

    /// Process items with `threads` workers until the queue drains.
    /// `f` receives the item and the queue (to push children).
    pub fn run<F>(&self, threads: usize, f: F)
    where
        F: Fn(T, &Self) + Sync,
        T: Send,
    {
        let threads = threads.max(1);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let item = {
                        let mut q = self.items.lock().unwrap();
                        match q.pop() {
                            Some(it) => {
                                self.in_flight.fetch_add(1, Ordering::SeqCst);
                                Some(it)
                            }
                            None => None,
                        }
                    };
                    match item {
                        Some(it) => {
                            f(it, self);
                            self.in_flight.fetch_sub(1, Ordering::SeqCst);
                        }
                        None => {
                            // Queue empty: done only if nobody is working
                            // (a worker might still push children).
                            if self.in_flight.load(Ordering::SeqCst) == 0 {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_matches_serial() {
        let got = parallel_map(1000, 8, |i| i * i);
        let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_map_single_thread() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn work_queue_processes_recursive_pushes() {
        // Binary-tree expansion: item = remaining depth; each item of depth
        // d pushes two items of depth d-1.  Total leaves = 2^D.
        let sum = AtomicU64::new(0);
        let q = WorkQueue::new(vec![6u32]);
        q.run(4, |d, q| {
            if d == 0 {
                sum.fetch_add(1, Ordering::Relaxed);
            } else {
                q.push(d - 1);
                q.push(d - 1);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
