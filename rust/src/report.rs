//! Table/figure rendering helpers: the benches print paper-style rows
//! through this module so every experiment reads the same way.

#![forbid(unsafe_code)]

use std::time::Instant;

/// A simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Table {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render to a string (pipe-separated, markdown-like).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push(' ');
                s.push_str(c);
                for _ in c.chars().count()..*w {
                    s.push(' ');
                }
                s.push_str(" |");
            }
            s
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        for row in &self.rows {
            out.push('\n');
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with 4 significant decimals (paper-table style).
pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}

/// Format a float with 2 decimals.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Whether full-size (paper-scale) experiments were requested.
pub fn full_scale() -> bool {
    std::env::var("HIREF_FULL").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// A named section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["Method", "Cost"]);
        t.row(vec!["HiRef".to_string(), f4(0.3533)]);
        t.row(vec!["Sinkhorn".to_string(), f4(0.3573)]);
        let r = t.render();
        assert!(r.contains("| HiRef    | 0.3533 |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
