//! Native low-rank OT (LROT): mirror descent on factors `(Q, R)` with the
//! inner marginal `g` pinned uniform — the Rust twin of the AOT model in
//! `python/compile/model.py` (same algorithm, same hyper-parameters), used
//!
//! * as the HiRef sub-problem backend for shapes outside the AOT bucket
//!   grid (and in artifact-free test environments), and
//! * as the LOT / FRLC low-rank *baselines* of Tables 1/S6/S7/S8 and
//!   Fig. S3 (rank r fixed, e.g. 40).
//!
//! Cost matrices never materialise: gradients go through the factorisation
//! `C = U Vᵀ`, so a solve is `O(outer · (s·k·r + inner · s·r))`.
//!
//! The solver is **zero-copy and allocation-free on the hot path**: cost
//! factors arrive as borrowed [`MatView`]s (HiRef slices its contiguous
//! working buffers, never gathers), and every intermediate — logits,
//! factor exponentials, gradients, Sinkhorn potentials — is checked out of
//! a [`ScratchArena`].
//!
//! # Batched execution
//!
//! The mirror-descent loop is written once, over **lanes**: a level of the
//! HiRef hierarchy hands all of its same-shape co-cluster blocks to
//! [`solve_factored_batch`] as one strided [`BatchView`] pair, and every
//! iteration runs one crew round over lane chunks instead of one task per
//! block.  The workers are a persistent [`pool::LaneCrew`] spawned once
//! per batch and parked on a condvar barrier between iterations — spawns
//! are O(threads) per batch, not O(iterations · threads) — and each round
//! hands the crew the same contiguous lane-chunk partition the historical
//! spawn-per-iteration code used, so the work division (and therefore the
//! arithmetic) is unchanged.  A **per-lane convergence mask** retires
//! lanes whose hard co-clustering has stabilised, so early-converged
//! blocks stop paying matmuls while their siblings finish.  [`solve_factored_in`] is the
//! 1-lane case of the same loop — the per-block and batched paths share
//! every floating-point operation and therefore cannot drift: lane `l` of
//! a batch is bit-identical to a solo solve of the same block with the
//! same seed, for any thread count and any batch composition.
//!
//! The iteration loop is **allocation-free**: every per-lane buffer —
//! logits, factor exponentials, gradients, the `UᵀQ`/`VᵀR` workspace —
//! lives in strided per-batch state checked out of the arena *once* at
//! batch setup, with per-lane window offsets fixed up front ([`Geo`]), so
//! an iteration touches no allocator and no arena freelist.  The gradient
//! stage applies the dispatched kernels ([`crate::linalg::matmul_into_slice`]
//! / [`crate::linalg::vt_matmul_into_slice`], scalar or SIMD — see
//! [`crate::linalg::kernels`]) per lane window — the same FLOPs, in the
//! same order, as the strided `batch_*` wrappers those kernels back, on
//! every dispatch path.

use crate::linalg::{
    fast_exp, matmul_into_slice, slice_max_abs, vt_matmul_into_slice, BatchItem, BatchView, Mat,
    MatView,
};
use crate::pool::{self, LaneCrew, RangeShared, ScratchArena, SharedSlice};
use crate::prng::Rng;

/// Row-parallelism threshold: blocks below this stay single-threaded (the
/// HiRef fan-out already saturates cores with many small blocks); above it
/// (top-of-hierarchy blocks) the inner loops split across threads.
const PAR_CELLS: usize = 1 << 17;

#[inline]
fn threads_for(cells: usize) -> usize {
    if cells >= PAR_CELLS {
        pool::default_threads()
    } else {
        1
    }
}

/// Log-mass of padded points (mirrors kernels/ref.py NEG).  The value
/// lives in [`crate::linalg::NEG_LOGMASS`] so the masked batch kernels
/// and this solver can never drift apart.
pub const NEG: f32 = crate::linalg::NEG_LOGMASS;

/// Hyper-parameters; defaults equal the AOT artifacts' baked values so the
/// native and PJRT backends are interchangeable.
#[derive(Clone, Debug)]
pub struct LrotConfig {
    pub rank: usize,
    /// Mirror-descent steps (L).
    pub outer: usize,
    /// Sinkhorn sweeps per KL projection (B).
    pub inner: usize,
    /// Base step size, rescaled by ‖grad‖∞.
    pub gamma: f32,
    /// Init noise scale (symmetry breaking).
    pub tau: f32,
}

impl Default for LrotConfig {
    fn default() -> Self {
        LrotConfig { rank: 2, outer: 30, inner: 12, gamma: 8.0, tau: 0.01 }
    }
}

/// Initial co-clustering for one lane of
/// [`solve_factored_batch_warm`]: per-row cluster labels in `0..rank`
/// for the X (`x`, first `active_x` rows) and Y (`y`, first `active_y`
/// rows) sides — e.g. the parent split's membership, or a
/// `coordinator::warmstart` clustering.  Labels bias the initial logits
/// toward the given co-clustering; mirror descent can still overturn
/// them wherever they are wrong.
#[derive(Clone, Copy)]
pub struct WarmLabels<'a> {
    pub x: &'a [u32],
    pub y: &'a [u32],
}

/// Log-domain bias a warm lane adds to its labelled column before the
/// first KL projection: `e^4 ≈ 55×` the mass of the unlabelled columns —
/// a strong prior (the first hard co-clustering equals the labels, so a
/// lane near its fixed point retires at the first convergence check)
/// that a few mirror-descent steps can still walk away from where the
/// clustering was wrong.
const WARM_BIAS: f32 = 4.0;

/// Factors `(Q, R)`, each `s×r`, column sums = 1/r, row sums = marginals.
pub struct LrotOutput {
    pub q: Mat,
    pub r: Mat,
    /// Mirror-descent iterations this solve actually entered (≤
    /// `cfg.outer`): the per-lane convergence mask stops a lane — solo or
    /// batched — once its hard co-clustering is stable for 5 iterations.
    pub iters: usize,
}

/// Solve LROT on cost factors `(u, v)` (C = U Vᵀ restricted to the block)
/// with uniform marginals over the first `active_x`/`active_y` rows; rows
/// beyond that are phantom padding with zero mass.  Deterministic in
/// `seed`.  Standalone entry point (baselines, tests): allocates a private
/// single-shard arena — callers in a solve loop should use
/// [`solve_factored_in`] with a shared arena instead.
pub fn solve_factored<'a, 'b>(
    u: impl Into<MatView<'a>>,
    v: impl Into<MatView<'b>>,
    active_x: usize,
    active_y: usize,
    cfg: &LrotConfig,
    seed: u64,
) -> LrotOutput {
    let arena = ScratchArena::new(1);
    solve_factored_in(u.into(), v.into(), active_x, active_y, cfg, seed, &arena)
}

/// [`solve_factored`] with every intermediate drawn from `arena`.
///
/// This is exactly the **1-lane case** of [`solve_factored_batch`]: the
/// per-block and batched execution paths share one mirror-descent loop
/// (one set of floating-point operations per lane), so they cannot drift.
pub fn solve_factored_in(
    u: MatView<'_>,
    v: MatView<'_>,
    active_x: usize,
    active_y: usize,
    cfg: &LrotConfig,
    seed: u64,
    arena: &ScratchArena,
) -> LrotOutput {
    let u_items = [BatchItem::new(0..u.rows, u.cols)];
    let v_items = [BatchItem::new(0..v.rows, v.cols)];
    solve_factored_batch(
        BatchView::new(u.data, &u_items),
        BatchView::new(v.data, &v_items),
        &[(active_x, active_y)],
        cfg,
        &[seed],
        arena,
        1,
    )
    .pop()
    .expect("one lane in, one output out")
}

/// Per-lane geometry: shapes, active row counts, and each lane's window
/// offsets into the strided state buffers shared by the whole batch —
/// computed once at batch setup so the iteration loop never rebuilds
/// per-lane layout.
#[derive(Clone, Copy)]
struct Geo {
    s: usize,
    sv: usize,
    ax: usize,
    ay: usize,
    off_s: usize,
    off_sv: usize,
    off_sr: usize,
    off_svr: usize,
    off_f: usize,
    /// Element offset of this lane's `k×r` workspace window.
    off_w: usize,
}

/// Per-lane convergence bookkeeping (worker-exclusive via `RangeShared`).
#[derive(Default)]
struct LaneCtl {
    prev: Option<(Vec<u16>, Vec<u16>)>,
    iters: usize,
}

/// Strided per-lane solver state: each buffer holds every lane's window
/// back to back; a lane is only ever touched by the single worker that
/// owns it for the current pass, which is what makes the `SharedSlice`
/// disjoint-range accesses sound.  The exponential, gradient and
/// workspace buffers are **persistent for the whole batch** — checked out
/// of the arena once at setup — so the mirror-descent hot loop allocates
/// nothing (first half of the ROADMAP "persistent lane workers" item).
struct BatchState<'a> {
    loga: SharedSlice<'a, f32>,
    logb: SharedSlice<'a, f32>,
    fpot: SharedSlice<'a, f32>,
    hpot: SharedSlice<'a, f32>,
    log_q: SharedSlice<'a, f32>,
    log_r: SharedSlice<'a, f32>,
    /// exp(log_Q) / exp(log_R), refreshed in place each iteration.
    q_exp: SharedSlice<'a, f32>,
    r_exp: SharedSlice<'a, f32>,
    /// Mirror-descent gradients, one `s×r` / `sv×r` window per lane.
    gq: SharedSlice<'a, f32>,
    gr: SharedSlice<'a, f32>,
    /// `k×r` matmul workspace per lane (holds `VᵀR`, then `UᵀQ`).
    w: SharedSlice<'a, f32>,
    ctl: RangeShared<LaneCtl>,
}

/// Partition `lanes` into at most `crew.width()` contiguous chunks, run
/// `f` on each chunk as one crew round, and concatenate the returned lane
/// lists in chunk order.  The chunk math is exactly the historical
/// spawn-per-iteration partition, so the per-lane computation — which is
/// self-contained — runs over identical chunks and results are
/// bit-identical for any crew width.
fn crew_lane_chunks(
    crew: &LaneCrew,
    lanes: &[u32],
    f: impl Fn(&[u32]) -> Vec<u32> + Sync,
) -> Vec<u32> {
    if lanes.is_empty() {
        return Vec::new();
    }
    let chunk = lanes.len().div_ceil(crew.width().max(1).min(lanes.len()));
    // re-derive the chunk count from the rounded-up chunk size: with e.g.
    // 5 lanes over 4 workers (chunk 2) only 3 chunks exist — indexing by
    // the crew width would step past the slice.
    let n_chunks = lanes.len().div_ceil(chunk);
    let mut slots: Vec<Option<Vec<u32>>> = (0..n_chunks).map(|_| None).collect();
    {
        let out = SharedSlice::new(&mut slots);
        crew.run(n_chunks, &|c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(lanes.len());
            let v = f(&lanes[lo..hi]);
            // SAFETY: chunk `c` runs on exactly one worker per round.
            unsafe { out.slice_mut(c, c + 1) }[0] = Some(v);
        });
    }
    slots.into_iter().flat_map(|v| v.expect("crew missed a chunk")).collect()
}

/// Solve many LROT sub-problems as **one strided batch**: lane `l` is the
/// factor pair `(u.item(l), v.item(l))` with uniform marginals over its
/// first `active[l]` rows, seeded by `seeds[l]`.  All lanes share one
/// mirror-descent iteration loop; per-lane convergence masks retire lanes
/// whose hard co-clustering has stabilised, so early-converged blocks stop
/// paying matmuls.  Lanes may be ragged (different shapes); the HiRef
/// level scheduler groups same-shape blocks so its batches are uniform.
///
/// Lane `l`'s output is **bit-identical** to
/// `solve_factored_in(u.item(l), v.item(l), ...)` with the same seed —
/// independent of `threads` and of which other lanes share the batch.
///
/// Parallelism comes from a persistent [`pool::LaneCrew`]: `min(threads,
/// lanes)` workers spawn once per call and park on a condvar barrier
/// between iterations, so a batch costs O(threads) thread spawns rather
/// than O(iterations · threads) (counted by [`pool::crew_spawns`],
/// surfaced as `RunStats::iter_spawns`).  With `threads <= 1` the crew is
/// worker-less and every round runs inline on the caller.
pub fn solve_factored_batch(
    u: BatchView<'_>,
    v: BatchView<'_>,
    active: &[(usize, usize)],
    cfg: &LrotConfig,
    seeds: &[u64],
    arena: &ScratchArena,
    threads: usize,
) -> Vec<LrotOutput> {
    solve_factored_batch_warm(u, v, active, cfg, seeds, &[], arena, threads)
}

/// [`solve_factored_batch`] with optional per-lane **warm starts**: lane
/// `l` with `warm[l] = Some(labels)` adds [`WARM_BIAS`] to each labelled
/// logit column after the noisy product-coupling init (and, when the
/// labels cover every row, pre-seeds the convergence mask with them, so
/// a lane already at its fixed point retires at the *first* stability
/// check instead of the second).  An empty `warm` slice — or `None` in
/// every lane — is **bit-identical** to the cold solver: the RNG draw
/// sequence and every subsequent floating-point operation are unchanged.
#[allow(clippy::too_many_arguments)]
pub fn solve_factored_batch_warm(
    u: BatchView<'_>,
    v: BatchView<'_>,
    active: &[(usize, usize)],
    cfg: &LrotConfig,
    seeds: &[u64],
    warm: &[Option<WarmLabels<'_>>],
    arena: &ScratchArena,
    threads: usize,
) -> Vec<LrotOutput> {
    let lanes = u.len();
    assert_eq!(lanes, v.len(), "u/v lane count mismatch");
    assert_eq!(lanes, active.len(), "active lane count mismatch");
    assert_eq!(lanes, seeds.len(), "seed lane count mismatch");
    assert!(warm.is_empty() || warm.len() == lanes, "warm lane count mismatch");
    if lanes == 0 {
        return Vec::new();
    }
    let r = cfg.rank;
    let logg = -(r as f32).ln();

    // --- per-lane geometry + strided offsets ---------------------------
    let mut geo = Vec::with_capacity(lanes);
    let (mut ts, mut tsv, mut tsr, mut tsvr, mut tf, mut tw) = (0usize, 0, 0, 0, 0, 0);
    for l in 0..lanes {
        let (s, k) = (u.items[l].nrows(), u.items[l].cols);
        let (sv, kv) = (v.items[l].nrows(), v.items[l].cols);
        assert_eq!(k, kv, "factor width mismatch in lane {l}");
        let (ax, ay) = active[l];
        assert!(ax <= s && ay <= sv, "lane {l}: active exceeds shape");
        geo.push(Geo {
            s,
            sv,
            ax,
            ay,
            off_s: ts,
            off_sv: tsv,
            off_sr: tsr,
            off_svr: tsvr,
            off_f: tf,
            off_w: tw,
        });
        ts += s;
        tsv += sv;
        tsr += s * r;
        tsvr += sv * r;
        tf += s.max(sv);
        tw += k * r;
    }

    // --- persistent per-lane state: lane windows of shared checkouts,
    // --- taken once per batch so the iteration loop never allocates ----
    let mut loga_buf = arena.take_f32(ts);
    let mut logb_buf = arena.take_f32(tsv);
    let mut fpot_buf = arena.take_f32(tf);
    let mut hpot_buf = arena.take_f32(lanes * r);
    let mut logq_buf = arena.take_f32(tsr);
    let mut logr_buf = arena.take_f32(tsvr);
    let mut qexp_buf = arena.take_f32(tsr);
    let mut rexp_buf = arena.take_f32(tsvr);
    let mut gq_buf = arena.take_f32(tsr);
    let mut gr_buf = arena.take_f32(tsvr);
    let mut w_buf = arena.take_f32(tw);
    let st = BatchState {
        loga: SharedSlice::new(&mut loga_buf),
        logb: SharedSlice::new(&mut logb_buf),
        fpot: SharedSlice::new(&mut fpot_buf),
        hpot: SharedSlice::new(&mut hpot_buf),
        log_q: SharedSlice::new(&mut logq_buf),
        log_r: SharedSlice::new(&mut logr_buf),
        q_exp: SharedSlice::new(&mut qexp_buf),
        r_exp: SharedSlice::new(&mut rexp_buf),
        gq: SharedSlice::new(&mut gq_buf),
        gr: SharedSlice::new(&mut gr_buf),
        w: SharedSlice::new(&mut w_buf),
        ctl: RangeShared::new((0..lanes).map(|_| LaneCtl::default()).collect()),
    };

    // --- persistent crew: workers spawn ONCE here and park on a condvar
    // --- barrier between iterations (O(threads) spawns per batch) ------
    let width = threads.max(1).min(lanes);
    pool::with_lane_crew(width, |crew| {
        // --- init every lane: product marginal + noise, projected ------
        let all: Vec<u32> = (0..lanes as u32).collect();
        crew_lane_chunks(crew, &all, |ids| {
            for &l in ids {
                init_lane(l as usize, r, logg, cfg, seeds, warm, &geo, &st);
            }
            Vec::new()
        });

        // --- the shared mirror-descent loop with per-lane masks --------
        let mut live = all;
        for it in 0..cfg.outer {
            if live.is_empty() {
                break;
            }
            let check = it % 5 == 4;
            let converged = crew_lane_chunks(crew, &live, |ids| {
                step_lanes(ids, check, u, v, cfg, r, logg, &geo, &st)
            });
            if !converged.is_empty() {
                let mut gone = vec![false; lanes];
                for &l in &converged {
                    gone[l as usize] = true;
                }
                live.retain(|&l| !gone[l as usize]);
            }
        }

        // --- finalise: exp the projected logits into owned factors -----
        let mut outs: Vec<Option<LrotOutput>> = (0..lanes).map(|_| None).collect();
        {
            let slots = SharedSlice::new(&mut outs);
            let chunk = lanes.div_ceil(width.min(lanes));
            let n_chunks = lanes.div_ceil(chunk);
            crew.run(n_chunks, &|c| {
                for l in c * chunk..((c + 1) * chunk).min(lanes) {
                    let g = &geo[l];
                    // SAFETY: the iteration loop has completed; nothing
                    // writes the logits any more.
                    let lq = unsafe { st.log_q.slice(g.off_sr, g.off_sr + g.s * r) };
                    // SAFETY: as above — iteration is over, reads only.
                    let lr = unsafe { st.log_r.slice(g.off_svr, g.off_svr + g.sv * r) };
                    let mut q = vec![0.0f32; g.s * r];
                    let mut rr = vec![0.0f32; g.sv * r];
                    exp_into(lq, &mut q);
                    exp_into(lr, &mut rr);
                    // SAFETY: iteration is over; no worker writes lane
                    // ctl entries any more, so a shared read is sound.
                    let iters = unsafe { st.ctl.slice(l, l + 1) }[0].iters;
                    let out = LrotOutput {
                        q: Mat::from_vec(g.s, r, q),
                        r: Mat::from_vec(g.sv, r, rr),
                        iters,
                    };
                    // SAFETY: lane `l` belongs to exactly this chunk.
                    unsafe { slots.slice_mut(l, l + 1) }[0] = Some(out);
                }
            });
        }
        outs.into_iter().map(|o| o.expect("crew missed a lane")).collect()
    })
}

/// Lane initialisation: marginals, noisy product-coupling logits,
/// optional warm-start bias, first KL projection.  Same operation order
/// as the historical per-block solve — a cold lane (no warm entry) draws
/// the identical RNG sequence and computes the identical floats.
#[allow(clippy::too_many_arguments)]
fn init_lane(
    l: usize,
    r: usize,
    logg: f32,
    cfg: &LrotConfig,
    seeds: &[u64],
    warm: &[Option<WarmLabels<'_>>],
    geo: &[Geo],
    st: &BatchState<'_>,
) {
    let g = &geo[l];
    let mut rng = Rng::new(seeds[l] ^ 0x160_7);
    // SAFETY: lane l's windows are owned by this worker for the whole pass.
    let loga = unsafe { st.loga.slice_mut(g.off_s, g.off_s + g.s) };
    // SAFETY: as above — lane l's `logb` window, this worker only.
    let logb = unsafe { st.logb.slice_mut(g.off_sv, g.off_sv + g.sv) };
    fill_log_marginal(loga, g.ax);
    fill_log_marginal(logb, g.ay);
    // SAFETY: as above — lane l's `log_q` window, this worker only.
    let lq = unsafe { st.log_q.slice_mut(g.off_sr, g.off_sr + g.s * r) };
    // SAFETY: as above — lane l's `log_r` window, this worker only.
    let lr = unsafe { st.log_r.slice_mut(g.off_svr, g.off_svr + g.sv * r) };
    init_logits(lq, loga, r, logg, cfg.tau, &mut rng);
    init_logits(lr, logb, r, logg, cfg.tau, &mut rng);
    if let Some(w) = warm.get(l).copied().flatten() {
        // warm start: bias the labelled column of each row before the
        // first projection (the noise stays — symmetry breaking for rows
        // the clustering got wrong)
        debug_assert!(w.x.len() <= g.s && w.y.len() <= g.sv, "warm labels exceed lane shape");
        for (i, &z) in w.x.iter().enumerate() {
            lq[i * r + z as usize] += WARM_BIAS;
        }
        for (j, &z) in w.y.iter().enumerate() {
            lr[j * r + z as usize] += WARM_BIAS;
        }
        if w.x.len() == g.s && w.y.len() == g.sv {
            // full-cover labels: pre-seed the convergence mask so the
            // first stability check can already retire the lane (the
            // row-argmax is preserved by the projection's row shifts and,
            // for balanced labels, near-uniform column potentials)
            // SAFETY: lane l's ctl entry — this worker only during init.
            let ctl = unsafe { &mut st.ctl.slice_mut(l, l + 1)[0] };
            ctl.prev = Some((
                w.x.iter().map(|&z| z as u16).collect(),
                w.y.iter().map(|&z| z as u16).collect(),
            ));
        }
    }
    // SAFETY: as above — lane l's potential scratch, this worker only.
    let f = unsafe { st.fpot.slice_mut(g.off_f, g.off_f + g.s.max(g.sv)) };
    // SAFETY: as above — lane l's column-potential window, this worker only.
    let h = unsafe { st.hpot.slice_mut(l * r, (l + 1) * r) };
    sinkhorn_project(lq, g.s, r, loga, logg, cfg.inner, &mut f[..g.s], h);
    sinkhorn_project(lr, g.sv, r, logb, logg, cfg.inner, &mut f[..g.sv], h);
}

/// One mirror-descent iteration for this worker's lanes: exp the logits
/// into the persistent exponential windows, (every 5th iteration) test
/// the hard co-clustering for stability and retire stable lanes, then
/// compute the gradient in each still-stepping lane's persistent windows,
/// take the step and re-project.  Everything writes into per-lane windows
/// of the batch state fixed at setup — the loop performs **zero**
/// allocations and zero arena checkouts.  Per-lane work is self-contained
/// (no cross-lane data flow), so results are bit-identical to the
/// historical stage-wise batched-kernel formulation.  Returns the lane
/// ids that converged this iteration.
#[allow(clippy::too_many_arguments)]
fn step_lanes(
    ids: &[u32],
    check: bool,
    u: BatchView<'_>,
    v: BatchView<'_>,
    cfg: &LrotConfig,
    r: usize,
    logg: f32,
    geo: &[Geo],
    st: &BatchState<'_>,
) -> Vec<u32> {
    let inv_g = r as f32;
    let mut converged = Vec::new();
    for &l in ids {
        let l = l as usize;
        let g = &geo[l];
        let k = u.items[l].cols;
        // Q = exp(log_Q), R = exp(log_R) into the persistent windows.
        // SAFETY (this and every lane-window slice below): lane l's
        // windows are owned by this worker for the whole call — the crew
        // hands each worker a disjoint lane subset, and lane windows of
        // distinct lanes never overlap (strided offsets from `Geo`).
        let lq = unsafe { st.log_q.slice(g.off_sr, g.off_sr + g.s * r) };
        // SAFETY: lane l's `log_r` window — this worker only.
        let lr = unsafe { st.log_r.slice(g.off_svr, g.off_svr + g.sv * r) };
        // SAFETY: lane l's `q_exp` window — this worker only.
        let qe = unsafe { st.q_exp.slice_mut(g.off_sr, g.off_sr + g.s * r) };
        // SAFETY: lane l's `r_exp` window — this worker only.
        let re = unsafe { st.r_exp.slice_mut(g.off_svr, g.off_svr + g.sv * r) };
        exp_into(lq, qe);
        exp_into(lr, re);

        // Early stop: once the hard co-clustering is stable, further
        // mirror-descent steps cannot change HiRef's refinement decision.
        // SAFETY: lane l's ctl entry — this worker only.
        let ctl = unsafe { &mut st.ctl.slice_mut(l, l + 1)[0] };
        ctl.iters += 1;
        if check {
            let labels = (argmax_labels(qe, r), argmax_labels(re, r));
            if ctl.prev.as_ref() == Some(&labels) {
                converged.push(l as u32);
                continue;
            }
            ctl.prev = Some(labels);
        }

        // gq = U (Vᵀ R) · inv_g ; gr = V (Uᵀ Q) · inv_g — scalar kernels
        // over this lane's windows (identical FLOPs to the batch_* form)
        let uv = u.item(l);
        let vv = v.item(l);
        // SAFETY: lane l's workspace window — this worker only.
        let w = unsafe { st.w.slice_mut(g.off_w, g.off_w + k * r) };
        // SAFETY: lane l's `gq` window — this worker only.
        let gq = unsafe { st.gq.slice_mut(g.off_sr, g.off_sr + g.s * r) };
        vt_matmul_into_slice(vv, MatView::from_slice(g.sv, r, re), w);
        matmul_into_slice(uv, MatView::from_slice(k, r, w), gq);
        gq.iter_mut().for_each(|x| *x *= inv_g);
        // SAFETY: re-borrow of lane l's workspace window (the previous
        // `w` borrow ended above) — this worker only.
        let w = unsafe { st.w.slice_mut(g.off_w, g.off_w + k * r) };
        // SAFETY: lane l's `gr` window — this worker only.
        let gr = unsafe { st.gr.slice_mut(g.off_svr, g.off_svr + g.sv * r) };
        vt_matmul_into_slice(uv, MatView::from_slice(g.s, r, qe), w);
        matmul_into_slice(vv, MatView::from_slice(k, r, w), gr);
        gr.iter_mut().for_each(|x| *x *= inv_g);

        // step-size normalisation, mirror step, KL projections
        let scale = slice_max_abs(gq).max(slice_max_abs(gr)).max(1e-12);
        let step = cfg.gamma / scale;
        // SAFETY: lane l's `log_q` window, re-borrowed mutably (the
        // shared `lq` borrow ended at the exp) — this worker only.
        let lq = unsafe { st.log_q.slice_mut(g.off_sr, g.off_sr + g.s * r) };
        // SAFETY: as above, for `log_r`.
        let lr = unsafe { st.log_r.slice_mut(g.off_svr, g.off_svr + g.sv * r) };
        for (x, &gv) in lq.iter_mut().zip(gq.iter()) {
            *x -= step * gv;
        }
        for (x, &gv) in lr.iter_mut().zip(gr.iter()) {
            *x -= step * gv;
        }
        // SAFETY: lane l's `loga` window — written only at init, shared
        // reads are sound for the rest of the batch.
        let loga = unsafe { st.loga.slice(g.off_s, g.off_s + g.s) };
        // SAFETY: as above, for `logb`.
        let logb = unsafe { st.logb.slice(g.off_sv, g.off_sv + g.sv) };
        // SAFETY: lane l's potential scratch — this worker only.
        let f = unsafe { st.fpot.slice_mut(g.off_f, g.off_f + g.s.max(g.sv)) };
        // SAFETY: lane l's column-potential window — this worker only.
        let h = unsafe { st.hpot.slice_mut(l * r, (l + 1) * r) };
        sinkhorn_project(lq, g.s, r, loga, logg, cfg.inner, &mut f[..g.s], h);
        sinkhorn_project(lr, g.sv, r, logb, logg, cfg.inner, &mut f[..g.sv], h);
    }
    converged
}

/// Primal cost `⟨C, Q diag(1/g) Rᵀ⟩` with C = U Vᵀ and uniform g = 1/r,
/// in O(s·k·r): equals `(1/g) Σ_z (UᵀQ)_z · (VᵀR)_z`.
pub fn lowrank_cost(u: &Mat, v: &Mat, q: &Mat, r: &Mat) -> f64 {
    let rank = q.cols;
    let uq = u.t_matmul(q); // k×r
    let vr = v.t_matmul(r); // k×r
    let mut s = 0.0f64;
    for z in 0..rank {
        let mut dz = 0.0f64;
        for k in 0..uq.rows {
            dz += uq.at(k, z) as f64 * vr.at(k, z) as f64;
        }
        s += dz;
    }
    s * rank as f64
}

fn fill_log_marginal(out: &mut [f32], active: usize) {
    let la = -(active as f32).ln();
    for (i, v) in out.iter_mut().enumerate() {
        *v = if i < active { la } else { NEG };
    }
}

fn init_logits(m: &mut [f32], loga: &[f32], r: usize, logg: f32, tau: f32, rng: &mut Rng) {
    for (i, row) in m.chunks_mut(r).enumerate() {
        for v in row.iter_mut() {
            *v = loga[i] + logg + tau * rng.normal_f32();
        }
    }
}

/// In-place masked log-domain Sinkhorn projection onto Π(a, g) over a
/// row-major `s×r` logit buffer.  Mirrors model.sinkhorn_project:
/// alternating f (rows) / h (cols) updates.  Row loops are chunked across
/// threads for large blocks — the exp/log-heavy f-update dominates LROT
/// runtime at the top of the hierarchy (see EXPERIMENTS.md §Perf).  The
/// caller supplies the potential buffers (`f` len `s`, `h` len `r`) so a
/// solve checks them out of the arena exactly once; `h` is reset here
/// (the projection always starts from zero column potentials), `f` is
/// fully overwritten before use.
#[allow(clippy::too_many_arguments)]
fn sinkhorn_project(
    log_k: &mut [f32],
    s: usize,
    r: usize,
    loga: &[f32],
    logg: f32,
    iters: usize,
    f: &mut [f32],
    h: &mut [f32],
) {
    debug_assert_eq!(log_k.len(), s * r);
    debug_assert_eq!(f.len(), s);
    debug_assert_eq!(h.len(), r);
    h.fill(0.0);
    let threads = threads_for(s * r * iters);
    let chunk = s.div_ceil(threads.max(1)).max(1);
    let n_chunks = s.div_ceil(chunk);

    for _ in 0..iters {
        // f-update (row LSE with current h) + per-chunk column partials
        let partials: Vec<(Vec<f32>, Vec<f32>)> = {
            let lk: &[f32] = log_k;
            let h_ref: &[f32] = &h;
            let mut f_chunks: Vec<&mut [f32]> = f.chunks_mut(chunk).collect();
            let results = std::sync::Mutex::new(vec![None; n_chunks]);
            std::thread::scope(|scope| {
                for (ci, f_chunk) in f_chunks.iter_mut().enumerate() {
                    let results = &results;
                    let f_chunk: &mut [f32] = f_chunk;
                    scope.spawn(move || {
                        let lo = ci * chunk;
                        // pass 1: f-update + local col max over exp args
                        let mut col_max = vec![f32::NEG_INFINITY; r];
                        for (o, i) in (lo..(lo + f_chunk.len())).enumerate() {
                            if loga[i] <= NEG / 2.0 {
                                f_chunk[o] = NEG;
                                continue;
                            }
                            let row = &lk[i * r..(i + 1) * r];
                            let mut mx = f32::NEG_INFINITY;
                            for (v, hv) in row.iter().zip(h_ref) {
                                mx = mx.max(v + hv);
                            }
                            let mx = mx.max(NEG);
                            let mut sum = 0.0f32;
                            for (v, hv) in row.iter().zip(h_ref) {
                                sum += fast_exp((v + hv) - mx);
                            }
                            let fi = loga[i] - (mx + sum.ln());
                            f_chunk[o] = fi;
                            for (cm, v) in col_max.iter_mut().zip(row) {
                                *cm = cm.max(v + fi);
                            }
                        }
                        // pass 2: local col sums against the LOCAL max
                        // (rescaled to the global max during the merge)
                        let mut col_acc = vec![0.0f32; r];
                        for (o, i) in (lo..(lo + f_chunk.len())).enumerate() {
                            let fi = f_chunk[o];
                            if fi <= NEG / 2.0 {
                                continue;
                            }
                            for ((acc, v), cm) in
                                col_acc.iter_mut().zip(&lk[i * r..(i + 1) * r]).zip(&col_max)
                            {
                                *acc += fast_exp(v + fi - cm);
                            }
                        }
                        results.lock().unwrap()[ci] = Some((col_max, col_acc));
                    });
                }
            });
            results
                .into_inner()
                .unwrap()
                .into_iter()
                .map(|o| o.unwrap())
                .collect()
        };
        // merge column partials: global max, then rescale each chunk's sums
        let mut gmax = vec![f32::NEG_INFINITY; r];
        for (cm, _) in &partials {
            for (g, &v) in gmax.iter_mut().zip(cm) {
                *g = g.max(v);
            }
        }
        let mut dh_max = 0.0f32;
        for z in 0..r {
            let g = gmax[z].max(NEG);
            let mut total = 0.0f64;
            for (cm, ca) in &partials {
                if ca[z] > 0.0 {
                    total += ca[z] as f64 * (((cm[z].max(NEG) - g) as f64).exp());
                }
            }
            let new_h = logg - (g + (total.ln() as f32));
            dh_max = dh_max.max((new_h - h[z]).abs());
            h[z] = new_h;
        }
        // converged projections exit early (typical after 3-5 sweeps)
        if dh_max < 1e-4 {
            break;
        }
    }
    // fold potentials in (chunk-parallel)
    {
        let h_ref: &[f32] = &h;
        let f_ref: &[f32] = &f;
        let rows_per = chunk;
        let mut data_chunks: Vec<&mut [f32]> = log_k.chunks_mut(rows_per * r).collect();
        std::thread::scope(|scope| {
            for (ci, dchunk) in data_chunks.iter_mut().enumerate() {
                let dchunk: &mut [f32] = dchunk;
                scope.spawn(move || {
                    let lo = ci * rows_per;
                    for (o, row) in dchunk.chunks_mut(r).enumerate() {
                        let fi = f_ref[lo + o];
                        for (v, hv) in row.iter_mut().zip(h_ref) {
                            *v += fi + hv;
                        }
                    }
                });
            }
        });
    }
}

/// Row argmax labels (compact u16; ranks are ≤ 2^16).
fn argmax_labels(m: &[f32], r: usize) -> Vec<u16> {
    m.chunks(r)
        .map(|row| {
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for (z, &v) in row.iter().enumerate() {
                if v > bv {
                    bv = v;
                    best = z;
                }
            }
            best as u16
        })
        .collect()
}

fn exp_into(src: &[f32], dst: &mut [f32]) {
    // dispatched fast_exp sweep (scalar or SIMD, bit-identical either
    // way); fast_exp underflows the NEG sentinel to 0
    crate::linalg::exp_slice(src, dst);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::factor::sq_euclidean_factors;
    use crate::prng::Rng;

    fn shuffled_pair(s: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(s, d);
        rng.fill_normal(&mut x.data);
        let perm = rng.permutation(s);
        let mut y = x.gather_rows(&perm);
        for v in y.data.iter_mut() {
            *v += 0.01 * rng.normal_f32();
        }
        (x, y, perm)
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn feasibility_uniform_marginals() {
        let (x, y, _) = shuffled_pair(128, 2, 0);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 128, 128, &LrotConfig::default(), 1);
        for cs in out.q.col_sums() {
            assert!((cs - 0.5).abs() < 5e-3, "col sum {cs}");
        }
        let total: f64 = out.q.data.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-3);
        assert!(out.q.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn monge_co_clustering() {
        // Prop 3.1 behaviour: x and T(x) land in the same cluster
        let (x, y, perm) = shuffled_pair(256, 2, 2);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 256, 256, &LrotConfig::default(), 3);
        let qa: Vec<usize> = (0..256)
            .map(|i| argmax(out.q.row(i)))
            .collect();
        let ra: Vec<usize> = (0..256)
            .map(|j| argmax(out.r.row(j)))
            .collect();
        // y_j = x_perm[j] + noise, so T(x_{perm[j]}) = y_j
        let agree = (0..256)
            .filter(|&j| qa[perm[j] as usize] == ra[j])
            .count() as f64
            / 256.0;
        assert!(agree > 0.9, "agreement {agree}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn padding_rows_get_zero_mass() {
        let (x, y, _) = shuffled_pair(64, 2, 4);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 48, 48, &LrotConfig::default(), 5);
        for i in 48..64 {
            assert!(out.q.row(i).iter().all(|&v| v == 0.0));
            assert!(out.r.row(i).iter().all(|&v| v == 0.0));
        }
        let total: f64 = out.q.data.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-3);
    }

    #[test]
    fn lowrank_cost_matches_dense() {
        let (x, y, _) = shuffled_pair(32, 2, 6);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 32, 32, &LrotConfig::default(), 7);
        let fast = lowrank_cost(&u, &v, &out.q, &out.r);
        // dense check
        let c = crate::costs::dense_cost(&x, &y, crate::costs::CostKind::SqEuclidean);
        let mut p = Mat::zeros(32, 32);
        for i in 0..32 {
            for j in 0..32 {
                let mut s = 0.0f32;
                for z in 0..2 {
                    s += out.q.at(i, z) * out.r.at(j, z) * 2.0;
                }
                *p.at_mut(i, j) = s;
            }
        }
        let dense = crate::metrics::dense_cost_of(&c, &p);
        assert!((fast - dense).abs() < 1e-3 * dense.abs().max(1.0), "{fast} vs {dense}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn higher_rank_lowers_cost() {
        // Fig. S3 trend: cost decreases as rank grows
        let (x, y, _) = shuffled_pair(128, 2, 8);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let mut costs = Vec::new();
        for &r in &[2usize, 8, 32] {
            let cfg = LrotConfig { rank: r, ..Default::default() };
            let out = solve_factored(&u, &v, 128, 128, &cfg, 9);
            costs.push(lowrank_cost(&u, &v, &out.q, &out.r));
        }
        assert!(costs[2] < costs[0] * 1.02, "rank-32 {} vs rank-2 {}", costs[2], costs[0]);
    }

    /// Stack per-lane factor matrices into one shared buffer + items —
    /// the layout `solve_factored_batch` consumes.
    fn stack_lanes(mats: &[&Mat]) -> (Vec<f32>, Vec<BatchItem>) {
        let mut data = Vec::new();
        let mut items = Vec::new();
        let mut row = 0usize;
        for m in mats {
            items.push(BatchItem::new(row..row + m.rows, m.cols));
            data.extend_from_slice(&m.data);
            row += m.rows;
        }
        (data, items)
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn batch_lanes_bit_identical_to_solo_solves() {
        // three same-shape lanes plus, separately, a ragged pair: every
        // lane of a batch must equal its solo solve exactly, for any
        // thread count.
        let cfg = LrotConfig { rank: 3, ..Default::default() };
        let mats: Vec<(Mat, Mat)> = (0..3)
            .map(|i| {
                let (x, y, _) = shuffled_pair(64, 2, 20 + i);
                sq_euclidean_factors(&x, &y)
            })
            .collect();
        let (udata, uitems) = stack_lanes(&mats.iter().map(|(u, _)| u).collect::<Vec<_>>());
        let (vdata, vitems) = stack_lanes(&mats.iter().map(|(_, v)| v).collect::<Vec<_>>());
        let seeds = [101u64, 102, 103];
        let active = [(64, 64); 3];
        let arena = ScratchArena::new(8);
        // 1 = inline (no crew workers), 2 = chunked lanes, 8 = more
        // workers than lanes — the LaneCrew must be invisible in all three
        for threads in [1usize, 2, 8] {
            let outs = solve_factored_batch(
                BatchView::new(&udata, &uitems),
                BatchView::new(&vdata, &vitems),
                &active,
                &cfg,
                &seeds,
                &arena,
                threads,
            );
            assert_eq!(outs.len(), 3);
            for (l, out) in outs.iter().enumerate() {
                let (u, v) = &mats[l];
                let solo = solve_factored(u, v, 64, 64, &cfg, seeds[l]);
                assert_eq!(out.q.data, solo.q.data, "lane {l} Q diverges (threads {threads})");
                assert_eq!(out.r.data, solo.r.data, "lane {l} R diverges (threads {threads})");
                assert_eq!(out.iters, solo.iters, "lane {l} iteration count diverges");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn ragged_batch_lanes_match_solo_solves() {
        let cfg = LrotConfig { rank: 2, ..Default::default() };
        let (xa, ya, _) = shuffled_pair(48, 2, 31);
        let (xb, yb, _) = shuffled_pair(33, 2, 32);
        let (ua, va) = sq_euclidean_factors(&xa, &ya);
        let (ub, vb) = sq_euclidean_factors(&xb, &yb);
        let (udata, uitems) = stack_lanes(&[&ua, &ub]);
        let (vdata, vitems) = stack_lanes(&[&va, &vb]);
        // second lane exercises padding too (active < rows)
        let active = [(48, 48), (30, 30)];
        let seeds = [7u64, 8];
        let arena = ScratchArena::new(2);
        let outs = solve_factored_batch(
            BatchView::new(&udata, &uitems),
            BatchView::new(&vdata, &vitems),
            &active,
            &cfg,
            &seeds,
            &arena,
            2,
        );
        let solo_a = solve_factored(&ua, &va, 48, 48, &cfg, 7);
        let solo_b = solve_factored(&ub, &vb, 30, 30, &cfg, 8);
        assert_eq!(outs[0].q.data, solo_a.q.data);
        assert_eq!(outs[0].r.data, solo_a.r.data);
        assert_eq!(outs[1].q.data, solo_b.q.data);
        assert_eq!(outs[1].r.data, solo_b.r.data);
        // padding rows of the short lane carry zero mass
        for i in 30..33 {
            assert!(outs[1].q.row(i).iter().all(|&v| v == 0.0));
        }
    }

    /// Two well-separated blobs; y is x plus tiny noise, so the rank-2
    /// hard co-clustering is the blob split and stabilises immediately.
    fn blob_pair(s: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(s, d);
        for i in 0..s {
            let c = if i % 2 == 0 { 4.0f32 } else { -4.0 };
            for v in x.row_mut(i) {
                *v = c + 0.1 * rng.normal_f32();
            }
        }
        let mut y = Mat::zeros(s, d);
        y.data.copy_from_slice(&x.data);
        for v in y.data.iter_mut() {
            *v += 0.01 * rng.normal_f32();
        }
        (x, y)
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn none_warm_lanes_are_bit_identical_to_cold() {
        // the warm seam must be invisible when no lane carries labels:
        // same RNG draws, same floats, same iteration counts
        let cfg = LrotConfig { rank: 3, ..Default::default() };
        let (x, y, _) = shuffled_pair(40, 2, 60);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let (udata, uitems) = stack_lanes(&[&u]);
        let (vdata, vitems) = stack_lanes(&[&v]);
        let arena = ScratchArena::new(2);
        let cold = solve_factored_batch(
            BatchView::new(&udata, &uitems),
            BatchView::new(&vdata, &vitems),
            &[(40, 40)],
            &cfg,
            &[9],
            &arena,
            2,
        );
        let warm = solve_factored_batch_warm(
            BatchView::new(&udata, &uitems),
            BatchView::new(&vdata, &vitems),
            &[(40, 40)],
            &cfg,
            &[9],
            &[None],
            &arena,
            2,
        );
        assert_eq!(cold[0].q.data, warm[0].q.data);
        assert_eq!(cold[0].r.data, warm[0].r.data);
        assert_eq!(cold[0].iters, warm[0].iters);
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn warm_labels_retire_converged_lanes_sooner() {
        // seed a lane with its own fixed-point co-clustering: the
        // pre-seeded convergence mask must retire it at the FIRST
        // stability check (5 iterations) instead of the second (10, the
        // cold minimum), without walking away from the labels.
        let cfg = LrotConfig { rank: 2, ..Default::default() };
        let (x, y) = blob_pair(64, 3, 61);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let cold = solve_factored(&u, &v, 64, 64, &cfg, 17);
        let lx: Vec<u32> = (0..64).map(|i| argmax(cold.q.row(i)) as u32).collect();
        let ly: Vec<u32> = (0..64).map(|j| argmax(cold.r.row(j)) as u32).collect();
        let (udata, uitems) = stack_lanes(&[&u]);
        let (vdata, vitems) = stack_lanes(&[&v]);
        let arena = ScratchArena::new(2);
        let warm = solve_factored_batch_warm(
            BatchView::new(&udata, &uitems),
            BatchView::new(&vdata, &vitems),
            &[(64, 64)],
            &cfg,
            &[17],
            &[Some(WarmLabels { x: &lx, y: &ly })],
            &arena,
            2,
        );
        assert!(
            warm[0].iters <= cold.iters,
            "warm {} vs cold {} iterations",
            warm[0].iters,
            cold.iters
        );
        assert!(warm[0].iters <= 10, "warm lane took {} iterations", warm[0].iters);
        for i in 0..64 {
            assert_eq!(argmax(warm[0].q.row(i)) as u32, lx[i], "warm solve left its labels");
        }
    }

    #[test]
    fn lane_count_not_divisible_by_threads_does_not_panic() {
        // regression: 5 lanes over 4 threads gives ceil(5/4)=2-lane chunks
        // — only 3 chunks exist, and the chunker must not index a 4th.
        let cfg = LrotConfig { rank: 2, outer: 6, ..Default::default() };
        let mats: Vec<(Mat, Mat)> = (0..5u64)
            .map(|i| {
                let (x, y, _) = shuffled_pair(24, 2, 50 + i);
                sq_euclidean_factors(&x, &y)
            })
            .collect();
        let (udata, uitems) = stack_lanes(&mats.iter().map(|(u, _)| u).collect::<Vec<_>>());
        let (vdata, vitems) = stack_lanes(&mats.iter().map(|(_, v)| v).collect::<Vec<_>>());
        let arena = ScratchArena::new(4);
        let seeds: Vec<u64> = (0..5).collect();
        let outs = solve_factored_batch(
            BatchView::new(&udata, &uitems),
            BatchView::new(&vdata, &vitems),
            &[(24, 24); 5],
            &cfg,
            &seeds,
            &arena,
            4,
        );
        assert_eq!(outs.len(), 5);
        for (l, out) in outs.iter().enumerate() {
            let (u, v) = &mats[l];
            let solo = solve_factored(u, v, 24, 24, &cfg, seeds[l]);
            assert_eq!(out.q.data, solo.q.data, "lane {l}");
        }
    }

    #[test]
    fn empty_batch_returns_no_outputs() {
        let arena = ScratchArena::new(1);
        let outs = solve_factored_batch(
            BatchView::new(&[], &[]),
            BatchView::new(&[], &[]),
            &[],
            &LrotConfig::default(),
            &[],
            &arena,
            4,
        );
        assert!(outs.is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn convergence_mask_stops_iterating_converged_lanes() {
        // lane A: two tight, far-apart clusters — the argmax co-clustering
        // locks in almost immediately, so the mask must retire the lane
        // long before `outer` runs out.  Lane B: a larger generic problem
        // that keeps stepping.  Each lane's iteration count must equal its
        // solo count (the mask is per lane, not per batch).
        let mut rng = Rng::new(40);
        let mut xa = Mat::zeros(16, 2);
        for i in 0..16 {
            let c = if i < 8 { -100.0 } else { 100.0 };
            xa.row_mut(i)[0] = c + 0.01 * rng.normal_f32();
            xa.row_mut(i)[1] = 0.01 * rng.normal_f32();
        }
        let ya = xa.clone();
        let (ua, va) = sq_euclidean_factors(&xa, &ya);
        let (xb, yb, _) = shuffled_pair(96, 2, 41);
        let (ub, vb) = sq_euclidean_factors(&xb, &yb);
        let cfg = LrotConfig { rank: 2, outer: 500, ..Default::default() };
        let solo_a = solve_factored(&ua, &va, 16, 16, &cfg, 1);
        let solo_b = solve_factored(&ub, &vb, 96, 96, &cfg, 2);
        assert!(
            solo_a.iters < cfg.outer,
            "well-separated clusters must early-stop (ran {} iters)",
            solo_a.iters
        );
        let (udata, uitems) = stack_lanes(&[&ua, &ub]);
        let (vdata, vitems) = stack_lanes(&[&va, &vb]);
        let arena = ScratchArena::new(2);
        let outs = solve_factored_batch(
            BatchView::new(&udata, &uitems),
            BatchView::new(&vdata, &vitems),
            &[(16, 16), (96, 96)],
            &cfg,
            &[1, 2],
            &arena,
            2,
        );
        assert_eq!(outs[0].iters, solo_a.iters, "batched lane A iter count");
        assert_eq!(outs[1].iters, solo_b.iters, "batched lane B iter count");
        // the retired lane's factors are frozen at its early-stop state
        assert_eq!(outs[0].q.data, solo_a.q.data);
        assert_eq!(outs[1].q.data, solo_b.q.data);
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: full mirror-descent solves")]
    fn shared_arena_run_matches_private_arena_run() {
        // solve_factored_in with a reused arena must be bit-identical to
        // the standalone entry point (buffers are zeroed on checkout).
        let (x, y, _) = shuffled_pair(96, 2, 10);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let cfg = LrotConfig { rank: 4, ..Default::default() };
        let a = solve_factored(&u, &v, 96, 96, &cfg, 11);
        let arena = ScratchArena::new(2);
        // run twice so the second solve hits warm freelists
        let _ = solve_factored_in(u.view(), v.view(), 96, 96, &cfg, 11, &arena);
        let b = solve_factored_in(u.view(), v.view(), 96, 96, &cfg, 11, &arena);
        assert_eq!(a.q.data, b.q.data);
        assert_eq!(a.r.data, b.r.data);
        assert!(arena.hits() > 0, "second solve should reuse buffers");
    }

    fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }
}

/// Unbiased Monte-Carlo estimate of the primal cost `⟨C, Q diag(1/g) Rᵀ⟩`
/// under the TRUE (non-factorised) cost: sample `(i, j) ~ P` by drawing a
/// component `z ~ g`, then `i ~ Q_{·z}/g_z`, `j ~ R_{·z}/g_z`, and average
/// `c(x_i, y_j)`.  Linear time and space — usable at the paper's 10⁵–10⁶
/// scales where exact evaluation of a dense low-rank coupling is O(n²).
pub fn lowrank_cost_sampled(
    x: &crate::linalg::Mat,
    y: &crate::linalg::Mat,
    kind: crate::costs::CostKind,
    q: &Mat,
    r: &Mat,
    samples: usize,
    seed: u64,
) -> f64 {
    let rank = q.cols;
    let mut rng = Rng::new(seed ^ 0x5A11);
    // cumulative distributions per component (O(n·r) once)
    let col_cdf = |m: &Mat| -> Vec<Vec<f64>> {
        (0..rank)
            .map(|z| {
                let mut acc = 0.0f64;
                let mut cdf = Vec::with_capacity(m.rows);
                for i in 0..m.rows {
                    acc += m.at(i, z) as f64;
                    cdf.push(acc);
                }
                cdf
            })
            .collect()
    };
    let qc = col_cdf(q);
    let rc = col_cdf(r);
    let g_mass: Vec<f64> = (0..rank).map(|z| *qc[z].last().unwrap_or(&0.0)).collect();
    let total: f64 = g_mass.iter().sum();
    let draw = |cdf: &[f64], u: f64| -> usize {
        let target = u * cdf.last().unwrap();
        cdf.partition_point(|&c| c < target).min(cdf.len() - 1)
    };
    let mut acc = 0.0f64;
    for _ in 0..samples {
        // z ~ g
        let mut u = rng.next_f64() * total;
        let mut z = 0;
        for (k, &m) in g_mass.iter().enumerate() {
            if u < m {
                z = k;
                break;
            }
            u -= m;
            z = k;
        }
        let i = draw(&qc[z], rng.next_f64());
        let j = draw(&rc[z], rng.next_f64());
        acc += kind.pair(x.row(i), y.row(j));
    }
    acc / samples as f64
}
