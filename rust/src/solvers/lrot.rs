//! Native low-rank OT (LROT): mirror descent on factors `(Q, R)` with the
//! inner marginal `g` pinned uniform — the Rust twin of the AOT model in
//! `python/compile/model.py` (same algorithm, same hyper-parameters), used
//!
//! * as the HiRef sub-problem backend for shapes outside the AOT bucket
//!   grid (and in artifact-free test environments), and
//! * as the LOT / FRLC low-rank *baselines* of Tables 1/S6/S7/S8 and
//!   Fig. S3 (rank r fixed, e.g. 40).
//!
//! Cost matrices never materialise: gradients go through the factorisation
//! `C = U Vᵀ`, so a solve is `O(outer · (s·k·r + inner · s·r))`.
//!
//! The solver is **zero-copy and allocation-free on the hot path**: cost
//! factors arrive as borrowed [`MatView`]s (HiRef slices its contiguous
//! working buffers, never gathers), and every intermediate — logits,
//! factor exponentials, gradients, Sinkhorn potentials — is checked out of
//! a [`ScratchArena`] ([`solve_factored_in`]).  Only the output factors
//! are owned, and those leave the arena without a copy via `detach`.

use crate::linalg::{fast_exp, matmul_into_slice, slice_max_abs, vt_matmul_into_slice, Mat, MatView};
use crate::pool::{self, ScratchArena};
use crate::prng::Rng;

/// Row-parallelism threshold: blocks below this stay single-threaded (the
/// HiRef fan-out already saturates cores with many small blocks); above it
/// (top-of-hierarchy blocks) the inner loops split across threads.
const PAR_CELLS: usize = 1 << 17;

#[inline]
fn threads_for(cells: usize) -> usize {
    if cells >= PAR_CELLS {
        pool::default_threads()
    } else {
        1
    }
}

/// Log-mass of padded points (mirrors kernels/ref.py NEG).
pub const NEG: f32 = -1.0e9;

/// Hyper-parameters; defaults equal the AOT artifacts' baked values so the
/// native and PJRT backends are interchangeable.
#[derive(Clone, Debug)]
pub struct LrotConfig {
    pub rank: usize,
    /// Mirror-descent steps (L).
    pub outer: usize,
    /// Sinkhorn sweeps per KL projection (B).
    pub inner: usize,
    /// Base step size, rescaled by ‖grad‖∞.
    pub gamma: f32,
    /// Init noise scale (symmetry breaking).
    pub tau: f32,
}

impl Default for LrotConfig {
    fn default() -> Self {
        LrotConfig { rank: 2, outer: 30, inner: 12, gamma: 8.0, tau: 0.01 }
    }
}

/// Factors `(Q, R)`, each `s×r`, column sums = 1/r, row sums = marginals.
pub struct LrotOutput {
    pub q: Mat,
    pub r: Mat,
}

/// Solve LROT on cost factors `(u, v)` (C = U Vᵀ restricted to the block)
/// with uniform marginals over the first `active_x`/`active_y` rows; rows
/// beyond that are phantom padding with zero mass.  Deterministic in
/// `seed`.  Standalone entry point (baselines, tests): allocates a private
/// single-shard arena — callers in a solve loop should use
/// [`solve_factored_in`] with a shared arena instead.
pub fn solve_factored<'a, 'b>(
    u: impl Into<MatView<'a>>,
    v: impl Into<MatView<'b>>,
    active_x: usize,
    active_y: usize,
    cfg: &LrotConfig,
    seed: u64,
) -> LrotOutput {
    let arena = ScratchArena::new(1);
    solve_factored_in(u.into(), v.into(), active_x, active_y, cfg, seed, &arena)
}

/// [`solve_factored`] with every intermediate drawn from `arena`.
pub fn solve_factored_in(
    u: MatView<'_>,
    v: MatView<'_>,
    active_x: usize,
    active_y: usize,
    cfg: &LrotConfig,
    seed: u64,
    arena: &ScratchArena,
) -> LrotOutput {
    let s = u.rows;
    let sv = v.rows;
    let r = cfg.rank;
    assert!(active_x <= s && active_y <= sv);
    let mut rng = Rng::new(seed ^ 0x160_7);

    let mut loga = arena.take_f32(s);
    let mut logb = arena.take_f32(sv);
    fill_log_marginal(&mut loga, active_x);
    fill_log_marginal(&mut logb, active_y);
    let logg = -(r as f32).ln();
    let inv_g = r as f32;

    // Sinkhorn potential buffers, checked out once per solve and reused by
    // every projection (f is sliced per side; h is zeroed per call).
    let mut fpot = arena.take_f32(s.max(sv));
    let mut hpot = arena.take_f32(r);

    // init: product marginal + noise, projected
    let mut log_q = arena.take_f32(s * r);
    let mut log_r = arena.take_f32(sv * r);
    init_logits(&mut log_q, &loga, r, logg, cfg.tau, &mut rng);
    init_logits(&mut log_r, &logb, r, logg, cfg.tau, &mut rng);
    sinkhorn_project(&mut log_q, s, r, &loga, logg, cfg.inner, &mut fpot[..s], &mut hpot);
    sinkhorn_project(&mut log_r, sv, r, &logb, logg, cfg.inner, &mut fpot[..sv], &mut hpot);

    // scratch buffers for the hot loop (freelist checkouts, not allocs)
    let mut q = arena.take_f32(s * r);
    let mut rr = arena.take_f32(sv * r);
    let mut w = arena.take_f32(u.cols * r);
    let mut gq = arena.take_f32(s * r);
    let mut gr = arena.take_f32(sv * r);

    let mut prev_labels: Option<(Vec<u16>, Vec<u16>)> = None;
    for it in 0..cfg.outer {
        exp_into(&log_q, &mut q);
        exp_into(&log_r, &mut rr);
        // Early stop: once the hard co-clustering is stable, further
        // mirror-descent steps cannot change HiRef's refinement decision.
        if it % 5 == 4 {
            let labels = (argmax_labels(&q, r), argmax_labels(&rr, r));
            if prev_labels.as_ref() == Some(&labels) {
                break;
            }
            prev_labels = Some(labels);
        }
        // gq = U (Vᵀ R) * inv_g ; gr = V (Uᵀ Q) * inv_g
        vt_matmul_into_slice(v, MatView::from_slice(sv, r, &rr), &mut w);
        matmul_into_slice(u, MatView::from_slice(u.cols, r, &w), &mut gq);
        gq.iter_mut().for_each(|x| *x *= inv_g);
        vt_matmul_into_slice(u, MatView::from_slice(s, r, &q), &mut w);
        matmul_into_slice(v, MatView::from_slice(v.cols, r, &w), &mut gr);
        gr.iter_mut().for_each(|x| *x *= inv_g);

        let scale = slice_max_abs(&gq).max(slice_max_abs(&gr)).max(1e-12);
        let step = cfg.gamma / scale;
        for (lq, g) in log_q.iter_mut().zip(gq.iter()) {
            *lq -= step * g;
        }
        for (lr, g) in log_r.iter_mut().zip(gr.iter()) {
            *lr -= step * g;
        }
        sinkhorn_project(&mut log_q, s, r, &loga, logg, cfg.inner, &mut fpot[..s], &mut hpot);
        sinkhorn_project(&mut log_r, sv, r, &logb, logg, cfg.inner, &mut fpot[..sv], &mut hpot);
    }
    exp_into(&log_q, &mut q);
    exp_into(&log_r, &mut rr);
    // detach(): the output factors leave the arena without a copy
    LrotOutput { q: Mat::from_vec(s, r, q.detach()), r: Mat::from_vec(sv, r, rr.detach()) }
}

/// Primal cost `⟨C, Q diag(1/g) Rᵀ⟩` with C = U Vᵀ and uniform g = 1/r,
/// in O(s·k·r): equals `(1/g) Σ_z (UᵀQ)_z · (VᵀR)_z`.
pub fn lowrank_cost(u: &Mat, v: &Mat, q: &Mat, r: &Mat) -> f64 {
    let rank = q.cols;
    let uq = u.t_matmul(q); // k×r
    let vr = v.t_matmul(r); // k×r
    let mut s = 0.0f64;
    for z in 0..rank {
        let mut dz = 0.0f64;
        for k in 0..uq.rows {
            dz += uq.at(k, z) as f64 * vr.at(k, z) as f64;
        }
        s += dz;
    }
    s * rank as f64
}

fn fill_log_marginal(out: &mut [f32], active: usize) {
    let la = -(active as f32).ln();
    for (i, v) in out.iter_mut().enumerate() {
        *v = if i < active { la } else { NEG };
    }
}

fn init_logits(m: &mut [f32], loga: &[f32], r: usize, logg: f32, tau: f32, rng: &mut Rng) {
    for (i, row) in m.chunks_mut(r).enumerate() {
        for v in row.iter_mut() {
            *v = loga[i] + logg + tau * rng.normal_f32();
        }
    }
}

/// In-place masked log-domain Sinkhorn projection onto Π(a, g) over a
/// row-major `s×r` logit buffer.  Mirrors model.sinkhorn_project:
/// alternating f (rows) / h (cols) updates.  Row loops are chunked across
/// threads for large blocks — the exp/log-heavy f-update dominates LROT
/// runtime at the top of the hierarchy (see EXPERIMENTS.md §Perf).  The
/// caller supplies the potential buffers (`f` len `s`, `h` len `r`) so a
/// solve checks them out of the arena exactly once; `h` is reset here
/// (the projection always starts from zero column potentials), `f` is
/// fully overwritten before use.
#[allow(clippy::too_many_arguments)]
fn sinkhorn_project(
    log_k: &mut [f32],
    s: usize,
    r: usize,
    loga: &[f32],
    logg: f32,
    iters: usize,
    f: &mut [f32],
    h: &mut [f32],
) {
    debug_assert_eq!(log_k.len(), s * r);
    debug_assert_eq!(f.len(), s);
    debug_assert_eq!(h.len(), r);
    h.fill(0.0);
    let threads = threads_for(s * r * iters);
    let chunk = s.div_ceil(threads.max(1)).max(1);
    let n_chunks = s.div_ceil(chunk);

    for _ in 0..iters {
        // f-update (row LSE with current h) + per-chunk column partials
        let partials: Vec<(Vec<f32>, Vec<f32>)> = {
            let lk: &[f32] = log_k;
            let h_ref: &[f32] = &h;
            let mut f_chunks: Vec<&mut [f32]> = f.chunks_mut(chunk).collect();
            let results = std::sync::Mutex::new(vec![None; n_chunks]);
            std::thread::scope(|scope| {
                for (ci, f_chunk) in f_chunks.iter_mut().enumerate() {
                    let results = &results;
                    let f_chunk: &mut [f32] = f_chunk;
                    scope.spawn(move || {
                        let lo = ci * chunk;
                        // pass 1: f-update + local col max over exp args
                        let mut col_max = vec![f32::NEG_INFINITY; r];
                        for (o, i) in (lo..(lo + f_chunk.len())).enumerate() {
                            if loga[i] <= NEG / 2.0 {
                                f_chunk[o] = NEG;
                                continue;
                            }
                            let row = &lk[i * r..(i + 1) * r];
                            let mut mx = f32::NEG_INFINITY;
                            for (v, hv) in row.iter().zip(h_ref) {
                                mx = mx.max(v + hv);
                            }
                            let mx = mx.max(NEG);
                            let mut sum = 0.0f32;
                            for (v, hv) in row.iter().zip(h_ref) {
                                sum += fast_exp((v + hv) - mx);
                            }
                            let fi = loga[i] - (mx + sum.ln());
                            f_chunk[o] = fi;
                            for (cm, v) in col_max.iter_mut().zip(row) {
                                *cm = cm.max(v + fi);
                            }
                        }
                        // pass 2: local col sums against the LOCAL max
                        // (rescaled to the global max during the merge)
                        let mut col_acc = vec![0.0f32; r];
                        for (o, i) in (lo..(lo + f_chunk.len())).enumerate() {
                            let fi = f_chunk[o];
                            if fi <= NEG / 2.0 {
                                continue;
                            }
                            for ((acc, v), cm) in
                                col_acc.iter_mut().zip(&lk[i * r..(i + 1) * r]).zip(&col_max)
                            {
                                *acc += fast_exp(v + fi - cm);
                            }
                        }
                        results.lock().unwrap()[ci] = Some((col_max, col_acc));
                    });
                }
            });
            results
                .into_inner()
                .unwrap()
                .into_iter()
                .map(|o| o.unwrap())
                .collect()
        };
        // merge column partials: global max, then rescale each chunk's sums
        let mut gmax = vec![f32::NEG_INFINITY; r];
        for (cm, _) in &partials {
            for (g, &v) in gmax.iter_mut().zip(cm) {
                *g = g.max(v);
            }
        }
        let mut dh_max = 0.0f32;
        for z in 0..r {
            let g = gmax[z].max(NEG);
            let mut total = 0.0f64;
            for (cm, ca) in &partials {
                if ca[z] > 0.0 {
                    total += ca[z] as f64 * (((cm[z].max(NEG) - g) as f64).exp());
                }
            }
            let new_h = logg - (g + (total.ln() as f32));
            dh_max = dh_max.max((new_h - h[z]).abs());
            h[z] = new_h;
        }
        // converged projections exit early (typical after 3-5 sweeps)
        if dh_max < 1e-4 {
            break;
        }
    }
    // fold potentials in (chunk-parallel)
    {
        let h_ref: &[f32] = &h;
        let f_ref: &[f32] = &f;
        let rows_per = chunk;
        let mut data_chunks: Vec<&mut [f32]> = log_k.chunks_mut(rows_per * r).collect();
        std::thread::scope(|scope| {
            for (ci, dchunk) in data_chunks.iter_mut().enumerate() {
                let dchunk: &mut [f32] = dchunk;
                scope.spawn(move || {
                    let lo = ci * rows_per;
                    for (o, row) in dchunk.chunks_mut(r).enumerate() {
                        let fi = f_ref[lo + o];
                        for (v, hv) in row.iter_mut().zip(h_ref) {
                            *v += fi + hv;
                        }
                    }
                });
            }
        });
    }
}

/// Row argmax labels (compact u16; ranks are ≤ 2^16).
fn argmax_labels(m: &[f32], r: usize) -> Vec<u16> {
    m.chunks(r)
        .map(|row| {
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for (z, &v) in row.iter().enumerate() {
                if v > bv {
                    bv = v;
                    best = z;
                }
            }
            best as u16
        })
        .collect()
}

fn exp_into(src: &[f32], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = fast_exp(s); // fast_exp underflows the NEG sentinel to 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::factor::sq_euclidean_factors;
    use crate::prng::Rng;

    fn shuffled_pair(s: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(s, d);
        rng.fill_normal(&mut x.data);
        let perm = rng.permutation(s);
        let mut y = x.gather_rows(&perm);
        for v in y.data.iter_mut() {
            *v += 0.01 * rng.normal_f32();
        }
        (x, y, perm)
    }

    #[test]
    fn feasibility_uniform_marginals() {
        let (x, y, _) = shuffled_pair(128, 2, 0);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 128, 128, &LrotConfig::default(), 1);
        for cs in out.q.col_sums() {
            assert!((cs - 0.5).abs() < 5e-3, "col sum {cs}");
        }
        let total: f64 = out.q.data.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-3);
        assert!(out.q.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn monge_co_clustering() {
        // Prop 3.1 behaviour: x and T(x) land in the same cluster
        let (x, y, perm) = shuffled_pair(256, 2, 2);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 256, 256, &LrotConfig::default(), 3);
        let qa: Vec<usize> = (0..256)
            .map(|i| argmax(out.q.row(i)))
            .collect();
        let ra: Vec<usize> = (0..256)
            .map(|j| argmax(out.r.row(j)))
            .collect();
        // y_j = x_perm[j] + noise, so T(x_{perm[j]}) = y_j
        let agree = (0..256)
            .filter(|&j| qa[perm[j] as usize] == ra[j])
            .count() as f64
            / 256.0;
        assert!(agree > 0.9, "agreement {agree}");
    }

    #[test]
    fn padding_rows_get_zero_mass() {
        let (x, y, _) = shuffled_pair(64, 2, 4);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 48, 48, &LrotConfig::default(), 5);
        for i in 48..64 {
            assert!(out.q.row(i).iter().all(|&v| v == 0.0));
            assert!(out.r.row(i).iter().all(|&v| v == 0.0));
        }
        let total: f64 = out.q.data.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-3);
    }

    #[test]
    fn lowrank_cost_matches_dense() {
        let (x, y, _) = shuffled_pair(32, 2, 6);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let out = solve_factored(&u, &v, 32, 32, &LrotConfig::default(), 7);
        let fast = lowrank_cost(&u, &v, &out.q, &out.r);
        // dense check
        let c = crate::costs::dense_cost(&x, &y, crate::costs::CostKind::SqEuclidean);
        let mut p = Mat::zeros(32, 32);
        for i in 0..32 {
            for j in 0..32 {
                let mut s = 0.0f32;
                for z in 0..2 {
                    s += out.q.at(i, z) * out.r.at(j, z) * 2.0;
                }
                *p.at_mut(i, j) = s;
            }
        }
        let dense = crate::metrics::dense_cost_of(&c, &p);
        assert!((fast - dense).abs() < 1e-3 * dense.abs().max(1.0), "{fast} vs {dense}");
    }

    #[test]
    fn higher_rank_lowers_cost() {
        // Fig. S3 trend: cost decreases as rank grows
        let (x, y, _) = shuffled_pair(128, 2, 8);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let mut costs = Vec::new();
        for &r in &[2usize, 8, 32] {
            let cfg = LrotConfig { rank: r, ..Default::default() };
            let out = solve_factored(&u, &v, 128, 128, &cfg, 9);
            costs.push(lowrank_cost(&u, &v, &out.q, &out.r));
        }
        assert!(costs[2] < costs[0] * 1.02, "rank-32 {} vs rank-2 {}", costs[2], costs[0]);
    }

    #[test]
    fn shared_arena_run_matches_private_arena_run() {
        // solve_factored_in with a reused arena must be bit-identical to
        // the standalone entry point (buffers are zeroed on checkout).
        let (x, y, _) = shuffled_pair(96, 2, 10);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let cfg = LrotConfig { rank: 4, ..Default::default() };
        let a = solve_factored(&u, &v, 96, 96, &cfg, 11);
        let arena = ScratchArena::new(2);
        // run twice so the second solve hits warm freelists
        let _ = solve_factored_in(u.view(), v.view(), 96, 96, &cfg, 11, &arena);
        let b = solve_factored_in(u.view(), v.view(), 96, 96, &cfg, 11, &arena);
        assert_eq!(a.q.data, b.q.data);
        assert_eq!(a.r.data, b.r.data);
        assert!(arena.hits() > 0, "second solve should reuse buffers");
    }

    fn argmax(xs: &[f32]) -> usize {
        xs.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }
}

/// Unbiased Monte-Carlo estimate of the primal cost `⟨C, Q diag(1/g) Rᵀ⟩`
/// under the TRUE (non-factorised) cost: sample `(i, j) ~ P` by drawing a
/// component `z ~ g`, then `i ~ Q_{·z}/g_z`, `j ~ R_{·z}/g_z`, and average
/// `c(x_i, y_j)`.  Linear time and space — usable at the paper's 10⁵–10⁶
/// scales where exact evaluation of a dense low-rank coupling is O(n²).
pub fn lowrank_cost_sampled(
    x: &crate::linalg::Mat,
    y: &crate::linalg::Mat,
    kind: crate::costs::CostKind,
    q: &Mat,
    r: &Mat,
    samples: usize,
    seed: u64,
) -> f64 {
    let rank = q.cols;
    let mut rng = Rng::new(seed ^ 0x5A11);
    // cumulative distributions per component (O(n·r) once)
    let col_cdf = |m: &Mat| -> Vec<Vec<f64>> {
        (0..rank)
            .map(|z| {
                let mut acc = 0.0f64;
                let mut cdf = Vec::with_capacity(m.rows);
                for i in 0..m.rows {
                    acc += m.at(i, z) as f64;
                    cdf.push(acc);
                }
                cdf
            })
            .collect()
    };
    let qc = col_cdf(q);
    let rc = col_cdf(r);
    let g_mass: Vec<f64> = (0..rank).map(|z| *qc[z].last().unwrap_or(&0.0)).collect();
    let total: f64 = g_mass.iter().sum();
    let draw = |cdf: &[f64], u: f64| -> usize {
        let target = u * cdf.last().unwrap();
        cdf.partition_point(|&c| c < target).min(cdf.len() - 1)
    };
    let mut acc = 0.0f64;
    for _ in 0..samples {
        // z ~ g
        let mut u = rng.next_f64() * total;
        let mut z = 0;
        for (k, &m) in g_mass.iter().enumerate() {
            if u < m {
                z = k;
                break;
            }
            u -= m;
            z = k;
        }
        let i = draw(&qc[z], rng.next_f64());
        let j = draw(&rc[z], rng.next_f64());
        acc += kind.pair(x.row(i), y.row(j));
    }
    acc / samples as f64
}
