//! ProgOT-style progressive entropic OT (Kassraie et al. 2024) — the
//! second full-rank baseline of §4.1.
//!
//! The solver anneals toward the Monge map by alternating (i) an entropic
//! OT solve at a decreasing ε_t with (ii) a partial displacement of the
//! source points along the barycentric map.  The final-stage plan (rows
//! still indexed by the original source points) is returned as the
//! coupling; like the original, it is markedly sparser than one-shot
//! Sinkhorn at the same final ε (Table S3).

#![forbid(unsafe_code)]

use crate::costs::{dense_cost, CostKind};
use crate::linalg::Mat;
use crate::solvers::sinkhorn::{self, SinkhornConfig};

/// Configuration for [`solve`].
#[derive(Clone, Debug)]
pub struct ProgOtConfig {
    /// Number of progressive stages.
    pub stages: usize,
    /// ε at the first stage (annealed geometrically down to `eps_final`).
    pub eps_start: f64,
    /// ε at the last stage.
    pub eps_final: f64,
    /// Displacement step α ∈ (0, 1) applied between stages.
    pub alpha: f64,
    /// Sinkhorn sweeps per stage.
    pub iters_per_stage: usize,
}

impl Default for ProgOtConfig {
    fn default() -> Self {
        ProgOtConfig {
            stages: 6,
            eps_start: 0.5,
            eps_final: 0.01,
            alpha: 0.5,
            iters_per_stage: 300,
        }
    }
}

/// Run ProgOT between `x` and `y` with uniform marginals; returns the
/// final coupling (n×n, dense — baseline only).
pub fn solve(x: &Mat, y: &Mat, kind: CostKind, cfg: &ProgOtConfig) -> Mat {
    let mut xt = x.clone();
    let mut plan = Mat::zeros(x.rows, y.rows);
    for t in 0..cfg.stages {
        let frac = if cfg.stages <= 1 { 1.0 } else { t as f64 / (cfg.stages - 1) as f64 };
        let eps = (cfg.eps_start.ln() * (1.0 - frac) + cfg.eps_final.ln() * frac).exp();
        let c = dense_cost(&xt, y, kind);
        let out = sinkhorn::solve(
            &c,
            &SinkhornConfig {
                epsilon: eps,
                max_iters: cfg.iters_per_stage,
                tol: 1e-7,
                eps_start: None,
                schedule_iters: 0,
                relative_eps: true,
            },
        );
        plan = out.coupling;
        if t + 1 < cfg.stages {
            // displace xt toward the barycentric image
            let bary = sinkhorn::barycentric_map(&plan, y);
            let a = cfg.alpha as f32;
            for (xv, bv) in xt.data.iter_mut().zip(&bary.data) {
                *xv = (1.0 - a) * *xv + a * bv;
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::prng::Rng;

    fn toy(n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 2);
        let mut y = Mat::zeros(n, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        (x, y)
    }

    #[test]
    fn coupling_is_feasible() {
        let (x, y) = toy(32, 0);
        let p = solve(&x, &y, CostKind::SqEuclidean, &ProgOtConfig::default());
        assert!(metrics::marginal_violation(&p) < 1e-3);
    }

    #[test]
    fn sparser_than_plain_sinkhorn() {
        let (x, y) = toy(48, 1);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let sk = sinkhorn::solve(&c, &SinkhornConfig::default());
        let pg = solve(&x, &y, CostKind::SqEuclidean, &ProgOtConfig::default());
        let nz_sk = metrics::nonzeros(&sk.coupling, 1e-8);
        let nz_pg = metrics::nonzeros(&pg, 1e-8);
        assert!(nz_pg < nz_sk, "progot nnz {nz_pg} !< sinkhorn nnz {nz_sk}");
    }

    #[test]
    fn cost_competitive_with_sinkhorn() {
        let (x, y) = toy(64, 2);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let sk = sinkhorn::solve(&c, &SinkhornConfig::default());
        let pg = solve(&x, &y, CostKind::SqEuclidean, &ProgOtConfig::default());
        let (cs, cp) = (metrics::dense_cost_of(&c, &sk.coupling), metrics::dense_cost_of(&c, &pg));
        assert!(cp < cs * 1.25 + 0.05, "progot {cp} vs sinkhorn {cs}");
    }
}
