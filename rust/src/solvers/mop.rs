//! MOP — multiscale optimal transport after Gerber & Maggioni (JMLR 2017),
//! the paper's multiscale baseline (Tables S4, S7).
//!
//! The original consumes a *regular family of multiscale partitions*
//! (Def. C.3; they use GMRA trees).  We build the equivalent substrate
//! from scratch: balanced hierarchical 2-means trees (principal-direction
//! median splits), which satisfy the partition/tree axioms and mirror
//! dyadic-cube behaviour on manifold-like data.  Transport then proceeds
//! coarse→fine with the *simple propagation* strategy (§C.2): the coupling
//! mass of a coarse pair is re-solved among its children only, so space
//! stays linear — and, as the paper reports, the locality of the
//! propagation costs accuracy (MOP trails the other methods in Table S4).

#![forbid(unsafe_code)]

use crate::api::coupling::SparseCoupling;
use crate::costs::CostKind;
use crate::linalg::Mat;

/// A balanced binary partition tree over point indices.
pub struct PartitionTree {
    /// Per level: list of clusters, each a sorted index list.  Level 0 is
    /// the root (all points); the last level has singleton clusters.
    pub levels: Vec<Vec<Vec<u32>>>,
}

impl PartitionTree {
    /// Build by recursive principal-direction median splits.
    pub fn build(x: &Mat) -> PartitionTree {
        let n = x.rows;
        let mut levels: Vec<Vec<Vec<u32>>> = vec![vec![(0..n as u32).collect()]];
        loop {
            let prev = levels.last().unwrap();
            if prev.iter().all(|c| c.len() <= 1) {
                break;
            }
            let mut next = Vec::with_capacity(prev.len() * 2);
            for cluster in prev {
                if cluster.len() <= 1 {
                    next.push(cluster.clone());
                    continue;
                }
                let (a, b) = median_split(x, cluster);
                next.push(a);
                next.push(b);
            }
            levels.push(next);
        }
        PartitionTree { levels }
    }

    /// Centroid of a cluster.
    pub fn centroid(x: &Mat, cluster: &[u32]) -> Vec<f32> {
        let d = x.cols;
        let mut c = vec![0.0f64; d];
        for &i in cluster {
            for (acc, &v) in c.iter_mut().zip(x.row(i as usize)) {
                *acc += v as f64;
            }
        }
        c.into_iter().map(|v| (v / cluster.len() as f64) as f32).collect()
    }
}

/// Split a cluster into two balanced halves along its principal direction
/// (power iteration on the covariance; median projection split).
fn median_split(x: &Mat, cluster: &[u32]) -> (Vec<u32>, Vec<u32>) {
    let d = x.cols;
    let mean = PartitionTree::centroid(x, cluster);
    // power iteration
    let mut dir = vec![1.0f32; d];
    normalize(&mut dir);
    for _ in 0..8 {
        let mut next = vec![0.0f32; d];
        for &i in cluster {
            let row = x.row(i as usize);
            let mut proj = 0.0f32;
            for ((&v, &m), &w) in row.iter().zip(&mean).zip(&dir) {
                proj += (v - m) * w;
            }
            for ((nv, &v), &m) in next.iter_mut().zip(row).zip(&mean) {
                *nv += proj * (v - m);
            }
        }
        if next.iter().all(|&v| v == 0.0) {
            break;
        }
        dir = next;
        normalize(&mut dir);
    }
    let mut projected: Vec<(f32, u32)> = cluster
        .iter()
        .map(|&i| {
            let row = x.row(i as usize);
            let mut p = 0.0f32;
            for ((&v, &m), &w) in row.iter().zip(&mean).zip(&dir) {
                p += (v - m) * w;
            }
            (p, i)
        })
        .collect();
    projected.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let half = cluster.len() / 2;
    let a = projected[..half].iter().map(|&(_, i)| i).collect();
    let b = projected[half..].iter().map(|&(_, i)| i).collect();
    (a, b)
}

fn normalize(v: &mut [f32]) {
    let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 0.0 {
        v.iter_mut().for_each(|x| *x /= n);
    }
}

/// Intermediate plan at some scale: (x-cluster, y-cluster, mass).
type ClusterPlan = Vec<(usize, usize, f64)>;

/// Run MOP between `x` and `y` (equal sizes, uniform weights).
/// Returns a bijection obtained by rounding the finest-scale coupling.
pub fn solve(x: &Mat, y: &Mat, kind: CostKind) -> Vec<u32> {
    let (sc, _) = solve_sparse(x, y, kind);
    round_sparse_to_bijection(&sc)
}

/// Run MOP and return the finest-scale sparse coupling plus its primal
/// cost (mass-weighted, already normalised).
pub fn solve_sparse(x: &Mat, y: &Mat, kind: CostKind) -> (SparseCoupling, f64) {
    let n = x.rows;
    assert_eq!(n, y.rows);
    let tx = PartitionTree::build(x);
    let ty = PartitionTree::build(y);
    let depth = tx.levels.len().min(ty.levels.len());

    // coarsest scale: single pair with all the mass
    let mut plan: ClusterPlan = vec![(0, 0, 1.0)];
    for lvl in 1..depth {
        let px = &tx.levels[lvl - 1];
        let py = &ty.levels[lvl - 1];
        let cx = &tx.levels[lvl];
        let cy = &ty.levels[lvl];
        // children index ranges: balanced splits mean cluster q at lvl-1
        // maps to children {2q, 2q+1} when it was split, or stays singular.
        let child_map = |parents: &Vec<Vec<u32>>, _children: &Vec<Vec<u32>>| -> Vec<Vec<usize>> {
            let mut map = Vec::with_capacity(parents.len());
            let mut cursor = 0usize;
            for p in parents {
                if p.len() <= 1 {
                    map.push(vec![cursor]);
                    cursor += 1;
                } else {
                    map.push(vec![cursor, cursor + 1]);
                    cursor += 2;
                }
            }
            map
        };
        let mx = child_map(px, cx);
        let my = child_map(py, cy);

        let mut next: ClusterPlan = Vec::with_capacity(plan.len() * 2);
        for &(qx, qy, mass) in &plan {
            let xc = &mx[qx];
            let yc = &my[qy];
            // local transport between ≤2 x-children and ≤2 y-children with
            // masses proportional to cluster sizes
            let rm: Vec<f64> = xc.iter().map(|&c| cx[c].len() as f64).collect();
            let cm: Vec<f64> = yc.iter().map(|&c| cy[c].len() as f64).collect();
            let rsum: f64 = rm.iter().sum();
            let rm: Vec<f64> = rm.iter().map(|v| v / rsum * mass).collect();
            let csum: f64 = cm.iter().sum();
            let cm: Vec<f64> = cm.iter().map(|v| v / csum * mass).collect();
            let cost = |a: usize, b: usize| -> f64 {
                let ca = PartitionTree::centroid(x, &cx[xc[a]]);
                let cb = PartitionTree::centroid(y, &cy[yc[b]]);
                kind.pair(&ca, &cb)
            };
            match (xc.len(), yc.len()) {
                (1, 1) => next.push((xc[0], yc[0], mass)),
                (1, 2) => {
                    next.push((xc[0], yc[0], cm[0]));
                    next.push((xc[0], yc[1], cm[1]));
                }
                (2, 1) => {
                    next.push((xc[0], yc[0], rm[0]));
                    next.push((xc[1], yc[0], rm[1]));
                }
                (2, 2) => {
                    // one-parameter family: P00 = t in [max(0, r0-c1), min(r0, c0)]
                    let lo = (rm[0] - cm[1]).max(0.0);
                    let hi = rm[0].min(cm[0]);
                    let delta = cost(0, 0) - cost(0, 1) - cost(1, 0) + cost(1, 1);
                    let t = if delta <= 0.0 { hi } else { lo };
                    let entries = [
                        (xc[0], yc[0], t),
                        (xc[0], yc[1], rm[0] - t),
                        (xc[1], yc[0], cm[0] - t),
                        (xc[1], yc[1], cm[1] - (rm[0] - t)),
                    ];
                    for (a, b, m) in entries {
                        if m > 1e-15 {
                            next.push((a, b, m));
                        }
                    }
                }
                _ => unreachable!(),
            }
        }
        plan = next;
    }

    // finest scale: clusters are singletons; translate to point indices
    let leaves_x = &tx.levels[depth - 1];
    let leaves_y = &ty.levels[depth - 1];
    let mut entries: Vec<(u32, u32, f64)> = Vec::with_capacity(plan.len());
    let mut total_cost = 0.0f64;
    for &(qx, qy, mass) in &plan {
        let i = leaves_x[qx][0];
        let j = leaves_y[qy][0];
        total_cost += mass * kind.pair(x.row(i as usize), y.row(j as usize));
        entries.push((i, j, mass));
    }
    (SparseCoupling { n, m: n, entries }, total_cost)
}

/// Round a sparse coupling to a bijection: take entries by decreasing
/// mass, then pair any leftovers greedily.
pub fn round_sparse_to_bijection(sc: &SparseCoupling) -> Vec<u32> {
    assert_eq!(sc.n, sc.m, "bijection rounding needs a square coupling");
    let n = sc.n;
    let entries = &sc.entries;
    let mut order: Vec<usize> = (0..entries.len()).collect();
    // total_cmp instead of partial_cmp().unwrap(): a NaN mass from a
    // degenerate solve must not panic the rounding (same hardening as
    // assign::balanced_assign and sinkhorn::round_to_bijection); ties and
    // NaNs break deterministically by entry index.
    order.sort_by(|&a, &b| entries[b].2.total_cmp(&entries[a].2).then(a.cmp(&b)));
    let mut perm = vec![u32::MAX; n];
    let mut used = vec![false; n];
    for &e in &order {
        let (i, j, _) = entries[e];
        let (i, j) = (i as usize, j as usize);
        if perm[i] == u32::MAX && !used[j] {
            perm[i] = j as u32;
            used[j] = true;
        }
    }
    let mut free_y: Vec<u32> =
        (0..n as u32).filter(|&j| !used[j as usize]).collect();
    for i in 0..n {
        if perm[i] == u32::MAX {
            perm[i] = free_y.pop().expect("mismatched leftovers");
        }
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::prng::Rng;

    fn toy(n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 2);
        let mut y = Mat::zeros(n, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        (x, y)
    }

    #[test]
    fn sparse_rounding_survives_nan_mass() {
        // a NaN mass entry must not panic the sort; the output must
        // still be a bijection (leftover pairing fills the gaps)
        let sc = SparseCoupling {
            n: 4,
            m: 4,
            entries: vec![(0, 1, 0.5), (1, 0, f64::NAN), (2, 2, 0.25), (3, 3, 0.25)],
        };
        let perm = round_sparse_to_bijection(&sc);
        let mut seen = vec![false; 4];
        for &j in &perm {
            assert!((j as usize) < 4 && !std::mem::replace(&mut seen[j as usize], true));
        }
    }

    #[test]
    fn tree_levels_partition_everything() {
        let (x, _) = toy(33, 0);
        let t = PartitionTree::build(&x);
        for level in &t.levels {
            let mut count = 0;
            let mut seen = vec![false; 33];
            for c in level {
                for &i in c {
                    assert!(!seen[i as usize]);
                    seen[i as usize] = true;
                    count += 1;
                }
            }
            assert_eq!(count, 33);
        }
        // last level: all singletons
        assert!(t.levels.last().unwrap().iter().all(|c| c.len() == 1));
    }

    #[test]
    fn balanced_splits() {
        let (x, _) = toy(64, 1);
        let t = PartitionTree::build(&x);
        for c in &t.levels[1] {
            assert_eq!(c.len(), 32);
        }
        for c in &t.levels[3] {
            assert_eq!(c.len(), 8);
        }
    }

    #[test]
    fn output_is_bijection() {
        let (x, y) = toy(50, 2);
        let perm = solve(&x, &y, CostKind::SqEuclidean);
        let mut seen = vec![false; 50];
        for &j in &perm {
            assert!(!seen[j as usize]);
            seen[j as usize] = true;
        }
    }

    #[test]
    fn mass_conserved_at_finest_scale() {
        let (x, y) = toy(40, 3);
        let (sc, _) = solve_sparse(&x, &y, CostKind::SqEuclidean);
        assert_eq!((sc.n, sc.m), (40, 40));
        assert!((sc.total_mass() - 1.0).abs() < 1e-9, "total {}", sc.total_mass());
    }

    #[test]
    fn worse_than_exact_but_bounded() {
        // MOP is a fast approximation: must land above optimal but below
        // random assignment (paper Table S4 places it well above exact).
        let (x, y) = toy(64, 4);
        let perm = solve(&x, &y, CostKind::SqEuclidean);
        let c_mop = metrics::bijection_cost(&x, &y, &perm, CostKind::SqEuclidean);
        let c = crate::costs::dense_cost(&x, &y, CostKind::SqEuclidean);
        let h = crate::solvers::exact::hungarian(&c);
        let c_opt = metrics::bijection_cost(&x, &y, &h, CostKind::SqEuclidean);
        let ident: Vec<u32> = (0..64).collect();
        let c_id = metrics::bijection_cost(&x, &y, &ident, CostKind::SqEuclidean);
        assert!(c_mop >= c_opt - 1e-9);
        assert!(c_mop < c_id, "MOP no better than identity pairing: {c_mop} vs {c_id}");
    }
}
