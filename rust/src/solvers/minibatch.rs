//! Mini-batch OT baseline (Genevay et al. 2018; Fatras et al. 2020/21).
//!
//! The paper's protocol (§D.2): partition both datasets into batches of
//! size B *without replacement*, solve each batch pair with Sinkhorn
//! (ε = 0.05 default), and instantiate the full-rank coupling as the
//! block-diagonal union of batch couplings.  Every batch alignment is a
//! locally-optimal but globally-biased estimate — the bias the paper
//! quantifies in Tables 1/S6/S7/S8 — and the bias shrinks as B grows.
//!
//! We additionally round each batch coupling to a bijection so the output
//! is a one-to-one map comparable with HiRef's (the paper's transfer task
//! does the same via row-argmax).

#![forbid(unsafe_code)]

use crate::costs::{dense_cost, CostKind};
use crate::linalg::Mat;
use crate::pool;
use crate::prng::Rng;
use crate::solvers::sinkhorn::{self, SinkhornConfig};

/// Configuration for [`solve`].
#[derive(Clone, Debug)]
pub struct MiniBatchConfig {
    /// Batch size B.
    pub batch: usize,
    /// Sinkhorn entropy on each batch.
    pub epsilon: f64,
    /// Sinkhorn iterations per batch.
    pub max_iters: usize,
    pub seed: u64,
    /// Worker threads for independent batches.
    pub threads: usize,
}

impl Default for MiniBatchConfig {
    fn default() -> Self {
        MiniBatchConfig {
            batch: 512,
            epsilon: 0.05,
            max_iters: 500,
            seed: 0,
            threads: pool::default_threads(),
        }
    }
}

/// Run mini-batch OT; returns a global bijection `perm` (x_i ↦ y_perm[i]).
pub fn solve(x: &Mat, y: &Mat, kind: CostKind, cfg: &MiniBatchConfig) -> Vec<u32> {
    let n = x.rows;
    assert_eq!(n, y.rows);
    let b = cfg.batch.min(n).max(1);
    let mut rng = Rng::new(cfg.seed ^ 0xB47C);
    let px = rng.permutation(n);
    let py = rng.permutation(n);
    let n_batches = n.div_ceil(b);

    let batch_results = pool::parallel_map(n_batches, cfg.threads, |bi| {
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(n);
        let xi = &px[lo..hi];
        let yi = &py[lo..hi];
        let xb = x.gather_rows(xi);
        let yb = y.gather_rows(yi);
        let c = dense_cost(&xb, &yb, kind);
        let out = sinkhorn::solve(
            &c,
            &SinkhornConfig {
                epsilon: cfg.epsilon,
                max_iters: cfg.max_iters,
                ..Default::default()
            },
        );
        sinkhorn::round_to_bijection(&out.coupling)
    });

    let mut perm = vec![u32::MAX; n];
    for (bi, local) in batch_results.into_iter().enumerate() {
        let lo = bi * b;
        for (k, &lj) in local.iter().enumerate() {
            perm[px[lo + k] as usize] = py[lo + lj as usize];
        }
    }
    debug_assert!(perm.iter().all(|&j| j != u32::MAX));
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;

    fn toy(n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 2);
        let mut y = Mat::zeros(n, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        (x, y)
    }

    #[test]
    fn output_is_bijection() {
        let (x, y) = toy(100, 0);
        let perm = solve(&x, &y, CostKind::SqEuclidean, &MiniBatchConfig {
            batch: 32,
            ..Default::default()
        });
        let mut seen = vec![false; 100];
        for &j in &perm {
            assert!(!seen[j as usize]);
            seen[j as usize] = true;
        }
    }

    #[test]
    fn larger_batches_lower_cost() {
        // The paper's central observation about MB bias (Table S6 trend).
        let (x, y) = toy(512, 1);
        let mut costs = Vec::new();
        for &b in &[16usize, 128, 512] {
            let perm = solve(&x, &y, CostKind::SqEuclidean, &MiniBatchConfig {
                batch: b,
                seed: 7,
                ..Default::default()
            });
            costs.push(metrics::bijection_cost(&x, &y, &perm, CostKind::SqEuclidean));
        }
        assert!(costs[2] < costs[0], "full-batch {} !< B=16 {}", costs[2], costs[0]);
    }

    #[test]
    fn batch_larger_than_n_is_single_batch() {
        let (x, y) = toy(40, 2);
        let perm = solve(&x, &y, CostKind::SqEuclidean, &MiniBatchConfig {
            batch: 1000,
            ..Default::default()
        });
        assert_eq!(perm.len(), 40);
    }
}
