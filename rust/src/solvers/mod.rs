//! Optimal-transport solvers: the native LROT sub-solver HiRef uses as a
//! fallback backend, plus every baseline the paper benchmarks against.
//!
//! Every module here is also reachable through the unified
//! [`crate::api::TransportSolver`] interface under its registry name
//! (middle column) — prefer that for new code; the raw functions remain
//! the low-level entry points.
//!
//! | Solver | Registry name | Paper reference | Role |
//! |---|---|---|---|
//! | [`lrot`] | `lrot` | Scetbon et al. 2021 / Halmos et al. 2024 (FRLC) | HiRef sub-problem + LOT/FRLC baselines |
//! | [`sinkhorn`] | `sinkhorn` | Cuturi 2013 (+ ε-schedule, Chen et al. 2023) | full-rank baseline |
//! | [`progot`] | `progot` | Kassraie et al. 2024 | progressive entropic baseline |
//! | [`minibatch`] | `minibatch` | Genevay et al. 2018; Fatras et al. 2020/21 | mini-batch baseline |
//! | [`exact`] | `exact` | Kuhn 1955 (Hungarian) / Bertsekas (auction) | optimal assignment; base case + "dual simplex" stand-in |
//! | [`mop`] | `mop` | Gerber & Maggioni 2017 | multiscale OT baseline (MOP) |

pub mod exact;
pub mod lrot;
pub mod minibatch;
pub mod mop;
pub mod progot;
pub mod sinkhorn;
