//! Exact and near-exact assignment solvers.
//!
//! * [`hungarian`] — Jonker–Volgenant shortest-augmenting-path Hungarian
//!   algorithm, O(n³).  Exact: stands in for the paper's dual revised
//!   simplex comparison (Table S4) and seals HiRef base-case blocks.
//! * [`auction`] — Bertsekas forward auction with ε-scaling.  Near-exact
//!   (within n·ε of optimal; exact for ε < gap/n), considerably faster on
//!   larger base-case blocks; the HiRef default above the Hungarian
//!   crossover size.

#![forbid(unsafe_code)]

use crate::linalg::MatView;
#[cfg(test)]
use crate::linalg::Mat;

/// Exact min-cost perfect matching on the square cost matrix `c`.
/// Returns `perm` with row `i` matched to column `perm[i]`.
/// Accepts `&Mat` or any [`MatView`] (e.g. a scratch-arena cost buffer),
/// so HiRef base blocks solve in place without an owned copy.
pub fn hungarian<'a>(c: impl Into<MatView<'a>>) -> Vec<u32> {
    let c = c.into();
    let n = c.rows;
    assert_eq!(n, c.cols, "hungarian needs a square cost");
    if n == 0 {
        return Vec::new();
    }
    const INF: f64 = f64::INFINITY;
    // 1-based arrays, p[j] = row matched to column j (0 = none)
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];
    let mut minv = vec![0.0f64; n + 1];
    let mut used = vec![false; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        minv.iter_mut().for_each(|x| *x = INF);
        used.iter_mut().for_each(|x| *x = false);
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            let crow = c.row(i0 - 1);
            for j in 1..=n {
                if !used[j] {
                    let cur = crow[j - 1] as f64 - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut perm = vec![0u32; n];
    for j in 1..=n {
        perm[p[j] - 1] = (j - 1) as u32;
    }
    perm
}

/// Bertsekas forward auction with ε-scaling.  Minimises Σ c[i, perm[i]].
/// `quality` scales the final ε: 1.0 targets exactness on generic inputs
/// (final ε < resolution/n); larger values trade cost for speed.
pub fn auction<'a>(c: impl Into<MatView<'a>>, quality: f64) -> Vec<u32> {
    let c = c.into();
    let n = c.rows;
    assert_eq!(n, c.cols, "auction needs a square cost");
    if n == 0 {
        return Vec::new();
    }
    // Work with benefits b = -c (auction maximises).
    let cmax = c.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
    let mut eps = (cmax / 4.0).max(1e-12);
    let eps_final = (cmax * quality / (n as f64 * 1000.0)).max(1e-12);
    let mut price = vec![0.0f64; n];
    let mut owner = vec![usize::MAX; n]; // column -> row
    let mut assign = vec![usize::MAX; n]; // row -> column
    loop {
        owner.iter_mut().for_each(|o| *o = usize::MAX);
        assign.iter_mut().for_each(|a| *a = usize::MAX);
        let mut unassigned: Vec<usize> = (0..n).collect();
        while let Some(i) = unassigned.pop() {
            // find best and second-best net value for bidder i
            let crow = c.row(i);
            let (mut best_j, mut best_v, mut second_v) = (0usize, f64::NEG_INFINITY, f64::NEG_INFINITY);
            for (j, &cv) in crow.iter().enumerate() {
                let v = -(cv as f64) - price[j];
                if v > best_v {
                    second_v = best_v;
                    best_v = v;
                    best_j = j;
                } else if v > second_v {
                    second_v = v;
                }
            }
            let bid = best_v - second_v + eps;
            price[best_j] += bid;
            // displace previous owner
            if owner[best_j] != usize::MAX {
                let prev = owner[best_j];
                assign[prev] = usize::MAX;
                unassigned.push(prev);
            }
            owner[best_j] = i;
            assign[i] = best_j;
        }
        if eps <= eps_final {
            break;
        }
        eps = (eps / 4.0).max(eps_final);
    }
    assign.into_iter().map(|j| j as u32).collect()
}

/// Exact brute-force assignment for tiny n (test oracle, n ≤ 10).
pub fn brute_force<'a>(c: impl Into<MatView<'a>>) -> (Vec<u32>, f64) {
    let c = c.into();
    let n = c.rows;
    assert!(n <= 10, "brute_force is exponential");
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut best = perm.clone();
    let mut best_cost = cost_of(c, &perm);
    // Heap's algorithm
    let mut stack = vec![0usize; n];
    let mut i = 0;
    while i < n {
        if stack[i] < i {
            if i % 2 == 0 {
                perm.swap(0, i);
            } else {
                perm.swap(stack[i], i);
            }
            let cst = cost_of(c, &perm);
            if cst < best_cost {
                best_cost = cst;
                best = perm.clone();
            }
            stack[i] += 1;
            i = 0;
        } else {
            stack[i] = 0;
            i += 1;
        }
    }
    (best, best_cost)
}

/// Total (unnormalised) cost of an assignment.
pub fn cost_of<'a>(c: impl Into<MatView<'a>>, perm: &[u32]) -> f64 {
    let c = c.into();
    perm.iter().enumerate().map(|(i, &j)| c.at(i, j as usize) as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_cost(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut c = Mat::zeros(n, n);
        for v in c.data.iter_mut() {
            *v = rng.next_f32() * 10.0;
        }
        c
    }

    fn assert_bijection(perm: &[u32]) {
        let mut seen = vec![false; perm.len()];
        for &j in perm {
            assert!(!seen[j as usize], "column used twice");
            seen[j as usize] = true;
        }
    }

    #[test]
    fn hungarian_matches_brute_force() {
        for seed in 0..20 {
            let c = rand_cost(7, seed);
            let h = hungarian(&c);
            assert_bijection(&h);
            let (_, want) = brute_force(&c);
            let got = cost_of(&c, &h);
            assert!((got - want).abs() < 1e-6, "seed {seed}: {got} vs {want}");
        }
    }

    #[test]
    fn hungarian_identity_on_diagonal_costs() {
        // c_ij = 0 iff i==j else 1 → identity is optimal
        let n = 12;
        let mut c = Mat::full(n, n, 1.0);
        for i in 0..n {
            *c.at_mut(i, i) = 0.0;
        }
        let h = hungarian(&c);
        assert_eq!(h, (0..n as u32).collect::<Vec<_>>());
    }

    #[test]
    fn auction_matches_brute_force() {
        for seed in 0..10 {
            let c = rand_cost(6, 100 + seed);
            let a = auction(&c, 1.0);
            assert_bijection(&a);
            let (_, want) = brute_force(&c);
            let got = cost_of(&c, &a);
            assert!(got <= want * 1.02 + 1e-4, "seed {seed}: {got} vs {want}");
        }
    }

    #[test]
    fn auction_near_optimal_on_larger_instances() {
        for seed in 0..5 {
            let c = rand_cost(64, 200 + seed);
            let a = auction(&c, 1.0);
            assert_bijection(&a);
            let h = hungarian(&c);
            let (ca, ch) = (cost_of(&c, &a), cost_of(&c, &h));
            assert!(ca <= ch * 1.01 + 1e-6, "auction {ca} vs hungarian {ch}");
        }
    }

    #[test]
    fn empty_and_singleton() {
        assert!(hungarian(&Mat::zeros(0, 0)).is_empty());
        assert_eq!(hungarian(&Mat::zeros(1, 1)), vec![0]);
        assert_eq!(auction(&Mat::zeros(1, 1), 1.0), vec![0]);
    }
}
