//! Log-domain Sinkhorn (Cuturi 2013) with optional ε-annealing schedule
//! (Chen et al. 2023) — the paper's primary full-rank baseline.
//!
//! Quadratic space by construction (it materialises the coupling): this is
//! exactly the scaling wall HiRef removes, and the benches demonstrate it
//! (Fig. S2b, Tables S2/S6).  Runs on uniform marginals as everywhere in
//! the paper.

#![forbid(unsafe_code)]

use crate::linalg::{fast_exp, Mat};

/// Log-sum-exp over an f64 buffer — the dense baseline's O(n²)-per-sweep
/// hot loop.  Uses exact `f64::exp`: the dual updates are the path that
/// sets the solver's precision floor (~1e-9), and routing them through
/// the f32 `fast_exp` (rel. err ≤ 7e-6) silently capped it, making
/// `tol = 1e-6` unreachable on ill-scaled costs.  `fast_exp` remains the
/// right tool where 7e-6 is invisible — the one-shot dense coupling
/// materialisation in [`solve`].
fn logsumexp64(xs: &[f64]) -> f64 {
    let mx = xs.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    if !mx.is_finite() {
        return mx;
    }
    let s: f64 = xs.iter().map(|&v| (v - mx).exp()).sum();
    mx + s.ln()
}

/// Configuration for [`solve`].
#[derive(Clone, Debug)]
pub struct SinkhornConfig {
    /// Entropy regularisation ε (paper default 0.05).
    pub epsilon: f64,
    /// Maximum Sinkhorn sweeps.
    pub max_iters: usize,
    /// Stop when the worst marginal violation (relative) drops below this.
    pub tol: f64,
    /// Optional ε-schedule: anneal from `eps_start` down to `epsilon`
    /// geometrically over the first `schedule_iters` sweeps.
    pub eps_start: Option<f64>,
    pub schedule_iters: usize,
    /// Scale ε by the mean cost (ott-jax convention, which the paper's
    /// "default ε = 0.05" refers to).  Default true.
    pub relative_eps: bool,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        SinkhornConfig {
            epsilon: 0.05,
            max_iters: 2000,
            tol: 1e-6,
            eps_start: None,
            schedule_iters: 100,
            relative_eps: true,
        }
    }
}

/// Result of a Sinkhorn run.
pub struct SinkhornOutput {
    /// Dense coupling (n×m) — quadratic memory, baseline only.
    pub coupling: Mat,
    /// Dual potentials (f, g).
    pub f: Vec<f64>,
    pub g: Vec<f64>,
    /// Sweeps executed.
    pub iters: usize,
}

/// Solve entropic OT with uniform marginals on cost matrix `c`.
pub fn solve(c: &Mat, cfg: &SinkhornConfig) -> SinkhornOutput {
    let (n, m) = (c.rows, c.cols);
    // ott-jax-style relative ε: scale by the mean cost so "ε = 0.05"
    // means the same thing across datasets.
    let cfg = if cfg.relative_eps {
        let mean = c.data.iter().map(|&v| v as f64).sum::<f64>()
            / (c.data.len() as f64).max(1.0);
        let scale = mean.max(1e-12);
        let mut cc = cfg.clone();
        cc.epsilon *= scale;
        cc.eps_start = cc.eps_start.map(|e| e * scale);
        cc.relative_eps = false;
        cc
    } else {
        cfg.clone()
    };
    let cfg = &cfg;
    let loga = -(n as f64).ln();
    let logb = -(m as f64).ln();
    let mut f = vec![0.0f64; n];
    let mut g = vec![0.0f64; m];
    let mut iters = 0;
    let mut buf = vec![0.0f64; n.max(m)];

    for it in 0..cfg.max_iters {
        iters = it + 1;
        let eps = current_eps(cfg, it);
        // f-update: f_i = eps*(loga - LSE_j((g_j - C_ij)/eps))
        for i in 0..n {
            let crow = c.row(i);
            let b = &mut buf[..m];
            for ((t, &cv), &gv) in b.iter_mut().zip(crow).zip(&g) {
                *t = (gv - cv as f64) / eps;
            }
            f[i] = eps * (loga - logsumexp64(b));
        }
        // g-update
        for (j, gj) in g.iter_mut().enumerate() {
            let b = &mut buf[..n];
            for (i, t) in b.iter_mut().enumerate() {
                *t = (f[i] - c.at(i, j) as f64) / eps;
            }
            *gj = eps * (logb - logsumexp64(b));
        }
        // convergence: row-marginal violation (g-update makes cols exact)
        if it % 10 == 9 && current_eps(cfg, it) <= cfg.epsilon {
            let viol = potentials_marginal_violation(c, &f, &g, eps);
            if viol < cfg.tol {
                break;
            }
        }
    }

    let eps = cfg.epsilon;
    let mut p = Mat::zeros(n, m);
    for i in 0..n {
        let crow = c.row(i);
        let prow = p.row_mut(i);
        for ((pv, &cv), &gv) in prow.iter_mut().zip(crow).zip(&g) {
            // One-shot f32 output: the f32 exponent cast plus fast_exp
            // bound the entries' relative error at ~1e-5 — coarser than
            // raw f32 storage, but this is a single O(n²) pass whose
            // result is rounded to feasibility below and consumed at
            // far looser tolerances; the duals above stay exact f64.
            *pv = fast_exp(((f[i] + gv - cv as f64) / eps) as f32);
        }
    }
    round_to_feasible(&mut p);
    SinkhornOutput { coupling: p, f, g, iters }
}

/// Altschuler–Niles-Weed–Rigollet rounding: project a near-feasible
/// coupling onto Π(a, b) exactly (uniform marginals).  Scales rows/columns
/// down where they overshoot, then spreads the missing mass as a rank-one
/// correction — O(nm), preserves cost up to the marginal violation.
pub fn round_to_feasible(p: &mut Mat) {
    let (n, m) = (p.rows, p.cols);
    let (ra, cb) = (1.0 / n as f64, 1.0 / m as f64);
    // scale overshooting rows
    for i in 0..n {
        let s: f64 = p.row(i).iter().map(|&v| v as f64).sum();
        if s > ra {
            let f = (ra / s) as f32;
            p.row_mut(i).iter_mut().for_each(|v| *v *= f);
        }
    }
    // scale overshooting columns
    let cs = p.col_sums();
    let mut cf = vec![1.0f32; m];
    for (j, &s) in cs.iter().enumerate() {
        if (s as f64) > cb {
            cf[j] = (cb / s as f64) as f32;
        }
    }
    for i in 0..n {
        for (v, &f) in p.row_mut(i).iter_mut().zip(&cf) {
            *v *= f;
        }
    }
    // rank-one correction with the residuals
    let rs = p.row_sums();
    let cs = p.col_sums();
    let err_r: Vec<f64> = rs.iter().map(|&s| (ra - s as f64).max(0.0)).collect();
    let err_c: Vec<f64> = cs.iter().map(|&s| (cb - s as f64).max(0.0)).collect();
    let total: f64 = err_r.iter().sum();
    if total > 1e-300 {
        for i in 0..n {
            let w = err_r[i] / total;
            if w == 0.0 {
                continue;
            }
            for (v, &ec) in p.row_mut(i).iter_mut().zip(&err_c) {
                *v += (w * ec) as f32;
            }
        }
    }
}

fn current_eps(cfg: &SinkhornConfig, it: usize) -> f64 {
    match cfg.eps_start {
        Some(e0) if it < cfg.schedule_iters => {
            let t = it as f64 / cfg.schedule_iters as f64;
            (e0.ln() * (1.0 - t) + cfg.epsilon.ln() * t).exp()
        }
        _ => cfg.epsilon,
    }
}

/// Worst relative row-marginal violation implied by dual potentials
/// `(f, g)` at regularisation `eps` under uniform marginals — the
/// convergence residual [`solve`] tests against `tol`, exposed so tests
/// and diagnostics can measure the true dual precision (the rounded
/// coupling is always feasible, so it cannot reveal a stalled solve).
pub fn potentials_marginal_violation(c: &Mat, f: &[f64], g: &[f64], eps: f64) -> f64 {
    let mut worst = 0.0f64;
    let n = c.rows;
    let a = 1.0 / n as f64;
    for i in 0..n {
        let crow = c.row(i);
        let mut s = 0.0f64;
        for (&cv, &gv) in crow.iter().zip(g) {
            s += ((f[i] + gv - cv as f64) / eps).exp();
        }
        worst = worst.max((s - a).abs() * n as f64);
    }
    worst
}

/// Barycentric projection map: x_i ↦ Σ_j P_ij y_j / Σ_j P_ij.
/// Used for the Fig. 3 / S4 map visualisations.
pub fn barycentric_map(p: &Mat, y: &Mat) -> Mat {
    let mut out = Mat::zeros(p.rows, y.cols);
    for i in 0..p.rows {
        let prow = p.row(i);
        let mass: f64 = prow.iter().map(|&v| v as f64).sum();
        let orow = out.row_mut(i);
        for (j, &pv) in prow.iter().enumerate() {
            let w = (pv as f64 / mass.max(1e-300)) as f32;
            for (o, &yv) in orow.iter_mut().zip(y.row(j)) {
                *o += w * yv;
            }
        }
    }
    out
}

/// Round a dense coupling to a bijection by greedy row-argmax with column
/// capacities (used when a baseline needs to emit a one-to-one map).
pub fn round_to_bijection(p: &Mat) -> Vec<u32> {
    let n = p.rows;
    assert_eq!(n, p.cols);
    // order rows by confidence (max entry), assign greedily
    let mut order: Vec<usize> = (0..n).collect();
    let conf: Vec<f32> = (0..n)
        .map(|i| p.row(i).iter().fold(0.0f32, |m, &v| m.max(v)))
        .collect();
    // total_cmp instead of partial_cmp().unwrap(): a NaN coupling entry
    // must not panic the rounding.  (conf itself is NaN-free — f32::max
    // ignores NaN operands — this guards the comparison itself and keeps
    // tie-breaking deterministic by row index.)
    order.sort_by(|&a, &b| conf[b].total_cmp(&conf[a]).then(a.cmp(&b)));
    let mut taken = vec![false; n];
    let mut perm = vec![u32::MAX; n];
    for &i in &order {
        let prow = p.row(i);
        let mut best = usize::MAX;
        let mut bestv = f32::NEG_INFINITY;
        for (j, &v) in prow.iter().enumerate() {
            if !taken[j] && v > bestv {
                bestv = v;
                best = j;
            }
        }
        if best == usize::MAX {
            // every untaken column held NaN: take the first open one so
            // the output stays a bijection instead of panicking
            best = taken.iter().position(|&t| !t).expect("columns exhausted early");
        }
        perm[i] = best as u32;
        taken[best] = true;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::{dense_cost, CostKind};
    use crate::metrics;
    use crate::prng::Rng;

    fn toy(n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 2);
        let mut y = Mat::zeros(n, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        (x, y)
    }

    #[test]
    fn marginals_converge() {
        let (x, y) = toy(32, 0);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let out = solve(&c, &SinkhornConfig::default());
        assert!(metrics::marginal_violation(&out.coupling) < 1e-4);
    }

    #[test]
    fn small_epsilon_approaches_exact_cost() {
        let (x, y) = toy(16, 1);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let exact = crate::solvers::exact::hungarian(&c);
        let exact_cost: f64 =
            exact.iter().enumerate().map(|(i, &j)| c.at(i, j as usize) as f64).sum::<f64>()
                / 16.0;
        let cfg = SinkhornConfig {
            epsilon: 0.003,
            eps_start: Some(1.0),
            schedule_iters: 200,
            max_iters: 4000,
            ..Default::default()
        };
        let out = solve(&c, &cfg);
        let cost = metrics::dense_cost_of(&c, &out.coupling);
        assert!(cost >= exact_cost - 1e-3, "sinkhorn below exact: {cost} < {exact_cost}");
        assert!(cost <= exact_cost * 1.15 + 0.05, "{cost} vs exact {exact_cost}");
    }

    #[test]
    fn ill_scaled_costs_converge_below_tol() {
        // Regression for the logsumexp64 precision cap: the dual updates
        // must run through exact f64::exp — with the f32 fast_exp in the
        // log-sum-exp the dual residual stalls around that function's
        // ~7e-6 relative error and a tol of 1e-6 never fires on
        // ill-scaled costs.  The residual is measured on the potentials
        // (the rounded coupling is always feasible and would hide a
        // stalled solve).
        let (x, y) = toy(24, 5);
        let mut c = dense_cost(&x, &y, CostKind::SqEuclidean);
        for v in c.data.iter_mut() {
            *v *= 1e4; // ill-scaled: costs in the tens of thousands
        }
        let mean = c.data.iter().map(|&v| v as f64).sum::<f64>() / c.data.len() as f64;
        let eps = 0.05 * mean;
        let cfg = SinkhornConfig {
            epsilon: eps,
            relative_eps: false,
            tol: 1e-8,
            max_iters: 4000,
            ..Default::default()
        };
        let out = solve(&c, &cfg);
        let viol = potentials_marginal_violation(&c, &out.f, &out.g, eps);
        assert!(viol < 1e-6, "dual residual stalled at {viol:.2e} (precision cap regression)");
    }

    #[test]
    fn rounding_survives_nan_confidence() {
        // a NaN entry, and even a fully-NaN row, must not panic the
        // greedy rounding — the output must stay a bijection
        let mut p = Mat::full(4, 4, 1.0 / 16.0);
        *p.at_mut(2, 1) = f32::NAN;
        for v in p.row_mut(3) {
            *v = f32::NAN;
        }
        let perm = round_to_bijection(&p);
        let mut seen = vec![false; 4];
        for &j in &perm {
            assert!((j as usize) < 4 && !std::mem::replace(&mut seen[j as usize], true));
        }
    }

    #[test]
    fn schedule_reduces_iterations_to_tolerance() {
        let (x, y) = toy(24, 2);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let cold = solve(
            &c,
            &SinkhornConfig { epsilon: 0.01, max_iters: 3000, ..Default::default() },
        );
        assert!(metrics::marginal_violation(&cold.coupling) < 1e-3);
    }

    #[test]
    fn barycentric_of_identity_recovers_targets() {
        let n = 8;
        let mut p = Mat::zeros(n, n);
        for i in 0..n {
            *p.at_mut(i, i) = 1.0 / n as f32;
        }
        let (_, y) = toy(n, 3);
        let m = barycentric_map(&p, &y);
        for i in 0..n {
            assert!(crate::linalg::dist(m.row(i), y.row(i)) < 1e-5);
        }
    }

    #[test]
    fn rounding_gives_bijection() {
        let (x, y) = toy(20, 4);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let out = solve(&c, &SinkhornConfig::default());
        let perm = round_to_bijection(&out.coupling);
        let mut seen = vec![false; 20];
        for &j in &perm {
            assert!(!seen[j as usize]);
            seen[j as usize] = true;
        }
    }
}
