//! Deterministic pseudo-random number generation.
//!
//! The vendored crate universe has no `rand`, so we ship a small,
//! well-tested PRNG of our own: SplitMix64 for seeding and a PCG64-style
//! (xorshift-multiply) core.  Everything downstream — dataset generation,
//! LROT symmetry-breaking noise, mini-batch sampling — is seeded through
//! this module, making every experiment bit-reproducible.

#![forbid(unsafe_code)]

/// SplitMix64: the standard seeding/stream-splitting generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A small, fast, deterministic PRNG (xoshiro256++).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed; distinct seeds give decorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-task seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough for our use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is not PRNG-bound anywhere).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        // Partial Fisher–Yates on an index map for small k, full shuffle
        // otherwise.
        if k * 4 < n {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.next_below(n) as u32;
                if chosen.insert(i) {
                    out.push(i);
                }
            }
            out
        } else {
            let mut p = self.permutation(n);
            p.truncate(k);
            p
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        assert!((s / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.03);
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(3);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| (i as usize) < n));
        }
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.next_below(7) < 7);
        }
    }
}
