//! Monge-map regression (paper §5 + Remark B.7).
//!
//! HiRef's bijection `{(x_i, T(x_i))}` lets a parametric map `T_θ` be
//! regressed **directly on the Monge map** — `min_θ E‖T_θ(x) − T(x)‖²` —
//! without mini-batch or entropic bias (Seguy et al. 2018 had to regress
//! against biased targets).  We provide the two estimators the paper's
//! discussion motivates:
//!
//! * [`AffineMap`] — global affine least squares (closed form);
//! * [`ClusterAffineMap`] — piecewise-affine over a k-means partition of
//!   the source, the natural nonparametric step up for maps like
//!   half-moon → S-curve that no global affine fits.
//!
//! `examples/monge_regression.rs` uses these to reproduce the discussion
//! experiment: regression targets from HiRef beat targets from small
//! mini-batches.

#![forbid(unsafe_code)]

use crate::linalg::{invert_spd, Mat};
use crate::prng::Rng;

/// Global affine map `x ↦ W x + b`, fit by ridge least squares.
pub struct AffineMap {
    /// (d_in + 1) × d_out, last row is the bias.
    w: Mat,
}

impl AffineMap {
    /// Fit on paired rows of `x` and `t` (`t_i = T(x_i)` targets).
    pub fn fit(x: &Mat, t: &Mat, ridge: f32) -> AffineMap {
        assert_eq!(x.rows, t.rows);
        let (n, d) = (x.rows, x.cols);
        // augmented design [x | 1]
        let mut xa = Mat::zeros(n, d + 1);
        for i in 0..n {
            xa.row_mut(i)[..d].copy_from_slice(x.row(i));
            xa.row_mut(i)[d] = 1.0;
        }
        let mut g = xa.t_matmul(&xa);
        for i in 0..=d {
            *g.at_mut(i, i) += ridge * n as f32;
        }
        let g_inv = invert_spd(&g);
        let xty = xa.t_matmul(t); // (d+1) × d_out
        AffineMap { w: g_inv.matmul(&xty) }
    }

    /// Apply to every row of `x`.
    pub fn apply(&self, x: &Mat) -> Mat {
        let d = x.cols;
        assert_eq!(self.w.rows, d + 1);
        let mut out = Mat::zeros(x.rows, self.w.cols);
        for i in 0..x.rows {
            let xi = x.row(i);
            let orow = out.row_mut(i);
            for (k, o) in orow.iter_mut().enumerate() {
                let mut s = self.w.at(d, k); // bias
                for (j, &v) in xi.iter().enumerate() {
                    s += v * self.w.at(j, k);
                }
                *o = s;
            }
        }
        out
    }
}

/// Piecewise-affine map over a k-means partition of the source points.
pub struct ClusterAffineMap {
    centers: Mat,
    pieces: Vec<AffineMap>,
}

impl ClusterAffineMap {
    /// Fit with `k` clusters (Lloyd's algorithm, seeded); each cluster
    /// gets its own ridge-affine piece.
    pub fn fit(x: &Mat, t: &Mat, k: usize, ridge: f32, seed: u64) -> ClusterAffineMap {
        assert_eq!(x.rows, t.rows);
        let n = x.rows;
        let k = k.min(n).max(1);
        let mut rng = Rng::new(seed ^ 0xC1A5);
        // init centers from random points
        let init = rng.sample_indices(n, k);
        let mut centers = x.gather_rows(&init);
        let mut assign = vec![0usize; n];
        for _ in 0..12 {
            for i in 0..n {
                assign[i] = nearest(&centers, x.row(i));
            }
            let mut sums = Mat::zeros(k, x.cols);
            let mut counts = vec![0usize; k];
            for i in 0..n {
                counts[assign[i]] += 1;
                for (s, &v) in sums.row_mut(assign[i]).iter_mut().zip(x.row(i)) {
                    *s += v;
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    let inv = 1.0 / counts[c] as f32;
                    for (dst, &s) in centers.row_mut(c).iter_mut().zip(sums.row(c)) {
                        *dst = s * inv;
                    }
                }
            }
        }
        // per-cluster fits (fall back to the global fit for tiny clusters)
        let global = AffineMap::fit(x, t, ridge);
        let pieces = (0..k)
            .map(|c| {
                let idx: Vec<u32> = (0..n as u32).filter(|&i| assign[i as usize] == c).collect();
                if idx.len() < x.cols + 2 {
                    AffineMap { w: global.w.clone() }
                } else {
                    AffineMap::fit(&x.gather_rows(&idx), &t.gather_rows(&idx), ridge)
                }
            })
            .collect();
        ClusterAffineMap { centers, pieces }
    }

    /// Apply: route each point through its nearest cluster's piece.
    pub fn apply(&self, x: &Mat) -> Mat {
        let d_out = self.pieces[0].w.cols;
        let mut out = Mat::zeros(x.rows, d_out);
        for i in 0..x.rows {
            let c = nearest(&self.centers, x.row(i));
            let single = x.gather_rows(&[i as u32]);
            let y = self.pieces[c].apply(&single);
            out.row_mut(i).copy_from_slice(y.row(0));
        }
        out
    }
}

fn nearest(centers: &Mat, p: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bd = f64::INFINITY;
    for c in 0..centers.rows {
        let d = crate::linalg::sq_dist(centers.row(c), p);
        if d < bd {
            bd = d;
            best = c;
        }
    }
    best
}

/// Mean squared error `E‖T̂(x_i) − t_i‖²` between a predicted map and
/// target pairs.
pub fn map_mse(pred: &Mat, target: &Mat) -> f64 {
    assert_eq!((pred.rows, pred.cols), (target.rows, target.cols));
    let mut s = 0.0f64;
    for i in 0..pred.rows {
        s += crate::linalg::sq_dist(pred.row(i), target.row(i));
    }
    s / pred.rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn affine_recovers_exact_affine_map() {
        let mut rng = Rng::new(0);
        let x = rand_mat(&mut rng, 200, 2);
        // t = A x + b
        let mut t = Mat::zeros(200, 2);
        for i in 0..200 {
            let (a, b) = (x.at(i, 0), x.at(i, 1));
            t.row_mut(i)[0] = 2.0 * a - b + 0.5;
            t.row_mut(i)[1] = 0.3 * a + 1.1 * b - 2.0;
        }
        let m = AffineMap::fit(&x, &t, 1e-6);
        let pred = m.apply(&x);
        assert!(map_mse(&pred, &t) < 1e-8);
    }

    #[test]
    fn cluster_affine_beats_global_on_nonlinear_map() {
        let mut rng = Rng::new(1);
        let x = rand_mat(&mut rng, 400, 2);
        // t = elementwise-nonlinear map no global affine can fit
        let mut t = Mat::zeros(400, 2);
        for i in 0..400 {
            let (a, b) = (x.at(i, 0), x.at(i, 1));
            t.row_mut(i)[0] = a * a;
            t.row_mut(i)[1] = (b * 2.0).sin();
        }
        let g = AffineMap::fit(&x, &t, 1e-6);
        let c = ClusterAffineMap::fit(&x, &t, 16, 1e-6, 7);
        let mse_g = map_mse(&g.apply(&x), &t);
        let mse_c = map_mse(&c.apply(&x), &t);
        assert!(mse_c < mse_g * 0.5, "cluster {mse_c} vs global {mse_g}");
    }

    #[test]
    fn mse_zero_on_identity() {
        let mut rng = Rng::new(2);
        let x = rand_mat(&mut rng, 50, 3);
        assert_eq!(map_mse(&x, &x), 0.0);
    }
}
