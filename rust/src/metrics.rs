//! Evaluation metrics — all computable in linear space for bijective
//! couplings, streaming for dense ones (the paper's headline point is
//! precisely that HiRef's output needs `n` nonzeros, not `n²`).

#![forbid(unsafe_code)]

use crate::costs::CostKind;
use crate::data::stream::DatasetSource;
use crate::linalg::Mat;
use crate::pool;

/// Primal transport cost `⟨C, P⟩` of a bijection `perm` (x_i ↔ y_perm[i]),
/// i.e. the cost of the coupling with mass 1/n on each matched pair.
pub fn bijection_cost(x: &Mat, y: &Mat, perm: &[u32], kind: CostKind) -> f64 {
    assert_eq!(x.rows, perm.len());
    let threads = pool::default_threads();
    let chunk = (x.rows / (threads * 4)).max(1024).min(x.rows.max(1));
    let n_chunks = x.rows.div_ceil(chunk);
    let partial = pool::parallel_map(n_chunks, threads, |ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(x.rows);
        let mut s = 0.0f64;
        for i in lo..hi {
            s += kind.pair(x.row(i), y.row(perm[i] as usize));
        }
        s
    });
    partial.into_iter().sum::<f64>() / x.rows as f64
}

/// Primal cost `⟨C, P⟩` of a dense coupling (baselines only).
pub fn dense_cost_of(c: &Mat, p: &Mat) -> f64 {
    c.dot(p)
}

/// [`bijection_cost`] over streamed [`DatasetSource`]s: x is swept in
/// `chunk_rows`-sized tiles (chunks in parallel, like the in-memory twin)
/// and each matched y row is fetched on demand, so evaluating a
/// million-point alignment needs `O(threads · chunk_rows·d)` memory —
/// neither cloud is ever materialised.  Per-chunk partial sums are
/// reduced in index order, so the result is deterministic.  Mid-sweep
/// read failures surface as the `io::Error` instead of panicking.
pub fn bijection_cost_source(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    perm: &[u32],
    kind: CostKind,
    chunk_rows: usize,
) -> std::io::Result<f64> {
    let d = x.dim();
    assert_eq!(d, y.dim(), "source dimensions must match");
    let n = x.rows();
    assert_eq!(n, perm.len(), "permutation length must match x");
    let m = y.rows();
    assert!(
        perm.iter().all(|&j| (j as usize) < m),
        "permutation target out of range for y ({m} rows)"
    );
    if n == 0 {
        return Ok(0.0);
    }
    let chunk = chunk_rows.max(1).min(n);
    let n_chunks = n.div_ceil(chunk);
    let threads = pool::default_threads();
    let partial = pool::parallel_map(n_chunks, threads, |ci| -> std::io::Result<f64> {
        let start = ci * chunk;
        let end = (start + chunk).min(n);
        let mut xtile = vec![0.0f32; (end - start) * d];
        let mut yrow = vec![0.0f32; d];
        x.fill_rows(start, &mut xtile)?;
        let mut s = 0.0f64;
        for (o, i) in (start..end).enumerate() {
            y.fetch_row(perm[i] as usize, &mut yrow)?;
            s += kind.pair(&xtile[o * d..(o + 1) * d], &yrow);
        }
        Ok(s)
    });
    let mut total = 0.0f64;
    for p in partial {
        total += p?;
    }
    Ok(total / n as f64)
}

/// Primal cost of *any* coupling representation — the uniform entry point
/// the benches and the CLI use instead of duplicating per-representation
/// cost code.  Delegates to [`crate::api::Coupling::cost`].
pub fn coupling_cost(x: &Mat, y: &Mat, coupling: &crate::api::Coupling, kind: CostKind) -> f64 {
    coupling.cost(x, y, kind)
}

/// Shannon entropy `H(P) = −Σ P_ij (log P_ij − 1)` minus-one convention of
/// the paper's Eq. 4; reported in Table S3 without the `−1` (the paper's
/// table uses plain −Σ p log p; we match that).
pub fn coupling_entropy(p: &Mat) -> f64 {
    let mut h = 0.0f64;
    for &v in &p.data {
        if v > 0.0 {
            let v = v as f64;
            h -= v * v.ln();
        }
    }
    h
}

/// Entropy of a bijective coupling with uniform weights: log n.
pub fn bijection_entropy(n: usize) -> f64 {
    (n as f64).ln()
}

/// Count entries above the paper's threshold (1e-8) in a dense coupling.
pub fn nonzeros(p: &Mat, thresh: f32) -> usize {
    p.data.iter().filter(|&&v| v > thresh).count()
}

/// Cosine similarity between two vectors (0 if either is null).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Cost of the block-diagonal coupling `P^(t)` induced by a co-clustering
/// (paper Eq. 12), computed streaming per block — used for the Fig. S3
/// refinement-cost curve without instantiating `P`.
/// `blocks` pairs index sets `(X_q, Y_q)`; any borrowed or owned `[u32]`
/// container works (`Vec<u32>` pairs, `&[u32]` slices of a recorded
/// hierarchy order, ...), so callers never clone index sets to get here.
pub fn block_coupling_cost<B: AsRef<[u32]> + Sync>(
    x: &Mat,
    y: &Mat,
    blocks: &[(B, B)],
    kind: CostKind,
) -> f64 {
    let n = x.rows as f64;
    let rho = blocks.len() as f64;
    let threads = pool::default_threads();
    let contrib = pool::parallel_map(blocks.len(), threads, |q| {
        let (bx, by) = &blocks[q];
        let mut s = 0.0f64;
        for &i in bx.as_ref() {
            let xi = x.row(i as usize);
            for &j in by.as_ref() {
                s += kind.pair(xi, y.row(j as usize));
            }
        }
        s
    });
    contrib.into_iter().sum::<f64>() * rho / (n * n)
}

/// Human-readable byte count (`1.5 MiB`-style) for scratch/peak-memory
/// reporting in the CLI and perf profiles.
pub fn human_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// Relative marginal violation of a dense coupling against uniform
/// marginals — a convergence diagnostic for the iterative baselines.
pub fn marginal_violation(p: &Mat) -> f64 {
    let n = p.rows as f64;
    let m = p.cols as f64;
    let mut worst = 0.0f64;
    for s in p.row_sums() {
        worst = worst.max(((s as f64) - 1.0 / n).abs() * n);
    }
    for s in p.col_sums() {
        worst = worst.max(((s as f64) - 1.0 / m).abs() * m);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn bijection_cost_identity_is_zero() {
        let mut rng = Rng::new(0);
        let mut x = Mat::zeros(100, 3);
        rng.fill_normal(&mut x.data);
        let perm: Vec<u32> = (0..100).collect();
        assert_eq!(bijection_cost(&x, &x, &perm, CostKind::SqEuclidean), 0.0);
    }

    #[test]
    fn bijection_cost_matches_dense() {
        let mut rng = Rng::new(1);
        let mut x = Mat::zeros(16, 2);
        let mut y = Mat::zeros(16, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let perm = rng.permutation(16);
        let mut p = Mat::zeros(16, 16);
        for (i, &j) in perm.iter().enumerate() {
            *p.at_mut(i, j as usize) = 1.0 / 16.0;
        }
        let c = crate::costs::dense_cost(&x, &y, CostKind::SqEuclidean);
        let want = dense_cost_of(&c, &p);
        let got = bijection_cost(&x, &y, &perm, CostKind::SqEuclidean);
        assert!((want - got).abs() < 1e-4, "{want} vs {got}");
    }

    #[test]
    fn bijection_cost_source_matches_in_memory() {
        use crate::data::stream::InMemorySource;
        let mut rng = Rng::new(6);
        let mut x = Mat::zeros(41, 3);
        let mut y = Mat::zeros(41, 3);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let perm = rng.permutation(41);
        let want = bijection_cost(&x, &y, &perm, CostKind::SqEuclidean);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        for chunk in [1usize, 9, 41, 100] {
            let got =
                bijection_cost_source(&xs, &ys, &perm, CostKind::SqEuclidean, chunk).unwrap();
            assert!((got - want).abs() < 1e-12, "chunk {chunk}: {got} vs {want}");
        }
    }

    #[test]
    fn entropy_of_uniform_coupling() {
        let n = 8;
        let p = Mat::full(n, n, 1.0 / (n * n) as f32);
        let h = coupling_entropy(&p);
        assert!((h - ((n * n) as f64).ln() / 1.0).abs() < 1e-3 * ((n * n) as f64).ln());
    }

    #[test]
    fn bijection_entropy_is_log_n() {
        assert!((bijection_entropy(1024) - 6.9314718).abs() < 1e-4);
    }

    #[test]
    fn nonzeros_counts() {
        let mut p = Mat::zeros(4, 4);
        *p.at_mut(0, 0) = 1.0;
        *p.at_mut(1, 2) = 1e-9;
        assert_eq!(nonzeros(&p, 1e-8), 1);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn coupling_cost_is_a_uniform_entry_point() {
        let mut rng = Rng::new(3);
        let mut x = Mat::zeros(12, 2);
        let mut y = Mat::zeros(12, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let perm = rng.permutation(12);
        let want = bijection_cost(&x, &y, &perm, CostKind::SqEuclidean);
        let cpl = crate::api::Coupling::Bijection(perm);
        assert_eq!(coupling_cost(&x, &y, &cpl, CostKind::SqEuclidean), want);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn block_cost_accepts_borrowed_slices() {
        let mut rng = Rng::new(5);
        let mut x = Mat::zeros(8, 2);
        let mut y = Mat::zeros(8, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let order: Vec<u32> = (0..8).collect();
        let owned = vec![
            ((0..4).collect::<Vec<u32>>(), (0..4).collect::<Vec<u32>>()),
            ((4..8).collect::<Vec<u32>>(), (4..8).collect::<Vec<u32>>()),
        ];
        let borrowed: Vec<(&[u32], &[u32])> =
            vec![(&order[0..4], &order[0..4]), (&order[4..8], &order[4..8])];
        let a = block_coupling_cost(&x, &y, &owned, CostKind::SqEuclidean);
        let b = block_coupling_cost(&x, &y, &borrowed, CostKind::SqEuclidean);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn block_cost_matches_dense_blocks() {
        let mut rng = Rng::new(2);
        let mut x = Mat::zeros(8, 2);
        let mut y = Mat::zeros(8, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        // 2 blocks of 4
        let blocks = vec![
            ((0..4).collect::<Vec<u32>>(), (0..4).collect::<Vec<u32>>()),
            ((4..8).collect::<Vec<u32>>(), (4..8).collect::<Vec<u32>>()),
        ];
        let got = block_coupling_cost(&x, &y, &blocks, CostKind::SqEuclidean);
        // dense check: P_ij = rho/n^2 inside blocks
        let c = crate::costs::dense_cost(&x, &y, CostKind::SqEuclidean);
        let mut want = 0.0;
        for (bx, by) in &blocks {
            for &i in bx {
                for &j in by {
                    want += c.at(i as usize, j as usize) as f64 * (2.0 / 64.0);
                }
            }
        }
        assert!((got - want).abs() < 1e-6);
    }
}
