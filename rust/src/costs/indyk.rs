//! Sample-linear low-rank factorisation of a *metric* distance matrix,
//! after Indyk, Vakilian, Wagner & Woodruff (COLT 2019) — the paper's
//! Algorithm 3.
//!
//! We implement the practical CUR-style variant with the IVWW sampling
//! distribution: reference row/column anchors define per-row sampling
//! probabilities `p_i ∝ d(x_i, y_{j*})² + d(x_{i*}, y_{j*})² + mean_j
//! d(x_{i*}, y_j)²`; `t` landmark columns are drawn, `U = C[:, S]`
//! (n×t distances — linear), and `V` solves the regularised least-squares
//! fit on a row sample so that `C ≈ U Vᵀ`.  Total work
//! `O((n+m)·t + t²·m)` — linear in the number of points for constant `t`,
//! which is what gives HiRef log-linear scaling for non-factorisable costs
//! (paper §3.4, Appendix E.1).

use crate::costs::CostKind;
use crate::linalg::{invert_spd, Mat, MatView};
use crate::prng::Rng;

/// Factorise the `kind` distance matrix between rows of `x` and `y` as
/// `C ≈ U Vᵀ` with width `t = target_k`.  Deterministic given `seed`.
/// Accepts [`MatView`]s, so callers can factorise borrowed row ranges.
pub fn factorize<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
    kind: CostKind,
    target_k: usize,
    seed: u64,
) -> (Mat, Mat) {
    let (x, y) = (x.into(), y.into());
    let n = x.rows;
    let m = y.rows;
    let t = target_k.min(n).min(m).max(1);
    let mut rng = Rng::new(seed ^ 0x1D1_9EB);

    // --- IVWW sampling probabilities -----------------------------------
    let i_star = rng.next_below(n);
    let j_star = rng.next_below(m);
    let xi_star = x.row(i_star);
    let yj_star = y.row(j_star);
    let mean_to_y: f64 = (0..m)
        .map(|j| {
            let d = kind.pair(xi_star, y.row(j));
            d * d
        })
        .sum::<f64>()
        / m as f64;
    let d_anchor = {
        let d = kind.pair(xi_star, yj_star);
        d * d
    };
    let probs: Vec<f64> = (0..n)
        .map(|i| {
            let d = kind.pair(x.row(i), yj_star);
            d * d + d_anchor + mean_to_y
        })
        .collect();

    // --- draw t landmark columns (rows of Y) by the induced column
    // distribution (sample rows of X first, then their nearest structure is
    // captured by sampling Y uniformly among the paired draws; IVWW sample
    // columns with the symmetric distribution — we mirror it).
    let col_probs: Vec<f64> = (0..m)
        .map(|j| {
            let d = kind.pair(xi_star, y.row(j));
            d * d + d_anchor + mean_to_y
        })
        .collect();
    let cols = sample_weighted_distinct(&mut rng, &col_probs, t);

    // --- U = C[:, S]  (n×t) ---------------------------------------------
    let mut u = Mat::zeros(n, t);
    for i in 0..n {
        let xi = x.row(i);
        let urow = u.row_mut(i);
        for (c, &j) in cols.iter().enumerate() {
            urow[c] = kind.pair(xi, y.row(j as usize)) as f32;
        }
    }

    // --- row sample for the regression fit ------------------------------
    let s = (4 * t).min(n);
    let rows = sample_weighted_distinct(&mut rng, &probs, s);

    // A = U[rows, :]  (s×t),  B = C[rows, :]  (s×m)
    let mut a = Mat::zeros(s, t);
    for (r, &i) in rows.iter().enumerate() {
        a.row_mut(r).copy_from_slice(u.row(i as usize));
    }
    // Solve (AᵀA + λI) W = Aᵀ B  for W (t×m);  V = Wᵀ (m×t).
    let ata = a.t_matmul(&a);
    let mut g = ata.clone();
    let lam = 1e-6_f32 * (1.0 + g.data.iter().fold(0.0f32, |mx, &v| mx.max(v.abs())));
    for i in 0..t {
        *g.at_mut(i, i) += lam;
    }
    let g_inv = invert_spd(&g);

    // Build V row-by-row over Y (linear in m): for each column j of C we
    // need c_j = C[rows, j] (s values), then V_j = G⁻¹ Aᵀ c_j.
    let mut v = Mat::zeros(m, t);
    let mut atc = vec![0.0f32; t];
    for j in 0..m {
        let yj = y.row(j);
        atc.iter_mut().for_each(|v| *v = 0.0);
        for (r, &i) in rows.iter().enumerate() {
            let cij = kind.pair(x.row(i as usize), yj) as f32;
            let arow = a.row(r);
            for (acc, &av) in atc.iter_mut().zip(arow) {
                *acc += av * cij;
            }
        }
        let vrow = v.row_mut(j);
        for c in 0..t {
            let mut s = 0.0f32;
            let grow = g_inv.row(c);
            for (gv, av) in grow.iter().zip(&atc) {
                s += gv * av;
            }
            vrow[c] = s;
        }
    }
    (u, v)
}

/// Weighted sampling of `k` distinct indices (probabilities ∝ weights).
fn sample_weighted_distinct(rng: &mut Rng, weights: &[f64], k: usize) -> Vec<u32> {
    let n = weights.len();
    let k = k.min(n);
    let mut taken = vec![false; n];
    let mut total: f64 = weights.iter().sum();
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut u = rng.next_f64() * total;
        let mut pick = usize::MAX;
        for (i, &w) in weights.iter().enumerate() {
            if taken[i] {
                continue;
            }
            if u < w {
                pick = i;
                break;
            }
            u -= w;
        }
        if pick == usize::MAX {
            // numeric fallthrough: pick first untaken
            pick = (0..n).find(|&i| !taken[i]).unwrap();
        }
        taken[pick] = true;
        total -= weights[pick];
        out.push(pick as u32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::dense_cost;

    fn rand_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn invert_spd_roundtrip() {
        let mut rng = Rng::new(0);
        let a = rand_mat(&mut rng, 6, 6);
        let mut spd = a.t_matmul(&a);
        for i in 0..6 {
            *spd.at_mut(i, i) += 1.0;
        }
        let inv = invert_spd(&spd);
        let eye = spd.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((eye.at(i, j) - want).abs() < 1e-3, "{}", eye.at(i, j));
            }
        }
    }

    #[test]
    fn weighted_sampling_distinct_and_biased() {
        let mut rng = Rng::new(1);
        let mut w = vec![1e-9; 100];
        w[7] = 1.0;
        w[13] = 1.0;
        let s = sample_weighted_distinct(&mut rng, &w, 2);
        assert_eq!(s.len(), 2);
        assert_ne!(s[0], s[1]);
        assert!(s.contains(&7) && s.contains(&13));
    }

    #[test]
    fn factorization_approximates_euclidean_cost() {
        let mut rng = Rng::new(2);
        // low-dimensional data => distance matrix is approximately low rank
        let x = rand_mat(&mut rng, 120, 2);
        let y = rand_mat(&mut rng, 120, 2);
        let (u, v) = factorize(&x, &y, CostKind::Euclidean, 16, 3);
        let c = dense_cost(&x, &y, CostKind::Euclidean);
        let approx = u.matmul(&v.t());
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in approx.data.iter().zip(&c.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.08, "relative error too high: {rel}");
    }

    #[test]
    fn factorization_shapes() {
        let mut rng = Rng::new(4);
        let x = rand_mat(&mut rng, 50, 3);
        let y = rand_mat(&mut rng, 40, 3);
        let (u, v) = factorize(&x, &y, CostKind::Euclidean, 8, 0);
        assert_eq!((u.rows, u.cols), (50, 8));
        assert_eq!((v.rows, v.cols), (40, 8));
    }
}
