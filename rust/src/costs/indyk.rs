//! Sample-linear low-rank factorisation of a *metric* distance matrix,
//! after Indyk, Vakilian, Wagner & Woodruff (COLT 2019) — the paper's
//! Algorithm 3.
//!
//! We implement the practical CUR-style variant with the IVWW sampling
//! distribution: reference row/column anchors define per-row sampling
//! probabilities `p_i ∝ d(x_i, y_{j*})² + d(x_{i*}, y_{j*})² + mean_j
//! d(x_{i*}, y_j)²`; `t` landmark columns are drawn, `U = C[:, S]`
//! (n×t distances — linear), and `V` solves the regularised least-squares
//! fit on a row sample so that `C ≈ U Vᵀ`.  Total work
//! `O((n+m)·t + t²·m)` — linear in the number of points for constant `t`,
//! which is what gives HiRef log-linear scaling for non-factorisable costs
//! (paper §3.4, Appendix E.1).

use std::io;

use crate::costs::{CostKind, ErrOnce};
use crate::data::stream::{for_each_chunk_parallel, DatasetSource, InMemorySource};
use crate::linalg::{invert_spd, Mat, MatView};
use crate::pool::{self, FactorStore, ResidentStore, ScratchArena, SharedSlice};
use crate::prng::Rng;

/// Factorise the `kind` distance matrix between rows of `x` and `y` as
/// `C ≈ U Vᵀ` with width `t = target_k`.  Deterministic given `seed`.
/// Accepts [`MatView`]s, so callers can factorise borrowed row ranges.
///
/// This is the memory-resident front-end of [`factorize_chunked`]: the
/// in-memory path streams zero-copy full-size windows through the same
/// chunked core, so the two can never drift numerically.
pub fn factorize<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
    kind: CostKind,
    target_k: usize,
    seed: u64,
) -> (Mat, Mat) {
    let (x, y) = (x.into(), y.into());
    let arena = ScratchArena::new(pool::default_threads());
    let chunk = x.rows.max(y.rows).max(1);
    factorize_chunked(
        &InMemorySource::from_view(x),
        &InMemorySource::from_view(y),
        kind,
        target_k,
        seed,
        chunk,
        &arena,
        pool::default_threads(),
    )
    .expect("in-memory sources are infallible")
}

/// Fixed row-segment length for scalar accumulations: per-segment partial
/// sums are taken linearly in row order and combined by [`tree_reduce`].
/// The segmentation depends on neither `chunk_rows` nor `threads`, which
/// is what keeps the sums — and therefore the sampled factorisation —
/// bit-identical across chunk sizes and thread counts.
const SEG_ROWS: usize = 4096;

/// Fixed-topology pairwise tree reduction: fold adjacent pairs until one
/// value remains.  The combine order is a function of the value count
/// alone, so the result is deterministic however the partials were
/// produced.
fn tree_reduce(mut vals: Vec<f64>) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    while vals.len() > 1 {
        vals = vals
            .chunks(2)
            .map(|p| if p.len() == 2 { p[0] + p[1] } else { p[0] })
            .collect();
    }
    vals[0]
}

/// `Σ_i d(anchor, src_i)²` over all rows of `src`: per-[`SEG_ROWS`]
/// segment partials computed in parallel, combined by the deterministic
/// [`tree_reduce`].  Partial-sum *boundaries* are the fixed segments, but
/// non-resident reads inside a segment honour the caller's `chunk_rows`
/// memory bound (sub-reads accumulate in row order, so their size cannot
/// change the per-segment value).
fn segmented_sq_sum(
    src: &dyn DatasetSource,
    anchor: &[f32],
    kind: CostKind,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
) -> io::Result<f64> {
    let n = src.rows();
    let d = src.dim();
    if n == 0 {
        return Ok(0.0);
    }
    let n_segs = n.div_ceil(SEG_ROWS);
    let partials = pool::parallel_map(n_segs, threads, |s| -> io::Result<f64> {
        let start = s * SEG_ROWS;
        let end = (start + SEG_ROWS).min(n);
        let mut acc = 0.0f64;
        match src.view_rows(start, end) {
            Some(vw) => {
                for i in 0..vw.rows {
                    let dd = kind.pair(anchor, vw.row(i));
                    acc += dd * dd;
                }
            }
            None => {
                // tile reads stay within the chunk_rows budget even though
                // the partial-sum segment is larger
                let sub = chunk_rows.max(1).min(end - start);
                let mut tile = arena.take_f32(sub * d);
                let mut lo = start;
                while lo < end {
                    let hi = (lo + sub).min(end);
                    let len = (hi - lo) * d;
                    src.fill_rows(lo, &mut tile[..len])?;
                    for row in tile[..len].chunks(d) {
                        let dd = kind.pair(anchor, row);
                        acc += dd * dd;
                    }
                    lo = hi;
                }
            }
        }
        Ok(acc)
    });
    let mut vals = Vec::with_capacity(partials.len());
    for p in partials {
        vals.push(p?);
    }
    Ok(tree_reduce(vals))
}

/// [`factorize`] over chunked [`DatasetSource`]s, writing the factors
/// **straight into a [`FactorStore`] pair** (no full-matrix intermediate,
/// so a [`crate::pool::SpillStore`] bounds factor memory during the build
/// too): every full-dataset sweep (anchor means, sampling probabilities,
/// the `U = C[:, S]` landmark distances, the regression right-hand sides
/// for `V`) is streamed in `chunk_rows`-sized tiles drawn from `arena`
/// and fanned out over up to `threads` workers — per-row outputs write
/// disjoint store row windows, the regression's sampled `U` rows are read
/// back through [`FactorStore::read_rows`], and the one order-sensitive
/// scalar sweep (the anchor mean) reduces through the fixed-topology
/// [`tree_reduce`] over [`SEG_ROWS`]-row segments.  Peak memory is one
/// point tile plus one factor tile (`chunk_rows·(d+t)`) per worker plus
/// the `O(s·(d+t))` sampled-row block (`s = 4t`) — never both full point
/// clouds and, with a spill store, never the full factors.  The result is
/// **bit-identical for any chunk size and any thread count**; mid-sweep
/// read failures surface as the `io::Error`.
#[allow(clippy::too_many_arguments)]
pub fn factorize_chunked_into(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    kind: CostKind,
    target_k: usize,
    seed: u64,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
    us: &dyn FactorStore,
    vs: &dyn FactorStore,
) -> io::Result<()> {
    let n = x.rows();
    let m = y.rows();
    let d = x.dim();
    assert_eq!(d, y.dim(), "dimension mismatch");
    // sampling width, independent of `kind` (the IVWW scheme works for any
    // metric); equals `factor_width` for the Euclidean dispatch path
    let t = target_k.min(n).min(m).max(1);
    assert_eq!((us.rows(), us.cols()), (n, t), "U store shape mismatch");
    assert_eq!((vs.rows(), vs.cols()), (m, t), "V store shape mismatch");
    let mut rng = Rng::new(seed ^ 0x1D1_9EB);

    // --- IVWW sampling probabilities -----------------------------------
    let i_star = rng.next_below(n);
    let j_star = rng.next_below(m);
    let mut xi_star = vec![0.0f32; d];
    let mut yj_star = vec![0.0f32; d];
    x.fetch_row(i_star, &mut xi_star)?;
    y.fetch_row(j_star, &mut yj_star)?;
    let sum_to_y = segmented_sq_sum(y, &xi_star, kind, chunk_rows, arena, threads)?;
    let mean_to_y = sum_to_y / m as f64;
    let d_anchor = {
        let dd = kind.pair(&xi_star, &yj_star);
        dd * dd
    };
    // per-row probabilities: independent per row, so tiles write disjoint
    // windows and the parallel sweep is trivially deterministic
    let mut probs = vec![0.0f64; n];
    {
        let ps = SharedSlice::new(&mut probs);
        for_each_chunk_parallel(x, chunk_rows, arena, threads, |start, tile| {
            // SAFETY: tiles partition the row space — windows are disjoint.
            let out = unsafe { ps.slice_mut(start, start + tile.rows) };
            for (i, o) in out.iter_mut().enumerate() {
                let dd = kind.pair(tile.row(i), &yj_star);
                *o = dd * dd + d_anchor + mean_to_y;
            }
        })?;
    }

    // --- draw t landmark columns (rows of Y) by the induced column
    // distribution (sample rows of X first, then their nearest structure is
    // captured by sampling Y uniformly among the paired draws; IVWW sample
    // columns with the symmetric distribution — we mirror it).
    let mut col_probs = vec![0.0f64; m];
    {
        let ps = SharedSlice::new(&mut col_probs);
        for_each_chunk_parallel(y, chunk_rows, arena, threads, |start, tile| {
            // SAFETY: as above.
            let out = unsafe { ps.slice_mut(start, start + tile.rows) };
            for (j, o) in out.iter_mut().enumerate() {
                let dd = kind.pair(&xi_star, tile.row(j));
                *o = dd * dd + d_anchor + mean_to_y;
            }
        })?;
    }
    let cols = sample_weighted_distinct(&mut rng, &col_probs, t);

    // --- U = C[:, S]  (n×t): landmarks gathered once (t·d floats), then
    // one parallel streamed sweep over X writing disjoint store windows.
    let mut landmarks = Mat::zeros(t, d);
    for (c, &j) in cols.iter().enumerate() {
        y.fetch_row(j as usize, landmarks.row_mut(c))?;
    }
    {
        let sink = ErrOnce::new();
        for_each_chunk_parallel(x, chunk_rows, arena, threads, |start, tile| {
            // SAFETY: disjoint row windows, as above.
            let res = unsafe {
                us.fill_rows_with(start, tile.rows, arena, &mut |out| {
                    for (i, urow) in out.chunks_mut(t).enumerate() {
                        let xi = tile.row(i);
                        for (uv, c) in urow.iter_mut().zip(0..t) {
                            *uv = kind.pair(xi, landmarks.row(c)) as f32;
                        }
                    }
                })
            };
            if let Err(e) = res {
                sink.set(e);
            }
        })?;
        sink.take()?;
    }

    // --- row sample for the regression fit ------------------------------
    let s = (4 * t).min(n);
    let rows = sample_weighted_distinct(&mut rng, &probs, s);

    // A = U[rows, :]  (s×t),  B = C[rows, :]  (s×m); the sampled X rows
    // are gathered once (s·d floats), the sampled U rows read back from
    // the store (bit-exact round-trip).
    let mut a = Mat::zeros(s, t);
    let mut xsamp = Mat::zeros(s, d);
    for (r, &i) in rows.iter().enumerate() {
        // SAFETY: the U build sweep has joined; no concurrent writers.
        unsafe { us.read_rows(i as usize, a.row_mut(r)) }?;
        x.fetch_row(i as usize, xsamp.row_mut(r))?;
    }
    // Solve (AᵀA + λI) W = Aᵀ B  for W (t×m);  V = Wᵀ (m×t).
    let ata = a.t_matmul(&a);
    let mut g = ata.clone();
    let lam = 1e-6_f32 * (1.0 + g.data.iter().fold(0.0f32, |mx, &v| mx.max(v.abs())));
    for i in 0..t {
        *g.at_mut(i, i) += lam;
    }
    let g_inv = invert_spd(&g);

    // Build V row-by-row over a parallel streamed Y sweep (linear in m):
    // for each column j of C we need c_j = C[rows, j] (s values), then
    // V_j = G⁻¹ Aᵀ c_j.  Rows are independent — disjoint store windows.
    {
        let sink = ErrOnce::new();
        for_each_chunk_parallel(y, chunk_rows, arena, threads, |start, tile| {
            let mut atc = vec![0.0f32; t];
            // SAFETY: disjoint row windows, as above.
            let res = unsafe {
                vs.fill_rows_with(start, tile.rows, arena, &mut |out| {
                    for (jo, vrow) in out.chunks_mut(t).enumerate() {
                        let yj = tile.row(jo);
                        atc.iter_mut().for_each(|v| *v = 0.0);
                        for r in 0..rows.len() {
                            let cij = kind.pair(xsamp.row(r), yj) as f32;
                            let arow = a.row(r);
                            for (acc, &av) in atc.iter_mut().zip(arow) {
                                *acc += av * cij;
                            }
                        }
                        for (c, slot) in vrow.iter_mut().enumerate() {
                            let mut acc = 0.0f32;
                            let grow = g_inv.row(c);
                            for (gv, av) in grow.iter().zip(&atc) {
                                acc += gv * av;
                            }
                            *slot = acc;
                        }
                    }
                })
            };
            if let Err(e) = res {
                sink.set(e);
            }
        })?;
        sink.take()?;
    }
    Ok(())
}

/// [`factorize_chunked_into`] materialised to owned matrices (resident
/// stores underneath) — the historical signature, still the back end of
/// the in-memory [`factorize`].
#[allow(clippy::too_many_arguments)]
pub fn factorize_chunked(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    kind: CostKind,
    target_k: usize,
    seed: u64,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
) -> io::Result<(Mat, Mat)> {
    let t = target_k.min(x.rows()).min(y.rows()).max(1);
    let us = ResidentStore::zeroed(x.rows(), t);
    let vs = ResidentStore::zeroed(y.rows(), t);
    factorize_chunked_into(x, y, kind, target_k, seed, chunk_rows, arena, threads, &us, &vs)?;
    Ok((Box::new(us).into_mat()?, Box::new(vs).into_mat()?))
}

/// Weighted sampling of `k` distinct indices (probabilities ∝ weights).
fn sample_weighted_distinct(rng: &mut Rng, weights: &[f64], k: usize) -> Vec<u32> {
    let n = weights.len();
    let k = k.min(n);
    let mut taken = vec![false; n];
    let mut total: f64 = weights.iter().sum();
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut u = rng.next_f64() * total;
        let mut pick = usize::MAX;
        for (i, &w) in weights.iter().enumerate() {
            if taken[i] {
                continue;
            }
            if u < w {
                pick = i;
                break;
            }
            u -= w;
        }
        if pick == usize::MAX {
            // numeric fallthrough: pick first untaken
            pick = (0..n).find(|&i| !taken[i]).unwrap();
        }
        taken[pick] = true;
        total -= weights[pick];
        out.push(pick as u32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::dense_cost;

    fn rand_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn invert_spd_roundtrip() {
        let mut rng = Rng::new(0);
        let a = rand_mat(&mut rng, 6, 6);
        let mut spd = a.t_matmul(&a);
        for i in 0..6 {
            *spd.at_mut(i, i) += 1.0;
        }
        let inv = invert_spd(&spd);
        let eye = spd.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((eye.at(i, j) - want).abs() < 1e-3, "{}", eye.at(i, j));
            }
        }
    }

    #[test]
    fn weighted_sampling_distinct_and_biased() {
        let mut rng = Rng::new(1);
        let mut w = vec![1e-9; 100];
        w[7] = 1.0;
        w[13] = 1.0;
        let s = sample_weighted_distinct(&mut rng, &w, 2);
        assert_eq!(s.len(), 2);
        assert_ne!(s[0], s[1]);
        assert!(s.contains(&7) && s.contains(&13));
    }

    #[test]
    fn factorization_approximates_euclidean_cost() {
        let mut rng = Rng::new(2);
        // low-dimensional data => distance matrix is approximately low rank
        let x = rand_mat(&mut rng, 120, 2);
        let y = rand_mat(&mut rng, 120, 2);
        let (u, v) = factorize(&x, &y, CostKind::Euclidean, 16, 3);
        let c = dense_cost(&x, &y, CostKind::Euclidean);
        let approx = u.matmul(&v.t());
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in approx.data.iter().zip(&c.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.08, "relative error too high: {rel}");
    }

    #[test]
    fn chunked_factorization_identical_to_in_memory_for_any_chunk_size() {
        let mut rng = Rng::new(9);
        let x = rand_mat(&mut rng, 61, 3);
        let y = rand_mat(&mut rng, 47, 3);
        let (u, v) = factorize(&x, &y, CostKind::Euclidean, 8, 5);
        let arena = ScratchArena::new(4);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        for chunk in [1usize, 5, 17, 61, 512] {
            let (uc, vc) =
                factorize_chunked(&xs, &ys, CostKind::Euclidean, 8, 5, chunk, &arena, 2).unwrap();
            assert_eq!(u.data, uc.data, "U diverges at chunk {chunk}");
            assert_eq!(v.data, vc.data, "V diverges at chunk {chunk}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "slow under Miri: many full factorization sweeps")]
    fn parallel_sweeps_bit_identical_to_serial_for_any_thread_count() {
        // the satellite contract: the deterministic tree reduction makes
        // the whole sampled factorisation (anchor mean → probabilities →
        // sampled landmarks → regression) invariant to the worker count
        let mut rng = Rng::new(14);
        // > SEG_ROWS rows would be ideal but slow; several segments still
        // form when chunk < n, and the tree shape is n-dependent only
        let x = rand_mat(&mut rng, 173, 3);
        let y = rand_mat(&mut rng, 131, 3);
        let arena = ScratchArena::new(8);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        let (u1, v1) =
            factorize_chunked(&xs, &ys, CostKind::Euclidean, 8, 5, 19, &arena, 1).unwrap();
        for threads in [2usize, 4, 8] {
            let (ut, vt) =
                factorize_chunked(&xs, &ys, CostKind::Euclidean, 8, 5, 19, &arena, threads)
                    .unwrap();
            assert_eq!(u1.data, ut.data, "U diverges at threads {threads}");
            assert_eq!(v1.data, vt.data, "V diverges at threads {threads}");
        }
        // the segmented anchor sum itself: serial == parallel, any segs
        let anchor = x.row(0);
        let s1 = segmented_sq_sum(&ys, anchor, CostKind::Euclidean, 19, &arena, 1).unwrap();
        let s8 = segmented_sq_sum(&ys, anchor, CostKind::Euclidean, 19, &arena, 8).unwrap();
        assert_eq!(s1.to_bits(), s8.to_bits());
        // with > SEG_ROWS rows several segments exist, so the pairwise
        // tree really fires — and a generated (fill_rows) source takes
        // the per-worker tile path, whose sub-reads honour chunk_rows
        // without changing the per-segment sums
        let big = crate::data::stream::GeneratorSource::new(2 * SEG_ROWS + 123, 2, |i, out| {
            out[0] = (i % 97) as f32 * 0.013;
            out[1] = (i % 89) as f32 * -0.007;
        });
        let anchor2 = [0.5f32, -0.25];
        let b1 = segmented_sq_sum(&big, &anchor2, CostKind::Euclidean, 64, &arena, 1).unwrap();
        let b7 = segmented_sq_sum(&big, &anchor2, CostKind::Euclidean, 977, &arena, 7).unwrap();
        let b_all = segmented_sq_sum(&big, &anchor2, CostKind::Euclidean, usize::MAX, &arena, 4)
            .unwrap();
        assert_eq!(b1.to_bits(), b7.to_bits());
        assert_eq!(b1.to_bits(), b_all.to_bits());
    }

    #[test]
    fn tree_reduce_is_fixed_topology() {
        assert_eq!(tree_reduce(vec![]), 0.0);
        assert_eq!(tree_reduce(vec![3.5]), 3.5);
        // ((a+b)+(c+d)) + e — not left-to-right
        let vals = vec![1e16, 1.0, -1e16, 1.0, 2.0];
        let want = ((1e16 + 1.0) + (-1e16 + 1.0)) + 2.0;
        assert_eq!(tree_reduce(vals).to_bits(), want.to_bits());
    }

    #[test]
    #[cfg_attr(miri, ignore = "file-backed: reads a real .bin dataset file")]
    fn chunked_factorization_from_file_matches_in_memory() {
        let mut rng = Rng::new(10);
        let x = rand_mat(&mut rng, 40, 2);
        let y = rand_mat(&mut rng, 40, 2);
        let dir = std::env::temp_dir();
        let px = dir.join(format!("hiref_indyk_x_{}.bin", std::process::id()));
        let py = dir.join(format!("hiref_indyk_y_{}.bin", std::process::id()));
        crate::data::stream::write_bin(&px, &x).unwrap();
        crate::data::stream::write_bin(&py, &y).unwrap();
        let fx = crate::data::stream::BinFileSource::open(&px, 2).unwrap();
        let fy = crate::data::stream::BinFileSource::open(&py, 2).unwrap();
        let arena = ScratchArena::new(2);
        let (u, v) = factorize(&x, &y, CostKind::Euclidean, 6, 3);
        let (uf, vf) =
            factorize_chunked(&fx, &fy, CostKind::Euclidean, 6, 3, 9, &arena, 2).unwrap();
        assert_eq!(u.data, uf.data);
        assert_eq!(v.data, vf.data);
        let _ = std::fs::remove_file(&px);
        let _ = std::fs::remove_file(&py);
    }

    #[test]
    fn factorization_shapes() {
        let mut rng = Rng::new(4);
        let x = rand_mat(&mut rng, 50, 3);
        let y = rand_mat(&mut rng, 40, 3);
        let (u, v) = factorize(&x, &y, CostKind::Euclidean, 8, 0);
        assert_eq!((u.rows, u.cols), (50, 8));
        assert_eq!((v.rows, v.cols), (40, 8));
    }
}
