//! Cost functions and linear-space cost-matrix factorisations.
//!
//! HiRef never materialises an `n×n` cost matrix.  LROT sub-problems
//! consume a low-rank factorisation `C ≈ U Vᵀ`:
//!
//! * squared Euclidean — the **exact** rank-`d+2` factorisation of
//!   Scetbon et al. 2021 ([`factor::sq_euclidean_factors`]);
//! * any metric cost — the sample-linear randomized factorisation in the
//!   spirit of Indyk et al. 2019 ([`indyk::factorize`]).
//!
//! Dense costs ([`dense_cost`]) exist only for baselines (Sinkhorn,
//! Hungarian) and small base-case blocks.
//!
//! Both factorisations also ship **chunked twins**
//! ([`factor::sq_euclidean_factors_chunked`], [`indyk::factorize_chunked`],
//! dispatched by [`factors_for_source`]) that consume
//! [`crate::data::stream::DatasetSource`]s in `chunk_rows`-sized tiles:
//! peak ingestion memory is one tile plus the `O(n·r)` factor output, and
//! the factors are identical to the in-memory path for any chunk size.

pub mod factor;
pub mod indyk;

use std::io;

use crate::data::stream::DatasetSource;
use crate::linalg::{dist, sq_dist, Mat, MatView};
use crate::pool::{FactorStore, ResidentStore, ScratchArena};

/// First-error sink for parallel tile sweeps whose closures are
/// infallible (`Fn(usize, MatView)`): workers stash the first failure,
/// the driver surfaces it once the sweep has joined.
pub(crate) struct ErrOnce(std::sync::Mutex<Option<io::Error>>);

impl ErrOnce {
    pub(crate) fn new() -> ErrOnce {
        ErrOnce(std::sync::Mutex::new(None))
    }

    pub(crate) fn set(&self, e: io::Error) {
        let mut guard = self.0.lock().unwrap();
        if guard.is_none() {
            *guard = Some(e);
        }
    }

    pub(crate) fn take(self) -> io::Result<()> {
        match self.0.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Ground cost selector. Matches the paper's two evaluation costs:
/// `‖·‖₂` (Wasserstein-1 ground cost) and `‖·‖₂²` (Wasserstein-2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostKind {
    Euclidean,
    SqEuclidean,
}

impl CostKind {
    /// Cost of a single pair.
    #[inline]
    pub fn pair(&self, x: &[f32], y: &[f32]) -> f64 {
        match self {
            CostKind::Euclidean => dist(x, y),
            CostKind::SqEuclidean => sq_dist(x, y),
        }
    }

    /// Human-readable label used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            CostKind::Euclidean => "‖·‖₂",
            CostKind::SqEuclidean => "‖·‖₂²",
        }
    }
}

/// Dense `n×m` cost matrix (baselines and test oracles only; the
/// refinement base case uses [`dense_cost_indexed_into`]).  Accepts
/// borrowed [`MatView`]s, so sub-blocks are sliced, never gathered.
pub fn dense_cost<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
    kind: CostKind,
) -> Mat {
    let (x, y) = (x.into(), y.into());
    let mut c = Mat::zeros(x.rows, y.rows);
    for i in 0..x.rows {
        let xi = x.row(i);
        let crow = c.row_mut(i);
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = kind.pair(xi, y.row(j)) as f32;
        }
    }
    c
}

/// Write the dense `xs.len()×ys.len()` cost matrix between the selected
/// original rows of `x`/`y` straight into a row-major `out` buffer
/// (typically a [`crate::pool::ScratchArena`] checkout).  This is the
/// base-case path of the refinement engine: no gathered point rows, no
/// freshly allocated `Mat` per block.
pub fn dense_cost_indexed_into<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
    xs: &[u32],
    ys: &[u32],
    kind: CostKind,
    out: &mut [f32],
) {
    let (x, y) = (x.into(), y.into());
    assert_eq!(out.len(), xs.len() * ys.len(), "cost buffer shape mismatch");
    for (i, &xi) in xs.iter().enumerate() {
        let xrow = x.row(xi as usize);
        let crow = &mut out[i * ys.len()..(i + 1) * ys.len()];
        for (cv, &yj) in crow.iter_mut().zip(ys) {
            *cv = kind.pair(xrow, y.row(yj as usize)) as f32;
        }
    }
}

/// Low-rank factors `(U, V)` with `C ≈ U Vᵀ`, choosing the best strategy
/// for `kind`: exact `d+2` for squared Euclidean, Indyk-style sampling
/// otherwise.  `target_k` bounds the factor width for the sampled path
/// (ignored by the exact path, whose width is `d+2`).
pub fn factors_for<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
    kind: CostKind,
    target_k: usize,
    seed: u64,
) -> (Mat, Mat) {
    let (x, y) = (x.into(), y.into());
    match kind {
        CostKind::SqEuclidean => factor::sq_euclidean_factors(x, y),
        CostKind::Euclidean => indyk::factorize(x, y, kind, target_k, seed),
    }
}

/// Width of the factor matrices [`factors_for`] / the chunked builders
/// produce for a `dim`-dimensional `n × m` problem: the exact `d + 2` for
/// squared Euclidean, the (clamped) sampling width `t` for the Indyk
/// path.  Callers that pre-create a [`FactorStore`] size it with this, so
/// the store shape and the builders cannot drift.
pub fn factor_width(kind: CostKind, dim: usize, n: usize, m: usize, target_k: usize) -> usize {
    match kind {
        CostKind::SqEuclidean => dim + 2,
        CostKind::Euclidean => target_k.min(n).min(m).max(1),
    }
}

/// Chunked twin of [`factors_for`]: build the cost factors from streamed
/// [`DatasetSource`]s **directly into a pair of [`FactorStore`]s** (sized
/// `rows × `[`factor_width`]), with the tile sweeps fanned out over up to
/// `threads` workers — never holding more than one `chunk_rows`-sized
/// tile plus one factor tile per worker; no full factor matrix is ever
/// materialised outside the stores, so a [`crate::pool::SpillStore`]
/// bounds factor memory end to end.  Scalar accumulations reduce through
/// a fixed-topology deterministic tree (see [`indyk::factorize_chunked`]),
/// so the factors are **identical for any chunk size and any thread
/// count**.  Mid-sweep dataset read failures surface as the `io::Error`
/// (solve paths convert it to [`crate::api::SolveError::Backend`]).
#[allow(clippy::too_many_arguments)]
pub fn factors_for_source_into(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    kind: CostKind,
    target_k: usize,
    seed: u64,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
    us: &dyn FactorStore,
    vs: &dyn FactorStore,
) -> io::Result<()> {
    match kind {
        CostKind::SqEuclidean => {
            factor::sq_euclidean_factors_chunked_into(x, y, chunk_rows, arena, threads, us, vs)
        }
        CostKind::Euclidean => indyk::factorize_chunked_into(
            x, y, kind, target_k, seed, chunk_rows, arena, threads, us, vs,
        ),
    }
}

/// [`factors_for_source_into`] materialised to owned matrices (resident
/// stores underneath) — for callers that want plain `(U, V)`.
#[allow(clippy::too_many_arguments)]
pub fn factors_for_source(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    kind: CostKind,
    target_k: usize,
    seed: u64,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
) -> std::io::Result<(Mat, Mat)> {
    let k = factor_width(kind, x.dim(), x.rows(), y.rows(), target_k);
    let us = ResidentStore::zeroed(x.rows(), k);
    let vs = ResidentStore::zeroed(y.rows(), k);
    factors_for_source_into(x, y, kind, target_k, seed, chunk_rows, arena, threads, &us, &vs)?;
    Ok((Box::new(us).into_mat()?, Box::new(vs).into_mat()?))
}

/// Write the dense `x.rows×y.rows` cost matrix between two (typically
/// gathered) tiles straight into a row-major `out` buffer — the streaming
/// twin of [`dense_cost_indexed_into`] for base-case blocks whose points
/// were fetched from a [`DatasetSource`] into arena scratch.
pub fn dense_cost_into<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
    kind: CostKind,
    out: &mut [f32],
) {
    let (x, y) = (x.into(), y.into());
    assert_eq!(out.len(), x.rows * y.rows, "cost buffer shape mismatch");
    for i in 0..x.rows {
        let xi = x.row(i);
        let crow = &mut out[i * y.rows..(i + 1) * y.rows];
        for (cv, j) in crow.iter_mut().zip(0..y.rows) {
            *cv = kind.pair(xi, y.row(j)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn pair_costs() {
        let x = [0.0f32, 0.0];
        let y = [3.0f32, 4.0];
        assert_eq!(CostKind::Euclidean.pair(&x, &y), 5.0);
        assert_eq!(CostKind::SqEuclidean.pair(&x, &y), 25.0);
    }

    #[test]
    fn dense_cost_matches_pairs() {
        let mut rng = Rng::new(0);
        let x = rand_mat(&mut rng, 5, 3);
        let y = rand_mat(&mut rng, 4, 3);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        for i in 0..5 {
            for j in 0..4 {
                let want = sq_dist(x.row(i), y.row(j)) as f32;
                assert!((c.at(i, j) - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn indexed_cost_matches_gathered_dense_cost() {
        let mut rng = Rng::new(7);
        let x = rand_mat(&mut rng, 9, 3);
        let y = rand_mat(&mut rng, 9, 3);
        let xs = [4u32, 1, 7];
        let ys = [0u32, 8, 3];
        let want = dense_cost(&x.gather_rows(&xs), &y.gather_rows(&ys), CostKind::Euclidean);
        let mut got = vec![0.0f32; 9];
        dense_cost_indexed_into(&x, &y, &xs, &ys, CostKind::Euclidean, &mut got);
        assert_eq!(got, want.data);
    }

    #[test]
    fn dense_cost_on_views_matches_gather() {
        let mut rng = Rng::new(8);
        let x = rand_mat(&mut rng, 10, 2);
        let y = rand_mat(&mut rng, 10, 2);
        let idx: Vec<u32> = (2..6).collect();
        let want = dense_cost(&x.gather_rows(&idx), &y.gather_rows(&idx), CostKind::SqEuclidean);
        let got = dense_cost(x.row_range(2, 6), y.row_range(2, 6), CostKind::SqEuclidean);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn factors_for_source_matches_in_memory_for_both_kinds() {
        use crate::data::stream::InMemorySource;
        let mut rng = Rng::new(11);
        let x = rand_mat(&mut rng, 33, 3);
        let y = rand_mat(&mut rng, 33, 3);
        let arena = ScratchArena::new(4);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        for kind in [CostKind::SqEuclidean, CostKind::Euclidean] {
            let (u, v) = factors_for(&x, &y, kind, 8, 4);
            for chunk in [3usize, 33] {
                for threads in [1usize, 4] {
                    let (uc, vc) =
                        factors_for_source(&xs, &ys, kind, 8, 4, chunk, &arena, threads).unwrap();
                    assert_eq!(u.data, uc.data, "{kind:?} chunk {chunk} threads {threads}");
                    assert_eq!(v.data, vc.data, "{kind:?} chunk {chunk} threads {threads}");
                }
            }
        }
    }

    #[test]
    fn factors_into_spill_store_bit_identical_to_resident() {
        use crate::data::stream::InMemorySource;
        use crate::pool::SpillStore;
        let mut rng = Rng::new(17);
        let x = rand_mat(&mut rng, 41, 3);
        let y = rand_mat(&mut rng, 41, 3);
        let arena = ScratchArena::new(2);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        let dir = std::env::temp_dir().join(format!("hiref_costs_spill_{}", std::process::id()));
        for kind in [CostKind::SqEuclidean, CostKind::Euclidean] {
            let (u, v) = factors_for(&x, &y, kind, 8, 4);
            let su = SpillStore::create(&dir, 41, u.cols, 0).unwrap();
            let sv = SpillStore::create(&dir, 41, v.cols, 0).unwrap();
            factors_for_source_into(&xs, &ys, kind, 8, 4, 7, &arena, 2, &su, &sv).unwrap();
            // the builders wrote tiles straight to disk...
            assert!(su.stats().spill_bytes_written >= 41 * u.cols * 4, "{kind:?}");
            // ...and the stored factors are bit-identical to the in-memory
            // build (the Indyk path reads its regression sample back
            // through the store, so this covers read_rows too)
            let (ud, vd) =
                (Box::new(su).into_mat().unwrap(), Box::new(sv).into_mat().unwrap());
            assert_eq!(u.data, ud.data, "{kind:?} U diverges through the spill store");
            assert_eq!(v.data, vd.data, "{kind:?} V diverges through the spill store");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn low_precision_builders_encode_on_write_and_agree_across_stores() {
        use crate::data::stream::InMemorySource;
        use crate::pool::{Precision, SpillStore};
        let mut rng = Rng::new(19);
        let x = rand_mat(&mut rng, 37, 3);
        let y = rand_mat(&mut rng, 37, 3);
        let arena = ScratchArena::new(2);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        let dir = std::env::temp_dir().join(format!("hiref_costs_lp_{}", std::process::id()));
        for kind in [CostKind::SqEuclidean, CostKind::Euclidean] {
            let k = factor_width(kind, 3, 37, 37, 8);
            let ru = ResidentStore::zeroed_with(37, k, Precision::Bf16);
            let rv = ResidentStore::zeroed_with(37, k, Precision::Bf16);
            factors_for_source_into(&xs, &ys, kind, 8, 4, 7, &arena, 2, &ru, &rv).unwrap();
            let su = SpillStore::create_with(&dir, 37, k, 0, Precision::Bf16).unwrap();
            let sv = SpillStore::create_with(&dir, 37, k, 0, Precision::Bf16).unwrap();
            factors_for_source_into(&xs, &ys, kind, 8, 4, 7, &arena, 2, &su, &sv).unwrap();
            // encode-on-write: every tile went to disk as 2-byte elements,
            // never materialising the factors at f32 width
            let written = su.stats().spill_bytes_written;
            assert!(
                written >= 37 * k * 2 && written < 37 * k * 4,
                "{kind:?}: {written} bytes for {} bf16 elements",
                37 * k
            );
            // resident and spilled stores hold the same encoded bits, so
            // they decode to the same factors (the Indyk path reads its
            // regression sample back through the store — both builds see
            // the same quantised read-back)
            let (ru, rv) =
                (Box::new(ru).into_mat().unwrap(), Box::new(rv).into_mat().unwrap());
            let (su, sv) =
                (Box::new(su).into_mat().unwrap(), Box::new(sv).into_mat().unwrap());
            assert_eq!(ru.data, su.data, "{kind:?} U diverges across store backends");
            assert_eq!(rv.data, sv.data, "{kind:?} V diverges across store backends");
            if kind == CostKind::SqEuclidean {
                // the exact path never reads back mid-build, so its stored
                // factors are exactly the narrowed in-memory factors
                let (u, v) = factors_for(&x, &y, kind, 8, 4);
                let want_u =
                    Box::new(ResidentStore::from_mat_with(u, Precision::Bf16)).into_mat().unwrap();
                let want_v =
                    Box::new(ResidentStore::from_mat_with(v, Precision::Bf16)).into_mat().unwrap();
                assert_eq!(ru.data, want_u.data);
                assert_eq!(rv.data, want_v.data);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn factors_for_source_propagates_read_errors() {
        struct Failing;
        impl crate::data::stream::DatasetSource for Failing {
            fn rows(&self) -> usize {
                16
            }
            fn dim(&self) -> usize {
                2
            }
            fn fill_rows(&self, _start: usize, _out: &mut [f32]) -> std::io::Result<()> {
                Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "gone"))
            }
        }
        let arena = ScratchArena::new(1);
        for kind in [CostKind::SqEuclidean, CostKind::Euclidean] {
            let got = factors_for_source(&Failing, &Failing, kind, 4, 0, 8, &arena, 2);
            assert!(got.is_err(), "{kind:?} must surface the read failure");
        }
    }

    #[test]
    fn dense_cost_into_matches_dense_cost() {
        let mut rng = Rng::new(12);
        let x = rand_mat(&mut rng, 6, 2);
        let y = rand_mat(&mut rng, 5, 2);
        let want = dense_cost(&x, &y, CostKind::SqEuclidean);
        let mut got = vec![0.0f32; 30];
        dense_cost_into(&x, &y, CostKind::SqEuclidean, &mut got);
        assert_eq!(got, want.data);
    }

    #[test]
    fn factors_for_sqeuclid_is_exact() {
        let mut rng = Rng::new(1);
        let x = rand_mat(&mut rng, 8, 2);
        let y = rand_mat(&mut rng, 8, 2);
        let (u, v) = factors_for(&x, &y, CostKind::SqEuclidean, 16, 0);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let approx = u.matmul(&v.t());
        for (a, b) in approx.data.iter().zip(&c.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}
