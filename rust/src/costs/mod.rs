//! Cost functions and linear-space cost-matrix factorisations.
//!
//! HiRef never materialises an `n×n` cost matrix.  LROT sub-problems
//! consume a low-rank factorisation `C ≈ U Vᵀ`:
//!
//! * squared Euclidean — the **exact** rank-`d+2` factorisation of
//!   Scetbon et al. 2021 ([`factor::sq_euclidean_factors`]);
//! * any metric cost — the sample-linear randomized factorisation in the
//!   spirit of Indyk et al. 2019 ([`indyk::factorize`]).
//!
//! Dense costs ([`dense_cost`]) exist only for baselines (Sinkhorn,
//! Hungarian) and small base-case blocks.

pub mod factor;
pub mod indyk;

use crate::linalg::{dist, sq_dist, Mat};

/// Ground cost selector. Matches the paper's two evaluation costs:
/// `‖·‖₂` (Wasserstein-1 ground cost) and `‖·‖₂²` (Wasserstein-2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostKind {
    Euclidean,
    SqEuclidean,
}

impl CostKind {
    /// Cost of a single pair.
    #[inline]
    pub fn pair(&self, x: &[f32], y: &[f32]) -> f64 {
        match self {
            CostKind::Euclidean => dist(x, y),
            CostKind::SqEuclidean => sq_dist(x, y),
        }
    }

    /// Human-readable label used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            CostKind::Euclidean => "‖·‖₂",
            CostKind::SqEuclidean => "‖·‖₂²",
        }
    }
}

/// Dense `n×m` cost matrix (baselines and small blocks only).
pub fn dense_cost(x: &Mat, y: &Mat, kind: CostKind) -> Mat {
    let mut c = Mat::zeros(x.rows, y.rows);
    for i in 0..x.rows {
        let xi = x.row(i);
        let crow = c.row_mut(i);
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = kind.pair(xi, y.row(j)) as f32;
        }
    }
    c
}

/// Low-rank factors `(U, V)` with `C ≈ U Vᵀ`, choosing the best strategy
/// for `kind`: exact `d+2` for squared Euclidean, Indyk-style sampling
/// otherwise.  `target_k` bounds the factor width for the sampled path
/// (ignored by the exact path, whose width is `d+2`).
pub fn factors_for(
    x: &Mat,
    y: &Mat,
    kind: CostKind,
    target_k: usize,
    seed: u64,
) -> (Mat, Mat) {
    match kind {
        CostKind::SqEuclidean => factor::sq_euclidean_factors(x, y),
        CostKind::Euclidean => indyk::factorize(x, y, kind, target_k, seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        rng.fill_normal(&mut m.data);
        m
    }

    #[test]
    fn pair_costs() {
        let x = [0.0f32, 0.0];
        let y = [3.0f32, 4.0];
        assert_eq!(CostKind::Euclidean.pair(&x, &y), 5.0);
        assert_eq!(CostKind::SqEuclidean.pair(&x, &y), 25.0);
    }

    #[test]
    fn dense_cost_matches_pairs() {
        let mut rng = Rng::new(0);
        let x = rand_mat(&mut rng, 5, 3);
        let y = rand_mat(&mut rng, 4, 3);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        for i in 0..5 {
            for j in 0..4 {
                let want = sq_dist(x.row(i), y.row(j)) as f32;
                assert!((c.at(i, j) - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn factors_for_sqeuclid_is_exact() {
        let mut rng = Rng::new(1);
        let x = rand_mat(&mut rng, 8, 2);
        let y = rand_mat(&mut rng, 8, 2);
        let (u, v) = factors_for(&x, &y, CostKind::SqEuclidean, 16, 0);
        let c = dense_cost(&x, &y, CostKind::SqEuclidean);
        let approx = u.matmul(&v.t());
        for (a, b) in approx.data.iter().zip(&c.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
}
