//! Exact rank-(d+2) factorisation of the squared-Euclidean cost matrix
//! (Scetbon et al. 2021): `C_ij = |x_i|² − 2 x_i·y_j + |y_j|²  = (U Vᵀ)_ij`
//! with `U = [|x|², 1, −2x]` and `V = [1, |y|², y]`.
//!
//! This is the Rust twin of `python/compile/kernels/ref.sqeuclid_factors_ref`
//! — both sides must produce identical factors because the Rust coordinator
//! feeds them to AOT executables lowered from the Python model.

use crate::linalg::{Mat, MatView};

/// Return `(U, V)`, each `n×(d+2)`, with `U Vᵀ` the exact squared-Euclidean
/// cost matrix between the rows of `x` and `y`.  Accepts [`MatView`]s so
/// factors can be built from borrowed row ranges without gathering.
pub fn sq_euclidean_factors<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
) -> (Mat, Mat) {
    let (x, y) = (x.into(), y.into());
    assert_eq!(x.cols, y.cols, "dimension mismatch");
    let d = x.cols;
    let mut u = Mat::zeros(x.rows, d + 2);
    let mut v = Mat::zeros(y.rows, d + 2);
    for i in 0..x.rows {
        let xi = x.row(i);
        let n2: f64 = xi.iter().map(|&a| (a as f64) * (a as f64)).sum();
        let urow = u.row_mut(i);
        urow[0] = n2 as f32;
        urow[1] = 1.0;
        for (k, &a) in xi.iter().enumerate() {
            urow[2 + k] = -2.0 * a;
        }
    }
    for j in 0..y.rows {
        let yj = y.row(j);
        let n2: f64 = yj.iter().map(|&a| (a as f64) * (a as f64)).sum();
        let vrow = v.row_mut(j);
        vrow[0] = 1.0;
        vrow[1] = n2 as f32;
        vrow[2..2 + d].copy_from_slice(yj);
    }
    (u, v)
}

/// Zero-pad factor width from `k` to `k_target` columns (exact: padded
/// columns contribute 0 to every inner product).  Used to fit a factor
/// pair into a wider AOT bucket.
pub fn pad_factor_width<'a>(m: impl Into<MatView<'a>>, k_target: usize) -> Mat {
    let m = m.into();
    assert!(k_target >= m.cols);
    if k_target == m.cols {
        return m.to_mat();
    }
    let mut out = Mat::zeros(m.rows, k_target);
    for i in 0..m.rows {
        out.row_mut(i)[..m.cols].copy_from_slice(m.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::{dense_cost, CostKind};
    use crate::prng::Rng;

    #[test]
    fn factorisation_is_exact() {
        let mut rng = Rng::new(0);
        for &(n, d) in &[(4usize, 1usize), (16, 2), (9, 5), (32, 16)] {
            let mut x = Mat::zeros(n, d);
            let mut y = Mat::zeros(n, d);
            rng.fill_normal(&mut x.data);
            rng.fill_normal(&mut y.data);
            let (u, v) = sq_euclidean_factors(&x, &y);
            assert_eq!(u.cols, d + 2);
            let c = dense_cost(&x, &y, CostKind::SqEuclidean);
            let lr = u.matmul(&v.t());
            for (a, b) in lr.data.iter().zip(&c.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn pad_width_preserves_products() {
        let mut rng = Rng::new(1);
        let mut x = Mat::zeros(8, 2);
        let mut y = Mat::zeros(8, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let (up, vp) = (pad_factor_width(&u, 64), pad_factor_width(&v, 64));
        let a = u.matmul(&v.t());
        let b = up.matmul(&vp.t());
        for (p, q) in a.data.iter().zip(&b.data) {
            assert!((p - q).abs() < 1e-6);
        }
    }
}
