//! Exact rank-(d+2) factorisation of the squared-Euclidean cost matrix
//! (Scetbon et al. 2021): `C_ij = |x_i|² − 2 x_i·y_j + |y_j|²  = (U Vᵀ)_ij`
//! with `U = [|x|², 1, −2x]` and `V = [1, |y|², y]`.
//!
//! This is the Rust twin of `python/compile/kernels/ref.sqeuclid_factors_ref`
//! — both sides must produce identical factors because the Rust coordinator
//! feeds them to AOT executables lowered from the Python model.

use std::io;

use crate::costs::ErrOnce;
use crate::data::stream::{for_each_chunk_parallel, DatasetSource};
use crate::linalg::{Mat, MatView};
use crate::pool::{FactorStore, ResidentStore, ScratchArena};

/// Write the U-side factor row (`[|x|², 1, −2x]`) for point `xi`.
#[inline]
fn u_row(xi: &[f32], urow: &mut [f32]) {
    let n2: f64 = xi.iter().map(|&a| (a as f64) * (a as f64)).sum();
    urow[0] = n2 as f32;
    urow[1] = 1.0;
    for (o, &a) in urow[2..].iter_mut().zip(xi) {
        *o = -2.0 * a;
    }
}

/// Write the V-side factor row (`[1, |y|², y]`) for point `yj`.
#[inline]
fn v_row(yj: &[f32], vrow: &mut [f32]) {
    let n2: f64 = yj.iter().map(|&a| (a as f64) * (a as f64)).sum();
    vrow[0] = 1.0;
    vrow[1] = n2 as f32;
    vrow[2..].copy_from_slice(yj);
}

/// Return `(U, V)`, each `n×(d+2)`, with `U Vᵀ` the exact squared-Euclidean
/// cost matrix between the rows of `x` and `y`.  Accepts [`MatView`]s so
/// factors can be built from borrowed row ranges without gathering.
pub fn sq_euclidean_factors<'a, 'b>(
    x: impl Into<MatView<'a>>,
    y: impl Into<MatView<'b>>,
) -> (Mat, Mat) {
    let (x, y) = (x.into(), y.into());
    assert_eq!(x.cols, y.cols, "dimension mismatch");
    let d = x.cols;
    let mut u = Mat::zeros(x.rows, d + 2);
    let mut v = Mat::zeros(y.rows, d + 2);
    for i in 0..x.rows {
        u_row(x.row(i), u.row_mut(i));
    }
    for j in 0..y.rows {
        v_row(y.row(j), v.row_mut(j));
    }
    (u, v)
}

/// Chunked twin of [`sq_euclidean_factors`]: build the exact `d+2` factors
/// from [`DatasetSource`]s in `chunk_rows`-sized tiles, swept by up to
/// `threads` workers, writing each factor tile **straight into the
/// [`FactorStore`] pair** — no full-matrix intermediate, so a
/// [`crate::pool::SpillStore`] bounds factor memory during the build too.
/// The factorisation is row-separable — every tile writes a disjoint row
/// window of the store — so the result is **bit-identical** to the
/// in-memory path for any chunk size *and any thread count*; peak memory
/// is one `chunk_rows×d` point tile plus one `chunk_rows×(d+2)` factor
/// tile per worker (arena scratch).  Mid-sweep read failures and store
/// I/O failures surface as the `io::Error` instead of panicking.
pub fn sq_euclidean_factors_chunked_into(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
    us: &dyn FactorStore,
    vs: &dyn FactorStore,
) -> io::Result<()> {
    let d = x.dim();
    assert_eq!(d, y.dim(), "dimension mismatch");
    let k = d + 2;
    assert_eq!((us.rows(), us.cols()), (x.rows(), k), "U store shape mismatch");
    assert_eq!((vs.rows(), vs.cols()), (y.rows(), k), "V store shape mismatch");
    let sink = ErrOnce::new();
    for_each_chunk_parallel(x, chunk_rows, arena, threads, |start, tile| {
        // SAFETY: tile [start, start+rows) windows are pairwise disjoint
        // across workers (tiles partition the row space).
        let res = unsafe {
            us.fill_rows_with(start, tile.rows, arena, &mut |out| {
                for (i, orow) in out.chunks_mut(k).enumerate() {
                    u_row(tile.row(i), orow);
                }
            })
        };
        if let Err(e) = res {
            sink.set(e);
        }
    })?;
    sink.take()?;
    let sink = ErrOnce::new();
    for_each_chunk_parallel(y, chunk_rows, arena, threads, |start, tile| {
        // SAFETY: as above.
        let res = unsafe {
            vs.fill_rows_with(start, tile.rows, arena, &mut |out| {
                for (j, orow) in out.chunks_mut(k).enumerate() {
                    v_row(tile.row(j), orow);
                }
            })
        };
        if let Err(e) = res {
            sink.set(e);
        }
    })?;
    sink.take()
}

/// [`sq_euclidean_factors_chunked_into`] materialised to owned matrices
/// (resident stores underneath).
pub fn sq_euclidean_factors_chunked(
    x: &dyn DatasetSource,
    y: &dyn DatasetSource,
    chunk_rows: usize,
    arena: &ScratchArena,
    threads: usize,
) -> io::Result<(Mat, Mat)> {
    let k = x.dim() + 2;
    let us = ResidentStore::zeroed(x.rows(), k);
    let vs = ResidentStore::zeroed(y.rows(), k);
    sq_euclidean_factors_chunked_into(x, y, chunk_rows, arena, threads, &us, &vs)?;
    Ok((Box::new(us).into_mat()?, Box::new(vs).into_mat()?))
}

/// Zero-pad factor width from `k` to `k_target` columns (exact: padded
/// columns contribute 0 to every inner product).  Used to fit a factor
/// pair into a wider AOT bucket.
pub fn pad_factor_width<'a>(m: impl Into<MatView<'a>>, k_target: usize) -> Mat {
    let m = m.into();
    assert!(k_target >= m.cols);
    if k_target == m.cols {
        return m.to_mat();
    }
    let mut out = Mat::zeros(m.rows, k_target);
    for i in 0..m.rows {
        out.row_mut(i)[..m.cols].copy_from_slice(m.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costs::{dense_cost, CostKind};
    use crate::prng::Rng;

    #[test]
    fn factorisation_is_exact() {
        let mut rng = Rng::new(0);
        for &(n, d) in &[(4usize, 1usize), (16, 2), (9, 5), (32, 16)] {
            let mut x = Mat::zeros(n, d);
            let mut y = Mat::zeros(n, d);
            rng.fill_normal(&mut x.data);
            rng.fill_normal(&mut y.data);
            let (u, v) = sq_euclidean_factors(&x, &y);
            assert_eq!(u.cols, d + 2);
            let c = dense_cost(&x, &y, CostKind::SqEuclidean);
            let lr = u.matmul(&v.t());
            for (a, b) in lr.data.iter().zip(&c.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn chunked_factors_identical_to_in_memory_for_any_chunk_size() {
        use crate::data::stream::InMemorySource;
        let mut rng = Rng::new(5);
        let mut x = Mat::zeros(53, 3);
        let mut y = Mat::zeros(53, 3);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let arena = ScratchArena::new(4);
        let (xs, ys) = (InMemorySource::new(&x), InMemorySource::new(&y));
        for chunk in [1usize, 7, 53, 4096] {
            // parallel tile sweeps are bit-identical for every thread count
            for threads in [1usize, 4] {
                let (uc, vc) =
                    sq_euclidean_factors_chunked(&xs, &ys, chunk, &arena, threads).unwrap();
                assert_eq!(u.data, uc.data, "U diverges at chunk {chunk} threads {threads}");
                assert_eq!(v.data, vc.data, "V diverges at chunk {chunk} threads {threads}");
            }
        }
    }

    #[test]
    fn pad_width_preserves_products() {
        let mut rng = Rng::new(1);
        let mut x = Mat::zeros(8, 2);
        let mut y = Mat::zeros(8, 2);
        rng.fill_normal(&mut x.data);
        rng.fill_normal(&mut y.data);
        let (u, v) = sq_euclidean_factors(&x, &y);
        let (up, vp) = (pad_factor_width(&u, 64), pad_factor_width(&v, 64));
        let a = u.matmul(&v.t());
        let b = up.matmul(&vp.t());
        for (p, q) in a.data.iter().zip(&b.data) {
            assert!((p - q).abs() < 1e-6);
        }
    }
}
