//! End-to-end tests of `hiref serve`: concurrent clients over real TCP,
//! warm-session behaviour, typed failure replies, and the bit-identity
//! guarantee — every served permutation must equal a solo offline
//! `HiRef::align` on the same data and config.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use hiref::coordinator::hiref::{BackendKind, HiRef, HiRefConfig};
use hiref::data::stream::write_bin;
use hiref::data::synthetic;
use hiref::linalg::Mat;
use hiref::serve::{protocol, serve, Json, ServeConfig, ServerHandle};

fn native_cfg() -> HiRefConfig {
    HiRefConfig {
        backend: BackendKind::Native,
        base_size: 32,
        max_rank: 4,
        threads: 2,
        ..HiRefConfig::default()
    }
}

fn serve_cfg(solver: HiRefConfig, workers: usize, queue_depth: usize) -> ServeConfig {
    ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        solver,
        workers,
        queue_depth,
        session_budget: 1 << 30,
        session_spill_dir: None,
        micro_window: Duration::from_millis(20),
    }
}

/// A blocking NDJSON client on one TCP connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(handle: &ServerHandle) -> Client {
        let stream = TcpStream::connect(handle.addr()).expect("connect to serve");
        Client { reader: BufReader::new(stream.try_clone().expect("clone stream")), writer: stream }
    }

    fn call(&mut self, req: &Json) -> Json {
        self.call_raw(&req.render())
    }

    fn call_raw(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).expect("send request");
        self.writer.write_all(b"\n").expect("send newline");
        self.writer.flush().expect("flush request");
        let mut reply = String::new();
        self.reader.read_line(&mut reply).expect("read reply");
        protocol::parse(&reply).expect("parse reply")
    }
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn rows_json(m: &Mat) -> Json {
    Json::Arr(
        (0..m.rows)
            .map(|i| {
                Json::Arr(
                    m.data[i * m.cols..(i + 1) * m.cols]
                        .iter()
                        .map(|&v| Json::Num(f64::from(v)))
                        .collect(),
                )
            })
            .collect(),
    )
}

fn register_inline(c: &mut Client, id: u64, m: &Mat) -> (String, bool) {
    let reply =
        c.call(&obj(vec![("id", Json::Num(id as f64)), ("verb", Json::Str("register".into())), ("rows", rows_json(m))]));
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{}", reply.render());
    assert_eq!(reply.u64_field("id"), Some(id), "id echoes back");
    let new = reply.get("new") == Some(&Json::Bool(true));
    (reply.str_field("dataset").expect("dataset id").to_string(), new)
}

fn solve_req(x: &str, y: &str, deadline_ms: Option<u64>) -> Json {
    let mut fields = vec![
        ("id", Json::Num(7.0)),
        ("verb", Json::Str("solve".into())),
        ("x", Json::Str(x.to_string())),
        ("y", Json::Str(y.to_string())),
    ];
    if let Some(ms) = deadline_ms {
        fields.push(("deadline_ms", Json::Num(ms as f64)));
    }
    obj(fields)
}

fn perm_of(reply: &Json) -> Vec<u32> {
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{}", reply.render());
    reply
        .get("perm")
        .and_then(Json::as_arr)
        .expect("perm array")
        .iter()
        .map(|v| v.as_f64().expect("perm entry") as u32)
        .collect()
}

fn error_kind_of(reply: &Json) -> String {
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{}", reply.render());
    reply.get("error").and_then(|e| e.str_field("kind")).expect("error kind").to_string()
}

fn stats_of(c: &mut Client) -> Json {
    let reply = c.call(&obj(vec![("verb", Json::Str("stats".into()))]));
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
    reply.get("stats").expect("stats object").clone()
}

fn stat(stats: &Json, key: &str) -> u64 {
    stats.u64_field(key).unwrap_or_else(|| panic!("stat {key} in {}", stats.render()))
}

#[test]
fn concurrent_clients_get_bit_identical_warm_solves() {
    let (x, y) = synthetic::half_moon_s_curve(256, 0);
    let want = HiRef::new(native_cfg()).align(&x, &y).expect("offline align").perm;

    let handle = serve(serve_cfg(native_cfg(), 2, 16)).expect("start server");
    let mut c = Client::connect(&handle);
    let (xid, xnew) = register_inline(&mut c, 1, &x);
    assert!(xnew);
    // the y side goes in as a server-side .bin file
    let ypath = std::env::temp_dir().join(format!("hiref_serve_y_{}.bin", std::process::id()));
    write_bin(&ypath, &y).expect("write y.bin");
    let reply = c.call(&obj(vec![
        ("id", Json::Num(2.0)),
        ("verb", Json::Str("register".into())),
        ("path", Json::Str(ypath.to_string_lossy().into_owned())),
        ("dim", Json::Num(y.cols as f64)),
    ]));
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{}", reply.render());
    let yid = reply.str_field("dataset").expect("y dataset id").to_string();
    assert_ne!(xid, yid);
    // re-registering identical content dedupes to the same id
    let (xid2, xnew2) = register_inline(&mut c, 3, &x);
    assert_eq!(xid, xid2);
    assert!(!xnew2);

    // four concurrent clients solving the same pair: exactly one cold
    // factorisation, everyone bit-identical to the offline solve
    let warm_count = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..4 {
            let (xid, yid) = (xid.clone(), yid.clone());
            let (handle, want, warm_count) = (&handle, &want, Arc::clone(&warm_count));
            s.spawn(move || {
                let mut c = Client::connect(handle);
                let reply = c.call(&solve_req(&xid, &yid, None));
                assert_eq!(&perm_of(&reply), want, "served perm drifted from offline align");
                if reply.get("warm") == Some(&Json::Bool(true)) {
                    warm_count.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(warm_count.load(Ordering::Relaxed), 3, "one cold build, three warm hits");

    let stats = stats_of(&mut c);
    assert_eq!(stat(&stats, "factor_builds"), 1, "warm solves must skip factorisation");
    assert_eq!(stat(&stats, "session_misses"), 1);
    assert_eq!(stat(&stats, "session_hits"), 3);
    assert_eq!(stat(&stats, "solves_ok"), 4);
    assert_eq!(stat(&stats, "session_pinned_bytes"), 0, "no leaked checkouts");
    assert_eq!(stat(&stats, "datasets"), 2);
    assert!(stat(&stats, "micro_calls") > 0, "batched dispatch went through the microbatcher");

    let reply = c.call(&obj(vec![("verb", Json::Str("shutdown".into()))]));
    assert_eq!(reply.get("stopped"), Some(&Json::Bool(true)));
    handle.join();
    let _ = std::fs::remove_file(&ypath);
}

#[test]
fn deadline_exceeded_is_a_typed_timeout_and_leaks_nothing() {
    let (x, y) = synthetic::half_moon_s_curve(128, 1);
    let want = HiRef::new(native_cfg()).align(&x, &y).expect("offline align").perm;

    let handle = serve(serve_cfg(native_cfg(), 1, 8)).expect("start server");
    let mut c = Client::connect(&handle);
    let (xid, _) = register_inline(&mut c, 1, &x);
    let (yid, _) = register_inline(&mut c, 2, &y);

    // a zero deadline has always expired by the time the job starts
    let reply = c.call(&solve_req(&xid, &yid, Some(0)));
    assert_eq!(error_kind_of(&reply), "timeout");
    let stats = stats_of(&mut c);
    assert_eq!(stat(&stats, "timeouts"), 1);
    assert_eq!(stat(&stats, "session_pinned_bytes"), 0, "timeout released every checkout");

    // the session recovers: the next solve succeeds and stays bit-identical
    let reply = c.call(&solve_req(&xid, &yid, None));
    assert_eq!(perm_of(&reply), want);
    let stats = stats_of(&mut c);
    assert_eq!(stat(&stats, "solves_ok"), 1);
    assert_eq!(stat(&stats, "session_pinned_bytes"), 0);
    handle.join();
}

#[test]
fn overload_is_typed_and_successes_stay_bit_identical() {
    let (x, y) = synthetic::half_moon_s_curve(2048, 2);
    let want = HiRef::new(native_cfg()).align(&x, &y).expect("offline align").perm;

    // one worker, one queue slot: a burst of 8 must overflow admission
    let handle = serve(serve_cfg(native_cfg(), 1, 1)).expect("start server");
    let mut c = Client::connect(&handle);
    let (xid, _) = register_inline(&mut c, 1, &x);
    let (yid, _) = register_inline(&mut c, 2, &y);

    let ok = AtomicUsize::new(0);
    let overloaded = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let (xid, yid) = (xid.clone(), yid.clone());
            let (handle, want, ok, overloaded) = (&handle, &want, &ok, &overloaded);
            s.spawn(move || {
                let mut c = Client::connect(handle);
                let reply = c.call(&solve_req(&xid, &yid, None));
                if reply.get("ok") == Some(&Json::Bool(true)) {
                    assert_eq!(&perm_of(&reply), want, "overload must not corrupt results");
                    ok.fetch_add(1, Ordering::Relaxed);
                } else {
                    assert_eq!(error_kind_of(&reply), "overloaded");
                    overloaded.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(ok.load(Ordering::Relaxed) + overloaded.load(Ordering::Relaxed), 8);
    assert!(ok.load(Ordering::Relaxed) >= 1, "some solve must get through");
    assert!(
        overloaded.load(Ordering::Relaxed) >= 1,
        "an 8-burst into a 1-worker/1-slot server must shed load"
    );
    let stats = stats_of(&mut c);
    assert_eq!(stat(&stats, "overloaded"), overloaded.load(Ordering::Relaxed) as u64);
    assert_eq!(stat(&stats, "factor_builds"), 1, "rejections never factorise");
    handle.join();
}

#[test]
fn protocol_failures_are_typed() {
    let (x, _) = synthetic::half_moon_s_curve(8, 3);
    let (big, _) = synthetic::half_moon_s_curve(12, 3);
    let handle = serve(serve_cfg(native_cfg(), 1, 4)).expect("start server");
    let mut c = Client::connect(&handle);

    assert_eq!(error_kind_of(&c.call_raw("this is not json")), "bad_request");
    assert_eq!(error_kind_of(&c.call(&obj(vec![("no_verb", Json::Bool(true))]))), "bad_request");
    assert_eq!(
        error_kind_of(&c.call(&obj(vec![("verb", Json::Str("frobnicate".into()))]))),
        "unknown_verb"
    );
    assert_eq!(
        error_kind_of(&c.call(&solve_req("0000000000000000", "0000000000000000", None))),
        "unknown_dataset"
    );
    let bad_rows = c.call(&obj(vec![
        ("verb", Json::Str("register".into())),
        ("rows", Json::Arr(vec![Json::Num(1.0)])),
    ]));
    assert_eq!(error_kind_of(&bad_rows), "bad_request");

    // typed solver errors pass through: 8 vs 12 points is a shape mismatch
    let (xid, _) = register_inline(&mut c, 1, &x);
    let (bid, _) = register_inline(&mut c, 2, &big);
    let reply = c.call(&solve_req(&xid, &bid, None));
    assert_eq!(error_kind_of(&reply), "shape_mismatch");
    let stats = stats_of(&mut c);
    assert_eq!(stat(&stats, "solve_errors"), 1);
    assert_eq!(stat(&stats, "factor_builds"), 0, "shape mismatch fails before factorising");

    // ping still answers on the same connection
    let pong = c.call(&obj(vec![("id", Json::Num(9.0)), ("verb", Json::Str("ping".into()))]));
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
    assert_eq!(pong.u64_field("id"), Some(9));
    handle.join();
}
