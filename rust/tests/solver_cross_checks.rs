//! Cross-solver consistency checks: every solver pair that should agree
//! (or should be ordered) on small instances, checked on real generators —
//! plus the registry cross-check: every solver reachable by name through
//! the uniform `TransportSolver` interface, with `Coupling::cost` agreeing
//! with the legacy per-representation cost paths.

use hiref::api::{Coupling, SolverRegistry, TransportProblem, TransportSolver, SOLVER_NAMES};
use hiref::coordinator::hiref::{BackendKind, HiRef, HiRefConfig};
use hiref::costs::{self, dense_cost, CostKind};
use hiref::data::synthetic::{self, Synthetic};
use hiref::linalg::Mat;
use hiref::metrics;
use hiref::solvers::{exact, lrot, minibatch, mop, progot, sinkhorn};

fn native() -> HiRefConfig {
    HiRefConfig { backend: BackendKind::Native, base_size: 64, ..Default::default() }
}

/// Optimal assignment cost from the Hungarian oracle.
fn exact_cost(x: &Mat, y: &Mat, kind: CostKind) -> f64 {
    let c = dense_cost(x, y, kind);
    let h = exact::hungarian(&c);
    metrics::bijection_cost(x, y, &h, kind)
}

#[test]
fn solver_ordering_on_all_synthetic_datasets() {
    // On every synthetic suite: exact ≤ HiRef ≤ MOP (Table S4 ordering),
    // and Sinkhorn's entropic cost sits at or above exact.
    for ds in Synthetic::ALL {
        let (x, y) = ds.generate(256, 11);
        let kind = CostKind::SqEuclidean;
        let opt = exact_cost(&x, &y, kind);

        let hiref_out = HiRef::new(native()).align(&x, &y).unwrap();
        let hiref_cost = hiref_out.cost(&x, &y, kind);

        let mop_perm = mop::solve(&x, &y, kind);
        let mop_cost = metrics::bijection_cost(&x, &y, &mop_perm, kind);

        assert!(hiref_cost >= opt - 1e-9, "{}", ds.label());
        assert!(
            hiref_cost <= opt * 1.35 + 0.02,
            "{}: hiref {hiref_cost} vs opt {opt}",
            ds.label()
        );
        assert!(
            mop_cost >= hiref_cost * 0.95,
            "{}: MOP {mop_cost} beat HiRef {hiref_cost}",
            ds.label()
        );
    }
}

/// The acceptance check for the unified API: every registered solver runs
/// on a small `half_moon_s_curve` instance through the uniform interface,
/// and the uniform `Coupling::cost` agrees with the legacy cost path of
/// that solver's native representation to ≤ 1e-6 relative error.
#[test]
fn solver_registry_uniform_interface_cross_check() {
    let n = 128;
    let (x, y) = synthetic::half_moon_s_curve(n, 17);
    let kind = CostKind::SqEuclidean;
    let prob = TransportProblem::new(&x, &y, kind).with_seed(5);
    let reg = SolverRegistry::with_defaults();

    // the registry covers HiRef plus every module in rust/src/solvers/
    let names = reg.names();
    for want in SOLVER_NAMES {
        assert!(names.contains(&want), "registry missing {want}");
    }

    for name in &names {
        let solver = reg.get(name).unwrap();
        let solved = solver.solve(&prob).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(solved.stats.solver, *name);

        let got = metrics::coupling_cost(&x, &y, &solved.coupling, kind);
        let want = match &solved.coupling {
            Coupling::Bijection(perm) => metrics::bijection_cost(&x, &y, perm, kind),
            Coupling::Dense(p) => metrics::dense_cost_of(&dense_cost(&x, &y, kind), p),
            Coupling::LowRank { q, r, .. } => {
                // legacy path: factored cost with the uniform inner marginal
                let (u, v) = costs::factors_for(&x, &y, kind, 32, prob.seed);
                lrot::lowrank_cost(&u, &v, q, r)
            }
            Coupling::Sparse(sc) => {
                // legacy path: mop::solve_sparse's own cost accumulator
                let (sc2, legacy_cost) = mop::solve_sparse(&x, &y, kind);
                assert_eq!(sc, &sc2, "{name}: sparse plan not reproducible");
                legacy_cost
            }
        };
        let rel = (got - want).abs() / want.abs().max(1e-12);
        assert!(rel <= 1e-6, "{name}: uniform cost {got} vs legacy {want} (rel {rel:.2e})");

        // uniform structural invariants
        assert!(got.is_finite() && got >= 0.0, "{name}: cost {got}");
        assert!(
            solved.coupling.marginal_error() < 0.05,
            "{name}: marginal error {}",
            solved.coupling.marginal_error()
        );
        assert_eq!(solved.coupling.shape(), (n, n), "{name}");
        let perm = solved.coupling.to_bijection().unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut seen = vec![false; n];
        for &j in &perm {
            assert!(
                !std::mem::replace(&mut seen[j as usize], true),
                "{name}: rounded map is not a bijection"
            );
        }
    }
}

#[test]
fn registry_solvers_respect_precomputed_cost() {
    // dense solvers accept a shared precomputed cost matrix and agree with
    // the from-points path bitwise (same matrix, same sweep sequence)
    let (x, y) = synthetic::half_moon_s_curve(64, 3);
    let kind = CostKind::SqEuclidean;
    let c = dense_cost(&x, &y, kind);
    let reg = SolverRegistry::with_defaults();
    for name in ["sinkhorn", "exact"] {
        let solver = reg.get(name).unwrap();
        let from_points = solver
            .solve(&TransportProblem::new(&x, &y, kind))
            .unwrap();
        let from_cost = solver
            .solve(&TransportProblem::new(&x, &y, kind).with_cost(&c))
            .unwrap();
        let a = metrics::coupling_cost(&x, &y, &from_points.coupling, kind);
        let b = metrics::coupling_cost(&x, &y, &from_cost.coupling, kind);
        assert_eq!(a, b, "{name}: precomputed cost changed the result");
    }
}

#[test]
fn sinkhorn_cost_at_least_exact() {
    let (x, y) = Synthetic::Checkerboard.generate(128, 3);
    let kind = CostKind::SqEuclidean;
    let c = dense_cost(&x, &y, kind);
    let sk = sinkhorn::solve(&c, &Default::default());
    let sk_cost = metrics::dense_cost_of(&c, &sk.coupling);
    let opt = exact_cost(&x, &y, kind);
    assert!(sk_cost >= opt - 1e-6, "sinkhorn {sk_cost} below exact {opt}");
}

#[test]
fn minibatch_bias_decreases_with_batch_size() {
    let (x, y) = Synthetic::HalfMoonSCurve.generate(512, 5);
    let kind = CostKind::SqEuclidean;
    let mut last = f64::INFINITY;
    let mut costs = Vec::new();
    for b in [32usize, 128, 512] {
        let perm = minibatch::solve(&x, &y, kind, &minibatch::MiniBatchConfig {
            batch: b,
            seed: 9,
            ..Default::default()
        });
        let cost = metrics::bijection_cost(&x, &y, &perm, kind);
        costs.push(cost);
        last = cost;
    }
    assert!(
        last <= costs[0] + 1e-9,
        "full batch {last} not better than B=32 {}",
        costs[0]
    );
}

#[test]
fn hiref_beats_minibatch_on_structured_data() {
    // The paper's headline comparison (Tables 1, 2): HiRef ≤ small-batch MB.
    let (x, y) = Synthetic::HalfMoonSCurve.generate(512, 6);
    let kind = CostKind::SqEuclidean;
    let hiref_cost = HiRef::new(native()).align(&x, &y).unwrap().cost(&x, &y, kind);
    let mb_perm = minibatch::solve(&x, &y, kind, &minibatch::MiniBatchConfig {
        batch: 32,
        seed: 3,
        ..Default::default()
    });
    let mb_cost = metrics::bijection_cost(&x, &y, &mb_perm, kind);
    assert!(
        hiref_cost <= mb_cost,
        "hiref {hiref_cost} vs mini-batch(32) {mb_cost}"
    );
}

#[test]
fn progot_and_sinkhorn_close_on_synthetic() {
    let (x, y) = Synthetic::MafMoonsRings.generate(128, 7);
    let kind = CostKind::SqEuclidean;
    let c = dense_cost(&x, &y, kind);
    let sk = metrics::dense_cost_of(&c, &sinkhorn::solve(&c, &Default::default()).coupling);
    let pg = metrics::dense_cost_of(&c, &progot::solve(&x, &y, kind, &Default::default()));
    let rel = (sk - pg).abs() / sk.max(1e-9);
    assert!(rel < 0.25, "sinkhorn {sk} vs progot {pg}");
}

#[test]
fn hiref_nonzeros_are_n_sinkhorn_quadratic() {
    // Table S3's structural claim.
    let n = 128;
    let (x, y) = Synthetic::Checkerboard.generate(n, 8);
    let kind = CostKind::SqEuclidean;
    let out = HiRef::new(native()).align(&x, &y).unwrap();
    assert!(out.is_bijection()); // exactly n nonzeros by construction
    let c = dense_cost(&x, &y, kind);
    let sk = sinkhorn::solve(&c, &Default::default());
    let nnz = metrics::nonzeros(&sk.coupling, 1e-8);
    assert!(nnz > n * n / 4, "sinkhorn unexpectedly sparse: {nnz}");
}

#[test]
fn expression_transfer_pipeline_end_to_end() {
    // Miniature Table S7: HiRef transfer beats low-rank-style coarse
    // transfer on the simulated MERFISH pair.
    use hiref::data::transcriptomics::{bin_average, merfish_pair, GENE_LABELS};
    let (src, tgt) = merfish_pair(600, 4);
    let out = HiRef::new(native()).align(&src.spatial, &tgt.spatial).unwrap();
    for gi in 0..GENE_LABELS.len() {
        let v1: Vec<f32> = (0..600).map(|i| src.genes.at(i, gi)).collect();
        let v2: Vec<f32> = (0..600).map(|i| tgt.genes.at(i, gi)).collect();
        // transfer through the bijection
        let mut vhat = vec![0.0f32; 600];
        for (i, &j) in out.perm.iter().enumerate() {
            vhat[j as usize] = v1[i];
        }
        let b_hat = bin_average(&tgt.spatial, &vhat, 10);
        let b_tgt = bin_average(&tgt.spatial, &v2, 10);
        let cos = metrics::cosine(&b_hat, &b_tgt);
        assert!(cos > 0.5, "gene {gi} transfer cosine {cos}");
    }
}
