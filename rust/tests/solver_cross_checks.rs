//! Cross-solver consistency checks: every solver pair that should agree
//! (or should be ordered) on small instances, checked on real generators.

use hiref::coordinator::hiref::{BackendKind, HiRef, HiRefConfig};
use hiref::costs::{dense_cost, CostKind};
use hiref::data::synthetic::Synthetic;
use hiref::linalg::Mat;
use hiref::metrics;
use hiref::solvers::{exact, minibatch, mop, progot, sinkhorn};

fn native() -> HiRefConfig {
    HiRefConfig { backend: BackendKind::Native, base_size: 64, ..Default::default() }
}

/// Optimal assignment cost from the Hungarian oracle.
fn exact_cost(x: &Mat, y: &Mat, kind: CostKind) -> f64 {
    let c = dense_cost(x, y, kind);
    let h = exact::hungarian(&c);
    metrics::bijection_cost(x, y, &h, kind)
}

#[test]
fn solver_ordering_on_all_synthetic_datasets() {
    // On every synthetic suite: exact ≤ HiRef ≤ MOP (Table S4 ordering),
    // and Sinkhorn's entropic cost sits at or above exact.
    for ds in Synthetic::ALL {
        let (x, y) = ds.generate(256, 11);
        let kind = CostKind::SqEuclidean;
        let opt = exact_cost(&x, &y, kind);

        let hiref_out = HiRef::new(native()).align(&x, &y).unwrap();
        let hiref_cost = hiref_out.cost(&x, &y, kind);

        let mop_perm = mop::solve(&x, &y, kind);
        let mop_cost = metrics::bijection_cost(&x, &y, &mop_perm, kind);

        assert!(hiref_cost >= opt - 1e-9, "{}", ds.label());
        assert!(
            hiref_cost <= opt * 1.35 + 0.02,
            "{}: hiref {hiref_cost} vs opt {opt}",
            ds.label()
        );
        assert!(
            mop_cost >= hiref_cost * 0.95,
            "{}: MOP {mop_cost} beat HiRef {hiref_cost}",
            ds.label()
        );
    }
}

#[test]
fn sinkhorn_cost_at_least_exact() {
    let (x, y) = Synthetic::Checkerboard.generate(128, 3);
    let kind = CostKind::SqEuclidean;
    let c = dense_cost(&x, &y, kind);
    let sk = sinkhorn::solve(&c, &Default::default());
    let sk_cost = metrics::dense_cost_of(&c, &sk.coupling);
    let opt = exact_cost(&x, &y, kind);
    assert!(sk_cost >= opt - 1e-6, "sinkhorn {sk_cost} below exact {opt}");
}

#[test]
fn minibatch_bias_decreases_with_batch_size() {
    let (x, y) = Synthetic::HalfMoonSCurve.generate(512, 5);
    let kind = CostKind::SqEuclidean;
    let mut last = f64::INFINITY;
    let mut costs = Vec::new();
    for b in [32usize, 128, 512] {
        let perm = minibatch::solve(&x, &y, kind, &minibatch::MiniBatchConfig {
            batch: b,
            seed: 9,
            ..Default::default()
        });
        let cost = metrics::bijection_cost(&x, &y, &perm, kind);
        costs.push(cost);
        last = cost;
    }
    assert!(
        last <= costs[0] + 1e-9,
        "full batch {last} not better than B=32 {}",
        costs[0]
    );
}

#[test]
fn hiref_beats_minibatch_on_structured_data() {
    // The paper's headline comparison (Tables 1, 2): HiRef ≤ small-batch MB.
    let (x, y) = Synthetic::HalfMoonSCurve.generate(512, 6);
    let kind = CostKind::SqEuclidean;
    let hiref_cost = HiRef::new(native()).align(&x, &y).unwrap().cost(&x, &y, kind);
    let mb_perm = minibatch::solve(&x, &y, kind, &minibatch::MiniBatchConfig {
        batch: 32,
        seed: 3,
        ..Default::default()
    });
    let mb_cost = metrics::bijection_cost(&x, &y, &mb_perm, kind);
    assert!(
        hiref_cost <= mb_cost,
        "hiref {hiref_cost} vs mini-batch(32) {mb_cost}"
    );
}

#[test]
fn progot_and_sinkhorn_close_on_synthetic() {
    let (x, y) = Synthetic::MafMoonsRings.generate(128, 7);
    let kind = CostKind::SqEuclidean;
    let c = dense_cost(&x, &y, kind);
    let sk = metrics::dense_cost_of(&c, &sinkhorn::solve(&c, &Default::default()).coupling);
    let pg = metrics::dense_cost_of(&c, &progot::solve(&x, &y, kind, &Default::default()));
    let rel = (sk - pg).abs() / sk.max(1e-9);
    assert!(rel < 0.25, "sinkhorn {sk} vs progot {pg}");
}

#[test]
fn hiref_nonzeros_are_n_sinkhorn_quadratic() {
    // Table S3's structural claim.
    let n = 128;
    let (x, y) = Synthetic::Checkerboard.generate(n, 8);
    let kind = CostKind::SqEuclidean;
    let out = HiRef::new(native()).align(&x, &y).unwrap();
    assert!(out.is_bijection()); // exactly n nonzeros by construction
    let c = dense_cost(&x, &y, kind);
    let sk = sinkhorn::solve(&c, &Default::default());
    let nnz = metrics::nonzeros(&sk.coupling, 1e-8);
    assert!(nnz > n * n / 4, "sinkhorn unexpectedly sparse: {nnz}");
}

#[test]
fn expression_transfer_pipeline_end_to_end() {
    // Miniature Table S7: HiRef transfer beats low-rank-style coarse
    // transfer on the simulated MERFISH pair.
    use hiref::data::transcriptomics::{bin_average, merfish_pair, GENE_LABELS};
    let (src, tgt) = merfish_pair(600, 4);
    let out = HiRef::new(native()).align(&src.spatial, &tgt.spatial).unwrap();
    for gi in 0..GENE_LABELS.len() {
        let v1: Vec<f32> = (0..600).map(|i| src.genes.at(i, gi)).collect();
        let v2: Vec<f32> = (0..600).map(|i| tgt.genes.at(i, gi)).collect();
        // transfer through the bijection
        let mut vhat = vec![0.0f32; 600];
        for (i, &j) in out.perm.iter().enumerate() {
            vhat[j as usize] = v1[i];
        }
        let b_hat = bin_average(&tgt.spatial, &vhat, 10);
        let b_tgt = bin_average(&tgt.spatial, &v2, 10);
        let cos = metrics::cosine(&b_hat, &b_tgt);
        assert!(cos > 0.5, "gene {gi} transfer cosine {cos}");
    }
}
